"""Measurement backends for the tuner.

Two backends, matching the two halves of the repo:

* **wallclock** — time the public Pallas/ref kernel wrappers
  (``src/repro/kernels/*/ops.py``).  On CPU this runs interpret mode, so
  absolute numbers are plumbing overhead, but the *relative* ordering of
  block shapes and ring depths is what the tuner needs; on a real TPU the
  same runner measures the compiled kernels.
* **simulator** — cycle counts from :mod:`repro.core.simulator` for the
  paper's DAE programs in :mod:`repro.core.workloads`.  Deterministic,
  fast, and it surfaces the §5.3 deadlocks (propagated to the searcher,
  which maps them to an infinite score).

Every runner returns ``(measure, key)``: a ``measure(config) -> score``
callable (lower is better) plus the canonical cache key for persisting
the winner.  Input data is built once per runner from a fixed seed, so a
tuning run is deterministic end to end.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.tune.cache import make_key
from repro.tune.space import Config

__all__ = ["kernel_runner", "compiled_runner", "workload_runner",
           "multi_workload_runner", "KERNEL_DIMS", "backend_tag",
           "time_callable", "wallclock_tag"]

# default problem dimensions per op: modest sizes so a CPU interpret-mode
# tuning sweep finishes in seconds, big enough that block shape matters
KERNEL_DIMS: Dict[str, Tuple[int, ...]] = {
    "dae_gather": (2048, 256, 512),          # (n, d, m)
    "dae_merge": (2048, 2048),               # (n, m)
    "flash_attention": (256, 256, 64),       # (sq, sk, d_head)
    "flash_decode": (512, 64),               # (cache len, d_head)
    "flash_decode_paged": (64, 64),          # (page, d_head)
    "grouped_matmul": (256, 256, 256),       # (t, d, f)
    "batched_searchsorted": (4096, 256),     # (n, m)
    "hash_lookup": (4096, 256),              # (n entries, m keys)
    "dae_spmv": (256, 4096, 4096),           # (nrows, ncols, nnz)
}


def backend_tag(interpret: bool) -> str:
    import jax
    return "interpret" if interpret else jax.default_backend()


def time_callable(fn: Callable[[], object], reps: int = 3,
                  contenders: int = 1) -> float:
    """Best-of-``reps`` wall time in seconds (first call compiles).

    ``contenders > 1`` times the *makespan* of N concurrent dispatches
    of ``fn`` per rep, launched from N threads (jax dispatch releases
    the GIL while the backend executes) — the paper's §5.4 shared-memory
    contention regime applied to wall-clock tuning, mirroring the
    simulator's ``multi_workload_runner``.
    """
    import jax
    if contenders <= 1:
        jax.block_until_ready(fn())
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best
    from concurrent.futures import ThreadPoolExecutor
    with ThreadPoolExecutor(max_workers=contenders) as pool:
        def makespan() -> None:
            futs = [pool.submit(fn) for _ in range(contenders)]
            for fu in futs:
                jax.block_until_ready(fu.result())
        makespan()  # warm every contender's compile before timing
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            makespan()
            best = min(best, time.perf_counter() - t0)
    return best


def wallclock_tag(contenders: int) -> str:
    """Cache-key mem tag for wall-clock runs: solo keeps the historical
    ``"wallclock"`` tag; contended runs key per-N (mirroring
    ``tune_workload(instances=N)``) so a winner measured under
    shared-memory contention never shadows the solo winner."""
    if contenders <= 1:
        return "wallclock"
    return f"wallclock:contenders={contenders}"


# ---------------------------------------------------------------------------
# Wall-clock kernel runners
# ---------------------------------------------------------------------------


def _gather_measure(dims, interpret, reps, contenders=1):
    import jax.numpy as jnp
    from repro.kernels.dae_gather import dae_gather
    n, d, m = dims
    r = np.random.default_rng(0)
    table = jnp.asarray(r.standard_normal((n, d)), jnp.float32)
    idx = jnp.asarray(r.integers(0, n, m), jnp.int32)

    def measure(cfg: Config) -> float:
        # every knob explicit so the dispatcher never consults the cache
        # mid-measurement (a stale entry must not contaminate the search)
        kw = {"method": cfg.get("method", "pipelined"),
              "block_d": cfg.get("block_d", 512),
              "chunk": cfg.get("chunk", 64),
              "rif": cfg.get("rif", 8),
              "interpret": interpret}
        return time_callable(lambda: dae_gather(table, idx, **kw), reps,
                             contenders=contenders)

    return measure, (n, d, m), "float32"


def _merge_measure(dims, interpret, reps, contenders=1):
    import jax.numpy as jnp
    from repro.kernels.dae_merge import merge_sorted
    n, m = dims
    r = np.random.default_rng(0)
    a = jnp.sort(jnp.asarray(r.standard_normal(n), jnp.float32))
    b = jnp.sort(jnp.asarray(r.standard_normal(m), jnp.float32))

    def measure(cfg: Config) -> float:
        return time_callable(
            lambda: merge_sorted(a, b, tile=cfg["tile"],
                                 rif=cfg.get("rif", 2),
                                 interpret=interpret), reps,
            contenders=contenders)

    return measure, (n, m), "float32"


def _flash_measure(dims, interpret, reps, contenders=1):
    import jax.numpy as jnp
    from repro.kernels.flash_attention import flash_attention
    sq, sk, d = dims
    r = np.random.default_rng(0)
    q = jnp.asarray(r.standard_normal((1, 4, sq, d)), jnp.float32)
    k = jnp.asarray(r.standard_normal((1, 2, sk, d)), jnp.float32)
    v = jnp.asarray(r.standard_normal((1, 2, sk, d)), jnp.float32)

    def measure(cfg: Config) -> float:
        return time_callable(
            lambda: flash_attention(q, k, v, bq=cfg["bq"], bk=cfg["bk"],
                                    interpret=interpret), reps,
            contenders=contenders)

    return measure, (sq, sk, d), "float32"


def _flash_decode_measure(dims, interpret, reps, contenders=1):
    import jax.numpy as jnp
    from repro.kernels.flash_attention import flash_decode
    s, d = dims
    b, kvh, g = 2, 2, 4
    r = np.random.default_rng(0)
    q = jnp.asarray(r.standard_normal((b, kvh * g, d)), jnp.float32)
    kc = jnp.asarray(r.standard_normal((b, kvh, s, d)), jnp.float32)
    vc = jnp.asarray(r.standard_normal((b, kvh, s, d)), jnp.float32)
    lens = jnp.asarray([s // 2, s], jnp.int32)

    def measure(cfg: Config) -> float:
        return time_callable(
            lambda: flash_decode(q, kc, vc, lens, bk=cfg["bk"],
                                 rif=cfg.get("rif", 2),
                                 interpret=interpret), reps,
            contenders=contenders)

    return measure, (s, d), "float32"


def _flash_decode_paged_measure(dims, interpret, reps, contenders=1):
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ops import flash_decode_paged
    page, d = dims
    b, kvh, g, npb = 2, 2, 4, 4
    s = npb * page
    r = np.random.default_rng(0)
    q = jnp.asarray(r.standard_normal((b, kvh * g, d)), jnp.float32)
    kc = r.standard_normal((b, kvh, s, d)).astype(np.float32)
    kp = jnp.asarray(kc.transpose(0, 2, 1, 3)
                     .reshape(b * npb, page, kvh, d).transpose(0, 2, 1, 3))
    vp = kp + 1.0
    pt = jnp.arange(b * npb, dtype=jnp.int32).reshape(b, npb)
    lens = jnp.asarray([s // 2, s], jnp.int32)

    def measure(cfg: Config) -> float:
        return time_callable(
            lambda: flash_decode_paged(q, kp, vp, pt, lens,
                                       rif=cfg.get("rif", 2),
                                       interpret=interpret), reps,
            contenders=contenders)

    return measure, (page, d), "float32"


def _gmm_measure(dims, interpret, reps, contenders=1):
    import jax.numpy as jnp
    from repro.kernels.grouped_matmul import grouped_matmul
    t, d, f = dims
    e, bt = 4, 128
    r = np.random.default_rng(0)
    x = jnp.asarray(r.standard_normal((t, d)), jnp.float32)
    w = jnp.asarray(r.standard_normal((e, d, f)), jnp.float32)
    blk = jnp.asarray(r.integers(0, e, t // bt), jnp.int32)

    def measure(cfg: Config) -> float:
        return time_callable(
            lambda: grouped_matmul(x, w, blk, bt=bt, bf=cfg["bf"],
                                   bd=cfg["bd"], rif=cfg.get("rif", 8),
                                   interpret=interpret), reps,
            contenders=contenders)

    return measure, (t, d, f), "float32"


def _searchsorted_measure(dims, interpret, reps, contenders=1):
    import jax.numpy as jnp
    from repro.kernels.dae_chase import batched_searchsorted
    n, m = dims
    r = np.random.default_rng(0)
    table = jnp.sort(jnp.asarray(r.integers(0, 1 << 30, n), jnp.int32))
    keys = jnp.asarray(r.integers(0, 1 << 30, m), jnp.int32)

    def measure(cfg: Config) -> float:
        return time_callable(
            lambda: batched_searchsorted(table, keys, block=cfg["block"],
                                         chunk=cfg.get("chunk", 64),
                                         rif=cfg.get("rif", 8),
                                         interpret=interpret), reps,
            contenders=contenders)

    return measure, (n, m), "int32"


def _hash_measure(dims, interpret, reps, contenders=1):
    import jax.numpy as jnp
    from repro.kernels.dae_chase import hash_lookup
    n, m = dims
    chain = 8
    r = np.random.default_rng(0)
    ek = jnp.asarray(np.arange(n), jnp.int32)
    ev = jnp.asarray(r.integers(0, 1 << 20, n), jnp.int32)
    en = jnp.asarray([(i + 1) if (i + 1) % chain else -1 for i in range(n)],
                     jnp.int32)
    heads = jnp.asarray(r.integers(0, n // chain, m) * chain, jnp.int32)
    keys = heads + jnp.asarray(r.integers(0, chain, m), jnp.int32)

    def measure(cfg: Config) -> float:
        return time_callable(
            lambda: hash_lookup(ek, ev, en, heads, keys, max_steps=chain,
                                chunk=cfg.get("chunk", 64),
                                rif=cfg.get("rif", 8),
                                interpret=interpret), reps,
            contenders=contenders)

    return measure, (n, m), "int32"


def _spmv_measure(dims, interpret, reps, contenders=1):
    import jax.numpy as jnp
    from repro.kernels.dae_spmv import csr_to_bsr, dae_spmv
    nrows, ncols, nnz = dims
    r = np.random.default_rng(0)
    counts = r.multinomial(nnz, np.ones(nrows) / nrows)
    rows = np.zeros(nrows + 1, np.int64)
    rows[1:] = np.cumsum(counts)
    cols = r.integers(0, ncols, nnz)
    val = r.standard_normal(nnz).astype(np.float32)
    vec = jnp.asarray(r.standard_normal(ncols), jnp.float32)

    def measure(cfg: Config) -> float:
        # block shape is a conversion-time knob: conversion cost is NOT
        # timed (amortized over many matvecs), the matvec is
        vb, ri, ci, _, nrb = csr_to_bsr(rows, cols, val, ncols,
                                        bm=cfg["bm"], bk=cfg["bk"])
        vbj, rij, cij = jnp.asarray(vb), jnp.asarray(ri), jnp.asarray(ci)
        return time_callable(
            lambda: dae_spmv(vbj, rij, cij, vec, nrb,
                             rif=cfg.get("rif", 2), interpret=interpret),
            reps, contenders=contenders)

    def alias_keys(best: Config):
        # csr_to_bsr dispatches its block shape under the CSR dims this
        # runner stores the winner at, but dae_spmv's rif lookup only
        # sees the *converted* operands — mirror the winner under the
        # BSR-dims key so the tuned rif actually dispatches.
        vb, _ri, _ci, _pad, nrb = csr_to_bsr(rows, cols, val, ncols,
                                             bm=best["bm"], bk=best["bk"])
        bsr_dims = (nrb * best["bm"], ncols, len(vb))
        return [make_key("dae_spmv", bsr_dims, "float32",
                         backend_tag(interpret),
                         wallclock_tag(contenders))]

    measure.alias_keys = alias_keys
    return measure, (nrows, ncols, nnz), "float32"


_KERNEL_MEASURES = {
    "dae_gather": _gather_measure,
    "dae_merge": _merge_measure,
    "flash_attention": _flash_measure,
    "flash_decode": _flash_decode_measure,
    "flash_decode_paged": _flash_decode_paged_measure,
    "grouped_matmul": _gmm_measure,
    "batched_searchsorted": _searchsorted_measure,
    "hash_lookup": _hash_measure,
    "dae_spmv": _spmv_measure,
}


def kernel_runner(op: str, dims: Optional[Tuple[int, ...]] = None, *,
                  interpret: Optional[bool] = None, reps: int = 2,
                  contenders: int = 1):
    """Wall-clock measurement for kernel ``op``.

    Returns ``(measure, key, dims)`` where ``key`` is the cache key the
    winner should be stored under.  ``contenders > 1`` scores each
    config by the makespan of N concurrent dispatches and keys the
    winner under the per-N ``wallclock:contenders=N`` tag.
    """
    from repro.kernels.common import resolve_interpret
    if op not in _KERNEL_MEASURES:
        raise KeyError(f"no kernel runner for {op!r}")
    if contenders < 1:
        raise ValueError(f"contenders must be >= 1, got {contenders}")
    dims = tuple(dims or KERNEL_DIMS[op])
    interp = resolve_interpret(interpret)
    measure, shape, dtype = _KERNEL_MEASURES[op](dims, interp, reps,
                                                 contenders)
    key = make_key(op, shape, dtype, backend_tag(interp),
                   wallclock_tag(contenders))
    return measure, key, dims


def compiled_runner(target: str, *, scale: str = "small",
                    interpret: Optional[bool] = None, reps: int = 2):
    """Wall-clock measurement for a `repro.compile` target program.

    The cache key is the *per-program* key from ``program_key_parts``
    (``compiled:<program name>`` + total requests × max port width), the
    same key ``infer_plans`` consults — so a winner persisted here
    dispatches automatically on the next plain ``compile_program`` call.
    """
    from repro.compile import compile_program, elaborate, \
        program_key_parts
    from repro.compile.targets import build_target
    from repro.kernels.common import resolve_interpret

    interp = resolve_interpret(interpret)
    t = build_target(target, scale)
    ir = elaborate(t.prog, t.memories)
    op, dims, dtype = program_key_parts(ir)
    key = make_key(op, dims, dtype, backend_tag(interp), "wallclock")

    def measure(cfg: Config) -> float:
        # chunk/rif explicit: recompile per point, never consult the
        # cache mid-search (same hygiene as the kernel measures)
        ck = compile_program(t.prog, t.memories, chase=t.chase,
                             chunk=cfg.get("chunk", 64),
                             rif=cfg.get("rif", 8), interpret=interp)
        return time_callable(lambda: ck(), reps)

    return measure, key, dims


# ---------------------------------------------------------------------------
# Simulator-backed workload runner
# ---------------------------------------------------------------------------


def workload_runner(benchmark: str, config: str = "rhls_dec", *,
                    scale: str = "small", mem: str = "fixed",
                    latency: int = 100, engine: str = "event"):
    """Cycle-count measurement of one (benchmark, config) simulator cell.

    ``measure`` returns simulated cycles; an incorrect result is scored
    ``inf`` and simulator deadlocks propagate (the searcher penalizes
    them), so capacity settings that violate §5.3 are rejected, not
    crashed on.

    ``engine`` picks the scheduler implementation; the default event
    engine is bit-exact with the legacy polling oracle, so cached scores
    stay valid across the engines and the key is only tagged for
    non-default choices.
    """
    from repro.core.workloads import run_workload

    def measure(cfg: Config) -> float:
        rep = run_workload(benchmark, config, scale=scale, mem=mem,
                           latency=latency, rif=cfg["rif"],
                           cap_slack=cfg.get("cap_slack"), engine=engine)
        if not rep.correct:
            return float("inf")
        return float(rep.cycles)

    tag = f"sim:{mem}:lat={latency}:scale={scale}"
    if engine != "event":
        tag += f":eng={engine}"
    key = make_key(f"workload:{benchmark}:{config}", (), "int", "sim", tag)
    return measure, key


def multi_workload_runner(benchmark: str, config: str = "rhls_dec", *,
                          n_instances: int = 4, scale: str = "small",
                          mem: str = "fixed", latency: int = 100,
                          max_outstanding: Optional[int] = 64,
                          engine: str = "event"):
    """Contention-aware cycle measurement: score a config by the makespan
    of ``n_instances`` tenants sharing one memory system.

    The single-tenant optimum is often too aggressive under sharing —
    a RIF sized to cover the full latency from one tenant over-subscribes
    the shared outstanding-request budget once N tenants each carry it —
    so knobs tuned here reflect the §5.4 contention regime directly.
    With the event-driven scheduler the per-config cost of an N-tenant
    measurement grows roughly with executed events rather than N x
    processes x passes, so tuning at realistic tenant counts is cheap
    (see docs/tuning.md).
    Incorrect results score ``inf``; deadlocks propagate to the searcher's
    deadlock penalty exactly as in :func:`workload_runner`.
    """
    from repro.core.workloads import run_workload_multi

    def measure(cfg: Config) -> float:
        rep = run_workload_multi(benchmark, config, n_instances,
                                 scale=scale, mem=mem, latency=latency,
                                 rif=cfg["rif"],
                                 max_outstanding=max_outstanding,
                                 cap_slack=cfg.get("cap_slack"),
                                 engine=engine)
        if not rep.correct:
            return float("inf")
        return float(rep.cycles)

    tag = (f"sim:{mem}:lat={latency}:scale={scale}"
           f":shared_mo={max_outstanding}")
    if engine != "event":
        tag += f":eng={engine}"
    key = make_key(f"workload:{benchmark}:{config}", (n_instances,), "int",
                   "sim", tag)
    return measure, key
