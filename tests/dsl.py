"""Per-cycle set/check DSL over the DAE simulator.

Golden-trace fixtures pin *aggregates* (occupancy means, histograms);
this DSL pins *moments*: "at cycle 150 the load ring is full", "by the
time the first result stores, the table port has issued 16 reads".  A
scheduler regression that preserves the aggregates but shifts when
things happen — exactly the class of bug a bit-exact dual-engine
design must guard against — fails these checks by name.

Shape of a script (record-then-replay: the engine is deterministic, so
running once under a :class:`~repro.core.waveform.WaveformTracer` and
replaying the timeline with a cycle cursor is equivalent to true
lock-step co-simulation, without restructuring the engine loop)::

    s = (SimScript("binsearch", "rhls_dec")
         .set(scale="small", latency=100, rif=8)
         .run())
    s.goto(150)
    s.check_occupancy("bs_load", 8)          # ring full while hiding latency
    s.check_issues("table", at_least=16)
    s.step(100).check_occupancy("bs_load", (1, 8))   # bounded, not drained
    s.label("steady")
    ...
    s.check_cycles(3104)
    s.write_vcd(tmp_path / "binsearch.vcd")  # debuggable in GTKWave/Surfer

``set`` fixes the workload inputs (any :func:`run_workload` kwarg),
``step``/``goto``/``label`` move a named-cycle cursor, ``check_*``
assert against the recorded waveforms and raise :class:`CheckFailed`
with the cycle and signal spelled out.  Raw (non-workload) programs
enter through :meth:`SimScript.from_program`.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple, Union

from repro.core.waveform import WaveformTracer

__all__ = ["CheckFailed", "SimScript"]

Expect = Union[int, Tuple[int, int], Callable[[int], bool]]


class CheckFailed(AssertionError):
    """A per-cycle check did not hold; the message names cycle+signal."""


def _match(expect: Expect, actual: int) -> bool:
    if callable(expect):
        return bool(expect(actual))
    if isinstance(expect, tuple):
        lo, hi = expect
        return lo <= actual <= hi
    return actual == expect


def _describe(expect: Expect) -> str:
    if callable(expect):
        return getattr(expect, "__name__", "predicate")
    if isinstance(expect, tuple):
        return f"in [{expect[0]}, {expect[1]}]"
    return f"== {expect}"


class SimScript:
    """One recorded simulation plus a cycle cursor for per-cycle checks."""

    def __init__(self, benchmark: str, config: str, **params):
        self._benchmark = benchmark
        self._config = config
        self._params: Dict[str, object] = dict(params)
        self._raw = None           # (program, memories, kwargs) alternative
        self._tracer: Optional[WaveformTracer] = None
        self._report = None
        self._cursor = 0
        self._labels: Dict[str, int] = {}

    @classmethod
    def from_program(cls, program, memories, **sim_kwargs) -> "SimScript":
        """Script a raw :class:`DaeProgram` via :func:`simulate` instead
        of a named workload."""
        self = cls.__new__(cls)
        self._benchmark = self._config = None
        self._params = {}
        self._raw = (program, memories, dict(sim_kwargs))
        self._tracer = None
        self._report = None
        self._cursor = 0
        self._labels = {}
        return self

    # -- set: fix the inputs ------------------------------------------------

    def set(self, **params) -> "SimScript":
        """Set workload inputs/knobs (``scale``, ``latency``, ``rif``,
        ``cap_slack``, ``engine``, ``seed``, ...) before the run."""
        if self._tracer is not None:
            raise CheckFailed("set() after run(): inputs are fixed once "
                              "the engine has executed")
        self._params.update(params)
        return self

    # -- run: record the full timeline --------------------------------------

    def run(self) -> "SimScript":
        if self._tracer is not None:
            return self
        self._tracer = WaveformTracer()
        if self._raw is not None:
            from repro.core.simulator import simulate
            program, memories, kw = self._raw
            self._report = simulate(program, memories, tracer=self._tracer,
                                    **kw)
        else:
            from repro.core.workloads import run_workload
            self._report = run_workload(self._benchmark, self._config,
                                        tracer=self._tracer, **self._params)
        return self

    @property
    def tracer(self) -> WaveformTracer:
        self.run()
        assert self._tracer is not None
        return self._tracer

    @property
    def report(self):
        """The underlying WorkloadReport / EngineResult."""
        self.run()
        return self._report

    @property
    def cycles(self) -> int:
        return int(self.report.cycles)

    # -- step/goto/label: the cycle cursor ----------------------------------

    @property
    def cursor(self) -> int:
        return self._cursor

    def step(self, n: int = 1) -> "SimScript":
        """Advance the cursor ``n`` cycles."""
        if n < 0:
            raise ValueError("step() goes forward; use goto() to rewind")
        self.run()
        self._cursor += n
        return self

    def goto(self, where: Union[int, str]) -> "SimScript":
        """Move the cursor to an absolute cycle or a named label."""
        self.run()
        self._cursor = self.at(where)
        return self

    def label(self, name: str, cycle: Optional[int] = None) -> "SimScript":
        """Name the current cursor position (or an explicit cycle)."""
        self.run()
        self._labels[name] = self._cursor if cycle is None else int(cycle)
        return self

    def at(self, where: Union[int, str]) -> int:
        if isinstance(where, str):
            if where not in self._labels:
                raise CheckFailed(f"unknown cycle label {where!r} "
                                  f"(have {sorted(self._labels)})")
            return self._labels[where]
        return int(where)

    # -- check: assertions against the recorded waveforms --------------------

    def _resolve(self, at: Optional[Union[int, str]]) -> int:
        self.run()
        return self._cursor if at is None else self.at(at)

    def check_occupancy(self, channel: str, expect: Expect,
                        at: Optional[Union[int, str]] = None) -> "SimScript":
        """FIFO depth of ``channel`` at the cursor (or ``at``)."""
        cycle = self._resolve(at)
        try:
            actual = self.tracer.occupancy_at(channel, cycle)
        except KeyError:
            raise CheckFailed(
                f"channel {channel!r} never appeared in the trace "
                f"(have {list(self.tracer.channels())})") from None
        if not _match(expect, actual):
            raise CheckFailed(
                f"occupancy({channel!r}) at cycle {cycle}: got {actual}, "
                f"expected {_describe(expect)}")
        return self

    def check_peak_occupancy(self, channel: str,
                             expect: Expect) -> "SimScript":
        """Whole-run peak FIFO depth of ``channel``."""
        try:
            actual = self.tracer.peak_occupancy(channel)
        except KeyError:
            raise CheckFailed(
                f"channel {channel!r} never appeared in the trace "
                f"(have {list(self.tracer.channels())})") from None
        if not _match(expect, actual):
            raise CheckFailed(
                f"peak occupancy({channel!r}): got {actual}, "
                f"expected {_describe(expect)}")
        return self

    def check_issues(self, port: str, expect: Expect = None, *,
                     at_least: Optional[int] = None,
                     at: Optional[Union[int, str]] = None) -> "SimScript":
        """Cumulative issues (reads+writes) on ``port`` up to the cursor."""
        cycle = self._resolve(at)
        actual = self.tracer.issues_until(port, cycle)
        if at_least is not None:
            if actual < at_least:
                raise CheckFailed(
                    f"issues({port!r}) by cycle {cycle}: got {actual}, "
                    f"expected >= {at_least}")
            return self
        if expect is None:
            raise TypeError("check_issues needs expect or at_least")
        if not _match(expect, actual):
            raise CheckFailed(
                f"issues({port!r}) by cycle {cycle}: got {actual}, "
                f"expected {_describe(expect)}")
        return self

    def check_cycles(self, expect: Expect) -> "SimScript":
        """Total simulated cycles of the run."""
        if not _match(expect, self.cycles):
            raise CheckFailed(f"run took {self.cycles} cycles, expected "
                              f"{_describe(expect)}")
        return self

    # -- export ---------------------------------------------------------------

    def to_vcd(self, **kw) -> str:
        return self.tracer.to_vcd(**kw)

    def write_vcd(self, path, **kw) -> None:
        self.tracer.write_vcd(path, **kw)
