"""Arch-family -> model builder registry.

``build_model(cfg)`` returns a uniform interface:
  init(key) -> params
  loss(params, batch) -> scalar                      (train objective)
  apply(params, tokens) -> logits                    (decoder families)
  cache_init(batch, s_max), decode_step(params, cache, token, pos)
  prefill(params, cache, tokens, pos, n_valid)       (chunked cache fill)
  cache_reset(cache, keep_mask)                      (slot recycling)
plus, for pure-attention decoder families (layer kinds ⊆ {attn, moe}):
  cache_init_paged(batch, n_pages, page)             (pooled KV pages)
  prefill_paged(params, cache, tok, pos, n_valid, page_table)
  copy_pages(cache, src, dst)                        (COW primitive)
  cache_reset_paged(cache, keep_mask, new_lens)      (page recycling)
These four are ``None`` for recurrent-state families (ssm, hybrid,
encdec) — the serve loop falls back to the contiguous path there.
``input_specs(cfg, shape)`` lives in repro.launch.specs.

``prefill`` is the serving hot-path primitive (see runtime.serve_loop):
one call advances every batch row by up to C prompt tokens through the
decode cache; with C=1 and a 0/1 ``n_valid`` mask it doubles as the
masked decode step, so every family serves through a single compiled
function per chunk width.  The encdec variant takes ``enc_out`` first,
mirroring ``decode_step``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec as _encdec
from repro.models import transformer as _t
from repro.models.common import ModelConfig


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    apply: Optional[Callable] = None
    cache_init: Optional[Callable] = None
    decode_step: Optional[Callable] = None
    prefill: Optional[Callable] = None
    cache_reset: Optional[Callable] = None
    encode: Optional[Callable] = None
    # paged-KV serving (None for families with recurrent state — the
    # serve loop falls back to the contiguous path, bit-parity-pinned)
    cache_init_paged: Optional[Callable] = None
    prefill_paged: Optional[Callable] = None
    copy_pages: Optional[Callable] = None
    cache_reset_paged: Optional[Callable] = None
    # disaggregated serving: migrate same-layout page blocks between
    # the prefill staging pool and the decode pool (module-level
    # functions, so _shared_jit compile caches are shared like
    # copy_pages)
    gather_pages: Optional[Callable] = None
    scatter_pages: Optional[Callable] = None


def cache_reset(cache: Any, keep: jnp.ndarray) -> Any:
    """Zero the decode cache of batch rows where ``keep`` (B,) is False.

    Works for every family because all cache leaves are stacked
    ``(layers, B, ...)``: attention K/V and lengths, MLA latents, SSM
    conv/state windows and RWKV shift/WKV states all zero correctly.
    Freshly admitted slots MUST be reset — attention masks stale K/V by
    length, but recurrent states and cache lengths carry real state
    across requests.
    """
    def zero(a):
        m = keep.reshape((1, keep.shape[0]) + (1,) * (a.ndim - 2))
        return jnp.where(m, a, jnp.zeros_like(a))
    return jax.tree.map(zero, cache)


def build_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.family == "encdec":
        return ModelBundle(
            cfg=cfg,
            init=lambda key: _encdec.encdec_init(cfg, key),
            loss=lambda p, batch: _encdec.encdec_loss(cfg, p, batch),
            encode=lambda p, frames: _encdec.encode(cfg, p, frames),
            cache_init=lambda b, s: _encdec.encdec_cache_init(cfg, b, s),
            decode_step=lambda p, enc_out, cache, tok, pos:
                _encdec.encdec_decode_step(cfg, p, enc_out, cache, tok, pos),
            prefill=lambda p, enc_out, cache, tok, pos, n_valid:
                _encdec.encdec_prefill(cfg, p, enc_out, cache, tok, pos,
                                       n_valid),
            cache_reset=cache_reset,
        )
    # decoder-only families (dense, moe, ssm, hybrid, vlm)
    kinds = {spec.kind for spec in cfg.layer_specs()}
    paged = kinds <= {"attn", "moe"}   # recurrent state cannot page
    return ModelBundle(
        cfg=cfg,
        init=lambda key: _t.lm_init(cfg, key),
        loss=lambda p, batch: _t.lm_loss(cfg, p, batch),
        apply=lambda p, tokens: _t.lm_apply(cfg, p, tokens),
        cache_init=lambda b, s: _t.lm_cache_init(cfg, b, s),
        decode_step=lambda p, cache, tok, pos:
            _t.lm_decode_step(cfg, p, cache, tok, pos),
        prefill=lambda p, cache, tok, pos, n_valid:
            _t.lm_prefill(cfg, p, cache, tok, pos, n_valid),
        cache_reset=cache_reset,
        cache_init_paged=(
            (lambda b, n_pages, page:
             _t.lm_cache_init_paged(cfg, b, n_pages, page))
            if paged else None),
        prefill_paged=(
            (lambda p, cache, tok, pos, n_valid, page_table:
             _t.lm_prefill(cfg, p, cache, tok, pos, n_valid,
                           page_table=page_table))
            if paged else None),
        copy_pages=_t.lm_copy_pages if paged else None,
        cache_reset_paged=_t.lm_paged_reset if paged else None,
        gather_pages=_t.lm_gather_pages if paged else None,
        scatter_pages=_t.lm_scatter_pages if paged else None,
    )
