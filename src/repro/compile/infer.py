"""Pass 2 — infer: size the rings (chunk + RIF per channel).

Dispatch order is the repo-wide contract (see ``tuned_knobs``):

  1. an explicit caller value always wins;
  2. else the ``repro.tune`` cache is consulted under the *per-program*
     key ``compiled:<program name>`` (what ``tune_compiled`` persists);
  3. else ``plan_rif`` sizes the ring analytically from one DMA block's
     byte size (paper §4.2's latency×bandwidth product).

The resolved RIF is additionally clamped to the simulated channel's
declared *capacity*: §5.3's deadlock-freedom bound is a property of the
program, and the compiled ring must not keep more copies in flight than
the program declared safe.  (The clamp is recorded as a note so the
check pass can surface it.)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.compile.ir import DaeIR

__all__ = ["ChannelPlan", "infer_plans", "program_key_parts"]


@dataclasses.dataclass
class ChannelPlan:
    """Ring sizing for one compiled channel."""

    channel: str
    chunk: int
    rif: int
    source: str          # 'explicit' | 'cache' | 'plan_rif'
    note: str = ""


def program_key_parts(ir: DaeIR):
    """(op, dims, dtype) identifying this program in the tune cache —
    one key per program (the knobs apply to every ring it emits)."""
    total = sum(c.count for c in ir.channels.values())
    width = max((ir.ports[c.port].width for c in ir.channels.values()
                 if c.port in ir.ports), default=1)
    dtypes = {str(ir.ports[c.port].array.dtype)
              for c in ir.channels.values() if c.port in ir.ports}
    dtype = "float32" if "float32" in dtypes else "int32"
    return f"compiled:{ir.name}", (total, width), dtype


def _cached_config(ir: DaeIR, interpret: bool) -> Dict:
    from repro.tune import dispatch_config  # deferred: tune <-> compile
    op, dims, dtype = program_key_parts(ir)
    return dispatch_config(op, dims, dtype, interpret)


def infer_plans(ir: DaeIR, *, rif: Optional[int] = None,
                chunk: Optional[int] = None,
                interpret: bool = True) -> Dict[str, ChannelPlan]:
    """One :class:`ChannelPlan` per load channel in ``ir``."""
    from repro.core.pipeline import plan_rif

    cfg = {} if (rif is not None and chunk is not None) \
        else _cached_config(ir, interpret)

    plans: Dict[str, ChannelPlan] = {}
    for c in ir.channels.values():
        port = ir.ports.get(c.port)
        width = port.width if port is not None else 1
        itemsize = port.array.dtype.itemsize if port is not None else 4

        if chunk is not None:
            ck, ck_src = chunk, "explicit"
        elif "chunk" in cfg:
            ck, ck_src = int(cfg["chunk"]), "cache"
        else:
            ck, ck_src = 64, "plan_rif"
        ck = max(1, min(ck, max(c.count, 1)))

        if rif is not None:
            rf, rf_src = rif, "explicit"
        elif "rif" in cfg:
            rf, rf_src = int(cfg["rif"]), "cache"
        else:
            rf, rf_src = plan_rif(width * itemsize).rif, "plan_rif"

        notes: List[str] = []
        if rf > c.capacity:
            notes.append(f"rif {rf} clamped to declared channel "
                         f"capacity {c.capacity} (§5.3 bound)")
            rf = c.capacity
        rf = max(1, min(rf, ck))

        src = rf_src if rf_src == ck_src else f"{rf_src}/{ck_src}"
        plans[c.name] = ChannelPlan(channel=c.name, chunk=ck, rif=rf,
                                    source=src, note="; ".join(notes))
    return plans
