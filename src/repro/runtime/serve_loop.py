"""Decoupled Access/Execute serving pipeline (paper §3 applied to serving).

The legacy loop (kept below as :class:`LegacyServeLoop`) admitted each
request by feeding its prompt one token at a time through the
*full-batch* decode step: admitting a P-token prompt cost P full-batch
rounds during which every already-active slot was stalled — and, worse,
each warmup round also ran the decode step for the stalled slots,
scattering their current token into their KV caches once per prompt
token and never resetting a recycled slot's cache length.  That loop is
the textbook *coupled* access/execute program of DAE4HLS §3: one
lock-step stream in which a slow access (prefill) serializes everything
behind it.

The rewrite splits serving into two engines joined by explicit bounded
channels (the ``repro.core`` channel/occupancy vocabulary — the same
:class:`~repro.core.trace.Tracer` that profiles the DAE simulator
profiles serving):

    requests ──admit──▶ [ACCESS: admission + chunked batched prefill]
                 │                    │
                 │              prefill_done (first token rides along)
                 │                    ▼
                 └─◀─free_slots── [EXECUTE: dense batched decode] ──▶ results

Both engines drive ONE compiled primitive, ``bundle.prefill``:

  * the Access engine advances every admitting slot by up to ``chunk``
    prompt tokens per step (one call, all slots batched) — admitting a
    P-token prompt costs ceil(P / chunk) steps instead of P;
  * the Execute engine calls the same primitive at chunk width 1 with a
    0/1 per-slot valid mask — a *masked* decode step under which
    inactive and mid-prefill slots are provably untouched (validity
    gates every cache scatter and recurrent-state update).

The scheduler interleaves them one step per round, so the dense decode
stream never stalls for more than a single prefill chunk.  ``run`` is
open-loop: each :class:`Request` carries a ``t_arrival`` offset (seconds
from run start, default 0 = closed-loop batch) and is only released to
the admit channel once that time has passed; TTFT is measured from each
request's own arrival, not from run start.

:class:`PagedServeLoop` rebuilds the same pipeline on *paged* KV (the
explicit-decoupling lesson applied to the serving memory system): KV
lives in a pool of fixed-size pages owned by a :class:`PageAllocator`
free-list, each slot addresses its logical sequence through a per-slot
page table, and decode in ``pallas`` mode drives
``flash_decode_paged``'s ring gather over the scalar-prefetched table.
Slot recycling becomes page recycling; refcounted pages enable
hash-keyed prompt-prefix reuse (:class:`PrefixCache`) with
copy-on-write on divergence; admission is preemption-aware — a request
that cannot get pages is parked at the head of the admit channel, and a
slot that cannot extend under memory pressure preempts the *youngest*
slot back to the admit queue (recompute-style resume, teacher-forced,
bit-identical outputs) instead of deadlocking.  Families with recurrent
state (SSM/RWKV/hybrid, encdec) have no growing KV to page: the loop
detects ``bundle.cache_init_paged is None`` and falls back to the dense
contiguous path, bit-parity-pinned by the serve tests.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.channels import LocalChannel
from repro.core.trace import Tracer

# slot phases
_FREE, _PREFILL, _HANDOFF, _DECODE = 0, 1, 2, 3


def _shared_jit(fn):
    """One jit wrapper (and hence one compile cache) per bundle
    function, shared across every loop instance built on that bundle —
    constructing a fresh ServeLoop costs no recompilation.  The wrapper
    is stashed on the function itself so it dies with the bundle."""
    jitted = getattr(fn, "_serve_jit", None)
    if jitted is None:
        jitted = jax.jit(fn)
        fn._serve_jit = jitted
    return jitted


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (P,) int — P may be 0 (treated as [bos])
    max_new: int = 16
    out: Optional[List[int]] = None
    frames: Optional[np.ndarray] = None   # encdec: (S_enc, D) frontend frames
    t_arrival: float = 0.0      # seconds after run() start (open-loop traces)


def _validate_requests(requests: List[Request], s_max: int,
                       encdec: bool = False) -> None:
    """Shared up-front validation: rejecting a request after part of the
    batch was admitted would leave slots mid-flight, and both loops key
    stats/results by rid, so duplicates would silently overwrite."""
    seen = set()
    for req in requests:
        if req.rid in seen:
            raise ValueError(f"duplicate request rid {req.rid}: results "
                             "and stats.ttft are keyed by rid")
        seen.add(req.rid)
        psize = max(1, np.asarray(req.prompt).size)   # empty -> [bos]
        if psize + req.max_new > s_max:
            raise ValueError(
                f"request {req.rid}: prompt ({psize}) + max_new "
                f"({req.max_new}) exceeds s_max ({s_max})")
        if encdec and req.max_new > 0 and req.frames is None:
            raise ValueError(f"request {req.rid}: encdec serving "
                             "requires Request.frames")


# The serving channel moved to repro.channels (one protocol from the
# simulator's Enq/Deq FIFOs to the shard_map mesh ring); ``Channel`` is
# kept as a back-compat alias of the in-process transport.
Channel = LocalChannel


@dataclasses.dataclass
class ServeStats:
    """Counters the serve bench reports; ttft is wall-clock seconds from
    each request's *arrival* (``t_arrival`` after run start) to its
    first emitted token.  The page counters stay 0 on the contiguous
    path."""

    rounds: int = 0
    prefill_steps: int = 0
    decode_steps: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    admitted: int = 0
    ttft: Dict[int, float] = dataclasses.field(default_factory=dict)
    # paged serving
    page_allocs: int = 0
    cow_copies: int = 0
    preemptions: int = 0
    prefix_hits: int = 0
    prefix_tokens_reused: int = 0
    # disaggregated serving: prefill->decode pool page migrations
    migrations: int = 0
    # peak over rounds of sum(prompt + max_new) across concurrently
    # active slots — what a reservation-based contiguous allocator
    # would have had to set aside (the oversubscription witness)
    peak_reserved_tokens: int = 0


class PageAllocator:
    """Free-list allocator over a pool of fixed-size KV pages.

    Page 0 is the reserved *trash page*: page tables default to it, and
    the paged attention path routes every invalid-token scatter there —
    it is never attended to because lengths mask it, so the allocator
    pins it (refcount 1) forever.  Pages are refcounted so the prefix
    cache and multiple adopting slots can share them; ``decref`` returns
    a page to the free list when its last reference drops.
    """

    def __init__(self, n_pages: int, page: int):
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self.page = page
        self.rc = np.zeros(n_pages, np.int32)
        self.rc[0] = 1                       # trash page, permanently pinned
        self.free = deque(range(1, n_pages))

    @property
    def free_count(self) -> int:
        return len(self.free)

    def alloc(self) -> Optional[int]:
        if not self.free:
            return None
        p = self.free.popleft()
        self.rc[p] = 1
        return p

    def incref(self, p: int) -> None:
        self.rc[p] += 1

    def decref(self, p: int) -> None:
        self.rc[p] -= 1
        if self.rc[p] == 0:
            self.free.append(p)


class PrefixCache:
    """Hash-keyed prompt-prefix -> KV-pages map with LRU eviction.

    When a slot finishes prefilling, every page-aligned prefix of its
    fill (plus the final partial length) is registered: the entry holds
    a refcount on each covering page, so the pages survive the slot.  A
    later request whose fill starts with a registered prefix adopts the
    pages outright — its page table points at the shared pages, its
    cache length starts at the matched length, and prefill resumes
    after it.  Divergence inside a shared partial page is handled by
    the serve loop's copy-on-write (the adopter copies the page before
    its first write).  Keys are sha1 over the token bytes; entries also
    keep the tokens and compare them exactly, so a hash collision can
    never adopt wrong KV.  Under page pressure the loop evicts entries
    LRU-first before resorting to preemption.
    """

    def __init__(self) -> None:
        # key -> (length, pages tuple, tokens copy)
        self._entries: "OrderedDict[bytes, Tuple[int, Tuple[int, ...], np.ndarray]]" = OrderedDict()
        self._lens: Dict[int, int] = {}       # length -> #entries of that length

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _key(tokens: np.ndarray) -> bytes:
        return hashlib.sha1(
            np.ascontiguousarray(tokens, np.int64).tobytes()).digest()

    def lookup(self, fill: np.ndarray, cap: int, alloc: PageAllocator
               ) -> Tuple[int, List[int]]:
        """Longest registered prefix of ``fill`` with length <= cap.
        On a hit the covering pages are increfed (caller must decref if
        it ends up parking instead of admitting)."""
        for ln in sorted(self._lens, reverse=True):
            if ln > cap or ln > fill.size:
                continue
            key = self._key(fill[:ln])
            entry = self._entries.get(key)
            if entry is None or entry[0] != ln:
                continue
            if not np.array_equal(entry[2], fill[:ln]):
                continue                      # sha1 collision: never adopt
            self._entries.move_to_end(key)
            pages = list(entry[1])
            for p in pages:
                alloc.incref(p)
            return ln, pages
        return 0, []

    def register(self, fill: np.ndarray, length: int, pages: List[int],
                 alloc: PageAllocator) -> bool:
        key = self._key(fill[:length])
        if key in self._entries:
            self._entries.move_to_end(key)
            return False
        for p in pages:
            alloc.incref(p)
        self._entries[key] = (length, tuple(pages), fill[:length].copy())
        self._lens[length] = self._lens.get(length, 0) + 1
        return True

    def evict_lru(self, alloc: PageAllocator) -> bool:
        if not self._entries:
            return False
        _, (length, pages, _) = self._entries.popitem(last=False)
        self._lens[length] -= 1
        if not self._lens[length]:
            del self._lens[length]
        for p in pages:
            alloc.decref(p)
        return True


class ServeLoop:
    """Continuous batching with decoupled chunked prefill (Access) and
    dense masked decode (Execute).

    ``chunk`` is the Access engine's tokens-per-step (the decoupling
    knob: larger chunks amortize dispatch, smaller chunks bound the
    decode stream's stall).  ``tracer`` (a ``repro.core.trace.Tracer``)
    records channel occupancy; ``stats`` counts steps/tokens and TTFT.
    Encoder-decoder bundles are served too: requests carry ``frames``,
    encoded once at admission into a per-slot encoder-output buffer.
    """

    def __init__(self, cfg, bundle, params, batch_slots: int, s_max: int,
                 eos_id: int = -1, chunk: int = 32, bos_id: int = 0,
                 tracer: Optional[Tracer] = None,
                 admit_capacity: Optional[int] = None):
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        self.cfg = cfg
        self.bundle = bundle
        self.params = params
        self.b = batch_slots
        self.s_max = s_max
        self.eos = eos_id
        self.chunk = chunk
        self.bos = bos_id
        self.tracer = tracer
        self.pos = np.zeros(batch_slots, np.int32)
        self.cur = np.zeros(batch_slots, np.int32)
        self.remaining = np.zeros(batch_slots, np.int64)
        self.phase = np.full(batch_slots, _FREE, np.int8)
        self.active: List[Optional[Request]] = [None] * batch_slots
        self._ptr = np.zeros(batch_slots, np.int64)     # prefill progress
        self._psize = np.zeros(batch_slots, np.int64)   # original prompt size
        self._prompt: List[Optional[np.ndarray]] = [None] * batch_slots

        self.paged = False
        self._make_cache()

        self._encdec = cfg.family == "encdec"
        if self._encdec:
            self._encode = _shared_jit(bundle.encode)
            self.enc_out = None                         # allocated lazily

        # explicit bounded channels between the engines
        self._admit_capacity = admit_capacity
        self._make_channels()
        for s in range(batch_slots):
            self.free_slots.push(s)
        self._overflow: deque = deque()     # beyond admit_q capacity
        self.stats = ServeStats()

    def _make_channels(self) -> None:
        """Engine-joining channels; the sharded loop overrides to place
        handoff/free_slots on a mesh transport."""
        self.admit_q = Channel("admit", self._admit_capacity, self.tracer)
        self.handoff = Channel("prefill_done", self.b, self.tracer)
        self.free_slots = Channel("free_slots", self.b, self.tracer)

    def _make_cache(self) -> None:
        """Cache + compiled-primitive setup; PagedServeLoop overrides."""
        self.cache = self.bundle.cache_init(self.b, self.s_max)
        self._fwd = _shared_jit(self.bundle.prefill)
        self._reset = _shared_jit(self.bundle.cache_reset)

    # -- shared step dispatch ------------------------------------------------

    def _step(self, tok: np.ndarray, n_valid: np.ndarray):
        args = (jnp.asarray(tok, jnp.int32), jnp.asarray(self.pos),
                jnp.asarray(n_valid, jnp.int32))
        if self.paged:
            args = args + (jnp.asarray(self.table),)
            logits, self.cache = self._fwd(self.params, self.cache, *args)
        elif self._encdec:
            logits, self.cache = self._fwd(self.params, self.enc_out,
                                           self.cache, *args)
        else:
            logits, self.cache = self._fwd(self.params, self.cache, *args)
        return np.asarray(logits)

    # -- Access engine: admission + chunked prefill --------------------------

    def _admit(self) -> None:
        reset: List[int] = []
        while self.free_slots and self.admit_q:
            slot = self.free_slots.pop()
            req = self.admit_q.pop()
            prompt = np.asarray(req.prompt, np.int64).reshape(-1)
            if prompt.size == 0:
                # empty prompt: generate from an implicit BOS token
                prompt = np.array([self.bos], np.int64)
            req.out = []
            self.active[slot] = req
            self._prompt[slot] = prompt
            self._psize[slot] = prompt.size
            self._ptr[slot] = 0
            self.pos[slot] = 0
            self.phase[slot] = _PREFILL
            self.stats.admitted += 1
            reset.append(slot)
        if reset:
            keep = np.ones(self.b, bool)
            keep[reset] = False
            self.cache = self._reset(self.cache, jnp.asarray(keep))
            if self._encdec:
                self._encode_slots(reset)

    def _encode_slots(self, slots: List[int]) -> None:
        for slot in slots:
            req = self.active[slot]
            if req.frames is None:
                raise ValueError(f"request {req.rid}: encdec serving "
                                 "requires Request.frames")
            row = self._encode(self.params, jnp.asarray(req.frames)[None])
            if self.enc_out is None:
                # the per-slot encoder-output buffer (and hence the jit
                # signature of the decode/prefill step) is sized by the
                # first request; callers must pad frames to one fixed
                # encoder length per loop
                self.enc_out = jnp.zeros((self.b,) + row.shape[1:],
                                         row.dtype)
            elif row.shape[1:] != self.enc_out.shape[1:]:
                raise ValueError(
                    f"request {req.rid}: frames encode to {row.shape[1:]} "
                    f"but this loop's encoder buffer is "
                    f"{self.enc_out.shape[1:]}; pad all requests' frames "
                    "to one fixed encoder length per ServeLoop")
            self.enc_out = self.enc_out.at[slot].set(row[0])

    # paged-serving hooks (no-ops on the contiguous path) --------------------

    def _prefill_grant(self, slot: int, ptr: int, n: int) -> int:
        return n

    def _on_prompt_complete(self, slot: int) -> None:
        pass

    def _first_token(self, slot: int, logits: np.ndarray) -> int:
        req = self.active[slot]
        first = int(np.argmax(logits[slot]))
        req.out.append(first)
        return first

    def _prefill_step(self, t0: float, results: Dict[int, List[int]]) -> None:
        slots = np.flatnonzero(self.phase == _PREFILL)
        if slots.size == 0:
            return
        tok = np.zeros((self.b, self.chunk), np.int64)
        n_valid = np.zeros(self.b, np.int64)
        for slot in slots:
            if self.phase[slot] != _PREFILL:    # preempted by an earlier grant
                continue
            prompt = self._prompt[slot]
            n = min(self.chunk, prompt.size - self._ptr[slot])
            n = self._prefill_grant(slot, int(self._ptr[slot]), int(n))
            if n > 0:
                tok[slot, :n] = prompt[self._ptr[slot]:self._ptr[slot] + n]
            n_valid[slot] = n
        n_valid[self.phase != _PREFILL] = 0
        if not n_valid.any():
            return                              # everyone stalled on pages
        logits = self._step(tok, n_valid)
        self.stats.prefill_steps += 1
        self.stats.prefill_tokens += int(n_valid.sum())
        for slot in slots:
            if self.phase[slot] != _PREFILL:
                continue
            self._ptr[slot] += n_valid[slot]
            self.pos[slot] += n_valid[slot]
            if self._ptr[slot] < self._prompt[slot].size:
                continue
            # prompt complete: the chunk's last-valid logits are the
            # prediction after the final prompt token — the first output
            # token rides the handoff channel into the Execute engine,
            # which activates the slot when it pops the entry
            req = self.active[slot]
            self._on_prompt_complete(slot)
            if self.active[slot] is not req:
                # the hook preempted/parked the slot (e.g. the sharded
                # loop's prefill->decode page migration ran dry)
                continue
            first = self._first_token(slot, logits)
            if req.rid not in self.stats.ttft:   # resumes keep the original
                self.stats.ttft[req.rid] = (time.perf_counter() - t0
                                            - req.t_arrival)
            self.remaining[slot] = req.max_new - len(req.out)
            if first == self.eos or self.remaining[slot] <= 0:
                self._finish(slot, results)
            else:
                self.phase[slot] = _HANDOFF
                self.handoff.push((slot, first))

    # -- Execute engine: dense masked decode ---------------------------------

    def _decode_mask(self) -> np.ndarray:
        return self.phase == _DECODE

    def _decode_step(self, results: Dict[int, List[int]]) -> None:
        # absorb freshly prefilled slots: the (slot, first token) entry
        # on the handoff channel is what activates decoding
        while self.handoff:
            slot, first = self.handoff.pop()
            self.cur[slot] = first
            self.phase[slot] = _DECODE
        active = self._decode_mask()
        if not active.any():
            return
        logits = self._step(self.cur[:, None], active.astype(np.int64))
        nxt = np.argmax(logits, axis=-1)
        self.stats.decode_steps += 1
        self.stats.decode_tokens += int(active.sum())
        for slot in np.flatnonzero(active):
            tok = int(nxt[slot])
            req = self.active[slot]
            req.out.append(tok)
            self.cur[slot] = tok
            self.pos[slot] += 1
            self.remaining[slot] -= 1
            if tok == self.eos or self.remaining[slot] <= 0:
                self._finish(slot, results)

    def _finish(self, slot: int, results: Dict[int, List[int]]) -> None:
        req = self.active[slot]
        results[req.rid] = req.out
        self.active[slot] = None
        self._prompt[slot] = None
        self.phase[slot] = _FREE
        self.free_slots.push(slot)

    # -- scheduler -----------------------------------------------------------

    def _reserved_tokens(self) -> int:
        res = 0
        for slot in range(self.b):
            req = self.active[slot]
            if req is not None:
                res += int(self._psize[slot]) + req.max_new
        return res

    def run(self, requests: List[Request], max_rounds: int = 100_000
            ) -> Dict[int, List[int]]:
        results: Dict[int, List[int]] = {}
        # validate everything up front: rejecting a request after some
        # of this batch was admitted would leave slots mid-flight
        _validate_requests(requests, self.s_max, self._encdec)
        t0 = time.perf_counter()
        pending = deque()
        for req in sorted(requests, key=lambda r: r.t_arrival):
            if req.max_new <= 0:
                results[req.rid] = []
            else:
                pending.append(req)
        rounds = 0
        while (pending or self._overflow or self.admit_q
               or (self.phase != _FREE).any()):
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError("serve loop exceeded max_rounds")
            # preempted/backlogged requests re-enter ahead of new arrivals
            while self._overflow and self.admit_q.push(self._overflow[0]):
                self._overflow.popleft()
            now = time.perf_counter() - t0
            while pending and pending[0].t_arrival <= now:
                req = pending.popleft()
                if not self.admit_q.push(req):
                    self._overflow.append(req)
            self._admit()
            self.stats.peak_reserved_tokens = max(
                self.stats.peak_reserved_tokens, self._reserved_tokens())
            self._decode_step(results)
            self._prefill_step(t0, results)
            if (pending and not self.admit_q and not self._overflow
                    and not (self.phase != _FREE).any()):
                wait = pending[0].t_arrival - (time.perf_counter() - t0)
                if wait > 0:                 # open-loop idle: sleep to arrival
                    time.sleep(min(wait, 0.05))
        self.stats.rounds = rounds
        return results


class PagedServeLoop(ServeLoop):
    """The serve pipeline on paged KV (see module docstring).

    ``page`` is the tokens-per-page granularity; ``n_pages`` the
    physical pool size (default: page 0 plus exactly ``batch_slots``
    full horizons, i.e. capacity-equivalent to the contiguous cache —
    pass less to oversubscribe); ``low_water`` parks admission while
    fewer than that many pages stay free for the decode stream;
    ``prefix_reuse=False`` disables the prefix cache.  For bundles
    without paged primitives (recurrent families, encdec) every
    override defers to the contiguous base-class path.
    """

    def __init__(self, cfg, bundle, params, batch_slots: int, s_max: int,
                 eos_id: int = -1, chunk: int = 32, bos_id: int = 0,
                 tracer: Optional[Tracer] = None,
                 admit_capacity: Optional[int] = None,
                 page: int = 16, n_pages: Optional[int] = None,
                 low_water: int = 0, prefix_reuse: bool = True):
        self.page = page
        self._n_pages_arg = n_pages
        self.low_water = low_water
        self._prefix_reuse = prefix_reuse
        super().__init__(cfg, bundle, params, batch_slots, s_max,
                         eos_id=eos_id, chunk=chunk, bos_id=bos_id,
                         tracer=tracer, admit_capacity=admit_capacity)

    def _make_cache(self) -> None:
        bundle = self.bundle
        self.paged = bundle.cache_init_paged is not None
        if not self.paged:
            super()._make_cache()       # dense fallback (recurrent state)
            return
        if self.page < 1:
            raise ValueError("page must be >= 1")
        self.npb = -(-self.s_max // self.page)      # blocks per slot horizon
        n_pages = self._n_pages_arg
        if n_pages is None:
            n_pages = 1 + self.b * self.npb
        if n_pages < 1 + self.npb:
            raise ValueError(
                f"n_pages ({n_pages}) must cover the trash page plus one "
                f"full horizon ({self.npb} pages) or no request can finish")
        self.n_pages = n_pages
        self.alloc = PageAllocator(n_pages, self.page)
        self.table = np.zeros((self.b, self.npb), np.int32)   # 0 = trash page
        self.n_blocks = np.zeros(self.b, np.int64)
        self.prefix = PrefixCache() if self._prefix_reuse else None
        self._slot_seq = np.zeros(self.b, np.int64)
        self._seq = 0
        self._resume_out: Dict[int, List[int]] = {}
        self._is_resume = np.zeros(self.b, bool)
        self.cache = bundle.cache_init_paged(self.b, n_pages, self.page)
        self._fwd = _shared_jit(bundle.prefill_paged)
        self._reset_paged = _shared_jit(bundle.cache_reset_paged)
        self._copy = _shared_jit(bundle.copy_pages)

    # -- page machinery ------------------------------------------------------

    def _reclaim(self, need_free: int) -> None:
        """Evict prefix-cache entries LRU-first until ``need_free``
        pages are free (or the cache is empty)."""
        while self.alloc.free_count < need_free:
            if self.prefix is None or not self.prefix.evict_lru(self.alloc):
                return

    def _pick_victim(self, requester: int) -> Optional[int]:
        """Strictly-younger victim (so the oldest slot always makes
        progress — no livelock), preferring decode-phase slots (they
        hold the most pages), youngest first."""
        my_seq = self._slot_seq[requester]
        pref_rank = {_DECODE: 2, _HANDOFF: 1, _PREFILL: 0}
        best, best_key = None, None
        for s in range(self.b):
            if s == requester or self.phase[s] == _FREE:
                continue
            if self._slot_seq[s] <= my_seq:
                continue
            key = (pref_rank[int(self.phase[s])], int(self._slot_seq[s]))
            if best_key is None or key > best_key:
                best, best_key = s, key
        return best

    def _preempt(self, victim: int) -> None:
        """Recompute-style preemption: release the victim's pages and
        park its request (with generated-so-far tokens) back on the
        admit queue; on re-admission the prefill teacher-forces
        prompt + out[:-1], so outputs are bit-identical."""
        req = self.active[victim]
        self._resume_out[req.rid] = req.out if req.out is not None else []
        # drop any pending handoff entry for this slot (pop/push cycle
        # keeps the tracer's occupancy record consistent)
        for _ in range(len(self.handoff)):
            entry = self.handoff.pop()
            if entry[0] != victim:
                self.handoff.push(entry)
        for i in range(int(self.n_blocks[victim])):
            self.alloc.decref(int(self.table[victim, i]))
            self.table[victim, i] = 0
        self.n_blocks[victim] = 0
        self.active[victim] = None
        self._prompt[victim] = None
        self.phase[victim] = _FREE
        self._is_resume[victim] = False
        self.free_slots.push(victim)
        if not self.admit_q.push(req):
            self._overflow.append(req)
        self.stats.preemptions += 1

    def _alloc_page(self, requester: int) -> Optional[int]:
        """Allocate one page for ``requester``, escalating: free list ->
        prefix-cache eviction -> preempt a strictly-younger slot.
        Returns None only when the requester is the youngest holder —
        it then stalls for the round and retries."""
        while True:
            pg = self.alloc.alloc()
            if pg is not None:
                self.stats.page_allocs += 1
                return pg
            if self.prefix is not None and self.prefix.evict_lru(self.alloc):
                continue
            victim = self._pick_victim(requester)
            if victim is None:
                return None
            self._preempt(victim)

    # -- Access engine overrides ---------------------------------------------

    def _admit(self) -> None:
        if not self.paged:
            return super()._admit()
        reset: List[int] = []
        new_lens = np.zeros(self.b, np.int64)
        while self.free_slots and self.admit_q:
            req = self.admit_q.peek()
            prompt = np.asarray(req.prompt, np.int64).reshape(-1)
            if prompt.size == 0:
                prompt = np.array([self.bos], np.int64)
            resume = self._resume_out.get(req.rid)
            if resume:
                # teacher-force the tokens generated before preemption;
                # the last one re-enters decode via the handoff channel
                fill = np.concatenate(
                    [prompt, np.asarray(resume[:-1], np.int64)])
            else:
                fill = prompt
            matched, pages = 0, []
            if self.prefix is not None:
                # at least one token must actually prefill (its logits
                # seed the first output), hence the size-1 cap
                matched, pages = self.prefix.lookup(
                    fill, fill.size - 1, self.alloc)
            total_blocks = -(-fill.size // self.page)
            # a shared partial tail page costs one extra page (COW copy)
            need = (total_blocks - len(pages)
                    + (1 if matched % self.page else 0))
            busy = (self.phase != _FREE).any()
            gate = need + (self.low_water if busy else 0)
            if self.alloc.free_count < gate:
                self._reclaim(gate)
            if self.alloc.free_count < gate:
                for p in pages:             # park: head stays queued
                    self.alloc.decref(p)
                break
            self.admit_q.pop()
            slot = self.free_slots.pop()
            req.out = self._resume_out.pop(req.rid, None) or []
            self._is_resume[slot] = bool(req.out)
            self.active[slot] = req
            self._prompt[slot] = fill
            self._psize[slot] = prompt.size
            self.table[slot, :] = 0
            for i, p in enumerate(pages):
                self.table[slot, i] = p
            self.n_blocks[slot] = len(pages)
            self._ptr[slot] = matched
            self.pos[slot] = matched
            self.phase[slot] = _PREFILL
            self._seq += 1
            self._slot_seq[slot] = self._seq
            self.stats.admitted += 1
            if matched:
                self.stats.prefix_hits += 1
                self.stats.prefix_tokens_reused += matched
            reset.append(slot)
            new_lens[slot] = matched
        if reset:
            keep = np.ones(self.b, bool)
            keep[reset] = False
            self._reset_slots(reset, keep, new_lens)

    def _reset_slots(self, reset, keep, new_lens) -> None:
        """Zero the cache lengths of freshly admitted slots; the sharded
        loop overrides to also reset its prefill staging pool."""
        self.cache = self._reset_paged(
            self.cache, jnp.asarray(keep),
            jnp.asarray(new_lens, jnp.int32))

    def _prefill_grant(self, slot: int, ptr: int, n: int) -> int:
        """Map pages under [ptr, ptr+n), copy-on-write if the write
        starts inside a shared page; returns how many of the n tokens
        are actually backed (0 = stall this round)."""
        if not self.paged or n <= 0:
            return n
        page = self.page
        if ptr % page:
            blk = ptr // page
            pg = int(self.table[slot, blk])
            if self.alloc.rc[pg] > 1:       # shared partial page: diverging
                fresh = self._alloc_page(slot)
                if fresh is None:
                    return 0
                self.cache = self._copy(self.cache,
                                        jnp.asarray(pg, jnp.int32),
                                        jnp.asarray(fresh, jnp.int32))
                self.alloc.decref(pg)
                self.table[slot, blk] = fresh
                self.stats.cow_copies += 1
        last_blk = (ptr + n - 1) // page
        while self.n_blocks[slot] <= last_blk:
            pg = self._alloc_page(slot)
            if pg is None:
                granted = int(self.n_blocks[slot]) * page - ptr
                return max(0, granted)
            self.table[slot, int(self.n_blocks[slot])] = pg
            self.n_blocks[slot] += 1
        return n

    def _on_prompt_complete(self, slot: int) -> None:
        if not self.paged or self.prefix is None:
            return
        fill = self._prompt[slot]
        page = self.page
        bounds = list(range(page, fill.size + 1, page))
        if fill.size % page:
            bounds.append(fill.size)
        for length in bounds:
            nb = -(-length // page)
            pages = [int(self.table[slot, i]) for i in range(nb)]
            self.prefix.register(fill, length, pages, self.alloc)

    def _first_token(self, slot: int, logits: np.ndarray) -> int:
        if self.paged and self._is_resume[slot]:
            self._is_resume[slot] = False
            return int(self.active[slot].out[-1])
        return super()._first_token(slot, logits)

    # -- Execute engine override ---------------------------------------------

    def _decode_mask(self) -> np.ndarray:
        if not self.paged:
            return super()._decode_mask()
        ready = np.ones(self.b, bool)
        for slot in np.flatnonzero(self.phase == _DECODE):
            if self.phase[slot] != _DECODE:     # preempted earlier this loop
                continue
            blk = int(self.pos[slot]) // self.page
            if blk >= self.n_blocks[slot]:
                pg = self._alloc_page(slot)
                if pg is None:
                    ready[slot] = False         # stall; retry next round
                    continue
                self.table[slot, blk] = pg
                self.n_blocks[slot] += 1
        return (self.phase == _DECODE) & ready

    def _finish(self, slot: int, results: Dict[int, List[int]]) -> None:
        if self.paged:
            for i in range(int(self.n_blocks[slot])):
                self.alloc.decref(int(self.table[slot, i]))
                self.table[slot, i] = 0
            self.n_blocks[slot] = 0
        super()._finish(slot, results)

    # -- introspection -------------------------------------------------------

    def page_stats(self) -> Dict[str, Any]:
        """Pool occupancy snapshot: fragmentation is the fraction of
        allocated page capacity not holding a live token (page-interior
        waste plus prefix-pinned pages)."""
        if not self.paged:
            return {"paged": False}
        used = self.n_pages - 1 - self.alloc.free_count
        committed = int(self.pos[self.phase != _FREE].sum())
        capacity = used * self.page
        return {"paged": True, "n_pages": self.n_pages, "page": self.page,
                "pages_used": used, "pages_free": self.alloc.free_count,
                "committed_tokens": committed,
                "capacity_tokens": capacity,
                "fragmentation": 1.0 - committed / capacity if capacity
                else 0.0,
                "prefix_entries": len(self.prefix) if self.prefix else 0}


class LegacyServeLoop:
    """The coupled pre-rewrite loop, kept as the serving baseline.

    Admission prefills one token at a time through the FULL-BATCH decode
    step, so every active slot stalls for the whole prompt length (and
    has its KV cache polluted once per prompt token — the loop is only
    actually correct for one slot serving one request from a fresh
    cache).  ``benchmarks/serve_bench.py`` measures the decoupled loop
    against this one, and the parity tests pin bit-identical outputs on
    the cells where this loop is correct.
    """

    def __init__(self, cfg, bundle, params, batch_slots: int, s_max: int,
                 eos_id: int = -1, bos_id: int = 0):
        self.cfg = cfg
        self.bundle = bundle
        self.params = params
        self.b = batch_slots
        self.s_max = s_max
        self.eos = eos_id
        self.bos = bos_id
        self.cache = bundle.cache_init(batch_slots, s_max)
        self.pos = jnp.zeros((batch_slots,), jnp.int32)
        self.cur = jnp.zeros((batch_slots,), jnp.int32)
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.remaining = np.zeros(batch_slots, np.int64)
        self._step = _shared_jit(bundle.decode_step)

    def _admit(self, queue: List[Request],
               results: Dict[int, List[int]]) -> None:
        for slot in range(self.b):
            if self.active[slot] is None and queue:
                req = queue.pop(0)
                req.out = []
                self.active[slot] = req
                prompt = np.asarray(req.prompt, np.int64).reshape(-1)
                if prompt.size == 0:
                    # empty prompt: generate from an implicit BOS token
                    # (without this, no prefill iteration ran and
                    # ``logits`` below was unbound)
                    prompt = np.array([self.bos], np.int64)
                # prefill: feed prompt tokens through the decode step
                pos = 0
                for tok in prompt:
                    logits, self.cache = self._step(
                        self.params, self.cache,
                        self.cur.at[slot].set(int(tok)),
                        self.pos.at[slot].set(pos))
                    pos += 1
                first = int(jnp.argmax(logits[slot]))
                req.out.append(first)          # prefill's own prediction
                self.pos = self.pos.at[slot].set(pos)
                self.cur = self.cur.at[slot].set(first)
                self.remaining[slot] = req.max_new - 1
                if first == self.eos or self.remaining[slot] <= 0:
                    results[req.rid] = req.out
                    self.active[slot] = None

    def run(self, requests: List[Request], max_rounds: int = 10_000
            ) -> Dict[int, List[int]]:
        # same up-front validation as the decoupled loop: without it,
        # oversized prompts silently scattered past s_max into the cache
        _validate_requests(requests, self.s_max)
        queue = []
        results: Dict[int, List[int]] = {}
        for req in requests:
            if req.max_new <= 0:
                results[req.rid] = []
                continue
            queue.append(req)
        rounds = 0
        while (queue or any(a is not None for a in self.active)):
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError("serve loop exceeded max_rounds")
            self._admit(queue, results)
            if not any(a is not None for a in self.active):
                continue
            logits, self.cache = self._step(self.params, self.cache,
                                            self.cur, self.pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self.pos = self.pos + jnp.asarray(
                [a is not None for a in self.active], jnp.int32)
            self.cur = nxt
            for slot, req in enumerate(self.active):
                if req is None:
                    continue
                tok = int(nxt[slot])
                req.out.append(tok)
                self.remaining[slot] -= 1
                if tok == self.eos or self.remaining[slot] <= 0:
                    results[req.rid] = req.out
                    self.active[slot] = None
        return results
