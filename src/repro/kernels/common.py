"""Shared helpers for the Pallas kernel layer.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with ``interpret=True``.  ``resolve_interpret`` picks
interpret mode automatically when no explicit choice is given.

``tuned_knobs`` implements the dispatchers' knob resolution order:
an explicit caller value wins; a ``None`` knob consults the
``repro.tune`` config cache for a winner tuned at this (op, shape,
dtype, backend) key; on a cache miss the caller-supplied analytic
fallback (typically derived from ``plan_rif``) applies.
"""

from __future__ import annotations

import os
from typing import Optional

import jax

__all__ = ["cdiv", "round_up", "env_flag", "resolve_interpret",
           "tuned_knobs", "ring_rif", "MXU_LANE", "VMEM_BYTES"]

# TPU v5e hardware shape constants (see benchmarks/hw.py for the full set)
MXU_LANE = 128          # lane dimension granularity
SUBLANE = 8             # float32 sublane granularity
VMEM_BYTES = 128 * 2**20  # ~128 MiB VMEM per core (v5e: 128MB unified)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def env_flag(name: str) -> Optional[bool]:
    """Parse a boolean environment variable: unset -> None; empty, "0",
    "false", "no", "off" (any case) -> False; anything else -> True."""
    raw = os.environ.get(name)
    if raw is None:
        return None
    return raw.strip().lower() not in ("", "0", "false", "no", "off")


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Explicit flag wins; else $REPRO_FORCE_INTERPRET (truthy values
    only — "0"/"false"/empty read as unset); else interpret everywhere
    except real TPU."""
    if interpret is not None:
        return interpret
    if env_flag("REPRO_FORCE_INTERPRET"):
        return True
    return jax.default_backend() != "tpu"


def ring_rif(rif: Optional[int], block_bytes: int) -> int:
    """Resolve a still-``None`` ring depth to the ``plan_rif`` analytic
    default for ``block_bytes`` requests — the last tier of the
    explicit → tune-cache → analytic dispatch order, shared by every
    ring-emitted kernel's dispatcher."""
    if rif is not None:
        return rif
    # deferred: repro.core.__init__ -> decouple -> kernels ops would
    # cycle on a top-level repro.core.pipeline import
    from repro.core.pipeline import plan_rif
    return plan_rif(block_bytes).rif


def tuned_knobs(op: str, dims, dtype, interpret: bool, **defaults):
    """Resolve a dispatcher's ``None`` knobs: tune-cache winner first,
    caller-supplied analytic default second.

    ``defaults`` maps knob name -> (caller value, fallback); a caller
    value of ``None`` means "not specified".  Returns the filled dict.
    """
    from repro.tune import dispatch_config  # deferred: kernels <-> tune
    cfg = dispatch_config(op, dims, dtype, interpret)
    return {k: (v if v is not None else cfg.get(k, fb))
            for k, (v, fb) in defaults.items()}
