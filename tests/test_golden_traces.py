"""Golden-trace regression fixtures.

One serialized :class:`repro.core.trace.TraceSummary` per workload lives
under ``tests/golden/``; the scheduler must reproduce each one exactly.
Cycle counts alone would miss a scheduler refactor that preserves the
makespan but silently shifts request-latency histograms, channel
occupancy, or port-utilization timelines — precisely the quantities the
trace subsystem exists to expose — so the whole summary is pinned.

Refresh after an *intentional* timing-model change with:

    python -m pytest tests/test_golden_traces.py --update-golden

and review the diff like any other golden change.
"""

import json
from pathlib import Path

import pytest

from repro.core.workloads import BENCHMARKS, run_workload

GOLDEN_DIR = Path(__file__).parent / "golden"

# fixed generation parameters: small scale keeps fixtures a few KiB
GOLDEN_PARAMS = dict(config="rhls_dec", scale="small", latency=100, rif=8,
                     trace=True, trace_bin_cycles=64)


def _summary_for(benchmark: str) -> dict:
    report = run_workload(benchmark, **GOLDEN_PARAMS)
    assert report.correct, f"{benchmark} produced wrong results"
    return report.trace.to_json()


@pytest.mark.parametrize("benchmark", BENCHMARKS)
def test_golden_trace(benchmark, update_golden):
    path = GOLDEN_DIR / f"{benchmark}.json"
    got = _summary_for(benchmark)
    if update_golden:
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(json.dumps(got, indent=1, sort_keys=True) + "\n")
        return
    assert path.exists(), (
        f"missing golden fixture {path.name}; generate it with "
        f"`python -m pytest tests/test_golden_traces.py --update-golden`")
    want = json.loads(path.read_text())
    assert got == want, (
        f"{benchmark}: trace summary drifted from {path.name} — if the "
        f"timing model changed intentionally, refresh with --update-golden")
