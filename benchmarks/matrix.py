"""Assemble and run the full benchmark matrix.

Four axes, one ``BENCH_<axis>.json`` each (written at the repo root,
diffed against ``benchmarks/baseline/`` by ``benchmarks.diff``):

  * ``sim``     — pure-simulator cells: Table 1/2/3 and Fig. 4 grids
                  (declared by their legacy modules) plus the ``grid``
                  group declared here: event-vs-polling scheduler
                  parity cells and 1-vs-N tenant contention cells;
  * ``kernels`` — decoupled-kernel microbenches, tuned-vs-default
                  pairs, chase decoupled-vs-XLA, compiled-vs-hand;
  * ``compile`` — every ``repro.compile`` target, pipeline + kernel
                  with the cold/warm split;
  * ``serve``   — the serving pipeline: open-loop arrival traces at
                  slots=64 on the paged-KV loop (tokens/s, TTFT
                  percentiles, prefix-hit/page-allocation counts),
                  paged-vs-contiguous bit-parity per attention family,
                  and the prefix-reuse allocation gate.

The runner executes **every** registered cell of each requested axis —
cell selection is deliberately not a feature (see
:mod:`repro.bench.matrix`).  ``--smoke`` switches problem scales to CI
size; baselines are committed from smoke runs, so the CI gate compares
like against like.
"""

from __future__ import annotations

from pathlib import Path
from typing import Callable, Dict, List

from repro.bench import BenchContext, Cell, CellResult, coords, run_axis

REPO_ROOT = Path(__file__).resolve().parents[1]

AXES = ("sim", "kernels", "compile", "serve")

# engine-parity cells: both schedulers must report the same cycles for
# the same cell; the diff gate pins each engine's count independently,
# and the cell itself cross-checks them (bit-exactness is an invariant,
# not a statistic)
_PARITY_BENCHES = ("binsearch", "hashtable")
_ENGINES = ("event", "polling")

# tenant-contention cells: N instances sharing one memory system under
# a shared outstanding-request budget (the §5.4 regime)
_TENANT_BENCHES = ("hashtable", "spmv")
_TENANT_NS = (1, 4)


def _engine_cell(bench: str, engine: str):
    def run(ctx: BenchContext) -> CellResult:
        from repro.core.workloads import run_workload
        kwargs = dict(scale=ctx.sim_scale, latency=100, rif=32,
                      engine=engine)
        r = run_workload(bench, "rhls_dec", **kwargs)
        other = "polling" if engine == "event" else "event"
        r2 = run_workload(bench, "rhls_dec", scale=ctx.sim_scale,
                          latency=100, rif=32, engine=other)
        assert r.cycles == r2.cycles, (
            f"engine parity broken on {bench}: {engine}={r.cycles} "
            f"vs {other}={r2.cycles}")
        return CellResult(cycles=int(r.cycles),
                          derived={"golden": int(r.golden)},
                          replay={"benchmark": bench, "config": "rhls_dec",
                                  "kwargs": kwargs})
    return run


def _tenant_cell(bench: str, n: int):
    def run(ctx: BenchContext) -> CellResult:
        from repro.core.workloads import run_workload_multi
        rep = run_workload_multi(bench, "rhls_dec", n, scale="small",
                                 latency=100, rif=32, max_outstanding=64)
        if not rep.correct:  # must fire even under python -O
            raise AssertionError(f"grid/{bench}/n{n} incorrect")
        return CellResult(
            cycles=int(rep.cycles),
            derived={"thr_per_inst":
                     round(rep.throughput_per_instance, 5)})
    return run


def _grid_cells() -> List[Cell]:
    out: List[Cell] = []
    for bench in _PARITY_BENCHES:
        for engine in _ENGINES:
            out.append(Cell(
                axis="sim", name=f"grid/{bench}/rhls_dec/engine={engine}",
                coords=coords(bench, "sim", engine=engine),
                run=_engine_cell(bench, engine), group="grid"))
    for bench in _TENANT_BENCHES:
        for n in _TENANT_NS:
            out.append(Cell(
                axis="sim", name=f"grid/{bench}/rhls_dec/tenants={n}",
                coords=coords(bench, "sim", tenants=n),
                run=_tenant_cell(bench, n), group="grid"))
    return out


def collect(axis: str, ctx: BenchContext) -> List[Cell]:
    """Every registered cell of ``axis`` — the whole suite, always."""
    if axis == "sim":
        from benchmarks import (fig4_golden, table1_perf, table2_resources,
                                table3_moms)
        return (table1_perf.cells(ctx) + table2_resources.cells(ctx)
                + table3_moms.cells(ctx) + fig4_golden.cells(ctx)
                + _grid_cells())
    if axis == "kernels":
        from benchmarks import kernel_bench
        return kernel_bench.cells(ctx)
    if axis == "compile":
        from benchmarks import compile_bench
        return compile_bench.cells(ctx)
    if axis == "serve":
        from benchmarks import serve_bench
        return serve_bench.cells(ctx)
    raise ValueError(f"unknown axis {axis!r} (have {AXES})")


def run_matrix(csv_print: Callable[[str], None], smoke: bool = False,
               *, out_dir: Path = REPO_ROOT,
               axes: tuple = AXES, seed: int = 0) -> Dict[str, Dict]:
    ctx = BenchContext(smoke=smoke, seed=seed)
    reports: Dict[str, Dict] = {}
    for axis in axes:
        reports[axis] = run_axis(axis, collect(axis, ctx), ctx,
                                 out_dir=out_dir, csv_print=csv_print)
    return reports


def run(csv_print, smoke: bool = False, axes: tuple = AXES) -> None:
    run_matrix(csv_print, smoke, axes=axes)
