"""Cold/warm wall-clock measurement for benchmark cells.

The one timing bug this module exists to prevent: folding first-call
JIT compilation into a steady-state number.  ``BENCH_compile.json``
shipped a ~701ms ``us_per_call`` for ``compile/binsearch/kernel`` that
was >99% trace-and-compile time — useless as a call-cost trajectory and
noisy enough to drown any real regression.  :func:`measure` therefore
always reports **both** sides of the split:

  * ``us_cold`` — the very first call, compilation included.  This is
    the user-visible latency of a cold cache and is worth tracking, but
    only as itself, never blended into a mean.
  * ``us_warm`` — best-of-``warm_reps`` after the cold call.  Best (not
    mean) because wall-clock noise on a shared CI container is strictly
    additive; the minimum is the stable lower envelope.

Wall-clock transfers poorly between machines, so the regression gate
(:mod:`repro.bench.diffing`) compares ``us_warm`` with a generous
percentage band and never gates ``us_cold`` at all; simulator cycle
counts are the exact-match signal.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

__all__ = ["Timing", "measure"]


@dataclasses.dataclass(frozen=True)
class Timing:
    """One cold/warm measurement, microseconds."""

    us_cold: float
    us_warm: float


def measure(fn: Callable[[], object], *, warm_reps: int = 3) -> Timing:
    """Time ``fn`` once cold (JIT included) then best-of-``warm_reps``.

    ``fn``'s result is passed through ``jax.block_until_ready`` so
    asynchronous dispatch cannot leak compute past the timer; non-array
    results pass through untouched.
    """
    import jax  # lazy: diff-only consumers of repro.bench need no jax

    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    us_cold = (time.perf_counter() - t0) * 1e6
    best = float("inf")
    for _ in range(max(1, warm_reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return Timing(us_cold=us_cold, us_warm=best * 1e6)
