"""Pure-jnp oracles for the decoupled SPMV kernel."""

from __future__ import annotations

import jax.numpy as jnp


def spmv_ref(rows, cols, val, vec) -> jnp.ndarray:
    """CSR matvec oracle via segment sums. rows (N+1,), cols/val (NNZ,)."""
    nrows = rows.shape[0] - 1
    nnz = val.shape[0]
    # row id per nnz
    row_ids = jnp.searchsorted(rows[1:], jnp.arange(nnz), side="right")
    prods = val * jnp.take(vec, cols)
    return jnp.zeros(nrows, val.dtype).at[row_ids].add(prods)


def bsr_spmv_ref(val_blocks, row_ids, col_ids, vec, nrows_blocks) -> jnp.ndarray:
    """BSR oracle: val_blocks (NB, BM, BK), vec (KB, BK) -> (nrows_blocks, BM)."""
    nb, bm, bk = val_blocks.shape
    vblocks = jnp.take(vec, col_ids, axis=0)             # (NB, BK)
    prods = jnp.einsum("nmk,nk->nm", val_blocks, vblocks)  # (NB, BM)
    out = jnp.zeros((nrows_blocks, bm), val_blocks.dtype)
    return out.at[row_ids].add(prods)
