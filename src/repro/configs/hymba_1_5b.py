"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attn+mamba heads; sliding-window
attention with 3 global-attention layers [arXiv:2411.13676; hf]."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32_001,
    ssm_state=16,
    ssm_expand=2,
    window=1024,
    global_attn_layers=(0, 15, 31),
)
