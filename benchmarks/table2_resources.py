"""Paper Table 2 analogue: resource usage.

FPGA LUT/FF/BRAM have no TPU meaning; the comparable quantities for the
decoupled designs are (a) the number of channels (request/response pairs
~ dataflow units) and (b) total buffer bytes implied by channel
capacities (the BRAM analogue), plus memory-port counts.  We reconstruct
them by instrumenting the simulator channel registry at paper scale.
"""

from __future__ import annotations

from repro.core.simulator import DeadlockError
from repro.core.workloads import BENCHMARKS, CONFIGS, run_workload


def run(csv_print) -> None:
    for bench in BENCHMARKS:
        for config in ("vitis_dec", "rhls_dec"):
            try:
                r = run_workload(bench, config, scale="small", latency=100,
                                 rif=128)
            except DeadlockError:
                continue
            n_ports = len(r.mem_reads)
            n_channels = max(1, n_ports - 1) * 2  # req/resp pair per port
            # buffer bytes: capacity entries x 4B words, summed over
            # channels (upper bound: every channel sized at RIF)
            buffer_bytes = n_channels * 128 * 4
            csv_print(f"table2/{bench}/{config},0,"
                      f"channels={n_channels};ports={n_ports};"
                      f"buffer_bytes<={buffer_bytes}")
