"""Batched serving loop: slot-based continuous batching.

Requests (prompt token arrays) enter a queue; a fixed-size slot pool maps
them onto the batch dimension of the compiled serve_step.  Finished slots
are refilled without stopping the decode loop — the decode stream stays
dense.  (On a real deployment the prefill would run on a separate mesh
slice; here prefill = teacher-forced cache warmup through serve_step.)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (P,) int32
    max_new: int = 16
    out: Optional[List[int]] = None


class ServeLoop:
    def __init__(self, cfg, bundle, params, batch_slots: int, s_max: int,
                 eos_id: int = -1):
        self.cfg = cfg
        self.bundle = bundle
        self.params = params
        self.b = batch_slots
        self.s_max = s_max
        self.eos = eos_id
        self.cache = bundle.cache_init(batch_slots, s_max)
        self.pos = jnp.zeros((batch_slots,), jnp.int32)
        self.cur = jnp.zeros((batch_slots,), jnp.int32)
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.remaining = np.zeros(batch_slots, np.int64)
        self._step = jax.jit(bundle.decode_step)

    def _admit(self, queue: List[Request],
               results: Dict[int, List[int]]) -> None:
        for slot in range(self.b):
            if self.active[slot] is None and queue:
                req = queue.pop(0)
                req.out = []
                self.active[slot] = req
                # prefill: feed prompt tokens through the decode step
                pos = 0
                for tok in req.prompt:
                    logits, self.cache = self._step(
                        self.params, self.cache,
                        self.cur.at[slot].set(int(tok)),
                        self.pos.at[slot].set(pos))
                    pos += 1
                first = int(jnp.argmax(logits[slot]))
                req.out.append(first)          # prefill's own prediction
                self.pos = self.pos.at[slot].set(pos)
                self.cur = self.cur.at[slot].set(first)
                self.remaining[slot] = req.max_new - 1
                if first == self.eos or self.remaining[slot] <= 0:
                    results[req.rid] = req.out
                    self.active[slot] = None

    def run(self, requests: List[Request], max_rounds: int = 10_000
            ) -> Dict[int, List[int]]:
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        rounds = 0
        while (queue or any(a is not None for a in self.active)):
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError("serve loop exceeded max_rounds")
            self._admit(queue, results)
            if not any(a is not None for a in self.active):
                continue
            logits, self.cache = self._step(self.params, self.cache,
                                            self.cur, self.pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self.pos = self.pos + jnp.asarray(
                [a is not None for a in self.active], jnp.int32)
            self.cur = nxt
            for slot, req in enumerate(self.active):
                if req is None:
                    continue
                tok = int(nxt[slot])
                req.out.append(tok)
                self.remaining[slot] -= 1
                if tok == self.eos or self.remaining[slot] <= 0:
                    results[req.rid] = req.out
                    self.active[slot] = None
        return results
