"""The compiler's dataflow IR — what `elaborate` extracts from a program.

A :class:`DaeIR` is the *staged* view of one :class:`~repro.core.dae.
DaeProgram` instance: per-channel request address streams, per-store
(port, addr, value) events, and the port data snapshots, all recorded by
a functional dry run (the same pump loop as
:meth:`~repro.core.dae.DaeProgram.validate_channels`).

Staging semantics (the honest part, documented in docs/compiler.md):
like a JAX trace, elaboration specializes the program on its concrete
inputs.  Control flow that depends on *loaded values* therefore bakes
into the trace — so every stream is classified by a second, perturbed
elaboration run:

  * ``STATIC``    — the address stream is identical under perturbed
    memory contents: addresses are control metadata (loop indices,
    closure data), legal to scalar-prefetch.
  * ``INDIRECT``  — address ``k`` equals channel *s*'s response ``k``
    (plus a constant offset) under both runs: a one-hop dependent load
    (``a[b[i]]``), compiled as a two-phase ring.
  * ``DEPENDENT`` — anything else: a genuine pointer chase whose
    addresses are functions of loaded values.  Compilable only with a
    :class:`ChaseSpec` carrying the loop's semantics in traceable form.

Stores are matched the same way: a store whose value equals some
channel's response ``k`` under both runs is a *copy* (the decoupled
execute loop is a data mover — the common case for every paper
benchmark's inner loop); a store whose value is run-invariant but
matches no response is a *constant* (data-independent compute, partially
evaluated at compile time); anything else is unexplained and needs a
:class:`ChaseSpec` (or is rejected by the check pass).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np


class StreamKind(enum.Enum):
    STATIC = "static"
    INDIRECT = "indirect"
    DEPENDENT = "dependent"


@dataclasses.dataclass
class ChannelIR:
    """One load channel's traced request stream (true-memory run)."""

    name: str
    port: str
    capacity: int
    addrs: List[int]                      # request addresses, issue order
    values: List[Any]                     # matching responses (run A)
    kind: StreamKind = StreamKind.DEPENDENT
    source: Optional[str] = None          # INDIRECT: feeding channel
    offset: int = 0                       # INDIRECT: addr = source_resp + offset

    @property
    def count(self) -> int:
        return len(self.addrs)


@dataclasses.dataclass
class StoreIR:
    """One traced store event, in program store order.

    ``source`` is the (channel, response index) whose value this store
    copies (both runs agree); ``const`` marks a run-invariant value with
    no response source.  A store that is neither is *unexplained* — the
    check pass rejects it unless a :class:`ChaseSpec` accounts for it.
    """

    port: str
    addr: int
    value: Any
    source: Optional[Tuple[str, int]] = None
    const: bool = False

    @property
    def explained(self) -> bool:
        return self.const or self.source is not None


@dataclasses.dataclass
class ChaseSpec:
    """Declarative semantics of a dependent-load loop, in traceable form.

    This is what a workload author supplies *instead of a kernel* when
    the access stream is a genuine pointer chase (kind ``DEPENDENT``):
    the chase's state machine as jnp-traceable callables, mirroring the
    ``init_state``/``step`` closures the simulator program itself is
    built from (see ``_binsearch_phases`` in :mod:`repro.core.workloads`
    and the binsearch target in :mod:`repro.compile.targets`).

    * ``state0``     — (M, S) int32 initial state, one row per item;
    * ``addr_fn(state) -> addr``   — the next request address;
    * ``step_fn(state, row) -> state`` — consume one loaded row
      (``row`` is the (W,) int32 port row at ``addr``); must be
      *lock-step safe*: running exactly ``max_steps`` iterations with
      redundant tail loads reproduces the early-exit results (Listing
      5's fixed-length trick — the repo's ``fixed_step`` closures are
      already written this way);
    * ``out_fn(state) -> (addr, value)`` — the final store per item.

    ``state`` is passed as a tuple of S int32 scalars.  All three
    callables must be jnp-traceable (they run inside the Pallas kernel)
    *and* valid on plain numpy ints (the check pass verifies the spec
    reproduces the simulator's stores before codegen trusts it).
    """

    port: str
    state0: np.ndarray
    max_steps: int
    addr_fn: Callable[[Tuple[Any, ...]], Any]
    step_fn: Callable[[Tuple[Any, ...], Any], Tuple[Any, ...]]
    out_fn: Callable[[Tuple[Any, ...]], Tuple[Any, Any]]
    out_port: str = "out"

    @property
    def n_items(self) -> int:
        return int(self.state0.shape[0])

    @property
    def state_width(self) -> int:
        return int(self.state0.shape[1])


@dataclasses.dataclass
class PortArray:
    """One memory port's data, staged as a dense (N, W) array.

    Scalars become width-1 rows; 1-D ndarray elements become width-W
    rows (the decoupled row fetch).  ``None`` entries (uninitialized
    output slots) are zero-filled.
    """

    name: str
    array: np.ndarray       # (N, W)

    @property
    def n(self) -> int:
        return int(self.array.shape[0])

    @property
    def width(self) -> int:
        return int(self.array.shape[1])


@dataclasses.dataclass
class DaeIR:
    """The elaborated program: streams + stores + staged port data."""

    name: str
    channels: Dict[str, ChannelIR]
    stores: List[StoreIR]
    ports: Dict[str, PortArray]
    raw_memories: Dict[str, Any]
    perturbed_ok: bool                    # the classification run finished
    notes: List[str] = dataclasses.field(default_factory=list)

    def channels_of_kind(self, kind: StreamKind) -> List[ChannelIR]:
        return [c for c in self.channels.values() if c.kind is kind]

    def describe(self) -> str:
        lines = [f"DaeIR({self.name})"]
        for c in self.channels.values():
            src = f" <- {c.source}+{c.offset}" if c.source else ""
            lines.append(f"  channel {c.name}: port={c.port} "
                         f"count={c.count} {c.kind.value}{src}")
        n_copy = sum(1 for s in self.stores if s.source is not None)
        n_const = sum(1 for s in self.stores if s.const)
        n_open = sum(1 for s in self.stores if not s.explained)
        lines.append(f"  stores: {len(self.stores)} "
                     f"(copy={n_copy} const={n_const} unexplained={n_open})")
        for n in self.notes:
            lines.append(f"  note: {n}")
        return "\n".join(lines)
