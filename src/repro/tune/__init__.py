"""repro.tune — empirical autotuning of decoupling parameters.

The paper picks requests-in-flight analytically (latency×bandwidth,
§4.2) and channel capacities by profiling (§5.3/§5.4).  This subsystem
keeps the analytic result (`repro.core.pipeline.plan_rif`) as the *seed*
of a measured search:

    space.py    discrete per-kernel / per-workload search spaces
    search.py   deterministic grid / hill-climb searchers
    runners.py  measurement backends (kernel wall-clock, simulator cycles)
    cache.py    persistent JSON cache of winners

Public API
----------

``tune_kernel(op)`` / ``tune_workload(bench, cfg)`` run a search and
persist the winner; ``dispatch_config(op, dims, dtype, interpret)`` is
the cheap cache-only lookup the kernel dispatchers in
``src/repro/kernels/*/ops.py`` call on every invocation — a hit returns
the tuned config, a miss returns ``{}`` and the dispatcher falls back to
the ``plan_rif`` analytic default.
"""

from __future__ import annotations

from typing import Optional, Tuple

from repro.tune.cache import (CacheEntry, TuneCache, cache_path,
                              default_cache, make_key, reset_default_cache)
from repro.tune.runners import (KERNEL_DIMS, backend_tag, compiled_runner,
                                kernel_runner, multi_workload_runner,
                                wallclock_tag, workload_runner)
from repro.tune.search import TuneResult, search
from repro.tune.space import (Config, SearchSpace, compiled_space,
                              kernel_space, workload_space)

__all__ = [
    "CacheEntry", "TuneCache", "TuneResult", "SearchSpace", "Config",
    "cache_path", "default_cache", "reset_default_cache", "make_key",
    "kernel_space", "workload_space", "compiled_space", "kernel_runner",
    "compiled_runner", "workload_runner", "multi_workload_runner",
    "KERNEL_DIMS", "wallclock_tag", "tune_kernel", "tune_workload",
    "tune_compiled", "dispatch_config",
]


def tune_kernel(op: str, dims: Optional[Tuple[int, ...]] = None, *,
                interpret: Optional[bool] = None, reps: int = 2,
                max_evals: int = 24, strategy: str = "auto",
                contenders: int = 1,
                cache: Optional[TuneCache] = None,
                force: bool = False) -> TuneResult:
    """Tune kernel ``op`` at ``dims`` by wall-clock and persist the winner.

    A prior winner in the cache short-circuits the search (returned as a
    zero-eval :class:`TuneResult`) unless ``force``.

    ``contenders > 1`` tunes for the §5.4 shared-memory contention
    regime: each config is scored by the makespan of N concurrent
    dispatches of the kernel, and the winner persists under a distinct
    per-N key (``wallclock:contenders=N``) so contention-aware winners
    never shadow the solo ones — the wall-clock mirror of
    ``tune_workload(instances=N)``.
    """
    cache = cache or default_cache()
    measure, key, dims = kernel_runner(op, dims, interpret=interpret,
                                       reps=reps, contenders=contenders)
    if not force:
        hit = cache.get(key)
        if hit is not None:
            return TuneResult(op, dict(hit.config), hit.score,
                              dict(hit.config), hit.baseline_score
                              or hit.score, 0, [])
    space = kernel_space(op, *dims)
    res = search(space, measure, max_evals=max_evals, strategy=strategy)
    entry = CacheEntry(config=res.best, score=res.best_score,
                       baseline_score=res.seed_score,
                       evals=res.evals, note=wallclock_tag(contenders))
    cache.put(key, entry)
    # some ops dispatch under transformed dims (e.g. dae_spmv's rif
    # lookup sees BSR operands while the winner is stored at CSR dims);
    # the runner declares those alias keys so the winner is visible at
    # every dispatch site
    alias = getattr(measure, "alias_keys", None)
    if alias is not None:
        for akey in alias(res.best):
            cache.put(akey, CacheEntry(config=res.best,
                                       score=res.best_score,
                                       baseline_score=res.seed_score,
                                       evals=res.evals,
                                       note=wallclock_tag(contenders)
                                       + "-alias"))
    return res


def tune_compiled(target: str, *, scale: str = "small",
                  interpret: Optional[bool] = None, reps: int = 2,
                  max_evals: int = 16, strategy: str = "auto",
                  cache: Optional[TuneCache] = None,
                  force: bool = False) -> TuneResult:
    """Tune chunk/RIF for a `repro.compile` target by wall-clock.

    The winner persists under the per-program ``compiled:<name>`` key,
    which is exactly what the compiler's infer pass consults — after
    this runs, a plain ``compile_program`` on the same program picks the
    tuned ring sizing from the cache with no caller involvement.
    """
    cache = cache or default_cache()
    measure, key, dims = compiled_runner(target, scale=scale,
                                         interpret=interpret, reps=reps)
    if not force:
        hit = cache.get(key)
        if hit is not None:
            return TuneResult(f"compiled:{target}", dict(hit.config),
                              hit.score, dict(hit.config),
                              hit.baseline_score or hit.score, 0, [])
    space = compiled_space(dims[0], dims[1], name=f"compiled:{target}")
    res = search(space, measure, max_evals=max_evals, strategy=strategy)
    cache.put(key, CacheEntry(config=res.best, score=res.best_score,
                              baseline_score=res.seed_score,
                              evals=res.evals, note="wallclock"))
    return res


def tune_workload(benchmark: str, config: str = "rhls_dec", *,
                  scale: str = "small", mem: str = "fixed",
                  latency: int = 100, max_evals: int = 32,
                  strategy: str = "auto", instances: int = 1,
                  cache: Optional[TuneCache] = None,
                  force: bool = False) -> TuneResult:
    """Tune (rif, cap_slack) for a simulated DAE workload by cycle count.

    ``instances > 1`` tunes for the multi-tenant contention regime: the
    score is the makespan of N instances sharing one memory system
    (:func:`repro.tune.runners.multi_workload_runner`), cached under a
    distinct per-N key so contention-aware winners never shadow the
    single-tenant ones.
    """
    if instances < 1:
        raise ValueError(f"instances must be >= 1, got {instances}")
    cache = cache or default_cache()
    if instances > 1:
        measure, key = multi_workload_runner(benchmark, config,
                                             n_instances=instances,
                                             scale=scale, mem=mem,
                                             latency=latency)
    else:
        measure, key = workload_runner(benchmark, config, scale=scale,
                                       mem=mem, latency=latency)
    if not force:
        hit = cache.get(key)
        if hit is not None:
            return TuneResult(f"workload:{benchmark}", dict(hit.config),
                              hit.score, dict(hit.config),
                              hit.baseline_score or hit.score, 0, [])
    space = workload_space(benchmark, latency=latency)
    res = search(space, measure, max_evals=max_evals, strategy=strategy)
    cache.put(key, CacheEntry(config=res.best, score=res.best_score,
                              baseline_score=res.seed_score,
                              evals=res.evals,
                              note=f"sim:{mem}:lat={latency}"))
    return res


def dispatch_config(op: str, dims: Tuple[int, ...], dtype, interpret: bool,
                    mem: str = "wallclock") -> Config:
    """Cache-only lookup for a kernel dispatcher — never raises, never
    searches; ``{}`` on a miss (callers fall back to ``plan_rif``)."""
    try:
        key = make_key(op, dims, str(dtype), backend_tag(interpret), mem)
        hit = default_cache().get(key)
        return dict(hit.config) if hit is not None else {}
    except Exception:
        return {}
