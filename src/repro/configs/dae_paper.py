"""The paper's own benchmark suite configuration (DAE4HLS §6): the seven
irregular workloads, the five HLS configurations, and the memory models
used by benchmarks/ and the simulator."""

DAE_SUITE = {
    "benchmarks": ("binsearch", "binsearch_for", "hashtable", "mergesort",
                   "mergesort_opt", "spmv", "multispmv"),
    "configs": ("vitis", "vitis_dec", "rhls", "rhls_stream", "rhls_dec"),
    "latency": 100,       # cycles (Verilator setup)
    "rif": 128,           # requests in flight (>= latency for full MLP)
    "moms": {             # Table 3 memory subsystem
        "cache_kib": 128,
        "max_outstanding": 64,
    },
}
