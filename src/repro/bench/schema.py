"""Versioned schema for ``BENCH_<axis>.json`` reports.

Hand-rolled structural validation (no jsonschema dependency in the
container): :func:`schema_problems` walks a report and returns every
violation as a human-readable path, :func:`validate_report` raises one
:class:`SchemaError` listing all of them.  Both the matrix writer and
the diff gate validate — a malformed baseline must fail the gate
loudly, not silently compare as "no overlapping cells".

Schema history:

  * **1** — the ad-hoc pre-matrix files (free-form ``rows`` with
    ``us_per_call`` that folded JIT into call time and packed cycle
    counts into a ``derived`` string).
  * **2** — this module: per-cell ``coords`` tuple, first-class
    ``cycles``, explicit ``us_cold``/``us_warm`` split, ``status`` for
    expected deadlocks, typed ``derived`` scalars, run metadata
    (git SHA, backend, seed) for provenance.
"""

from __future__ import annotations

from typing import Dict, List

from repro.bench.registry import COORD_KEYS, KINDS

__all__ = ["SCHEMA_VERSION", "SchemaError", "schema_problems",
           "validate_report"]

SCHEMA_VERSION = 2

_STATUSES = ("ok", "deadlock")
_SCALARS = (str, int, float, bool)


class SchemaError(ValueError):
    """A report violated the BENCH schema; ``problems`` lists every hit."""

    def __init__(self, problems: List[str]):
        self.problems = list(problems)
        super().__init__(
            "BENCH report failed schema validation:\n  "
            + "\n  ".join(self.problems))


def _is_num(v: object) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def schema_problems(report: object) -> List[str]:
    """Every schema violation in ``report`` (empty list == valid)."""
    p: List[str] = []
    if not isinstance(report, dict):
        return [f"report must be an object, got {type(report).__name__}"]
    if report.get("schema") != SCHEMA_VERSION:
        p.append(f"schema: expected {SCHEMA_VERSION}, "
                 f"got {report.get('schema')!r}")
    if not (isinstance(report.get("axis"), str) and report.get("axis")):
        p.append("axis: must be a non-empty string")
    if not isinstance(report.get("smoke"), bool):
        p.append("smoke: must be a bool")

    meta = report.get("meta")
    if not isinstance(meta, dict):
        p.append("meta: must be an object")
    else:
        for key in ("git_sha", "backend", "python"):
            if not isinstance(meta.get(key), str):
                p.append(f"meta.{key}: must be a string")
        if not isinstance(meta.get("seed"), int):
            p.append("meta.seed: must be an int")

    cells = report.get("cells")
    if not (isinstance(cells, list) and cells):
        p.append("cells: must be a non-empty list")
        return p
    seen: Dict[str, int] = {}
    for i, cell in enumerate(cells):
        where = f"cells[{i}]"
        if not isinstance(cell, dict):
            p.append(f"{where}: must be an object")
            continue
        name = cell.get("name")
        if not (isinstance(name, str) and name):
            p.append(f"{where}.name: must be a non-empty string")
        else:
            where = f"cells[{name}]"
            if name in seen:
                p.append(f"{where}: duplicate cell name")
            seen[name] = i
        if not isinstance(cell.get("group"), str):
            p.append(f"{where}.group: must be a string")
        p.extend(_coord_problems(cell.get("coords"), where))
        p.extend(_result_problems(cell, where))
    return p


def _coord_problems(coords: object, where: str) -> List[str]:
    p: List[str] = []
    if not isinstance(coords, dict):
        return [f"{where}.coords: must be an object"]
    extra = sorted(set(coords) - set(COORD_KEYS))
    missing = sorted(set(COORD_KEYS) - set(coords))
    if extra or missing:
        p.append(f"{where}.coords: keys must be exactly {COORD_KEYS} "
                 f"(missing={missing}, extra={extra})")
        return p
    for key in ("workload", "engine", "backend"):
        if not (isinstance(coords[key], str) and coords[key]):
            p.append(f"{where}.coords.{key}: must be a non-empty string")
    if coords["kind"] not in KINDS:
        p.append(f"{where}.coords.kind: {coords['kind']!r} not in {KINDS}")
    tenants = coords["tenants"]
    if not (isinstance(tenants, int) and not isinstance(tenants, bool)
            and tenants >= 1):
        p.append(f"{where}.coords.tenants: must be an int >= 1")
    if coords["tuned"] is not None and not isinstance(coords["tuned"], bool):
        p.append(f"{where}.coords.tuned: must be true, false or null")
    return p


def _result_problems(cell: Dict, where: str) -> List[str]:
    p: List[str] = []
    status = cell.get("status")
    if status not in _STATUSES:
        p.append(f"{where}.status: {status!r} not in {_STATUSES}")
    cycles = cell.get("cycles")
    if cycles is not None and not (isinstance(cycles, int)
                                   and not isinstance(cycles, bool)
                                   and cycles >= 0):
        p.append(f"{where}.cycles: must be a non-negative int or null")
    for key in ("us_cold", "us_warm"):
        v = cell.get(key)
        if v is not None and not (_is_num(v) and v >= 0):
            p.append(f"{where}.{key}: must be a non-negative number or null")
    if cell.get("us_cold") is not None and cell.get("us_warm") is None:
        # the split is the point: a cold time with no warm time is the
        # old folded-JIT bug wearing a new name
        p.append(f"{where}: us_cold without us_warm (cold/warm split "
                 f"must record both)")
    derived = cell.get("derived")
    if not isinstance(derived, dict):
        p.append(f"{where}.derived: must be an object")
    else:
        for k, v in derived.items():
            if not isinstance(k, str):
                p.append(f"{where}.derived: non-string key {k!r}")
            elif not isinstance(v, _SCALARS):
                p.append(f"{where}.derived.{k}: must be a scalar, got "
                         f"{type(v).__name__}")
    replay = cell.get("replay")
    if replay is not None and not isinstance(replay, dict):
        p.append(f"{where}.replay: must be an object or absent")
    if status == "ok" and cycles is None and cell.get("us_warm") is None \
            and not derived:
        p.append(f"{where}: an ok cell must carry cycles, us_warm or "
                 f"derived data")
    return p


def validate_report(report: object) -> Dict:
    """Raise :class:`SchemaError` on any violation; return the report."""
    problems = schema_problems(report)
    if problems:
        raise SchemaError(problems)
    assert isinstance(report, dict)
    return report
