"""Decoupled block-sparse SPMV (paper Listing 2, TPU-native form).

Hardware adaptation (docs/architecture.md §"TPU adaptation"): the FPGA
version streams scalar ``val``/``cols`` words; a TPU moves 512-byte-
granule DMAs and multiplies on a 128x128 MXU, so the unit of irregular
access is a *block*: the matrix is BSR (blocks of (BM, BK)), the dense
vector is tiled in BK chunks, and the decoupled load is the vec-tile
fetch whose address comes from the scalar-prefetched ``col_ids`` stream.
That fetch is emitted through :mod:`repro.kernels.ring`: a
:class:`~repro.kernels.ring.RingChannel` of depth ``rif`` runs the
Access stream ``rif`` grid steps ahead of the MXU consume
(:func:`~repro.kernels.ring.ring_step` spans the ring across grid
steps) — exactly the paper's Access loop running ahead of Execute.

The ``row_ids`` stream (CSR order, monotone) drives *output* block
revisiting: consecutive grid steps with the same row accumulate in VMEM,
and the first step of each row zero-initializes — removing the false
dependency of products on row-pointer loads, as in Listing 2 (right).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ring import (RingChannel, clamp_rif,
                                ring_scratch_shapes, ring_step)


def _spmv_kernel(row_ref, col_ref, val_ref, vec_hbm, out_ref, vscr, vsem, *,
                 nb: int, rif: int):
    i = pl.program_id(0)
    ring = RingChannel(vscr, vsem, rif,
                       src=lambda k: vec_hbm.at[pl.ds(col_ref[k], 1), :])

    def execute(vec_tile):
        is_first = jnp.logical_or(i == 0,
                                  row_ref[i] != row_ref[jnp.maximum(i - 1, 0)])

        @pl.when(is_first)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        # (1, BK) @ (BM, BK)^T -> (1, BM) on the MXU
        prod = jax.lax.dot_general(
            vec_tile, val_ref[0],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        out_ref[...] += prod.astype(out_ref.dtype)

    ring_step([ring], i, nb, execute)


def bsr_spmv(val_blocks: jax.Array, row_ids: jax.Array, col_ids: jax.Array,
             vec_tiles: jax.Array, nrows_blocks: int, *, rif: int = 2,
             interpret: bool = True) -> jax.Array:
    """val_blocks (NB, BM, BK); row_ids/col_ids (NB,) with row_ids sorted
    ascending and every row block present at least once (ops.py pads empty
    rows with zero blocks); vec_tiles (KB, BK) -> out (nrows_blocks, BM).
    ``rif`` vec-tile fetches stream ahead of the consuming grid step."""
    nb, bm, bk = val_blocks.shape
    rif = clamp_rif(rif, nb)
    grid = (nb,)
    kernel = functools.partial(_spmv_kernel, nb=nb, rif=rif)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk), lambda i, r, c: (i, 0, 0)),
                pl.BlockSpec(memory_space=pl.ANY),
            ],
            out_specs=pl.BlockSpec((1, bm), lambda i, r, c: (r[i], 0)),
            scratch_shapes=[
                *ring_scratch_shapes(rif, (1, bk), vec_tiles.dtype),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((nrows_blocks, bm), val_blocks.dtype),
        interpret=interpret,
    )(row_ids, col_ids, val_blocks.reshape(nb, 1 * bm, bk), vec_tiles)
