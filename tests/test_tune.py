"""repro.tune: spaces, searchers, cache round-trip, plan_rif edges,
and the kernel dispatchers' cache consultation."""

import json
import math

import numpy as np
import pytest

from repro.core.pipeline import VMEM_BUDGET_FRACTION, plan_rif
from repro.kernels.common import VMEM_BYTES
from repro.tune import (CacheEntry, TuneCache, cache_path, default_cache,
                        dispatch_config, kernel_space, make_key,
                        reset_default_cache, tune_workload, workload_space)
from repro.tune.search import hill_climb, search
from repro.tune.space import SearchSpace


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    path = tmp_path / "tune_cache.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    reset_default_cache()
    yield path
    reset_default_cache()


# -- plan_rif edge cases ------------------------------------------------------


def test_plan_rif_block_larger_than_vmem_budget():
    budget = int(VMEM_BYTES * VMEM_BUDGET_FRACTION)
    plan = plan_rif(budget * 2)
    # can't even double-buffer: clamped to the min_rif floor
    assert plan.rif == 2
    assert plan.inflight_bytes == 2 * budget * 2


def test_plan_rif_zero_size_block_clamps_to_max():
    plan = plan_rif(0, max_rif=64)
    assert plan.rif == 64
    assert plan.inflight_bytes == 0


def test_plan_rif_min_max_clamping():
    # huge blocks -> latency needs almost nothing -> min_rif floor
    lo = plan_rif(1 << 24, min_rif=3)
    assert lo.rif >= 3
    # tiny blocks -> latency wants thousands -> max_rif ceiling
    hi = plan_rif(64, max_rif=17)
    assert hi.rif == 17
    assert hi.note == "clamped"
    # the latency-bound middle: rif covers latency x bandwidth
    mid = plan_rif(1 << 20, latency_s=2e-6, bandwidth=819e9)
    assert mid.rif * mid.block_bytes >= 2e-6 * 819e9
    assert mid.note == "latency-bound"


def test_plan_rif_respects_explicit_vmem_budget():
    plan = plan_rif(1024, vmem_budget=4096, max_rif=1 << 20)
    assert plan.rif <= 4
    assert plan.vmem_fraction <= 1.0


# -- cache round-trip ---------------------------------------------------------


def test_cache_roundtrip_identical_config(tmp_cache):
    key = make_key("dae_gather", (4096, 256, 512), "float32", "interpret",
                   "wallclock")
    cfg = {"method": "rif", "chunk": 32, "rif": 16, "block_d": 256}
    TuneCache(tmp_cache).put(key, CacheEntry(config=cfg, score=1.5e-3,
                                             baseline_score=2.0e-3, evals=9))
    fresh = TuneCache(tmp_cache)  # separate instance -> reads from disk
    hit = fresh.get(key)
    assert hit is not None and hit.config == cfg
    assert hit.score == 1.5e-3 and hit.baseline_score == 2.0e-3
    assert fresh.hits == 1 and fresh.misses == 0
    assert fresh.get("nope|1|f32|cpu|wallclock") is None
    assert fresh.misses == 1


def test_cache_survives_corrupt_file(tmp_cache):
    tmp_cache.write_text("{not json")
    c = TuneCache(tmp_cache)
    assert len(c) == 0  # corrupt == empty, never raises
    c.put("k", CacheEntry(config={"a": 1}, score=1.0))
    assert TuneCache(tmp_cache).get("k").config == {"a": 1}


def test_cache_path_honours_env(tmp_cache):
    assert cache_path() == tmp_cache
    assert default_cache().path == tmp_cache


def test_concurrent_saves_merge_instead_of_clobbering(tmp_cache):
    """Regression: save() used to replace the whole file from a
    load-once snapshot, so two tuner processes sharing one cache path
    silently dropped each other's winners."""
    a = TuneCache(tmp_cache)
    b = TuneCache(tmp_cache)
    a.put("op_a", CacheEntry(config={"rif": 8}, score=1.0))   # saves
    b.put("op_b", CacheEntry(config={"rif": 16}, score=2.0))  # saves
    merged = TuneCache(tmp_cache)
    assert merged.get("op_a").config == {"rif": 8}
    assert merged.get("op_b").config == {"rif": 16}
    # a's handle also sees b's entry after its next save
    a.save()
    assert a.get("op_b").config == {"rif": 16}


def test_concurrent_saves_keep_better_score_on_conflict(tmp_cache):
    a = TuneCache(tmp_cache)
    b = TuneCache(tmp_cache)
    a.put("op", CacheEntry(config={"rif": 8}, score=5.0))
    # b never saw a's write; its winner for the same key is better
    b.put("op", CacheEntry(config={"rif": 32}, score=3.0))
    assert TuneCache(tmp_cache).get("op").config == {"rif": 32}
    # and the worse config cannot clobber the better one back
    a.put("op", CacheEntry(config={"rif": 8}, score=5.0))
    assert TuneCache(tmp_cache).get("op").config == {"rif": 32}


# -- spaces -------------------------------------------------------------------


def test_space_snap_and_neighbours():
    sp = SearchSpace("t", {"rif": (2, 4, 8, 16), "tile": (128, 256)},
                     {"rif": 4, "tile": 128})
    assert sp.size == 8
    assert sp.snap({"rif": 5, "tile": 9999, "junk": 1}) == \
        {"rif": 4, "tile": 256}
    ns = list(sp.neighbours({"rif": 4, "tile": 128}))
    assert {"rif": 2, "tile": 128} in ns and {"rif": 8, "tile": 128} in ns
    assert {"rif": 4, "tile": 256} in ns and len(ns) == 3


def test_kernel_space_seed_on_grid():
    for op, dims in (("dae_gather", (2048, 256, 512)),
                     ("dae_merge", (2048, 2048)),
                     ("flash_attention", (256, 256, 64)),
                     ("dae_spmv", (256, 4096, 4096))):
        sp = kernel_space(op, *dims)
        for k, v in sp.seed.items():
            assert v in sp.params[k], (op, k, v)


def test_workload_space_seed_covers_latency():
    sp = workload_space("hashtable", latency=100)
    assert sp.seed["rif"] >= 100  # §4.2: RIF >= memory latency in cycles
    assert sp.seed["cap_slack"] >= 1  # legacy-safe, deadlock-free seed


# -- searchers ----------------------------------------------------------------


def _quadratic(cfg):
    return (cfg["x"] - 6) ** 2 + (cfg["y"] - 3) ** 2


def test_search_grid_finds_optimum():
    sp = SearchSpace("q", {"x": tuple(range(10)), "y": tuple(range(5))},
                     {"x": 0, "y": 0})
    res = search(sp, _quadratic, max_evals=sp.size, strategy="grid")
    assert res.best == {"x": 6, "y": 3} and res.best_score == 0


def test_hill_climb_descends_from_seed():
    sp = SearchSpace("q", {"x": tuple(range(10)), "y": tuple(range(5))},
                     {"x": 2, "y": 1})
    res = hill_climb(sp, _quadratic, max_evals=40)
    assert res.best == {"x": 6, "y": 3}
    assert res.seed_score == _quadratic({"x": 2, "y": 1})
    assert res.improvement == math.inf  # best_score hit exact 0


def test_search_deterministic():
    sp = SearchSpace("q", {"x": tuple(range(10)), "y": tuple(range(5))},
                     {"x": 2, "y": 1})
    a = hill_climb(sp, _quadratic, max_evals=30)
    b = hill_climb(sp, _quadratic, max_evals=30)
    assert a.best == b.best and a.trace == b.trace


def test_search_penalizes_deadlock():
    from repro.core.simulator import DeadlockError
    sp = SearchSpace("d", {"x": (0, 1, 2, 3)}, {"x": 1})

    def measure(cfg):
        if cfg["x"] < 2:
            raise DeadlockError("undersized capacity")
        return float(cfg["x"])

    res = search(sp, measure, max_evals=16, strategy="grid")
    assert res.best == {"x": 2} and res.best_score == 2.0
    assert not math.isfinite(res.seed_score)


# -- workload tuning + cache short-circuit ------------------------------------


def test_tune_workload_end_to_end(tmp_cache):
    res = tune_workload("hashtable", "rhls_dec", scale="small", latency=20,
                        max_evals=8)
    assert res.evals > 0 and math.isfinite(res.best_score)
    assert res.best_score <= res.seed_score
    assert tmp_cache.exists()
    again = tune_workload("hashtable", "rhls_dec", scale="small", latency=20,
                          max_evals=8)
    assert again.evals == 0  # cache hit: no re-measurement
    assert again.best == res.best and again.best_score == res.best_score


def test_cap_slack_reproduces_deadlock():
    from repro.core.simulator import DeadlockError
    from repro.core.workloads import run_workload
    with pytest.raises(DeadlockError):
        run_workload("hashtable", "rhls_dec", scale="small", latency=20,
                     rif=8, cap_slack=-4)
    # legacy sizing (cap_slack=1) matches the no-override default
    a = run_workload("hashtable", "rhls_dec", scale="small", latency=20,
                     rif=8)
    b = run_workload("hashtable", "rhls_dec", scale="small", latency=20,
                     rif=8, cap_slack=1)
    assert a.cycles == b.cycles and a.correct and b.correct


# -- dispatcher consultation --------------------------------------------------


def test_dispatch_config_miss_returns_empty(tmp_cache):
    assert dispatch_config("dae_gather", (8, 8, 8), "float32", True) == {}


def test_dispatcher_uses_tuned_config_and_stays_correct(tmp_cache):
    import jax.numpy as jnp
    from repro.kernels.dae_merge import merge_sorted

    key = make_key("dae_merge", (64, 64), "float32", "interpret", "wallclock")
    default_cache().put(key, CacheEntry(config={"tile": 64}, score=1.0))
    assert dispatch_config("dae_merge", (64, 64), np.dtype("float32"),
                           True) == {"tile": 64}
    r = np.random.default_rng(0)
    a = jnp.sort(jnp.asarray(r.standard_normal(64), jnp.float32))
    b = jnp.sort(jnp.asarray(r.standard_normal(64), jnp.float32))
    out = merge_sorted(a, b, interpret=True)  # tile=None -> tuned tile=64
    ref = np.sort(np.concatenate([np.asarray(a), np.asarray(b)]))
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_gather_plan_rif_fallback_dispatch(tmp_cache):
    import jax.numpy as jnp
    from repro.kernels.dae_gather import dae_gather

    r = np.random.default_rng(0)
    table = jnp.asarray(r.standard_normal((128, 128)), jnp.float32)
    idx = jnp.asarray(r.integers(0, 128, 32), jnp.int32)
    # cache empty -> analytic plan_rif sizing; result must match the oracle
    out = dae_gather(table, idx, method="rif", interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(table)[np.asarray(idx)])


def test_cache_entry_json_is_plain(tmp_cache):
    key = make_key("op", (1, 2), "f32", "cpu", "wallclock")
    TuneCache(tmp_cache).put(key, CacheEntry(config={"rif": 4}, score=2.0))
    raw = json.loads(tmp_cache.read_text())
    assert raw["version"] == 1
    assert raw["entries"][key]["config"] == {"rif": 4}


# -- contended (multi-tenant) wall-clock tuning (§5.4) ------------------------


def test_wallclock_tag_solo_and_contended():
    from repro.tune import wallclock_tag
    assert wallclock_tag(1) == "wallclock"
    assert wallclock_tag(4) == "wallclock:contenders=4"


def test_kernel_runner_rejects_nonpositive_contenders():
    from repro.tune import kernel_runner
    with pytest.raises(ValueError, match="contenders"):
        kernel_runner("dae_merge", (64, 64), interpret=True, contenders=0)


def test_time_callable_contended_dispatches_concurrently():
    """The makespan path must launch all N contenders at once: each call
    parks on a 2-party barrier, so sequential execution would time the
    barrier out instead of passing."""
    import threading
    from repro.tune.runners import time_callable

    barrier = threading.Barrier(2)

    def fn():
        barrier.wait(timeout=30)

    assert time_callable(fn, reps=2, contenders=2) >= 0.0


def test_tune_kernel_contended_keys_and_winner_divergence(tmp_cache,
                                                          monkeypatch):
    """``contenders=N`` persists under its own cache key, and a
    contention profile that penalizes what solo rewards yields a
    different winner — the §5.4 regime the per-N keying exists for.

    The measure is a deterministic stand-in (real contended wall-clock
    is load-dependent; the benchmark matrix's contended cells measure
    the real thing) shaped like the regime it models: deep weight
    prefetch wins solo but loses HBM bandwidth to its neighbour under
    contention.
    """
    import repro.tune.runners as runners
    from repro.tune import backend_tag, tune_kernel, wallclock_tag

    def fake_gmm_measure(dims, interpret, reps, contenders=1):
        def measure(cfg):
            target_bd = 512 if contenders <= 1 else 128
            return abs(cfg["bd"] - target_bd) + cfg["rif"] * 1e-3
        return measure, dims, "float32"

    monkeypatch.setitem(runners._KERNEL_MEASURES, "grouped_matmul",
                        fake_gmm_measure)
    dims = (256, 512, 256)
    # the space at these dims (30 points) fits the eval budget, so both
    # searches grid-solve and land exactly on their profile's optimum
    solo = tune_kernel("grouped_matmul", dims, interpret=True, max_evals=40)
    duo = tune_kernel("grouped_matmul", dims, interpret=True, max_evals=40,
                      contenders=2)
    assert solo.best["bd"] == 512 and duo.best["bd"] == 128

    k1 = make_key("grouped_matmul", dims, "float32", backend_tag(True),
                  wallclock_tag(1))
    k2 = make_key("grouped_matmul", dims, "float32", backend_tag(True),
                  wallclock_tag(2))
    assert k1 != k2
    e1, e2 = default_cache().get(k1), default_cache().get(k2)
    assert e1 is not None and e2 is not None
    assert e1.config["bd"] == 512 and e2.config["bd"] == 128
    assert e2.note == "wallclock:contenders=2"

    # dispatchers see the per-N winner only under the per-N mem tag
    assert dispatch_config("grouped_matmul", dims, "float32",
                           True)["bd"] == 512
    assert dispatch_config("grouped_matmul", dims, "float32", True,
                           mem=wallclock_tag(2))["bd"] == 128
