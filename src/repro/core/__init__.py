"""The paper's primary contribution: explicit decoupling (DAE4HLS).

Layers:
  * :mod:`repro.core.dae` / :mod:`repro.core.simulator` /
    :mod:`repro.core.workloads` — the paper-faithful programming model,
    cycle-level simulator, and the seven benchmark programs (Tables 1/3,
    Fig 4).
  * :mod:`repro.core.decouple` / :mod:`repro.core.pipeline` — the
    TPU-native decoupled ops (Pallas kernels behind a JAX API) and RIF
    planning used by the LM framework.
"""

from repro.core.decouple import *  # noqa: F401,F403
