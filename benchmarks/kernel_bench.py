"""Decoupled-kernel microbenchmarks.

Wall-clock on this CPU container is NOT TPU performance; the derived
metric that transfers is the simulator's cycle model (RIF sweeps showing
latency hiding) plus interpret-mode correctness-at-shape.  We report
both: us_per_call is the CPU interpret wall time (plumbing overhead
indicator), derived carries the simulator cycles.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.workloads import run_workload


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(csv_print) -> None:
    r = np.random.default_rng(0)

    # RIF sweep (the paper's central knob) from the simulator
    for rif in (2, 8, 32, 128):
        res = run_workload("hashtable", "rhls_dec", scale="paper",
                           latency=100, rif=rif)
        csv_print(f"kernel/rif_sweep/hashtable/rif={rif},0,"
                  f"cycles={res.cycles};golden={res.golden}")

    # channel-capacity sensitivity sweep (§5.3/§5.4): capacity = rif+slack;
    # negative slack starves the round-robin chase into the deadlock the
    # capacity bound exists to prevent
    from repro.core.simulator import DeadlockError
    for slack in (-4, 0, 1, 16, 64):
        try:
            res = run_workload("hashtable", "rhls_dec", scale="paper",
                               latency=100, rif=32, cap_slack=slack)
            derived = f"cycles={res.cycles};golden={res.golden}"
        except DeadlockError:
            derived = "cycles=deadlock"
        csv_print(f"kernel/cap_sweep/hashtable/slack={slack},0,{derived}")

    # gather: decoupled kernel (interpret) vs XLA take.  Knobs are passed
    # explicitly so these baseline rows never pick up a tuned config from
    # a previous run's cache.
    from repro.kernels.dae_gather import dae_gather
    table = jnp.asarray(r.standard_normal((4096, 256)), jnp.float32)
    idx = jnp.asarray(r.integers(0, 4096, 512), jnp.int32)
    for method in ("pipelined", "rif", "ref"):
        us = _time(lambda: dae_gather(table, idx, method=method,
                                      block_d=512, chunk=64, rif=8))
        csv_print(f"kernel/gather/{method},{us:.0f},interpret_cpu")

    # gather: plan_rif analytic default vs the tuned config the dispatcher
    # resolves from the repro.tune cache (tuning here on a miss)
    from repro.core.pipeline import plan_rif
    from repro.tune import dispatch_config, tune_kernel
    from repro.kernels.common import resolve_interpret
    res = tune_kernel("dae_gather", (4096, 256, 512), max_evals=16, reps=2)
    rif_plan = plan_rif(64 * 256 * 4).rif  # the dispatcher's miss fallback
    us_default = _time(lambda: dae_gather(table, idx, method="pipelined",
                                          block_d=512, chunk=64,
                                          rif=rif_plan))
    us_tuned = _time(lambda: dae_gather(table, idx))  # consults the cache
    cfg = dispatch_config("dae_gather", (4096, 256, 512), table.dtype,
                          resolve_interpret(None))
    cfg_s = ";".join(f"{k}={v}" for k, v in sorted(cfg.items()))
    csv_print(f"kernel/gather/plan_default,{us_default:.0f},interpret_cpu")
    csv_print(f"kernel/gather/tuned,{us_tuned:.0f},"
              f"{cfg_s};tune_evals={res.evals}")

    # merge
    from repro.kernels.dae_merge import merge_sorted
    a = jnp.sort(jnp.asarray(r.standard_normal(2048), jnp.float32))
    b = jnp.sort(jnp.asarray(r.standard_normal(2048), jnp.float32))
    us = _time(lambda: merge_sorted(a, b, tile=256))
    csv_print(f"kernel/merge/pallas,{us:.0f},interpret_cpu")

    # flash attention
    from repro.kernels.flash_attention import flash_attention
    q = jnp.asarray(r.standard_normal((1, 4, 512, 64)), jnp.float32)
    k = jnp.asarray(r.standard_normal((1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(r.standard_normal((1, 2, 512, 64)), jnp.float32)
    us = _time(lambda: flash_attention(q, k, v))
    csv_print(f"kernel/flash/pallas,{us:.0f},interpret_cpu")
