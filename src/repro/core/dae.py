"""Explicit-decoupling programming model (DAE4HLS §3).

This module embeds the paper's four primitives

    stream_enq(channel, value)        stream_deq(channel, capacity)
    decouple_request(channel, addr)   decouple_response(channel, capacity)

as an executable program representation.  A *DAE program* is a set of
communicating sequential processes (the paper's Access / Execute loops,
instantiated as parallel execution units by the HLS `dataflow` pragma).
Each process is a Python generator that yields effect objects; the
scheduler in :mod:`repro.core.simulator` executes them either

  * functionally (zero-latency memory) to check algorithmic correctness, or
  * under a cycle-level timing model (fixed-latency AXI or a MOMS-like
    coalescing memory) to reproduce the paper's cycle counts.

The same programs therefore serve as the paper-faithful reproduction and
as the oracle for the TPU adaptation in :mod:`repro.core.decouple`.

Correctness rules (paper §5.1) are enforced structurally:

  * every ``decouple_request`` must be matched by exactly one
    ``decouple_response`` on the same channel (checked at program end);
  * a request blocks while the channel already has ``capacity`` responses
    in flight or queued (deadlock-freedom by capacity bounding, §5.4);
  * streams block on enq when full and on deq when empty; leftover stream
    entries at termination are reported as a conservation violation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Generator, Iterable, List, Optional, Tuple

__all__ = [
    "Channel",
    "LoadChannel",
    "StreamChannel",
    "Req",
    "Resp",
    "Enq",
    "Deq",
    "Delay",
    "Store",
    "StoreWait",
    "Halt",
    "Process",
    "DaeProgram",
    "ConservationError",
]


class ConservationError(RuntimeError):
    """Raised when request/response or enq/deq counts do not match."""


# ---------------------------------------------------------------------------
# Channels
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Channel:
    """Base point-to-point channel identified by name.

    ``capacity`` bounds the number of in-flight entries; the paper passes
    capacity at the dequeue site (Listing 1), we attach it to the channel
    object (equivalent, single consumer).
    """

    name: str
    capacity: int = 16

    def __post_init__(self) -> None:
        if self.capacity < 1:
            raise ValueError(f"channel {self.name}: capacity must be >= 1")


@dataclasses.dataclass
class StreamChannel(Channel):
    """In-order value FIFO between two program points (paper §3.1)."""


@dataclasses.dataclass
class LoadChannel(Channel):
    """Decoupled-load channel (paper §3.2).

    A request enqueues an *address*; the memory subsystem supplies the
    response.  ``port`` names the memory port (AXI interface / HBM stream)
    this channel issues on; multiple channels may share a port, which is
    exactly the Mergesort deadlock scenario of §5.3 that capacity
    bounding protects against.
    """

    port: str = "mem"


# ---------------------------------------------------------------------------
# Effects yielded by processes
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Req:
    """decouple_request(channel, addr): issue a load for ``addr``."""

    channel: LoadChannel
    addr: int


@dataclasses.dataclass
class Resp:
    """decouple_response(channel): consume the oldest response (in order).

    The scheduler sends the loaded value back into the generator.
    """

    channel: LoadChannel


@dataclasses.dataclass
class Enq:
    """stream_enq(channel, value)."""

    channel: StreamChannel
    value: Any


@dataclasses.dataclass
class Deq:
    """stream_deq(channel) -> value (sent back into the generator)."""

    channel: StreamChannel


@dataclasses.dataclass
class Delay:
    """Occupy the process for ``cycles`` cycles of compute."""

    cycles: int = 1


@dataclasses.dataclass
class Store:
    """Issue a store of ``value`` to ``addr`` on ``port`` (fire and forget;

    ordering per static AXI ID is guaranteed by the memory model, paper
    §5.4)."""

    port: str
    addr: int
    value: Any


@dataclasses.dataclass
class StoreWait:
    """Wait until all previously issued stores on ``port`` are observable

    (the write-response channel of §5.4)."""

    port: str


@dataclasses.dataclass
class Halt:
    """Explicit end-of-process marker (optional; returning also halts)."""


Effect = Any
ProcessGen = Generator[Effect, Any, None]


@dataclasses.dataclass
class Process:
    """A named sequential process (one Access or Execute loop).

    ``ii`` is the initiation interval floor imposed by the *schedule* of
    the surrounding implementation: statically scheduled HLS (the Vitis
    baseline) often cannot reach II=1 for these loops (paper §7), while
    dynamically scheduled R-HLS can.  Every yielded effect costs at least
    ``ii`` cycles of issue occupancy on the process.
    """

    name: str
    gen: ProcessGen
    ii: int = 1


@dataclasses.dataclass
class DaeProgram:
    """A set of processes plus the memory ports they reference."""

    name: str
    processes: List[Process]
    # map port name -> one of the simulator's memory models; filled by the
    # scheduler, declared here so programs are self-describing.
    ports: Tuple[str, ...] = ("mem",)

    def validate_channels(self) -> None:
        seen: Dict[str, Channel] = {}
        for p in self.processes:
            del p
        # channels are discovered dynamically during execution; nothing to
        # do statically.  Kept for API symmetry.
        del seen


# ---------------------------------------------------------------------------
# Helpers used by workload authors
# ---------------------------------------------------------------------------


def request_all(channel: LoadChannel, addrs: Iterable[int]) -> ProcessGen:
    """An Access loop that issues one request per address (paper Listing 2/3)."""

    for a in addrs:
        yield Req(channel, a)


def drain(channel: StreamChannel, n: int) -> ProcessGen:
    for _ in range(n):
        yield Deq(channel)
