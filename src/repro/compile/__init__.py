"""repro.compile — a staged DAE → Pallas compiler.

The paper's dynamic-HLS arm *compiles* explicitly-decoupled programs
into hardware; this package closes the same loop for the repo: any
rebuildable :class:`~repro.core.dae.DaeProgram` lowers onto the ring
emitter (:mod:`repro.kernels.ring`) through a staged pass group, with
the event-driven simulator as the differential oracle.

Pass group (the pymtl3 ``PassGroup`` shape — each pass a pure function
from the previous pass's artifact):

  ``elaborate``  DaeProgram + memories  ->  :class:`DaeIR`
  ``infer``      DaeIR  ->  per-channel :class:`ChannelPlan` (chunk/RIF)
  ``check``      DaeIR  ->  :class:`CheckResult` or :class:`CompileError`
  ``codegen``    DaeIR + plans  ->  :class:`CompiledKernel`

See ``docs/compiler.md`` for the pipeline diagram, the staging
semantics (what honestly compiles vs. what needs a
:class:`ChaseSpec`), and the add-a-workload-without-a-kernel
walkthrough.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

from repro.compile.check import CheckResult, CompileError, check
from repro.compile.codegen import CompiledKernel, codegen
from repro.compile.elaborate import ElaborationError, elaborate
from repro.compile.infer import (ChannelPlan, infer_plans,
                                 program_key_parts)
from repro.compile.ir import (ChannelIR, ChaseSpec, DaeIR, PortArray,
                              StoreIR, StreamKind)

__all__ = [
    "compile_program", "PASSES",
    "CompiledKernel", "CompileError", "ElaborationError",
    "ChaseSpec", "DaeIR", "ChannelIR", "StoreIR", "PortArray",
    "StreamKind", "ChannelPlan", "CheckResult",
    "elaborate", "infer_plans", "check", "codegen",
    "program_key_parts",
]

#: The staged pass group, in execution order.
PASSES = ("elaborate", "infer", "check", "codegen")


def compile_program(prog, memories: Optional[Dict[str, Any]] = None, *,
                    chase: Optional[ChaseSpec] = None,
                    rif: Optional[int] = None,
                    chunk: Optional[int] = None,
                    interpret: Optional[bool] = None,
                    max_steps: int = 1_000_000) -> CompiledKernel:
    """Compile ``prog`` into a runnable Pallas kernel.

    ``memories`` maps port name -> indexable data (plain lists/arrays,
    or simulator ``MemoryModel`` objects — their ``.data`` is used).
    ``chase`` supplies the loop semantics for DEPENDENT access streams
    (see :class:`ChaseSpec`); ``rif``/``chunk`` override the inference
    pass (else: tune cache under the ``compiled:<name>`` key, else
    ``plan_rif``).  Raises :class:`CompileError` with per-finding
    diagnostics for programs the ring scaffolds cannot express.
    """
    from repro.kernels.common import resolve_interpret

    interp = resolve_interpret(interpret)
    mems = {port: getattr(data, "data", data)
            for port, data in (memories or {}).items()}

    try:
        ir = elaborate(prog, mems, max_steps=max_steps)
    except ElaborationError as e:
        raise CompileError("elaborate", [str(e)]) from e

    plans = infer_plans(ir, rif=rif, chunk=chunk, interpret=interp)
    chk = check(prog, ir, chase=chase)
    return codegen(ir, chk, plans, chase=chase, interpret=interp)
