"""Decoupled-kernel microbenchmarks.

Wall-clock on this CPU container is NOT TPU performance; the derived
metric that transfers is the simulator's cycle model (RIF sweeps showing
latency hiding) plus interpret-mode correctness-at-shape.  We report
both: us_per_call is the CPU interpret wall time (plumbing overhead
indicator), derived carries the simulator cycles.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.workloads import run_workload


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(csv_print) -> None:
    r = np.random.default_rng(0)

    # RIF sweep (the paper's central knob) from the simulator
    for rif in (2, 8, 32, 128):
        res = run_workload("hashtable", "rhls_dec", scale="paper",
                           latency=100, rif=rif)
        csv_print(f"kernel/rif_sweep/hashtable/rif={rif},0,"
                  f"cycles={res.cycles};golden={res.golden}")

    # gather: decoupled kernel (interpret) vs XLA take
    from repro.kernels.dae_gather import dae_gather
    table = jnp.asarray(r.standard_normal((4096, 256)), jnp.float32)
    idx = jnp.asarray(r.integers(0, 4096, 512), jnp.int32)
    for method in ("pipelined", "rif", "ref"):
        us = _time(lambda: dae_gather(table, idx, method=method))
        csv_print(f"kernel/gather/{method},{us:.0f},interpret_cpu")

    # merge
    from repro.kernels.dae_merge import merge_sorted
    a = jnp.sort(jnp.asarray(r.standard_normal(2048), jnp.float32))
    b = jnp.sort(jnp.asarray(r.standard_normal(2048), jnp.float32))
    us = _time(lambda: merge_sorted(a, b, tile=256))
    csv_print(f"kernel/merge/pallas,{us:.0f},interpret_cpu")

    # flash attention
    from repro.kernels.flash_attention import flash_attention
    q = jnp.asarray(r.standard_normal((1, 4, 512, 64)), jnp.float32)
    k = jnp.asarray(r.standard_normal((1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(r.standard_normal((1, 2, 512, 64)), jnp.float32)
    us = _time(lambda: flash_attention(q, k, v))
    csv_print(f"kernel/flash/pallas,{us:.0f},interpret_cpu")
