"""Paper Table 1: cycles for all benchmarks x HLS configs, side-by-side
with the published numbers.

Declared as matrix cells on the ``sim`` axis (group ``table1``): one
cell per (benchmark, config), cycle counts exact-diffed against the
committed baseline by ``benchmarks.diff``.  The R-HLS Stream mergesort
deadlock is the paper's own result, so that cell reports
``status="deadlock"`` rather than raising.
"""

from __future__ import annotations

from typing import List

from repro.bench import BenchContext, Cell, CellResult, coords, run_cells
from repro.core.simulator import DeadlockError
from repro.core.workloads import BENCHMARKS, CONFIGS, run_workload

PAPER_TABLE1 = {
    ("binsearch", "vitis"): 2_298_439, ("binsearch", "vitis_dec"): 65_091,
    ("binsearch", "rhls"): 2_039_174, ("binsearch", "rhls_stream"): 21_364,
    ("binsearch", "rhls_dec"): 21_354,
    ("binsearch_for", "vitis"): 2_357_243,
    ("binsearch_for", "vitis_dec"): 83_937,
    ("binsearch_for", "rhls"): 2_163_106,
    ("binsearch_for", "rhls_stream"): 22_230,
    ("binsearch_for", "rhls_dec"): 22_206,
    ("hashtable", "vitis"): 1_953_903, ("hashtable", "vitis_dec"): 53_887,
    ("hashtable", "rhls"): 1_687_760, ("hashtable", "rhls_stream"): 19_292,
    ("hashtable", "rhls_dec"): 19_086,
    ("mergesort", "vitis"): 259_157, ("mergesort", "vitis_dec"): 145_423,
    ("mergesort", "rhls"): 199_862, ("mergesort", "rhls_dec"): 7_038,
    ("mergesort_opt", "rhls_dec"): 3_960,
    ("multispmv", "vitis"): 348_343, ("multispmv", "vitis_dec"): 60_243,
    ("multispmv", "rhls"): 71_214, ("multispmv", "rhls_stream"): 32_218,
    ("multispmv", "rhls_dec"): 21_904,
    ("spmv", "vitis"): 286_379, ("spmv", "vitis_dec"): 55_071,
    ("spmv", "rhls"): 18_644, ("spmv", "rhls_stream"): 17_532,
    ("spmv", "rhls_dec"): 17_530,
}


def _cell_run(bench: str, config: str):
    def run(ctx: BenchContext) -> CellResult:
        kwargs = dict(scale=ctx.sim_scale, latency=100, rif=128)
        replay = {"benchmark": bench, "config": config, "kwargs": kwargs}
        try:
            r = run_workload(bench, config, **kwargs)
        except DeadlockError:
            # paper: R-HLS Stream mergesort deadlocks by design
            return CellResult(status="deadlock", replay=replay)
        assert r.correct, f"{bench}/{config} incorrect"
        derived = {"golden": int(r.golden)}
        paper = PAPER_TABLE1.get((bench, config), 0)
        if paper and not ctx.smoke:
            derived["paper"] = paper  # int, but constant — safe to diff
            derived["sim_vs_paper"] = round(r.cycles / paper, 2)
        return CellResult(cycles=int(r.cycles), derived=derived,
                          replay=replay)
    return run


def cells(ctx: BenchContext) -> List[Cell]:
    return [
        Cell(axis="sim", name=f"table1/{bench}/{config}", group="table1",
             coords=coords(bench, "sim"), run=_cell_run(bench, config))
        for bench in BENCHMARKS for config in CONFIGS
    ]


def run(csv_print) -> None:
    ctx = BenchContext(smoke=False)
    run_cells(cells(ctx), ctx, csv_print)
