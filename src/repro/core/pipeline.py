"""RIF planning: how many requests in flight do we need?

The paper's rule (§4.2): "as many values should be looked up in parallel
as the memory latency in cycles."  The TPU equivalent is the classic
latency-bandwidth product: to keep HBM busy, the bytes in flight must
cover latency × bandwidth; the ring depth (num_buffers / RIF) is that
divided by the block size, clamped by the VMEM budget.
"""

from __future__ import annotations

import dataclasses

from repro.kernels.common import VMEM_BYTES

# v5e-ish DMA characteristics (see benchmarks/hw.py)
HBM_BW = 819e9            # bytes/s
DMA_LATENCY_S = 2e-6      # issue-to-land for a small HBM->VMEM copy
VMEM_BUDGET_FRACTION = 0.5


@dataclasses.dataclass
class RifPlan:
    rif: int                 # buffers in flight
    block_bytes: int
    inflight_bytes: int
    vmem_fraction: float
    note: str


def plan_rif(block_bytes: int, *, latency_s: float = DMA_LATENCY_S,
             bandwidth: float = HBM_BW, vmem_budget: int | None = None,
             min_rif: int = 2, max_rif: int = 64) -> RifPlan:
    """Choose the buffer-ring depth for a decoupled stream of
    ``block_bytes`` blocks."""
    vmem_budget = vmem_budget or int(VMEM_BYTES * VMEM_BUDGET_FRACTION)
    need_bytes = latency_s * bandwidth
    rif_latency = max(min_rif, int(need_bytes // max(block_bytes, 1)) + 1)
    rif_vmem = max(1, vmem_budget // max(block_bytes, 1))
    rif = max(min_rif, min(rif_latency, rif_vmem, max_rif))
    note = ("latency-bound" if rif == rif_latency else
            "vmem-bound" if rif == rif_vmem else "clamped")
    return RifPlan(rif=rif, block_bytes=block_bytes,
                   inflight_bytes=rif * block_bytes,
                   vmem_fraction=rif * block_bytes / vmem_budget, note=note)
