"""The unified channel protocol (repro.channels): one vocabulary from
the simulator's Enq/Deq FIFOs through the serve loop to the shard_map
mesh ring.  Every transport must report post-event depths through the
same Tracer hook — that is the invariant the golden traces and serve
parity tests build on."""

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.channels import ChannelBase, LocalChannel, MeshChannel, SimChannel
from repro.core.trace import Tracer


class RecordingTracer(Tracer):
    def __init__(self):
        self.occ = []
        self.req = []

    def on_occupancy(self, instance, channel, depth, t=0.0):
        self.occ.append((instance, channel, depth, t))

    def on_request(self, instance, channel, port, t_issue, t_done):
        self.req.append((instance, channel, port, t_issue, t_done))


# ---------------------------------------------------------------------------
# shared protocol semantics, parametrized over host transports
# ---------------------------------------------------------------------------


def _make(transport, name="ch", capacity=3, tracer=None):
    if transport == "local":
        return LocalChannel(name, capacity, tracer)
    if transport == "sim":
        return SimChannel(name, capacity, tracer, instance="serve")
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    return MeshChannel(name, capacity, mesh, "data", tracer=tracer)


TRANSPORTS = ("local", "sim", "mesh")


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_fifo_order_and_backpressure(transport):
    c = _make(transport, capacity=2)
    assert isinstance(c, ChannelBase)
    assert c.transport == transport
    assert len(c) == 0 and not c
    assert c.push(1) and c.push(2)
    assert c.full
    assert not c.push(3)           # refused, no side effects
    assert len(c) == 2
    assert c.peek() == 1
    assert c.pop() == 1 and c.pop() == 2
    assert not c.full and len(c) == 0


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_post_event_depth_trace(transport):
    tr = RecordingTracer()
    c = _make(transport, name="q", capacity=4, tracer=tr)
    c.push(10)
    c.push(11)
    c.pop()
    c.push(12)
    c.pop()
    c.pop()
    depths = [d for (_, _, d, _) in tr.occ]
    assert depths == [1, 2, 1, 2, 1, 0]
    assert all(inst == "serve" and ch == "q" for (inst, ch, _, _) in tr.occ)


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_refused_push_does_not_trace(transport):
    tr = RecordingTracer()
    c = _make(transport, capacity=1, tracer=tr)
    c.push(1)
    assert not c.push(2)
    assert len(tr.occ) == 1        # only the accepted push traced


@pytest.mark.parametrize("transport", ("local", "mesh"))
def test_pop_empty_raises(transport):
    c = _make(transport)
    with pytest.raises(IndexError):
        c.pop()


# ---------------------------------------------------------------------------
# sim transport: timed engine surface + conservation counters
# ---------------------------------------------------------------------------


def test_sim_timed_surface_counters_and_trace():
    tr = RecordingTracer()
    st = SimChannel()
    st.push_timed(5.0, "v", "req", tr, "inst0", "a2e", t=3.0)
    assert st.reqs == 1 and st.enqs == 0
    assert st.front_ready == 5.0
    assert tr.occ[-1] == ("inst0", "a2e", 1, 3.0)
    assert st.pop_timed("resp", tr, "inst0", "a2e", t=6.0) == "v"
    assert st.resps == 1 and st.deqs == 0
    assert tr.occ[-1] == ("inst0", "a2e", 0, 6.0)
    st.push_timed(2.0, 7, "enq", tr, "inst0", "e2w", t=1.0)
    assert st.enqs == 1
    assert st.pop_timed("deq", tr, "inst0", "e2w", t=4.0) == 7
    assert st.deqs == 1
    # the engines peek raw state: keep those attributes stable
    assert hasattr(st, "fifo") and hasattr(st, "push_key")


def test_sim_protocol_surface_maps_to_enq_deq():
    st = SimChannel("q", capacity=2)
    assert st.push("a") and st.push("b") and not st.push("c")
    assert st.enqs == 2 and st.reqs == 0
    assert st.front_ready == 0.0   # protocol pushes land immediately
    assert st.pop() == "a"
    assert st.deqs == 1


def test_simulator_uses_shared_channel():
    from repro.core import simulator
    assert simulator._ChanState is SimChannel


def test_serve_loop_channel_is_local_alias():
    from repro.runtime import serve_loop
    assert serve_loop.Channel is LocalChannel


# ---------------------------------------------------------------------------
# mesh transport: wire format + device ring
# ---------------------------------------------------------------------------


def test_mesh_ring_wraps_and_carries_tuples():
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    c = MeshChannel("handoff", 3, mesh, "data")
    assert c.push(5)
    assert c.push((7, 11))
    assert c.push(42)
    assert c.pop() == 5
    assert c.pop() == (7, 11)
    assert c.push(-3)              # tail wraps to ring slot 0
    assert c.pop() == 42
    assert c.pop() == -3
    assert len(c) == 0


def test_mesh_wire_format_rejections():
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    c = MeshChannel("ctl", 2, mesh, "data", width=2)
    with pytest.raises(TypeError):
        c.push("not-an-int")
    with pytest.raises(ValueError):
        c.push((1, 2, 3))          # arity exceeds width
    with pytest.raises(ValueError):
        c.push(2 ** 40)            # does not fit int32


def test_mesh_requires_finite_capacity_and_known_axis():
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError):
        MeshChannel("c", None, mesh, "data")
    with pytest.raises(ValueError):
        MeshChannel("c", 2, mesh, "model")
