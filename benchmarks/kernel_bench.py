"""Decoupled-kernel microbenchmarks.

Wall-clock on this CPU container is NOT TPU performance; the derived
metric that transfers is the simulator's cycle model (RIF sweeps showing
latency hiding) plus interpret-mode correctness-at-shape.  We report
both: us_per_call is the CPU interpret wall time (plumbing overhead
indicator), derived carries the simulator cycles.

Besides the CSV stream, every run emits a machine-readable
``BENCH_kernels.json`` at the repo root (uploaded as a CI artifact) so
the perf trajectory — per-op tuned-vs-default wall-clock plus the chase
kernels' decoupled-vs-XLA-fallback ratio — is tracked across PRs.

``--smoke`` shrinks problem sizes and tuning budgets to CI scale and
additionally drives both new ``dae_chase`` kernels end-to-end against
their oracles.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.workloads import run_workload

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_kernels.json"


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(csv_print, smoke: bool = False) -> None:
    r = np.random.default_rng(0)
    rows = []

    def emit(name: str, us: float, derived: str) -> None:
        csv_print(f"{name},{us:.0f},{derived}")
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})

    report = {"schema": 1, "smoke": smoke, "backend": jax.default_backend(),
              "rows": rows, "tuned_vs_default": {}, "chase": {}}

    sim_scale = "small" if smoke else "paper"

    # RIF sweep (the paper's central knob) from the simulator
    for rif in (2, 8, 32, 128):
        res = run_workload("hashtable", "rhls_dec", scale=sim_scale,
                           latency=100, rif=rif)
        emit(f"kernel/rif_sweep/hashtable/rif={rif}", 0,
             f"cycles={res.cycles};golden={res.golden}")

    # channel-capacity sensitivity sweep (§5.3/§5.4): capacity = rif+slack;
    # negative slack starves the round-robin chase into the deadlock the
    # capacity bound exists to prevent
    from repro.core.simulator import DeadlockError
    for slack in (-4, 0, 1, 16, 64):
        try:
            res = run_workload("hashtable", "rhls_dec", scale=sim_scale,
                               latency=100, rif=32, cap_slack=slack)
            derived = f"cycles={res.cycles};golden={res.golden}"
        except DeadlockError:
            derived = "cycles=deadlock"
        emit(f"kernel/cap_sweep/hashtable/slack={slack}", 0, derived)

    # gather: decoupled kernel (interpret) vs XLA take.  Knobs are passed
    # explicitly so these baseline rows never pick up a tuned config from
    # a previous run's cache.
    from repro.kernels.dae_gather import dae_gather
    gn, gm = (1024, 128) if smoke else (4096, 512)
    table = jnp.asarray(r.standard_normal((gn, 256)), jnp.float32)
    idx = jnp.asarray(r.integers(0, gn, gm), jnp.int32)
    for method in ("pipelined", "rif", "ref"):
        us = _time(lambda: dae_gather(table, idx, method=method,
                                      block_d=512, chunk=64, rif=8))
        emit(f"kernel/gather/{method}", us, "interpret_cpu")

    # per-op tuned-vs-default: the analytic fallback the dispatcher
    # resolves on a cold cache (plan_rif-sized rings, documented default
    # blocks — passed explicitly so a warm cache cannot contaminate the
    # baseline), vs the tuned-cache winner it resolves after tuning
    from repro.core.pipeline import plan_rif
    from repro.tune import KERNEL_DIMS, dispatch_config, tune_kernel
    from repro.kernels.common import resolve_interpret
    from repro.kernels.dae_merge import merge_sorted
    from repro.kernels.dae_chase import batched_searchsorted, hash_lookup
    from repro.kernels.dae_chase.kernel import ENTRY_LANES

    evals = 4 if smoke else 16
    a = jnp.sort(jnp.asarray(r.standard_normal(2048), jnp.float32))
    b = jnp.sort(jnp.asarray(r.standard_normal(2048), jnp.float32))
    ss_n, ss_m = KERNEL_DIMS["batched_searchsorted"]
    ss_table = jnp.sort(jnp.asarray(r.integers(0, 1 << 30, ss_n), jnp.int32))
    ss_keys = jnp.asarray(r.integers(0, 1 << 30, ss_m), jnp.int32)
    hl_n, hl_m = KERNEL_DIMS["hash_lookup"]
    chain = 8
    hl_ek = jnp.asarray(np.arange(hl_n), jnp.int32)
    hl_ev = jnp.asarray(r.integers(0, 1 << 20, hl_n), jnp.int32)
    hl_en = jnp.asarray([(i + 1) if (i + 1) % chain else -1
                         for i in range(hl_n)], jnp.int32)
    hl_heads = jnp.asarray(r.integers(0, hl_n // chain, hl_m) * chain,
                           jnp.int32)
    hl_keys = hl_heads + jnp.asarray(r.integers(0, chain, hl_m), jnp.int32)

    # the cold-cache fallback knobs, mirrored from each dispatcher
    gather_rif0 = plan_rif(64 * 256 * 4).rif          # chunk * dp * f32
    merge_rif0 = plan_rif(256 * 4).rif                # tile * f32
    ss_rif0 = plan_rif(128 * 4).rif                   # block * i32
    hl_rif0 = plan_rif(ENTRY_LANES * 4).rif           # packed entry row
    tuned_cells = {
        # op -> (dims, cold-cache-default call, tuned/dispatcher call)
        "dae_gather": (
            (gn, 256, gm),
            lambda: dae_gather(table, idx, method="pipelined", block_d=256,
                               chunk=64, rif=gather_rif0),
            lambda: dae_gather(table, idx)),
        "dae_merge": (
            (2048, 2048),
            lambda: merge_sorted(a, b, tile=256, rif=merge_rif0),
            lambda: merge_sorted(a, b)),
        "batched_searchsorted": (
            (ss_n, ss_m),
            lambda: batched_searchsorted(ss_table, ss_keys, block=128,
                                         chunk=64, rif=ss_rif0),
            lambda: batched_searchsorted(ss_table, ss_keys)),
        "hash_lookup": (
            (hl_n, hl_m),
            lambda: hash_lookup(hl_ek, hl_ev, hl_en, hl_heads, hl_keys,
                                max_steps=chain, chunk=64, rif=hl_rif0),
            lambda: hash_lookup(hl_ek, hl_ev, hl_en, hl_heads, hl_keys,
                                max_steps=chain)),
    }
    for op, (dims, default_fn, tuned_fn) in tuned_cells.items():
        res = tune_kernel(op, dims, max_evals=evals, reps=2)
        us_default = _time(default_fn)
        us_tuned = _time(tuned_fn)   # dispatcher consults the cache
        dt = ss_table.dtype if op == "batched_searchsorted" else \
            jnp.int32.dtype if op == "hash_lookup" else jnp.float32.dtype
        cfg = dispatch_config(op, dims, dt, resolve_interpret(None))
        cfg_s = ";".join(f"{k}={v}" for k, v in sorted(cfg.items()))
        emit(f"kernel/{op}/plan_default", us_default, "interpret_cpu")
        emit(f"kernel/{op}/tuned", us_tuned,
             f"{cfg_s};tune_evals={res.evals}")
        report["tuned_vs_default"][op] = {
            "dims": list(dims), "default_us": round(us_default, 1),
            "tuned_us": round(us_tuned, 1), "config": cfg,
            "tune_evals": res.evals,
        }

    # chase: decoupled Pallas kernel vs the XLA fallback (method='ref')
    # — the paper's headline irregular workloads on the kernel path.
    # Wall-clock here is interpret-mode plumbing, so the json records
    # both sides rather than gating a ratio; correctness IS gated.
    from repro.kernels.dae_chase import hash_lookup_ref, searchsorted_ref
    ss_out = batched_searchsorted(ss_table, ss_keys, block=128, chunk=64,
                                  rif=8)
    np.testing.assert_array_equal(
        np.asarray(ss_out), np.asarray(searchsorted_ref(ss_table, ss_keys)))
    hl_out = hash_lookup(hl_ek, hl_ev, hl_en, hl_heads, hl_keys,
                         max_steps=chain, chunk=64, rif=8)
    np.testing.assert_array_equal(
        np.asarray(hl_out),
        np.asarray(hash_lookup_ref(hl_ek, hl_ev, hl_en, hl_heads, hl_keys,
                                   chain)))
    chase_cells = {
        "batched_searchsorted": lambda m: batched_searchsorted(
            ss_table, ss_keys, block=128, chunk=64, rif=8, method=m),
        "hash_lookup": lambda m: hash_lookup(
            hl_ek, hl_ev, hl_en, hl_heads, hl_keys, max_steps=chain,
            chunk=64, rif=8, method=m),
    }
    for op, fn in chase_cells.items():
        us_pallas = _time(lambda: fn("pallas"))
        us_xla = _time(lambda: fn("ref"))
        emit(f"kernel/{op}/decoupled", us_pallas, "interpret_cpu;parity=ok")
        emit(f"kernel/{op}/xla_fallback", us_xla, "xla_cpu")
        report["chase"][op] = {"decoupled_us": round(us_pallas, 1),
                               "xla_fallback_us": round(us_xla, 1),
                               "parity": "ok"}
    # hash_probe's found/val state moved from per-scalar SMEM loops to
    # VMEM vector fills/emits; the baseline is the pre-vectorization
    # wall time at this exact cell (4096x256, chain=8, chunk=64, rif=8,
    # best-of-5), so the after-side is measured the same way
    def _best_of(fn, reps=5):
        fn()
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    report["chase"]["hash_lookup"]["probe_vectorization"] = {
        "scalar_smem_baseline_us": 3650.2,
        "vectorized_us": round(_best_of(
            lambda: hash_lookup(hl_ek, hl_ev, hl_en, hl_heads, hl_keys,
                                max_steps=chain, chunk=64, rif=8)), 1),
    }

    # compiled-vs-handwritten: the generic repro.compile lowering vs
    # the hand-written kernel family on the same problem data.  Output
    # conventions differ (the compiled binsearch stores found-index-or
    # -1 where batched_searchsorted returns insertion points), so each
    # side is asserted against its OWN oracle — the simulator for the
    # compiled kernel, the XLA reference for the hand-written one — and
    # wall-clock is the comparable number.
    from repro.compile.targets import assert_parity, compile_target
    from repro.core.workloads import make_binsearch_data, make_gather_data

    report["compiled"] = {}
    ck_g, t_g = compile_target("gather")
    assert_parity(ck_g(), t_g.simulate_oracle())
    us_cg = _time(lambda: ck_g())
    g = make_gather_data("small")
    g_table = jnp.asarray(g["table"])
    g_idx = jnp.asarray(g["idx"], jnp.int32)

    def hand_gather():
        return dae_gather(g_table, g_idx, method="rif", chunk=16, rif=8)

    np.testing.assert_array_equal(
        np.asarray(hand_gather()), np.asarray(g_table)[np.asarray(g_idx)])
    us_hg = _time(hand_gather)
    emit("kernel/compiled_vs_hand/gather/compiled", us_cg,
         "parity=sim_oracle")
    emit("kernel/compiled_vs_hand/gather/handwritten", us_hg,
         "parity=xla_take")
    report["compiled"]["gather"] = {
        "compiled_us": round(us_cg, 1), "handwritten_us": round(us_hg, 1),
        "handwritten_op": "dae_gather[rif]", "parity": "ok",
    }

    ck_b, t_b = compile_target("binsearch")
    assert_parity(ck_b(), t_b.simulate_oracle())
    us_cb = _time(lambda: ck_b())
    bs = make_binsearch_data("small")
    bs_arr = jnp.asarray(bs["arr"], jnp.int32)
    bs_keys = jnp.asarray(bs["keys"], jnp.int32)

    def hand_binsearch():
        return batched_searchsorted(bs_arr, bs_keys, block=128, chunk=16,
                                    rif=8)

    np.testing.assert_array_equal(
        np.asarray(hand_binsearch()),
        np.asarray(searchsorted_ref(bs_arr, bs_keys)))
    us_hb = _time(hand_binsearch)
    emit("kernel/compiled_vs_hand/binsearch/compiled", us_cb,
         "parity=sim_oracle")
    emit("kernel/compiled_vs_hand/binsearch/handwritten", us_hb,
         "parity=xla_take")
    report["compiled"]["binsearch"] = {
        "compiled_us": round(us_cb, 1), "handwritten_us": round(us_hb, 1),
        "handwritten_op": "batched_searchsorted", "parity": "ok",
    }

    # merge + flash single cells (plumbing-overhead indicators)
    us = _time(lambda: merge_sorted(a, b, tile=256, rif=2))
    emit("kernel/merge/pallas", us, "interpret_cpu")

    from repro.kernels.flash_attention import flash_attention
    q = jnp.asarray(r.standard_normal((1, 4, 512, 64)), jnp.float32)
    k = jnp.asarray(r.standard_normal((1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(r.standard_normal((1, 2, 512, 64)), jnp.float32)
    us = _time(lambda: flash_attention(q, k, v))
    emit("kernel/flash/pallas", us, "interpret_cpu")

    BENCH_JSON.write_text(json.dumps(report, indent=1, sort_keys=True)
                          + "\n")
    csv_print(f"kernel/bench_json,0,path={BENCH_JSON.name}")
