"""Merge-path split computation + public merge/sort wrappers."""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import (cdiv, resolve_interpret, ring_rif,
                                  round_up, tuned_knobs)
from repro.kernels.dae_merge import kernel as _k


def _sentinel(dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def merge_path_splits(a: jax.Array, b: jax.Array, tile: int, n_tiles: int):
    """For each output diagonal k = t*tile, find ia = the number of
    elements taken from ``a`` among the first k merged elements (ties take
    from a first).  Vectorized binary search: ia is the smallest i in
    [max(0, k-m), min(k, n)] with a[i] > b[k-i-1]."""
    n, m = a.shape[0], b.shape[0]
    ks = jnp.arange(n_tiles, dtype=jnp.int32) * tile
    lo = jnp.maximum(0, ks - m).astype(jnp.int32)
    hi = jnp.minimum(ks, n).astype(jnp.int32)

    big = _sentinel(a.dtype)
    a_pad = jnp.concatenate([a, jnp.full((1,), big, a.dtype)])
    b_pad = jnp.concatenate([b, jnp.full((1,), big, b.dtype)])

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        av = a_pad[jnp.minimum(mid, n)]
        bk = ks - mid - 1
        bv = jnp.where(bk >= 0, b_pad[jnp.clip(bk, 0, m - 1)],
                       jnp.full_like(b_pad[0], -jnp.inf)
                       if jnp.issubdtype(b.dtype, jnp.floating)
                       else jnp.iinfo(b.dtype).min)
        take_a = av <= bv  # a[mid] <= b[k-mid-1] -> split is right of mid
        lo = jnp.where((lo < hi) & take_a, mid + 1, lo)
        hi = jnp.where((lo <= hi) & ~take_a, jnp.minimum(hi, mid), hi)
        return lo, hi

    steps = max(1, math.ceil(math.log2(max(n + m, 2))) + 1)
    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    ia = lo
    ib = ks - ia
    return ia.astype(jnp.int32), ib.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile", "rif", "interpret",
                                              "method"))
def _merge_impl(a, b, *, tile, rif, interpret, method):
    n, m = a.shape[0], b.shape[0]
    total = n + m
    if method == "ref":
        return jnp.sort(jnp.concatenate([a, b]))
    n_tiles = cdiv(total, tile)
    ia, ib = merge_path_splits(a, b, tile, n_tiles)
    big = _sentinel(a.dtype)
    # pad so every (start, start+tile) window is in bounds
    a_pad = jnp.concatenate([a, jnp.full((tile,), big, a.dtype)])
    b_pad = jnp.concatenate([b, jnp.full((tile,), big, b.dtype)])
    out = _k.merge_tiles(a_pad, b_pad, ia, ib, n_tiles * tile, tile=tile,
                         rif=rif, interpret=interpret)
    return out[:total]


def merge_sorted(a: jax.Array, b: jax.Array, *, tile: Optional[int] = None,
                 rif: Optional[int] = None, method: str = "pallas",
                 interpret: Optional[bool] = None) -> jax.Array:
    """Merge two sorted 1-D arrays (decoupled merge-path kernel).

    ``tile``/``rif`` left ``None`` resolve in the dispatch order
    explicit → tune cache → analytic (tile 256; ``plan_rif`` sizes the
    window ring from the tile's byte size).
    """
    if a.dtype != b.dtype:
        raise TypeError(f"dtype mismatch: {a.dtype} vs {b.dtype}")
    interpret = resolve_interpret(interpret)
    if tile is None or rif is None:
        knobs = tuned_knobs("dae_merge", (a.shape[0], b.shape[0]), a.dtype,
                            interpret, tile=(tile, 256), rif=(rif, None))
        tile, rif = knobs["tile"], knobs["rif"]
    tile = min(tile, 1 << max(1, (a.shape[0] + b.shape[0] - 1).bit_length()))
    # tile must be a power of two for the bitonic network
    tile = 1 << (tile.bit_length() - 1)
    rif = ring_rif(rif, tile * a.dtype.itemsize)
    return _merge_impl(a, b, tile=tile, rif=rif, interpret=interpret,
                       method=method)


def merge_sort(x: jax.Array, *, tile: int = 256, method: str = "pallas",
               interpret: Optional[bool] = None) -> jax.Array:
    """Bottom-up mergesort built from the decoupled merge unit — the
    paper's mergesort benchmark in TPU form.  The ping-pong between
    passes is the mergesort_opt optimization (no copy loop, §4.1)."""
    n = x.shape[0]
    width = tile
    # sort tiles locally first (one bitonic pass per tile via jnp.sort on
    # a reshaped view keeps the host loop short)
    np_ = round_up(n, tile)
    big = _sentinel(x.dtype)
    xp = jnp.concatenate([x, jnp.full((np_ - n,), big, x.dtype)])
    xp = jnp.sort(xp.reshape(-1, tile), axis=1).reshape(-1)
    while width < np_:
        pieces = []
        for lo in range(0, np_, 2 * width):
            a = xp[lo: lo + width]
            e = min(lo + 2 * width, np_)
            b = xp[lo + width: e]
            if b.shape[0] == 0:
                pieces.append(a)
            else:
                pieces.append(merge_sorted(a, b, tile=tile, method=method,
                                           interpret=interpret))
        xp = jnp.concatenate(pieces)
        width *= 2
    return xp[:n]
