from repro.kernels.grouped_matmul.ops import grouped_matmul
from repro.kernels.grouped_matmul.ref import grouped_matmul_ref

__all__ = ["grouped_matmul", "grouped_matmul_ref"]
