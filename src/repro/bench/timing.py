"""Cold/warm wall-clock measurement for benchmark cells.

The one timing bug this module exists to prevent: folding first-call
JIT compilation into a steady-state number.  ``BENCH_compile.json``
shipped a ~701ms ``us_per_call`` for ``compile/binsearch/kernel`` that
was >99% trace-and-compile time — useless as a call-cost trajectory and
noisy enough to drown any real regression.  :func:`measure` therefore
always reports **both** sides of the split:

  * ``us_cold`` — the very first call, compilation included.  This is
    the user-visible latency of a cold cache and is worth tracking, but
    only as itself, never blended into a mean.
  * ``us_warm`` — best-of-``warm_reps`` after the cold call.  Best (not
    mean) because wall-clock noise on a shared CI container is strictly
    additive; the minimum is the stable lower envelope.

Wall-clock transfers poorly between machines, so the regression gate
(:mod:`repro.bench.diffing`) compares ``us_warm`` with a generous
percentage band and never gates ``us_cold`` at all; simulator cycle
counts are the exact-match signal.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Sequence

__all__ = ["Timing", "measure", "percentile", "percentiles"]


@dataclasses.dataclass(frozen=True)
class Timing:
    """One cold/warm measurement, microseconds."""

    us_cold: float
    us_warm: float


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile (numpy's default method), shared
    by the serve bench and its matrix cells.

    The naive ``sorted(v)[int(q/100 * len(v))]`` index the serve bench
    used to compute is biased: for n < 20 a "p95" lands on the max (or
    past it, saved only by a min()), and it jumps discontinuously with
    n.  Interpolating between the two straddling order statistics is
    exact for the quantile definition diffable across runs.
    """
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    if not values:
        raise ValueError("percentile of an empty sequence")
    xs = sorted(float(v) for v in values)
    if len(xs) == 1:
        return xs[0]
    rank = q / 100.0 * (len(xs) - 1)
    lo = int(rank)
    frac = rank - lo
    if lo + 1 >= len(xs):
        return xs[-1]
    return xs[lo] + (xs[lo + 1] - xs[lo]) * frac


def percentiles(values: Sequence[float],
                qs: Sequence[float] = (50.0, 95.0, 99.0)
                ) -> Dict[str, float]:
    """``{"p50": ..., "p95": ..., "p99": ...}`` via :func:`percentile`."""
    return {f"p{q:g}": percentile(values, q) for q in qs}


def measure(fn: Callable[[], object], *, warm_reps: int = 3) -> Timing:
    """Time ``fn`` once cold (JIT included) then best-of-``warm_reps``.

    ``fn``'s result is passed through ``jax.block_until_ready`` so
    asynchronous dispatch cannot leak compute past the timer; non-array
    results pass through untouched.
    """
    import jax  # lazy: diff-only consumers of repro.bench need no jax

    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    us_cold = (time.perf_counter() - t0) * 1e6
    best = float("inf")
    for _ in range(max(1, warm_reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return Timing(us_cold=us_cold, us_warm=best * 1e6)
