"""Tests for the per-cycle set/check DSL (tests/dsl.py).

Three layers:

  * DSL self-tests — cursor/label semantics, expect forms, loud
    failures on typo'd channels, set-after-run rejection;
  * the binsearch golden-trace test **rewritten in the DSL** — the
    aggregate summary still matches ``tests/golden/binsearch.json``
    (the WaveformTracer is a strict superset of the plain Tracer), and
    the per-cycle moments the aggregates cannot see are pinned;
  * a **mutation check** — a deliberately-perturbed scheduler (every
    ``Req`` executed one cycle late, patched in at the engine's
    ``_exec_ev`` seam) must be caught by the same checks that pass on
    the unperturbed engine;
  * VCD structural checks — the export must be parseable by a standard
    waveform tool (GTKWave/Surfer), so the test enforces the IEEE 1364
    §18 structure: declarations, one id per var, initial dump, strictly
    increasing timestamps, only declared ids referenced.
"""

from __future__ import annotations

import json
from pathlib import Path

import pytest

from dsl import CheckFailed, SimScript
from repro.core.waveform import vcd_identifier

GOLDEN = Path(__file__).parent / "golden"


def _binsearch() -> SimScript:
    # mirrors tests/test_golden_traces.py GOLDEN_PARAMS
    return SimScript("binsearch", "rhls_dec").set(scale="small",
                                                  latency=100, rif=8)


# -- migrated golden-trace test -----------------------------------------------


def test_binsearch_golden_trace_in_dsl():
    """The golden-trace fixture equality, plus the per-cycle moments."""
    s = _binsearch().run()

    # aggregate layer: bit-identical to the committed TraceSummary
    want = json.loads((GOLDEN / "binsearch.json").read_text())
    assert s.tracer.summary().to_json() == want

    # per-cycle layer: what the aggregates cannot express.
    # The access engine fills the rif=8 ring immediately (one enq per
    # cycle at t=0..) and keeps it full while hiding latency.
    s.goto(0).check_occupancy("bs_load", 1)
    s.goto(150)
    s.check_occupancy("bs_load", 8).check_occupancy("bs_state", 8)
    s.check_issues("table", at_least=8)
    s.label("steady")
    s.check_peak_occupancy("bs_load", 8)
    s.check_peak_occupancy("bs_state", 8)
    # bounded-buffer invariant at every probe point, not just the peak
    for t in range(0, s.cycles, 97):
        s.check_occupancy("bs_load", (0, 8), at=t)
    s.check_issues("out", at_least=1, at=s.cycles)


def test_waveform_tracer_matches_plain_tracer_on_every_golden():
    """WaveformTracer's inherited aggregates stay byte-identical to the
    plain Tracer's committed fixtures for every workload."""
    from repro.core.workloads import BENCHMARKS, run_workload
    from repro.core.waveform import WaveformTracer
    for benchmark in BENCHMARKS:
        wt = WaveformTracer(64)
        run_workload(benchmark, "rhls_dec", scale="small", latency=100,
                     rif=8, tracer=wt)
        want = json.loads((GOLDEN / f"{benchmark}.json").read_text())
        assert wt.summary().to_json() == want, benchmark


# -- mutation check: the DSL must catch a perturbed scheduler ----------------


def _baseline_expectations():
    """Per-cycle expectations recorded off the *unperturbed* engine."""
    base = _binsearch().run()
    probes = list(range(0, base.cycles, 61))
    return {
        "cycles": base.cycles,
        "probes": probes,
        "occ": [base.tracer.occupancy_at("bs_load", t) for t in probes],
        "issues": [base.tracer.issues_until("table", t) for t in probes],
    }


def _probe_script(s: SimScript, want: dict) -> None:
    """The check script the engine is held to: makespan, occupancy at a
    grid of cycles, cumulative port-issue counts."""
    s.run().check_cycles(want["cycles"])
    for t, o, i in zip(want["probes"], want["occ"], want["issues"]):
        s.check_occupancy("bs_load", o, at=t)
        s.check_issues("table", i, at=t)


def test_probe_script_passes_on_real_engine():
    _probe_script(_binsearch(), _baseline_expectations())


def test_scheduler_perturbation_is_caught(monkeypatch):
    """Delay every Req by one cycle inside the event engine: a genuine
    scheduler perturbation (issue timing shifts, conservation holds —
    the dependent chase's requests are not port-bound, so the shift is
    NOT absorbed; cycles move 3104 -> 3134 on this cell).  The same
    script that passes above must fail, by cycle and name.

    The expectations are recorded BEFORE the patch: the mutation check
    is only honest if the baseline comes from the real engine."""
    import repro.core.simulator as sim
    from repro.core.dae import Req

    want = _baseline_expectations()
    real = sim._exec_ev

    def skewed(ctx, inst, eff, t, ev):
        if eff.__class__ is Req:
            return real(ctx, inst, eff, t + 1.0, ev)
        return real(ctx, inst, eff, t, ev)

    monkeypatch.setattr(sim, "_exec_ev", skewed)
    with pytest.raises(CheckFailed):
        _probe_script(_binsearch(), want)


# -- DSL semantics ------------------------------------------------------------


def test_set_after_run_rejected():
    s = _binsearch().run()
    with pytest.raises(CheckFailed, match="fixed once"):
        s.set(rif=16)


def test_unknown_channel_fails_loudly():
    s = _binsearch().run()
    with pytest.raises(CheckFailed, match="never appeared"):
        s.check_occupancy("bs_laod", 8)   # typo must not read as empty
    with pytest.raises(CheckFailed, match="never appeared"):
        s.check_peak_occupancy("nope", 1)


def test_unknown_port_reads_zero():
    # ports are aggregated under shared names; an idle port is a valid 0
    s = _binsearch().run()
    s.check_issues("not_a_port", 0)


def test_expect_forms_and_messages():
    s = _binsearch().run().goto(150)
    s.check_occupancy("bs_load", 8)                       # exact
    s.check_occupancy("bs_load", (1, 8))                  # inclusive range
    s.check_occupancy("bs_load", lambda v: v % 2 == 0)    # predicate
    with pytest.raises(CheckFailed) as e:
        s.check_occupancy("bs_load", 3)
    assert "cycle 150" in str(e.value) and "bs_load" in str(e.value)
    with pytest.raises(CheckFailed):
        s.check_occupancy("bs_load", (0, 2))
    with pytest.raises(CheckFailed):
        s.check_occupancy("bs_load", lambda v: v > 100)


def test_cursor_step_goto_label():
    s = _binsearch().run()
    assert s.cursor == 0
    s.step(10).step(5)
    assert s.cursor == 15
    s.label("here")
    s.goto(500)
    assert s.cursor == 500
    s.goto("here")
    assert s.cursor == 15
    s.label("explicit", cycle=99)
    assert s.at("explicit") == 99
    with pytest.raises(ValueError):
        s.step(-1)
    with pytest.raises(CheckFailed, match="unknown cycle label"):
        s.goto("nowhere")


def test_check_issues_requires_expectation():
    s = _binsearch().run()
    with pytest.raises(TypeError):
        s.check_issues("table")


def test_from_program_raw_pipeline():
    """Raw DaeProgram entry: a 2-process pipeline over a latency-3 load
    port, checked at the channel-capacity level."""
    from repro.core.dae import (DaeProgram, Deq, Enq, LoadChannel, Process,
                                Req, Resp, Store, StreamChannel)
    from repro.core.simulator import FixedLatencyMemory

    n, cap = 6, 2
    load = LoadChannel("ld", capacity=4, port="mem")
    stream = StreamChannel("st", capacity=cap)

    def producer():
        for i in range(n):
            yield Req(load, i)
            v = yield Resp(load)
            yield Enq(stream, v)

    def consumer():
        for i in range(n):
            v = yield Deq(stream)
            yield Store("out", i, v)

    prog = DaeProgram("pipe", [Process("prod", producer),
                               Process("cons", consumer)])
    mems = {"mem": FixedLatencyMemory(list(range(10, 10 + n)), latency=3),
            "out": FixedLatencyMemory([None] * n, latency=1)}
    s = SimScript.from_program(prog, mems).run()
    s.check_peak_occupancy("st", (1, cap))        # §5.3 capacity bound
    s.check_peak_occupancy("ld", (1, 4))
    s.check_issues("mem", n, at=s.cycles)         # every element fetched
    s.check_issues("out", n, at=s.cycles)         # ... and stored
    for t in range(s.cycles + 1):
        s.check_occupancy("st", (0, cap), at=t)
    assert s.report.stored_array("out", n) == list(range(10, 10 + n))


# -- VCD export ---------------------------------------------------------------


def test_vcd_identifier_unique_and_printable():
    ids = [vcd_identifier(i) for i in range(300)]
    assert len(set(ids)) == 300
    assert all(33 <= ord(c) <= 126 for i in ids for c in i)
    assert all(len(i) == 1 for i in ids[:94])     # compact single chars
    assert all(len(i) == 2 for i in ids[94:300])


def _parse_vcd(text: str):
    """Minimal IEEE 1364 §18 structural parser: returns (vars, changes)
    or raises AssertionError where a waveform viewer would choke."""
    lines = text.splitlines()
    assert lines, "empty VCD"
    i = 0
    vars_: dict = {}
    in_defs = True
    while in_defs:
        assert i < len(lines), "no $enddefinitions"
        tok = lines[i].split()
        if tok and tok[0] == "$var":
            # $var integer 32 <id> <name> $end
            assert tok[1] == "integer" and tok[2] == "32" and \
                tok[-1] == "$end", lines[i]
            ident, name = tok[3], tok[4]
            assert ident not in vars_, f"duplicate id {ident}"
            assert all(33 <= ord(c) <= 126 for c in ident)
            assert " " not in name
            vars_[ident] = name
        elif tok and tok[0] == "$enddefinitions":
            in_defs = False
        i += 1
    assert vars_, "no variables declared"
    assert lines[i] == "$dumpvars"
    i += 1
    initial = set()
    while lines[i] != "$end":
        bits, ident = lines[i].split()
        assert bits.startswith("b") and set(bits[1:]) <= {"0", "1"}
        assert ident in vars_, f"undeclared id {ident} in dumpvars"
        initial.add(ident)
        i += 1
    assert initial == set(vars_), "every var needs an initial value"
    i += 1
    changes = []
    last_t = -1
    while i < len(lines):
        line = lines[i]
        if line.startswith("#"):
            t = int(line[1:])
            assert t > last_t, f"timestamps not increasing at {line}"
            last_t = t
        else:
            bits, ident = line.split()
            assert bits.startswith("b") and set(bits[1:]) <= {"0", "1"}
            assert ident in vars_, f"undeclared id {ident}"
            changes.append((last_t, ident, int(bits[1:], 2)))
        i += 1
    return vars_, changes


def test_vcd_export_is_structurally_valid():
    s = _binsearch().run()
    text = s.to_vcd(comment="binsearch golden cell")
    assert text.endswith("\n")
    vars_, changes = _parse_vcd(text)
    names = set(vars_.values())
    assert {"bs_load_occ", "bs_state_occ", "table_issues",
            "out_issues"} <= names
    assert changes, "waveform has no value changes"
    # the VCD must tell the same story as the query API: replaying the
    # change list reproduces occupancy_at for the load channel
    ident = next(k for k, v in vars_.items() if v == "bs_load_occ")
    value = 0
    for t, ident_i, v in changes:
        if ident_i == ident:
            value = v
    assert value == s.tracer.occupancy_at("bs_load", s.tracer.end_cycle)


def test_vcd_multitenant_signals_are_namespaced():
    from repro.core.waveform import WaveformTracer
    from repro.core.workloads import run_workload_multi
    wt = WaveformTracer()
    run_workload_multi("hashtable", "rhls_dec", 2, scale="small",
                       latency=100, rif=8, tracer=wt)
    text = wt.to_vcd()
    vars_, _ = _parse_vcd(text)
    names = set(vars_.values())
    # per-tenant channels split (instance qualifier becomes hierarchy
    # dot), shared table port aggregates under the physical name
    assert any(n.startswith("t0.") for n in names), names
    assert any(n.startswith("t1.") for n in names), names
    assert "table_issues" in names
