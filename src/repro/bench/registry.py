"""Declarative benchmark-cell registry.

A *cell* is one point of the evaluation matrix the paper's §6 grid
implies: ``(workload, sim|kernel|compiled, engine, backend, tenants,
tuned?)``.  Benchmarks declare cells; the matrix runner
(:mod:`repro.bench.matrix`) runs **every** cell of an axis — the SPEC
discipline of running whole suites, never cherry-picking — and the
schema (:mod:`repro.bench.schema`) pins the result shape.

Cells are plain data plus a ``run(ctx)`` closure so benchmark modules
stay importable without executing anything: enumeration is free,
execution is explicit.  ``BenchContext`` carries the only two global
knobs (``smoke`` problem scale and the RNG ``seed``) so a cell can
never consult ambient state the report does not record.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "COORD_KEYS", "KINDS", "BenchContext", "Cell", "CellResult",
    "check_cells", "coords",
]

# the axis tuple every cell is keyed by, in canonical order
COORD_KEYS: Tuple[str, ...] = (
    "workload", "kind", "engine", "backend", "tenants", "tuned")
KINDS: Tuple[str, ...] = ("sim", "kernel", "compiled", "serve")


@dataclasses.dataclass(frozen=True)
class BenchContext:
    """Global knobs a cell may depend on; everything else is in-coords.

    ``smoke`` selects the CI-sized problem scale; ``seed`` feeds every
    RNG a cell constructs (and is recorded in the report metadata, so a
    run is reproducible from its JSON alone).
    """

    smoke: bool = False
    seed: int = 0

    @property
    def sim_scale(self) -> str:
        """Simulator dataset scale: CI runs small, full runs paper."""
        return "small" if self.smoke else "paper"


def coords(workload: str, kind: str, *, engine: str = "event",
           backend: str = "sim", tenants: int = 1,
           tuned: Optional[bool] = None) -> Dict[str, object]:
    """Build (and sanity-check) a cell's coordinate dict.

    ``engine`` is the scheduler for ``sim`` cells ("event"/"polling")
    and the execution path for kernel cells ("pallas"/"xla");
    ``backend`` is "sim" for pure-simulator cells, else the JAX backend
    the kernel ran on.  ``tuned`` is three-valued: ``True``/``False``
    for cells on either side of a tuned-vs-default pair, ``None`` where
    the axis does not apply.
    """
    if kind not in KINDS:
        raise ValueError(f"kind {kind!r} not in {KINDS}")
    if not workload:
        raise ValueError("workload must be non-empty")
    if tenants < 1:
        raise ValueError(f"tenants must be >= 1, got {tenants}")
    return {"workload": workload, "kind": kind, "engine": engine,
            "backend": backend, "tenants": int(tenants), "tuned": tuned}


@dataclasses.dataclass
class CellResult:
    """What one cell run produced.

    ``cycles`` is first-class (exact-diffed): simulator cycle counts
    are deterministic across machines, unlike wall-clock.  ``status``
    is "deadlock" for cells whose *expected* outcome is the §5.3
    deadlock (e.g. negative capacity slack); an unexpected deadlock
    should raise instead.  ``derived`` holds scalar side-channels —
    integer values are exact-diffed, floats and strings are
    informational.  ``replay`` optionally records how to re-run the
    cell (``run_workload`` kwargs) so the diff gate can dump a VCD
    waveform of a failing simulator cell.
    """

    status: str = "ok"                     # "ok" | "deadlock"
    cycles: Optional[int] = None
    us_cold: Optional[float] = None
    us_warm: Optional[float] = None
    derived: Dict[str, object] = dataclasses.field(default_factory=dict)
    replay: Optional[Dict[str, object]] = None

    def to_json(self) -> Dict[str, object]:
        out: Dict[str, object] = {"status": self.status,
                                  "cycles": self.cycles,
                                  "us_cold": None, "us_warm": None,
                                  "derived": dict(self.derived)}
        if self.us_cold is not None:
            out["us_cold"] = round(float(self.us_cold), 1)
        if self.us_warm is not None:
            out["us_warm"] = round(float(self.us_warm), 1)
        if self.replay is not None:
            out["replay"] = dict(self.replay)
        return out


@dataclasses.dataclass(frozen=True, eq=False)
class Cell:
    """One declared matrix cell: identity + coordinates + how to run it.

    ``name`` is unique within its axis and stable across runs (it is
    the diff key); ``group`` is the legacy ``benchmarks.run`` selector
    the cell migrated from (table1, fig4, kernel-bench, ...), kept so
    the old per-table entry points keep working.
    """

    axis: str
    name: str
    coords: Dict[str, object]
    run: Callable[[BenchContext], CellResult]
    group: str = ""


def check_cells(cells: List[Cell], axis: str) -> None:
    """Reject duplicate names / mixed axes before a run starts."""
    seen: Dict[str, Cell] = {}
    for c in cells:
        if c.axis != axis:
            raise ValueError(f"cell {c.name!r} declares axis {c.axis!r}, "
                             f"expected {axis!r}")
        if c.name in seen:
            raise ValueError(f"duplicate cell name {c.name!r} on axis "
                             f"{axis!r}")
        seen[c.name] = c
        missing = [k for k in COORD_KEYS if k not in c.coords]
        if missing:
            raise ValueError(f"cell {c.name!r} coords missing {missing}")
