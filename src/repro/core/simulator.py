"""Cycle-level simulator for DAE programs (paper §6 methodology).

Executes a :class:`repro.core.dae.DaeProgram` under a timing model and
returns cycle counts plus all stored results.  Two memory models are
provided, matching the paper's two evaluation setups:

  * :class:`FixedLatencyMemory` — the Verilator setup: every read and
    write takes a fixed ``latency`` (100 cycles in the paper), one
    request per cycle per port, bounded outstanding requests.
  * :class:`MomsMemory` — the Miss-Optimized Memory Subsystem + DRAMSim2
    setup (Table 3): request coalescing on cache lines, a small
    temporal-reuse cache, and a banked row-buffer DRAM model, with a cap
    on outstanding reads (64 in the paper).

The simulator is event driven (it skips idle cycles), so the multi-million
cycle baseline runs of Table 1 complete in well under a second.

Semantics enforced here (paper §5.1/§5.4):

  * loads on a channel complete **in issue order** (static AXI ID);
  * a ``Req`` blocks while ``capacity`` responses are already in flight
    or waiting — this is the buffer bound that makes sharing a port
    between channels deadlock-free;
  * stores become *observable* only when their write response returns;
    ``StoreWait`` models the end-of-accelerator state-edge merge;
  * if no process can make progress the simulator raises
    :class:`DeadlockError` (this reproduces the R-HLS-Stream mergesort
    deadlock of §6 when capacity rules are violated);
  * every request is answered exactly once and every stream entry is
    drained, else :class:`ConservationError` is raised at termination.

``Par`` bundles several effects into one issue slot — the dataflow
circuit equivalent of consuming the ``val`` and ``vec`` responses in the
same cycle in decoupled SPMV (paper Listing 2).

Multi-instance execution: the scheduler is an engine
(:class:`SharedMemoryEngine`) that runs **N concurrent program
instances against one shared memory system** — the contention regime
that motivates the paper's capacity bounding.  Each instance keeps its
own channel namespace, store results, and cycle count; memory ports are
either *private* to an instance or *shared*, in which case all
instances compete for the port's one-issue-per-cycle slot (round-robin
arbitration on ties) and for the memory model's outstanding-request
budget.  :func:`simulate` is the single-instance wrapper and is
bit-exact with the pre-engine scheduler.  An optional
:class:`repro.core.trace.Tracer` streams per-channel occupancy,
request-latency histograms, and port-utilization timelines.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.dae import (
    Channel,
    ConservationError,
    DaeProgram,
    Delay,
    Deq,
    Enq,
    Halt,
    LoadChannel,
    Process,
    Req,
    Resp,
    Store,
    StoreWait,
    StreamChannel,
)

__all__ = [
    "FixedLatencyMemory",
    "MomsMemory",
    "Par",
    "SimResult",
    "EngineInstance",
    "EngineResult",
    "SharedMemoryEngine",
    "DeadlockError",
    "simulate",
]

INF = float("inf")


class DeadlockError(RuntimeError):
    pass


@dataclasses.dataclass
class Par:
    """Execute several effects in a single issue slot (same cycle).

    Blocks until *all* sub-effects are ready; the value sent back into
    the generator is a tuple with one entry per sub-effect (``None`` for
    effects that produce no value).
    """

    effects: Sequence[Any]


@dataclasses.dataclass
class Fused:
    """A dataflow operator: consume ``first`` and *in the same cycle* run

    ``then(value)`` which may return a follow-up effect (Store/Enq/Req/
    Par/Fused) or ``None``.  This models combinational paths in a
    dataflow circuit — e.g. the copy loop's load-response feeding the
    store port at II=1, or mergesort's response feeding the comparison
    that selects the store value (paper Listing 3).

    Readiness is checked on ``first`` only; the follow-up must be
    non-blocking by construction (capacity freed by the consume in the
    same slot, as in Listing 4's request/enq after response/deq).
    """

    first: Any
    then: Any  # Callable[[Any], Optional[effect]]


# ---------------------------------------------------------------------------
# Memory models
# ---------------------------------------------------------------------------


class MemoryModel:
    """Interface: ``access(addr, t_issue) -> (t_complete, value)``."""

    def __init__(self, data: Any, max_outstanding: int = 64):
        self.data = data
        self.max_outstanding = max_outstanding
        self._inflight: List[float] = []  # completion-time heap (reads)
        self.reads = 0
        self.writes = 0

    def free_slot_at(self, t: float) -> float:
        """Earliest time >= t a new read may issue given the
        outstanding-request cap."""
        while self._inflight and self._inflight[0] <= t:
            heapq.heappop(self._inflight)
        if len(self._inflight) < self.max_outstanding:
            return t
        return self._inflight[0]

    def _commit(self, t_complete: float) -> None:
        heapq.heappush(self._inflight, t_complete)

    def read_value(self, addr: int) -> Any:
        return self.data[addr]

    def access(self, addr: int, t: float) -> Tuple[float, Any]:
        raise NotImplementedError

    def write_latency(self) -> float:
        raise NotImplementedError


class FixedLatencyMemory(MemoryModel):
    """Uniform fixed-latency memory (the paper's 100-cycle Verilator model)."""

    def __init__(self, data: Any, latency: int = 100, max_outstanding: int = 64):
        super().__init__(data, max_outstanding)
        self.latency = latency

    def access(self, addr: int, t: float) -> Tuple[float, Any]:
        self.reads += 1
        t_done = t + self.latency
        self._commit(t_done)
        return t_done, self.read_value(addr)

    def write_latency(self) -> float:
        return self.latency


class MomsMemory(MemoryModel):
    """Miss-optimized memory subsystem (Asiatici [2]) + row-buffer DRAM.

    * word addresses are grouped into ``line_words``-word cache lines;
    * a request to a line already in flight coalesces: it completes when
      the in-flight line lands (+1 cycle response serialization);
    * a small ``cache_kib`` FIFO cache of recently landed lines serves
      repeats at ``hit_latency``;
    * misses pay the DRAM model: per-bank open-row tracking, ``t_row_hit``
      vs ``t_row_miss``, plus bank busy time.
    """

    def __init__(
        self,
        data: Any,
        line_words: int = 16,
        cache_kib: int = 128,
        word_bytes: int = 4,
        hit_latency: int = 12,
        t_row_hit: int = 45,
        t_row_miss: int = 110,
        banks: int = 8,
        row_words: int = 256,
        max_outstanding: int = 64,
    ):
        super().__init__(data, max_outstanding)
        self.line_words = line_words
        self.hit_latency = hit_latency
        self.t_row_hit = t_row_hit
        self.t_row_miss = t_row_miss
        self.banks = banks
        self.row_words = row_words
        self.n_cache_lines = max(1, (cache_kib * 1024) // (line_words * word_bytes))
        self._inflight_lines: Dict[int, float] = {}
        self._cache: "deque[int]" = deque()
        self._cache_set: set = set()
        self._open_row: Dict[int, int] = {}
        self._bank_free: Dict[int, float] = {}
        self.stats = {"coalesced": 0, "hits": 0, "row_hits": 0, "row_misses": 0}

    def _dram_access(self, line: int, t: float) -> float:
        bank = line % self.banks
        row = (line * self.line_words) // self.row_words
        t_bank = max(t, self._bank_free.get(bank, 0.0))
        if self._open_row.get(bank) == row:
            dt = self.t_row_hit
            self.stats["row_hits"] += 1
        else:
            dt = self.t_row_miss
            self.stats["row_misses"] += 1
            self._open_row[bank] = row
        self._bank_free[bank] = t_bank + 4  # burst occupancy
        return t_bank + dt

    def _cache_insert(self, line: int) -> None:
        if line in self._cache_set:
            return
        self._cache.append(line)
        self._cache_set.add(line)
        while len(self._cache) > self.n_cache_lines:
            old = self._cache.popleft()
            self._cache_set.discard(old)

    def access(self, addr: int, t: float) -> Tuple[float, Any]:
        self.reads += 1
        line = addr // self.line_words
        tf = self._inflight_lines.get(line)
        if tf is not None and tf > t:
            self.stats["coalesced"] += 1
            return tf + 1, self.read_value(addr)
        if line in self._cache_set:
            self.stats["hits"] += 1
            return t + self.hit_latency, self.read_value(addr)
        t_done = self._dram_access(line, t)
        self._commit(t_done)
        self._inflight_lines[line] = t_done
        self._cache_insert(line)
        return t_done, self.read_value(addr)

    def write_latency(self) -> float:
        return self.t_row_miss


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimResult:
    cycles: int
    stores: Dict[str, Dict[int, Any]]
    counts: Dict[str, int]
    mem_reads: Dict[str, int]

    def stored_array(self, port: str, n: int) -> List[Any]:
        s = self.stores.get(port, {})
        return [s.get(i) for i in range(n)]


class _ChanState:
    __slots__ = ("fifo", "reqs", "resps", "enqs", "deqs")

    def __init__(self) -> None:
        self.fifo: "deque[Tuple[float, Any]]" = deque()  # (ready_time, value)
        self.reqs = 0
        self.resps = 0
        self.enqs = 0
        self.deqs = 0


class _Proc:
    __slots__ = ("proc", "time", "effect", "send", "done", "blocked_on")

    def __init__(self, proc: Process):
        self.proc = proc
        self.time = 0.0
        self.effect: Any = None
        self.send: Any = None
        self.done = False
        self.blocked_on: Optional[str] = None


@dataclasses.dataclass
class EngineInstance:
    """One tenant of the engine: a program plus its *private* memory
    ports.  Ports not listed in ``memories`` resolve to the engine's
    shared memory system — the instance competes with every other tenant
    for those ports' issue slots and outstanding-request budget."""

    name: str
    program: DaeProgram
    memories: Dict[str, MemoryModel] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class EngineResult:
    """Per-instance results plus the shared-run aggregates.

    ``cycles`` is the makespan (slowest instance); ``instances`` holds
    one :class:`SimResult` per tenant in submission order.  ``trace`` is
    the :class:`repro.core.trace.TraceSummary` when a tracer was
    attached, else ``None``.
    """

    cycles: int
    instances: List[SimResult]
    trace: Optional[Any] = None


class _Inst:
    """Engine-internal per-tenant state: its own channel namespace,
    store results, and store-completion tracking."""

    __slots__ = ("name", "index", "private", "procs", "chans",
                 "port_last_store", "stores", "port_reads")

    def __init__(self, name: str, index: int, program: DaeProgram,
                 private: Dict[str, MemoryModel]):
        self.name = name
        self.index = index
        self.private = private
        self.procs = [_Proc(p) for p in program.processes]
        self.chans: Dict[str, _ChanState] = {}
        self.port_last_store: Dict[str, float] = {}
        self.stores: Dict[str, Dict[int, Any]] = {}
        self.port_reads: Dict[str, int] = {}

    def chan(self, c: Channel) -> _ChanState:
        st = self.chans.get(c.name)
        if st is None:
            st = self.chans[c.name] = _ChanState()
        return st


class _Ctx:
    """Shared engine state: the shared memory system, per-physical-port
    issue serialization, and the (optional) tracer."""

    def __init__(self, memories: Dict[str, MemoryModel], trace: Any = None):
        self.memories = memories
        # keyed by (owner, port): owner "" for shared ports, else the
        # instance name — two tenants' private "out" ports must not
        # serialize against each other
        self.port_next_issue: Dict[Tuple[str, str], float] = {}
        self.trace = trace

    def mem(self, inst: _Inst, port: str) -> Tuple[MemoryModel, str]:
        """Resolve ``port`` for ``inst``: private first, then shared.
        Returns ``(memory, owner)`` with owner "" for shared ports."""
        m = inst.private.get(port)
        if m is not None:
            return m, inst.name
        m = self.memories.get(port)
        if m is None:
            raise KeyError(
                f"program references port {port!r} with no memory model bound"
            )
        return m, ""


def _port_label(owner: str, port: str) -> str:
    return f"{owner}/{port}" if owner else port


def _readiness(ctx: _Ctx, inst: _Inst, eff: Any, t: float
               ) -> Tuple[bool, float, str]:
    """Can ``eff`` execute at time t?  -> (ok, retry_time, reason)."""
    if isinstance(eff, (Delay, Halt, Store)):
        return True, t, ""
    if isinstance(eff, Req):
        c = eff.channel
        st = inst.chan(c)
        if len(st.fifo) >= c.capacity:
            # clears only when the consumer takes a response (unknown time);
            # if the front entry is still in flight, its landing time is a
            # usable lower bound for the global-time jump.
            front_ready = st.fifo[0][0] if st.fifo else INF
            retry = front_ready if front_ready > t else INF
            return False, retry, f"cap:{c.name}"
        mem, owner = ctx.mem(inst, c.port)
        t_issue = max(t, ctx.port_next_issue.get((owner, c.port), 0.0))
        slot = mem.free_slot_at(t_issue)
        if slot > t:
            return False, slot, f"mshr:{c.port}"
        return True, t, ""
    if isinstance(eff, Resp):
        st = inst.chan(eff.channel)
        if not st.fifo:
            return False, INF, f"resp:{eff.channel.name}"
        ready = st.fifo[0][0]
        if ready > t:
            return False, ready, f"resp-wait:{eff.channel.name}"
        return True, t, ""
    if isinstance(eff, Enq):
        st = inst.chan(eff.channel)
        if len(st.fifo) >= eff.channel.capacity:
            return False, INF, f"full:{eff.channel.name}"
        return True, t, ""
    if isinstance(eff, Deq):
        st = inst.chan(eff.channel)
        if not st.fifo:
            return False, INF, f"empty:{eff.channel.name}"
        ready = st.fifo[0][0]
        if ready > t:
            return False, ready, f"deq-wait:{eff.channel.name}"
        return True, t, ""
    if isinstance(eff, StoreWait):
        done_at = inst.port_last_store.get(eff.port, 0.0)
        if done_at > t:
            return False, done_at, f"storewait:{eff.port}"
        return True, t, ""
    if isinstance(eff, Par):
        retries: List[float] = []
        reasons: List[str] = []
        for sub in eff.effects:
            ok, retry, reason = _readiness(ctx, inst, sub, t)
            if not ok:
                retries.append(retry)
                reasons.append(reason)
        if reasons:
            finite = [r for r in retries if r is not INF]
            # conservative: re-check at the earliest time any blocker could
            # clear; unknown (INF) blockers are re-checked whenever another
            # process makes progress.
            return False, (min(finite) if finite else INF), "&".join(reasons)
        return True, t, ""
    if isinstance(eff, Fused):
        return _readiness(ctx, inst, eff.first, t)
    raise TypeError(f"unknown effect {eff!r}")


def _execute(ctx: _Ctx, inst: _Inst, eff: Any, t: float) -> Any:
    """Execute a ready effect at time t; returns the value to send."""
    if isinstance(eff, (Delay, Halt)):
        return None
    if isinstance(eff, Req):
        c = eff.channel
        st = inst.chan(c)
        mem, owner = ctx.mem(inst, c.port)
        key = (owner, c.port)
        t_issue = max(t, ctx.port_next_issue.get(key, 0.0))
        t_done, value = mem.access(eff.addr, t_issue)
        ctx.port_next_issue[key] = t_issue + 1.0
        st.fifo.append((t_done, value))
        st.reqs += 1
        inst.port_reads[c.port] = inst.port_reads.get(c.port, 0) + 1
        if ctx.trace is not None:
            ctx.trace.on_request(inst.name, c.name,
                                 _port_label(owner, c.port), t_issue, t_done)
            ctx.trace.on_occupancy(inst.name, c.name, len(st.fifo))
        return None
    if isinstance(eff, Resp):
        st = inst.chan(eff.channel)
        _, value = st.fifo.popleft()
        st.resps += 1
        if ctx.trace is not None:
            ctx.trace.on_occupancy(inst.name, eff.channel.name,
                                   len(st.fifo))
        return value
    if isinstance(eff, Enq):
        st = inst.chan(eff.channel)
        st.fifo.append((t + 1.0, eff.value))
        st.enqs += 1
        if ctx.trace is not None:
            ctx.trace.on_occupancy(inst.name, eff.channel.name,
                                   len(st.fifo))
        return None
    if isinstance(eff, Deq):
        st = inst.chan(eff.channel)
        _, value = st.fifo.popleft()
        st.deqs += 1
        if ctx.trace is not None:
            ctx.trace.on_occupancy(inst.name, eff.channel.name,
                                   len(st.fifo))
        return value
    if isinstance(eff, Store):
        port = eff.port
        mem, owner = ctx.mem(inst, port)
        mem.writes += 1
        key = (owner, port)
        t_issue = max(t, ctx.port_next_issue.get(key, 0.0))
        ctx.port_next_issue[key] = t_issue + 1.0
        t_done = t_issue + mem.write_latency()
        inst.port_last_store[port] = max(
            inst.port_last_store.get(port, 0.0), t_done)
        inst.stores.setdefault(port, {})[eff.addr] = eff.value
        try:
            mem.data[eff.addr] = eff.value
        except (TypeError, IndexError, KeyError):
            pass
        if ctx.trace is not None:
            ctx.trace.on_store(inst.name, _port_label(owner, port), t_issue)
        return None
    if isinstance(eff, StoreWait):
        return None
    if isinstance(eff, Par):
        return tuple(_execute(ctx, inst, sub, t) for sub in eff.effects)
    if isinstance(eff, Fused):
        value = _execute(ctx, inst, eff.first, t)
        follow = eff.then(value)
        if follow is not None:
            _execute(ctx, inst, follow, t)
        return value
    raise TypeError(f"unknown effect {eff!r}")


class SharedMemoryEngine:
    """Execute N concurrent DAE program instances against one shared
    memory system.

    * **Round-robin port arbitration** — live processes are scheduled in
      local-time order; among processes tied at the same time the
      starting instance rotates every scheduler pass, so no tenant can
      persistently win a shared port's issue slot.  With one instance
      the order degenerates to the legacy scheduler's, making
      :func:`simulate` bit-exact with the pre-engine implementation.
    * **Per-instance cycle accounting** — each tenant's cycle count is
      the completion time of its own processes and stores; the engine's
      ``cycles`` is the makespan.
    * **Shared outstanding-request budget** — a shared port's
      ``max_outstanding`` (the MOMS MSHR budget) is one pool all
      tenants draw from, which is exactly the §5.4 contention regime.

    Conservation (§5.1) is checked per instance at termination; a global
    scheduling fixpoint with no runnable process raises
    :class:`DeadlockError` naming every blocked process.
    """

    def __init__(self, instances: Sequence[EngineInstance],
                 shared_memories: Optional[Dict[str, MemoryModel]] = None,
                 *, tracer: Any = None, max_steps: int = 500_000_000):
        if not instances:
            raise ValueError("SharedMemoryEngine needs at least one instance")
        names = [i.name for i in instances]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate instance names: {names}")
        self.instances = list(instances)
        self.shared = dict(shared_memories or {})
        self.tracer = tracer
        self.max_steps = max_steps

    def run(self) -> EngineResult:
        insts = [_Inst(spec.name, i, spec.program, spec.memories)
                 for i, spec in enumerate(self.instances)]
        pairs = [(inst, p) for inst in insts for p in inst.procs]
        n_inst = len(insts)
        ctx = _Ctx(self.shared, self.tracer)

        steps = 0
        rotation = 0
        while True:
            steps += 1
            if steps > self.max_steps:
                raise RuntimeError("simulation step limit exceeded")

            for inst, p in pairs:
                if not p.done and p.effect is None:
                    try:
                        p.effect = p.proc.gen.send(p.send)
                        p.send = None
                    except StopIteration:
                        p.done = True
            live = [(inst, p) for inst, p in pairs if not p.done]
            if not live:
                break

            if n_inst > 1:
                rot = rotation
                order = sorted(live, key=lambda ip: (
                    ip[1].time, (ip[0].index - rot) % n_inst))
            else:
                order = sorted(live, key=lambda ip: ip[1].time)
            rotation += 1

            progressed = False
            best_retry = INF
            for inst, p in order:
                eff, t, ii = p.effect, p.time, p.proc.ii
                ok, retry, reason = _readiness(ctx, inst, eff, t)
                if not ok:
                    best_retry = min(best_retry, retry)
                    p.blocked_on = reason
                    continue
                p.send = _execute(ctx, inst, eff, t)
                if isinstance(eff, Delay):
                    p.time = t + max(eff.cycles, 0)
                else:
                    p.time = t + ii
                if isinstance(eff, Halt):
                    p.done = True
                p.effect = None
                p.blocked_on = None
                progressed = True

            if not progressed:
                if best_retry is INF:
                    if n_inst == 1:
                        blocked = {p.proc.name: p.blocked_on
                                   for _, p in live}
                        raise DeadlockError(
                            f"deadlock in program "
                            f"{self.instances[0].program.name!r}: {blocked}")
                    blocked = {f"{inst.name}:{p.proc.name}": p.blocked_on
                               for inst, p in live}
                    raise DeadlockError(
                        f"deadlock across {n_inst} instances: {blocked}")
                for inst, p in pairs:
                    if not p.done and p.time < best_retry:
                        p.time = best_retry

        results = [self._finalize(inst) for inst in insts]
        makespan = max([r.cycles for r in results] + [0])
        trace = self.tracer.summary() if self.tracer is not None else None
        return EngineResult(cycles=makespan, instances=results, trace=trace)

    def _finalize(self, inst: _Inst) -> SimResult:
        counts: Dict[str, int] = {}
        for name, st in inst.chans.items():
            if st.fifo:
                raise ConservationError(
                    f"channel {name!r} finished with {len(st.fifo)} "
                    f"undrained entries"
                )
            if st.reqs != st.resps:
                raise ConservationError(
                    f"channel {name!r}: {st.reqs} requests but "
                    f"{st.resps} responses"
                )
            if st.enqs != st.deqs:
                raise ConservationError(
                    f"channel {name!r}: {st.enqs} enqs but {st.deqs} deqs"
                )
            counts[name] = st.reqs + st.enqs

        t_end = max(
            [p.time for p in inst.procs]
            + list(inst.port_last_store.values()) + [0.0]
        )
        # per-instance attribution: only the reads THIS tenant issued —
        # a shared model's global .reads counter would credit every
        # tenant with the whole port's traffic
        visible = dict(self.shared)
        visible.update(inst.private)
        return SimResult(
            cycles=int(round(t_end)),
            stores=inst.stores,
            counts=counts,
            mem_reads={port: inst.port_reads.get(port, 0)
                       for port in visible},
        )


def simulate(
    program: DaeProgram,
    memories: Dict[str, MemoryModel],
    max_steps: int = 500_000_000,
    tracer: Any = None,
) -> SimResult:
    """Run ``program`` against ``memories`` (one entry per port name).

    Single-instance wrapper over :class:`SharedMemoryEngine`; all ports
    are bound as shared (with one tenant there is nobody to share with,
    so the timing is identical to the legacy single-program scheduler).
    """
    engine = SharedMemoryEngine(
        [EngineInstance("", program)], memories,
        tracer=tracer, max_steps=max_steps)
    return engine.run().instances[0]
