"""Parallel pointer chasing (paper §4.2, Listings 4/5) on TPU.

Hardware adaptation (DESIGN.md §2/§8): an FPGA follows one pointer per
chain per memory response; a TPU fetches 512-byte DMA granules.  Two
consequences drive the design:

* **binsearch** becomes a *block* search: every probe fetches a whole
  128-wide block of the sorted table (via the decoupled gather kernel),
  which resolves log2(128) = 7 levels of the search in one response.
  The chase loop is the lock-step CHUNK-wide variant (Listing 5): all B
  keys advance one level per round, with the gather's scalar-prefetch
  stream as the decoupled request channel.

* **hashtable** keeps the chain-walk structure, but walks B chains in
  lock-step with masking (a resolved chain keeps re-requesting its tail,
  exactly like the paper's fixed-length variant keeps issuing redundant
  loads rather than adding conditional-issue circuitry).

Both ops are compositions: jax.lax control flow (the Execute loop) over
the dae_gather Pallas kernel (the decoupled Access engine).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import (cdiv, resolve_interpret, round_up,
                                  tuned_knobs)
from repro.kernels.dae_gather.ops import dae_gather


@functools.partial(jax.jit, static_argnames=("block", "interpret", "method"))
def _searchsorted_impl(table, keys, *, block, interpret, method):
    n = table.shape[0]
    if method == "ref":
        return jnp.searchsorted(table, keys, side="right").astype(jnp.int32)

    big = (jnp.inf if jnp.issubdtype(table.dtype, jnp.floating)
           else jnp.iinfo(table.dtype).max)
    np_ = round_up(max(n, 1), block)
    tp = jnp.concatenate([table, jnp.full((np_ - n,), big, table.dtype)])
    tiles = tp.reshape(-1, block)          # (NB, block)
    n_blocks = tiles.shape[0]

    # level-0 summary: first element of each block (table is sorted)
    summary = tiles[:, 0]                   # (NB,)
    # block id per key: last block whose min <= key  (searchsorted on the
    # small summary is VMEM-resident compute — the top of the B-tree)
    blk = jnp.clip(jnp.searchsorted(summary, keys, side="right") - 1,
                   0, n_blocks - 1).astype(jnp.int32)

    # decoupled probe: fetch each key's block (the irregular access)
    rows = dae_gather(tiles, blk, method="pipelined", interpret=interpret)
    within = jnp.sum(rows <= keys[:, None], axis=1).astype(jnp.int32)
    idx = blk * block + within
    return jnp.minimum(idx, n).astype(jnp.int32)


def batched_searchsorted(table: jax.Array, keys: jax.Array, *,
                         block: Optional[int] = None, method: str = "pallas",
                         interpret: Optional[bool] = None) -> jax.Array:
    """'right' insertion points of ``keys`` in sorted ``table`` via
    decoupled block probes.  ``block=None`` resolves via the tune cache
    (falling back to the 128-lane DMA granule)."""
    interp = resolve_interpret(interpret)
    if block is None:
        block = tuned_knobs("batched_searchsorted",
                            (table.shape[0], keys.shape[0]), table.dtype,
                            interp, block=(None, 128))["block"]
    return _searchsorted_impl(table, keys, block=block, interpret=interp,
                              method=method)


@functools.partial(jax.jit, static_argnames=("max_steps", "interpret", "method"))
def _hash_lookup_impl(entry_keys, entry_vals, entry_next, heads, keys, *,
                      max_steps, interpret, method):
    from repro.kernels.dae_chase.ref import hash_lookup_ref
    if method == "ref":
        return hash_lookup_ref(entry_keys, entry_vals, entry_next, heads,
                               keys, max_steps)

    n = entry_keys.shape[0]
    # pack (key, val, next) into rows so one decoupled gather fetches a
    # full entry; lane padding inside dae_gather keeps it DMA-aligned
    packed = jnp.stack([entry_keys.astype(jnp.int32),
                        entry_vals.astype(jnp.int32),
                        entry_next.astype(jnp.int32)], axis=1)  # (N, 3)

    b = heads.shape[0]

    def step(state, _):
        idx, found, val = state
        safe = jnp.clip(idx, 0, n - 1)
        ent = dae_gather(packed, safe, method="pipelined",
                         interpret=interpret)           # (B, 3)
        k, v, nxt = ent[:, 0], ent[:, 1], ent[:, 2]
        alive = (idx >= 0) & ~found
        hit = alive & (k == keys)
        val = jnp.where(hit, v, val)
        found = found | hit
        idx = jnp.where(alive & ~hit, nxt, idx)
        return (idx, found, val), None

    init = (heads.astype(jnp.int32), jnp.zeros(b, bool),
            jnp.full(b, -1, jnp.int32))
    (idx, found, val), _ = jax.lax.scan(step, init, None, length=max_steps)
    return jnp.where(found, val, -1)


def hash_lookup(entry_keys: jax.Array, entry_vals: jax.Array,
                entry_next: jax.Array, heads: jax.Array, keys: jax.Array, *,
                max_steps: int = 16, method: str = "pallas",
                interpret: Optional[bool] = None) -> jax.Array:
    """Lock-step parallel chain walk over a separate-chaining hash table."""
    return _hash_lookup_impl(entry_keys, entry_vals, entry_next, heads, keys,
                             max_steps=max_steps,
                             interpret=resolve_interpret(interpret),
                             method=method)
