"""Mesh-sharded decoupled serving (runtime/mesh_serve.py).

Fast tier: single-device co-located placement must be *bit-identical*
to PagedServeLoop, pinned per family (GQA, MoE, MLA paged; recurrent
falls back contiguously), plus mesh-construction error paths.

Slow tier: 8 forced host devices in subprocesses (the
tests/test_distributed.py pattern) — disaggregated prefill/decode on
disjoint submeshes stays output-identical, including under page-pool
pressure with preemption and teacher-forced resume."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.channels import MeshChannel
from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh, make_serve_meshes
from repro.models.registry import build_model
from repro.runtime.mesh_serve import ShardedPagedServeLoop
from repro.runtime.serve_loop import PagedServeLoop, Request

ROOT = Path(__file__).resolve().parents[1]

# one representative per attention family (matches the serve bench's
# PARITY_ARCHS): GQA, MoE+GQA, MLA, and a recurrent fallback
FAMILIES = ("qwen3-4b", "granite-moe-3b-a800m", "minicpm3-4b",
            "rwkv6-1.6b")

_STATS = ("prefill_steps", "decode_steps", "prefill_tokens",
          "decode_tokens", "admitted", "page_allocs", "cow_copies",
          "preemptions", "prefix_hits", "migrations")


def _requests(vocab, sizes=(12, 3, 25, 7), max_new=5, seed=7):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, vocab, size=n),
                    max_new=max_new)
            for i, n in enumerate(sizes)]


@pytest.mark.parametrize("arch", FAMILIES)
def test_mesh1_bit_parity(arch):
    import jax
    cfg = get_config(arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    kw = dict(batch_slots=3, s_max=40, chunk=16, page=8)
    base = PagedServeLoop(cfg, bundle, params, **kw)
    r0 = base.run(_requests(cfg.vocab))
    sharded = ShardedPagedServeLoop(cfg, bundle, params,
                                    meshes=make_serve_meshes(1), **kw)
    r1 = sharded.run(_requests(cfg.vocab))
    assert r0 == r1
    for k in _STATS:
        assert getattr(base.stats, k) == getattr(sharded.stats, k), k
    assert isinstance(sharded.handoff, MeshChannel)
    assert sharded.handoff.span == 1


def test_make_debug_mesh_actionable_error():
    # single-device fast tier: asking for 8 must NOT die inside
    # np.reshape — it names the deficit and the fix
    with pytest.raises(RuntimeError) as e:
        make_debug_mesh((2, 4), ("data", "model"))
    msg = str(e.value)
    assert "need 8 devices" in msg and "have 1" in msg
    assert "xla_force_host_platform_device_count=8" in msg


def test_make_serve_meshes_validation():
    meshes = make_serve_meshes(1)
    assert not meshes.disaggregated
    assert meshes.prefill is meshes.decode is meshes.union
    with pytest.raises(ValueError):
        make_serve_meshes(0)
    with pytest.raises(RuntimeError) as e:
        make_serve_meshes(8)       # only one CPU device visible here
    assert "need 8 devices" in str(e.value)
    with pytest.raises(ValueError):
        make_serve_meshes(1, disaggregate=True)   # cannot split one device


# ---------------------------------------------------------------------------
# 8-device subprocesses
# ---------------------------------------------------------------------------


def _run(snippet: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(snippet)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
@pytest.mark.parametrize("arch", ("qwen3-4b", "granite-moe-3b-a800m",
                                  "minicpm3-4b"))
def test_disaggregated_output_parity_8dev(arch):
    out = _run(f"""
        import jax, numpy as np
        assert jax.device_count() == 8
        from repro.configs import get_config
        from repro.models.registry import build_model
        from repro.launch.mesh import make_serve_meshes
        from repro.runtime.serve_loop import PagedServeLoop, Request
        from repro.runtime.mesh_serve import ShardedPagedServeLoop

        cfg = get_config({arch!r}, smoke=True)
        bundle = build_model(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        def reqs():
            rng = np.random.default_rng(7)
            return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=n),
                            max_new=6)
                    for i, n in enumerate((12, 3, 25, 7, 1, 18))]
        base = PagedServeLoop(cfg, bundle, params, batch_slots=8, s_max=40,
                              chunk=16, page=8, prefix_reuse=False)
        r0 = base.run(reqs())
        meshes = make_serve_meshes(8)
        assert meshes.disaggregated
        sh = ShardedPagedServeLoop(cfg, bundle, params, batch_slots=8,
                                   s_max=40, meshes=meshes, chunk=16, page=8)
        r1 = sh.run(reqs())
        assert r0 == r1
        assert sh.stats.migrations == 6      # one per completed prefill
        print("PARITY OK")
    """)
    assert "PARITY OK" in out


@pytest.mark.slow
def test_disaggregated_preemption_resume_8dev():
    out = _run("""
        import jax, numpy as np
        from repro.configs import get_config
        from repro.models.registry import build_model
        from repro.launch.mesh import make_serve_meshes
        from repro.runtime.serve_loop import PagedServeLoop, Request
        from repro.runtime.mesh_serve import ShardedPagedServeLoop

        cfg = get_config("qwen3-4b", smoke=True)
        bundle = build_model(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        def reqs():
            rng = np.random.default_rng(3)
            return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=n),
                            max_new=8)
                    for i, n in enumerate((30, 28, 26, 24, 22, 20))]
        # n_pages=13: the decode pool holds barely over two horizons, so
        # migrations fail and slots self-preempt + resume teacher-forced
        base = PagedServeLoop(cfg, bundle, params, batch_slots=4, s_max=40,
                              chunk=16, page=8, n_pages=13,
                              prefix_reuse=False)
        r0 = base.run(reqs())
        sh = ShardedPagedServeLoop(cfg, bundle, params, batch_slots=4,
                                   s_max=40, meshes=make_serve_meshes(8),
                                   chunk=16, page=8, n_pages=13)
        r1 = sh.run(reqs())
        assert r0 == r1
        assert sh.stats.preemptions > 0
        print("RESUME OK", sh.stats.preemptions, sh.stats.migrations)
    """)
    assert "RESUME OK" in out


@pytest.mark.slow
def test_colocated_mesh8_output_parity():
    # non-disaggregated 8-way mesh: one mesh runs both engines, the pool
    # page dim shards 8 ways, channels ride the data axis end to end
    out = _run("""
        import jax, numpy as np
        from repro.configs import get_config
        from repro.models.registry import build_model
        from repro.launch.mesh import make_serve_meshes
        from repro.runtime.serve_loop import PagedServeLoop, Request
        from repro.runtime.mesh_serve import ShardedPagedServeLoop

        cfg = get_config("qwen3-4b", smoke=True)
        bundle = build_model(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        def reqs():
            rng = np.random.default_rng(11)
            return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=n),
                            max_new=6)
                    for i, n in enumerate((12, 3, 25, 7))]
        base = PagedServeLoop(cfg, bundle, params, batch_slots=4, s_max=48,
                              chunk=16, page=8)
        r0 = base.run(reqs())
        meshes = make_serve_meshes(8, disaggregate=False)
        sh = ShardedPagedServeLoop(cfg, bundle, params, batch_slots=4,
                                   s_max=48, meshes=meshes, chunk=16, page=8)
        r1 = sh.run(reqs())
        assert r0 == r1
        assert sh.handoff.span == 8          # ring spans the full axis
        print("COLOCATED OK")
    """)
    assert "COLOCATED OK" in out
