"""Public decoupled-access-execute ops — the paper's technique as a
composable JAX layer.

Every op has three dispatch modes:
  * ``pallas``   — the TPU kernel (compiled pl.pallas_call);
  * ``ref``      — the pure-jnp oracle (used by the dry-run so the
                   roofline reflects XLA's own gather/scatter lowering);
  * interpret    — kernels executed in interpret mode (CPU validation).

The RIF (requests-in-flight) knob of the paper maps to the buffer-ring
depth.  Knobs left at ``None`` resolve in dispatch order (see
``repro.kernels.common.tuned_knobs``):

  1. an explicit caller value always wins;
  2. else the ``repro.tune`` config cache is consulted for a winner
     tuned at this (op, shape, dtype, backend) key;
  3. else ``repro.core.pipeline.plan_rif`` sizes the ring analytically
     from the latency-bandwidth product.

The kernels themselves share one emission layer,
:mod:`repro.kernels.ring` (re-exported here): ``RingChannel.request`` /
``.response`` are the TPU forms of ``decouple_request`` /
``decouple_response`` from :mod:`repro.core.dae`, so the simulator IR
and the TPU emitter speak the same §3 vocabulary.

Workloads with no hand-written kernel at all reach the same emitter
through :mod:`repro.compile`: a rebuildable :class:`DaeProgram`
(generator factories — ``validate_channels`` and the compiler's
elaborate pass pump *fresh* instances, so neither consumes the
program) lowers onto the ring scaffolds directly.  See
``docs/compiler.md``.
"""

from __future__ import annotations

from repro.core.pipeline import plan_rif, RifPlan
from repro.kernels.ring import (RingChannel, access_execute, ring_step,
                                ring_scratch_shapes)
from repro.kernels.dae_gather.ops import dae_gather as decoupled_gather
from repro.kernels.dae_spmv.ops import dae_spmv as decoupled_spmv
from repro.kernels.dae_spmv.ops import csr_to_bsr
from repro.kernels.dae_merge.ops import merge_sorted as decoupled_merge
from repro.kernels.dae_merge.ops import merge_sort as decoupled_merge_sort
from repro.kernels.dae_chase.ops import (
    batched_searchsorted as decoupled_searchsorted,
    hash_lookup as decoupled_hash_lookup,
)
from repro.kernels.flash_attention.ops import (
    flash_attention,
    flash_decode,
    flash_decode_paged,
)
from repro.kernels.grouped_matmul.ops import grouped_matmul

__all__ = [
    "plan_rif",
    "RifPlan",
    "RingChannel",
    "access_execute",
    "ring_step",
    "ring_scratch_shapes",
    "decoupled_gather",
    "decoupled_spmv",
    "csr_to_bsr",
    "decoupled_merge",
    "decoupled_merge_sort",
    "decoupled_searchsorted",
    "decoupled_hash_lookup",
    "flash_attention",
    "flash_decode",
    "flash_decode_paged",
    "grouped_matmul",
]
