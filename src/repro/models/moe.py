"""Mixture-of-experts layer with decoupled dispatch (paper §4.1 analogue).

The token→expert map after top-k routing is CSR-shaped: ``group offsets``
play the role of SPMV's ``rows`` array, and the expert GEMM stream is the
decoupled access stream.  Two dispatch paths:

* ``xla`` (default; used by the sharded dry-run): sort-based
  capacity-bounded dispatch — argsort tokens by expert, place the first
  C per expert into an (E, C) table, batched-einsum all experts, and
  scatter-add back with gate weights.  Shards cleanly with experts on
  the model axis (EP).

* ``pallas``: tokens sorted by expert and padded to block multiples,
  then the grouped_matmul kernel streams expert weight blocks via the
  scalar-prefetched block→expert map (the decoupled load of weights).

Both compute identical math up to capacity drops (the pallas path drops
nothing; tests compare against a no-drop oracle with ample capacity).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init
from repro.models.mlp import mlp_init, mlp_apply
from repro.kernels.grouped_matmul.ops import grouped_matmul
from repro.kernels.common import round_up


def moe_init(cfg: ModelConfig, key) -> Dict[str, Any]:
    # expert weights may be padded so the expert dim divides the model
    # axis (EP); the router only ever routes to the real n_experts.
    e, d, f = cfg.n_experts_padded, cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, cfg.n_experts, cfg.pdtype),
        "w_gate": _expert_init(ks[1], e, d, f, cfg.pdtype),
        "w_up": _expert_init(ks[2], e, d, f, cfg.pdtype),
        "w_down": _expert_init(ks[3], e, f, d, cfg.pdtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(cfg, ks[4],
                               d_ff=(cfg.moe_d_ff or cfg.d_ff) * cfg.n_shared_experts)
    return p


def _expert_init(key, e, d_in, d_out, dtype):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (e, d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def _route(cfg: ModelConfig, p, x2d):
    """x2d (T, D) -> gates (T, K), experts (T, K)."""
    logits = (x2d @ p["router"].astype(cfg.adtype)).astype(jnp.float32)
    gates, experts = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, experts.astype(jnp.int32)


def moe_apply(cfg: ModelConfig, p: Dict[str, Any], x: jnp.ndarray,
              *, capacity_factor: float = 0.0) -> jnp.ndarray:
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    gates, experts = _route(cfg, p, x2d)

    if cfg.kernel_mode == "pallas":
        y2d = _dispatch_pallas(cfg, p, x2d, gates, experts)
    else:
        y2d = _dispatch_xla(cfg, p, x2d, gates, experts,
                            capacity_factor or cfg.capacity_factor)

    if cfg.n_shared_experts:
        y2d = y2d + mlp_apply(cfg, p["shared"], x2d)
    return y2d.reshape(b, s, d)


# -- xla sort-based capacity dispatch ----------------------------------------


def _dispatch_xla(cfg, p, x2d, gates, experts, capacity_factor):
    t, d = x2d.shape
    e, k = cfg.n_experts_padded, cfg.top_k
    c = int(max(1, math.ceil(t * k * capacity_factor / cfg.n_experts)))
    dt = cfg.adtype

    flat_e = experts.reshape(-1)                       # (T*K,)
    flat_g = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(flat_e, stable=True)
    se, sg, stok = flat_e[order], flat_g[order], flat_tok[order]
    # position within expert group
    starts = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype), side="left")
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    keep = pos < c

    # (E, C) token table; dropped/empty slots point at the zero pad row
    table = jnp.full((e, c), t, jnp.int32)
    table = table.at[se, jnp.where(keep, pos, 0)].set(
        jnp.where(keep, stok, t), mode="drop")
    gtable = jnp.zeros((e, c), jnp.float32)
    gtable = gtable.at[se, jnp.where(keep, pos, 0)].set(
        jnp.where(keep, sg, 0.0), mode="drop")

    x_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)])
    xe = jnp.take(x_pad, table, axis=0)                # (E, C, D)

    wg, wu, wd = (p["w_gate"].astype(dt), p["w_up"].astype(dt),
                  p["w_down"].astype(dt))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg))
    h = h * jnp.einsum("ecd,edf->ecf", xe, wu)
    ye = jnp.einsum("ecf,efd->ecd", h, wd)             # (E, C, D)

    y = jnp.zeros((t + 1, d), jnp.float32)
    y = y.at[table.reshape(-1)].add(
        (ye * gtable[..., None]).reshape(-1, d).astype(jnp.float32))
    return y[:t].astype(x2d.dtype)


# -- pallas grouped-matmul dispatch -------------------------------------------


def _dispatch_pallas(cfg, p, x2d, gates, experts, bt: int = 128):
    t, d = x2d.shape
    e, k = cfg.n_experts_padded, cfg.top_k
    dt = cfg.adtype

    flat_e = experts.reshape(-1)
    flat_g = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(flat_e, stable=True)
    se, sg, stok = flat_e[order], flat_g[order], flat_tok[order]

    # pad each expert group to a multiple of bt: compute per-token slot in a
    # block-aligned layout
    counts = jnp.bincount(se, length=e)
    padded = ((counts + bt - 1) // bt) * bt
    block_starts = jnp.concatenate([jnp.zeros(1, padded.dtype),
                                    jnp.cumsum(padded)])[:-1]
    starts = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype), side="left")
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    slot = (block_starts[se] + pos).astype(jnp.int32)

    from repro.kernels.common import round_up as _ru
    tp = _ru(t * k, bt) + e * bt  # upper bound on padded length (static)
    xs = jnp.zeros((tp, d), x2d.dtype).at[slot].set(jnp.take(x2d, stok, 0))
    # block -> expert map
    nblocks = tp // bt
    block_first = jnp.arange(nblocks, dtype=jnp.int32) * bt
    block_expert = jnp.sum(block_first[:, None] >=
                           (block_starts + padded)[None, :], axis=1
                           ).astype(jnp.int32)
    block_expert = jnp.minimum(block_expert, e - 1)

    wg, wu, wd = (p["w_gate"].astype(dt), p["w_up"].astype(dt),
                  p["w_down"].astype(dt))
    h = jax.nn.silu(grouped_matmul(xs, wg, block_expert, bt=bt))
    h = h * grouped_matmul(xs, wu, block_expert, bt=bt)
    ys = grouped_matmul(h, wd, block_expert, bt=bt)    # (TP, D)

    contrib = jnp.take(ys, slot, axis=0).astype(jnp.float32) * sg[:, None]
    y = jnp.zeros((t, d), jnp.float32).at[stok].add(contrib)
    return y.astype(x2d.dtype)


def moe_aux_loss(cfg: ModelConfig, p, x: jnp.ndarray) -> jnp.ndarray:
    """Load-balancing auxiliary loss (Switch-style)."""
    b, s, d = x.shape
    x2d = x.reshape(-1, d)
    logits = (x2d @ p["router"].astype(cfg.adtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    _, experts = jax.lax.top_k(probs, cfg.top_k)
    me = probs.mean(0)
    ce = jnp.zeros(cfg.n_experts).at[experts.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    return cfg.n_experts * jnp.sum(me * ce)
