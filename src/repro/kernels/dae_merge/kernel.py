"""Decoupled merge of sorted runs (paper Listing 3, TPU-native form).

Hardware adaptation (docs/architecture.md §"TPU adaptation"): the FPGA
merge consumes one element per cycle with a data-dependent two-pointer
walk.  A TPU has no profitable serial path — instead we use the
*merge-path* decomposition:

  1. ops.py computes, for every output tile of size T, the (ia, ib)
     split such that the tile's output equals the first T elements of
     merge(a[ia:ia+T], b[ib:ib+T]).  These splits are the *Access*
     stream: they are computed *ahead* of the merge (a vectorized
     binary search over the diagonal), exactly like the paper's
     ``decouple_request`` loops run ahead over both runs.

  2. The kernel scalar-prefetches the split offsets; two
     :class:`~repro.kernels.ring.RingChannel`\\ s DMA the T-windows from
     HBM at *element* granularity (async copies with dynamic starts —
     irregular, decoupled loads) ``rif`` tiles ahead of the grid step
     that consumes them (:func:`~repro.kernels.ring.ring_step` spans the
     ring across grid steps), then each step merges its two windows with
     a branch-free bitonic merge network on the VPU and writes one dense
     output tile.

The request/response pairing is structural (the ring emitter issues one
request and one response per tile per run), and window padding with
+inf sentinels guarantees every tile consumes the exact number of
elements the splits promise (paper §5.1 correctness).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ring import (RingChannel, clamp_rif,
                                ring_scratch_shapes, ring_step)


def bitonic_merge_first_half(v: jnp.ndarray) -> jnp.ndarray:
    """Given v = concat(sorted_a, reversed(sorted_b)) of length 2T (a
    bitonic sequence), return the sorted first half (the T smallest)."""
    n = v.shape[0]
    d = n // 2
    while d >= 1:
        w = v.reshape(-1, 2, d)
        lo = jnp.minimum(w[:, 0, :], w[:, 1, :])
        hi = jnp.maximum(w[:, 0, :], w[:, 1, :])
        v = jnp.stack([lo, hi], axis=1).reshape(n)
        d //= 2
    return v[: n // 2]


def _merge_kernel(sa_ref, sb_ref, a_hbm, b_hbm, out_ref, wa, sem_a, wb, sem_b,
                  *, tile: int, n_tiles: int, rif: int):
    t = pl.program_id(0)
    ring_a = RingChannel(wa, sem_a, rif,
                         src=lambda k: a_hbm.at[pl.ds(sa_ref[k], tile)])
    ring_b = RingChannel(wb, sem_b, rif,
                         src=lambda k: b_hbm.at[pl.ds(sb_ref[k], tile)])

    def execute(win_a, win_b):
        v = jnp.concatenate([win_a, win_b[::-1]])
        out_ref[...] = bitonic_merge_first_half(v)

    ring_step([ring_a, ring_b], t, n_tiles, execute)


def merge_tiles(a_pad: jax.Array, b_pad: jax.Array, starts_a: jax.Array,
                starts_b: jax.Array, n_out: int, *, tile: int, rif: int = 2,
                interpret: bool = True) -> jax.Array:
    """a_pad/b_pad are the runs padded with +inf sentinels so any
    (start, start+tile) window is in bounds; starts_* (n_tiles,) are the
    merge-path splits; output is n_out = n_tiles * tile elements.
    ``rif`` window pairs stream ahead of the consuming grid step."""
    n_tiles = starts_a.shape[0]
    rif = clamp_rif(rif, n_tiles)
    kernel = functools.partial(_merge_kernel, tile=tile, n_tiles=n_tiles,
                               rif=rif)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(n_tiles,),
            in_specs=[
                pl.BlockSpec(memory_space=pl.ANY),
                pl.BlockSpec(memory_space=pl.ANY),
            ],
            out_specs=pl.BlockSpec((tile,), lambda t, sa, sb: (t,)),
            scratch_shapes=[
                *ring_scratch_shapes(rif, (tile,), a_pad.dtype),
                *ring_scratch_shapes(rif, (tile,), b_pad.dtype),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n_out,), a_pad.dtype),
        interpret=interpret,
    )(starts_a, starts_b, a_pad, b_pad)
