"""Local transport: an in-process deque.

This is the serve loop's original ``Channel`` moved behind the shared
protocol — semantics (including the traced post-event depths) are
bit-identical to the pre-refactor class, which the serve goldens and
parity tests pin.
"""

from __future__ import annotations

from collections import deque
from typing import Any

from repro.channels.base import ChannelBase


class LocalChannel(ChannelBase):
    """Bounded FIFO between engines of one process."""

    __slots__ = ("_q",)

    transport = "local"

    def __init__(self, name, capacity=None, tracer=None, instance="serve"):
        super().__init__(name, capacity, tracer, instance)
        self._q: deque = deque()

    def push(self, item: Any) -> bool:
        if self.capacity is not None and len(self._q) >= self.capacity:
            return False
        self._q.append(item)
        self._trace(len(self._q))
        return True

    def pop(self) -> Any:
        item = self._q.popleft()
        self._trace(len(self._q))
        return item

    def peek(self) -> Any:
        return self._q[0]

    def __len__(self) -> int:
        return len(self._q)
