"""Production mesh definitions.

A FUNCTION (not module-level constant) so importing this module never
touches jax device state.  The dry-run forces 512 host devices via
XLA_FLAGS *before* any jax import (see dryrun.py).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax")
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev_array, axes)


def make_debug_mesh(shape=(2, 4), axes=("data", "model")) -> Mesh:
    """Small mesh for unit tests (e.g. 8 forced host devices)."""
    n = int(np.prod(shape))
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev_array, axes)
