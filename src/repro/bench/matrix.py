"""Run a whole benchmark axis: every registered cell, in order.

The runner is deliberately boring — no cell selection, no skips, no
retries.  The SPEC discipline (SNIPPETS.md §1) is that a suite either
runs completely or not at all: cherry-picking cells is how a benchmark
file silently stops covering what its baseline pins.  Anything a cell
needs to vary (problem scale, seeds) comes through
:class:`~repro.bench.registry.BenchContext`, so the report's metadata
fully determines the run.

An unexpected exception from a cell aborts the axis: benchmarks are
load-bearing tests here, and a half-written BENCH file that a later
diff would read as "cells removed" is worse than a loud failure.
Expected deadlocks are *results* (``status="deadlock"``), not
exceptions — cells that sweep into the §5.3 regime catch
:class:`~repro.core.simulator.DeadlockError` themselves.
"""

from __future__ import annotations

from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

from repro.bench.registry import (BenchContext, Cell, CellResult,
                                  check_cells)
from repro.bench.report import (bench_path, build_report, cell_csv,
                                write_report)

__all__ = ["run_axis", "run_cells"]


def run_cells(cells: List[Cell], ctx: BenchContext,
              csv_print: Optional[Callable[[str], None]] = None,
              ) -> List[Tuple[Cell, CellResult]]:
    """Execute every cell, streaming legacy CSV rows as results land."""
    results: List[Tuple[Cell, CellResult]] = []
    for cell in cells:
        result = cell.run(ctx)
        if not isinstance(result, CellResult):
            raise TypeError(f"cell {cell.name!r} returned "
                            f"{type(result).__name__}, expected CellResult")
        results.append((cell, result))
        if csv_print is not None:
            csv_print(cell_csv(cell, result))
    return results


def run_axis(axis: str, cells: List[Cell], ctx: BenchContext, *,
             out_dir: Path,
             csv_print: Optional[Callable[[str], None]] = None) -> Dict:
    """Run one axis end-to-end and write its ``BENCH_<axis>.json``.

    Returns the (schema-validated) report dict; the file lands at
    ``out_dir/BENCH_<axis>.json``.
    """
    check_cells(cells, axis)
    results = run_cells(cells, ctx, csv_print)
    report = build_report(axis, results, smoke=ctx.smoke, seed=ctx.seed)
    path = write_report(report, bench_path(axis, out_dir))
    if csv_print is not None:
        csv_print(f"matrix/{axis}/bench_json,0,path={path.name};"
                  f"cells={len(cells)}")
    return report
