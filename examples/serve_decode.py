"""Serving driver: decoupled Access/Execute continuous batching.

Run: PYTHONPATH=src python examples/serve_decode.py --requests 6 --slots 2

``--legacy`` runs the coupled pre-rewrite loop instead (one prompt
token per full-batch step) for an on-machine comparison; ``--paged``
serves from the paged KV pool (page allocator + prefix reuse) and
prints its page stats; see docs/serving.md and
benchmarks/serve_bench.py.
"""

import argparse
import time

import jax
import numpy as np

from repro.bench import percentile
from repro.configs import get_config
from repro.core.trace import Tracer
from repro.models.registry import build_model
from repro.runtime.serve_loop import (LegacyServeLoop, PagedServeLoop,
                                      Request, ServeLoop)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill tokens per Access-engine step")
    ap.add_argument("--legacy", action="store_true",
                    help="run the coupled legacy loop instead")
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged KV pool (PagedServeLoop)")
    ns = ap.parse_args()

    cfg = get_config(ns.arch, smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=4),
                    max_new=ns.max_new)
            for i in range(ns.requests)]
    t0 = time.time()
    if ns.legacy:
        loop = LegacyServeLoop(cfg, m, params, batch_slots=ns.slots,
                               s_max=128)
        results = loop.run(reqs)
    else:
        tracer = Tracer()
        cls = PagedServeLoop if ns.paged else ServeLoop
        loop = cls(cfg, m, params, batch_slots=ns.slots, s_max=128,
                   chunk=ns.chunk, tracer=tracer)
        results = loop.run(reqs)
    dt = time.time() - t0
    total_toks = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {total_toks} tokens "
          f"in {dt:.1f}s on {ns.slots} slots")
    for rid in sorted(results):
        print(f"  req {rid}: {results[rid]}")
    if not ns.legacy:
        s = loop.stats
        p50 = percentile(list(s.ttft.values()), 50)
        print(f"steps: {s.prefill_steps} prefill ({s.prefill_tokens} tok), "
              f"{s.decode_steps} decode ({s.decode_tokens} tok); "
              f"ttft p50 {1e3 * p50:.0f}ms")
        occ = tracer.summary().channel_occupancy()
        print("channel occupancy (mean/max): "
              + ", ".join(f"{k.split('/')[-1]}={v[0]:.1f}/{v[1]}"
                          for k, v in sorted(occ.items())))
        if ns.paged:
            ps = loop.page_stats()
            print(f"pages: {ps['pages_used']}/{ps['n_pages']} used, "
                  f"{s.page_allocs} allocs, {s.prefix_hits} prefix hits, "
                  f"{s.cow_copies} cow, {s.preemptions} preemptions, "
                  f"fragmentation {ps['fragmentation']:.2f}")
    assert len(results) == ns.requests


if __name__ == "__main__":
    main()
