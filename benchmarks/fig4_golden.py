"""Paper Fig 4: overhead of the decoupled designs over the 'golden'
reference (zero latency, one request/cycle/port) at scaled-up datasets.

Matrix cells on the ``sim`` axis (group ``fig4``).  Both the decoupled
cycle count and the golden-reference count are integers, so the gate
pins the overhead ratio from both ends; the percent itself is a float
and rides along informationally.  ``--smoke`` collapses every label to
the small dataset (the sparse/dense spmv labels then coincide in
content but stay distinct cells, keeping the enumeration identical
across modes).
"""

from __future__ import annotations

from typing import List

from repro.bench import BenchContext, Cell, CellResult, coords, run_cells
from repro.core.workloads import run_workload

PAPER_FIG4 = {  # percent overhead over golden
    "binsearch": 11.9, "binsearch_for": 8.6, "hashtable": 17.6,
    "mergesort": 95.4, "mergesort_opt": 1.3, "multispmv": 33.7,
    "spmv_sparse": 55.3, "spmv_dense": 0.3,
}

CELLS = [
    ("binsearch", "fig4", "binsearch"),
    ("binsearch_for", "fig4", "binsearch_for"),
    ("hashtable", "fig4", "hashtable"),
    ("mergesort", "fig4", "mergesort"),
    ("mergesort_opt", "fig4", "mergesort_opt"),
    ("multispmv", "paper", "multispmv"),
    ("spmv", "fig4_sparse", "spmv_sparse"),
    ("spmv", "fig4_dense", "spmv_dense"),
]


def _cell_run(bench: str, scale: str, label: str):
    def run(ctx: BenchContext) -> CellResult:
        kwargs = dict(scale="small" if ctx.smoke else scale, latency=100,
                      rif=128)
        r = run_workload(bench, "rhls_dec", **kwargs)
        assert r.correct, f"fig4/{label} incorrect"
        derived = {"golden": int(r.golden),
                   "overhead_pct": round(100.0 * r.overhead, 1)}
        if not ctx.smoke:
            derived["paper_pct"] = PAPER_FIG4[label]
        return CellResult(cycles=int(r.cycles), derived=derived,
                          replay={"benchmark": bench, "config": "rhls_dec",
                                  "kwargs": kwargs})
    return run


def cells(ctx: BenchContext) -> List[Cell]:
    return [
        Cell(axis="sim", name=f"fig4/{label}", group="fig4",
             coords=coords(bench, "sim"), run=_cell_run(bench, scale, label))
        for bench, scale, label in CELLS
    ]


def run(csv_print) -> None:
    ctx = BenchContext(smoke=False)
    run_cells(cells(ctx), ctx, csv_print)
