"""Cycle-level simulator for DAE programs (paper §6 methodology).

Executes a :class:`repro.core.dae.DaeProgram` under a timing model and
returns cycle counts plus all stored results.  Two memory models are
provided, matching the paper's two evaluation setups:

  * :class:`FixedLatencyMemory` — the Verilator setup: every read and
    write takes a fixed ``latency`` (100 cycles in the paper), one
    request per cycle per port, bounded outstanding requests.
  * :class:`MomsMemory` — the Miss-Optimized Memory Subsystem + DRAMSim2
    setup (Table 3): request coalescing on cache lines, a small
    temporal-reuse cache, and a banked row-buffer DRAM model, with a cap
    on outstanding reads (64 in the paper).

The simulator is event driven (it skips idle cycles), so the multi-million
cycle baseline runs of Table 1 complete in well under a second.

Semantics enforced here (paper §5.1/§5.4):

  * loads on a channel complete **in issue order** (static AXI ID);
  * a ``Req`` blocks while ``capacity`` responses are already in flight
    or waiting — this is the buffer bound that makes sharing a port
    between channels deadlock-free;
  * stores become *observable* only when their write response returns;
    ``StoreWait`` models the end-of-accelerator state-edge merge;
  * if no process can make progress the simulator raises
    :class:`DeadlockError` (this reproduces the R-HLS-Stream mergesort
    deadlock of §6 when capacity rules are violated);
  * every request is answered exactly once and every stream entry is
    drained, else :class:`ConservationError` is raised at termination.

``Par`` bundles several effects into one issue slot — the dataflow
circuit equivalent of consuming the ``val`` and ``vec`` responses in the
same cycle in decoupled SPMV (paper Listing 2).

Multi-instance execution: the scheduler is an engine
(:class:`SharedMemoryEngine`) that runs **N concurrent program
instances against one shared memory system** — the contention regime
that motivates the paper's capacity bounding.  Each instance keeps its
own channel namespace, store results, and cycle count; memory ports are
either *private* to an instance or *shared*, in which case all
instances compete for the port's one-issue-per-cycle slot (round-robin
arbitration on ties) and for the memory model's outstanding-request
budget.  :func:`simulate` is the single-instance wrapper and is
bit-exact with the pre-engine scheduler.  An optional
:class:`repro.core.trace.Tracer` streams per-channel occupancy,
request-latency histograms, and port-utilization timelines.

Two scheduler implementations share one semantics:

  * ``engine="event"`` (default) — an event-driven scheduler: blocked
    processes sit in wait-sets keyed by the channel/port event that
    could unblock them (FIFO push/pop, port issue, store completion)
    and are re-examined only when that event fires; the no-progress
    clock jump comes from a retry-time heap instead of an O(procs)
    sweep, and jumped time is a lazily applied global floor.  Scheduler
    passes map 1:1 onto the polling scheduler's passes, so the
    round-robin arbitration rotation — and therefore every cycle count,
    store, trace record, and deadlock message — is bit-exact with
    ``engine="polling"`` (pinned by ``tests/test_parity.py``).
  * ``engine="polling"`` — the legacy pass-based scheduler that
    re-checks readiness of every live process on every pass.  Kept as
    the differential-testing oracle; O(procs) per pass, so large
    multi-tenant sweeps are much slower on it.
"""

from __future__ import annotations

import dataclasses
import heapq
import operator
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.dae import (
    Channel,
    ConservationError,
    DaeProgram,
    Delay,
    Deq,
    Enq,
    Halt,
    LoadChannel,
    Process,
    Req,
    Resp,
    Store,
    StoreWait,
    StreamChannel,
)
from repro.channels.sim import SimChannel

__all__ = [
    "ENGINES",
    "FixedLatencyMemory",
    "MomsMemory",
    "Par",
    "SimResult",
    "EngineInstance",
    "EngineResult",
    "SharedMemoryEngine",
    "DeadlockError",
    "simulate",
]

INF = float("inf")


class DeadlockError(RuntimeError):
    pass


@dataclasses.dataclass
class Par:
    """Execute several effects in a single issue slot (same cycle).

    Blocks until *all* sub-effects are ready; the value sent back into
    the generator is a tuple with one entry per sub-effect (``None`` for
    effects that produce no value).
    """

    effects: Sequence[Any]


@dataclasses.dataclass
class Fused:
    """A dataflow operator: consume ``first`` and *in the same cycle* run

    ``then(value)`` which may return a follow-up effect (Store/Enq/Req/
    Par/Fused) or ``None``.  This models combinational paths in a
    dataflow circuit — e.g. the copy loop's load-response feeding the
    store port at II=1, or mergesort's response feeding the comparison
    that selects the store value (paper Listing 3).

    Readiness is checked on ``first`` only; the follow-up must be
    non-blocking by construction (capacity freed by the consume in the
    same slot, as in Listing 4's request/enq after response/deq).
    """

    first: Any
    then: Any  # Callable[[Any], Optional[effect]]


# ---------------------------------------------------------------------------
# Memory models
# ---------------------------------------------------------------------------


class MemoryModel:
    """Interface: ``access(addr, t_issue) -> (t_complete, value)``."""

    def __init__(self, data: Any, max_outstanding: int = 64):
        self.data = data
        self.max_outstanding = max_outstanding
        self._inflight: List[float] = []  # completion-time heap (reads)
        self.reads = 0
        self.writes = 0

    def free_slot_at(self, t: float) -> float:
        """Earliest time >= t a new read may issue given the
        outstanding-request cap."""
        while self._inflight and self._inflight[0] <= t:
            heapq.heappop(self._inflight)
        if len(self._inflight) < self.max_outstanding:
            return t
        return self._inflight[0]

    def _commit(self, t_complete: float) -> None:
        heapq.heappush(self._inflight, t_complete)

    def read_value(self, addr: int) -> Any:
        return self.data[addr]

    def access(self, addr: int, t: float) -> Tuple[float, Any]:
        raise NotImplementedError

    def write_latency(self) -> float:
        raise NotImplementedError


class FixedLatencyMemory(MemoryModel):
    """Uniform fixed-latency memory (the paper's 100-cycle Verilator model)."""

    def __init__(self, data: Any, latency: int = 100, max_outstanding: int = 64):
        super().__init__(data, max_outstanding)
        self.latency = latency

    def access(self, addr: int, t: float) -> Tuple[float, Any]:
        self.reads += 1
        t_done = t + self.latency
        self._commit(t_done)
        return t_done, self.read_value(addr)

    def write_latency(self) -> float:
        return self.latency


class MomsMemory(MemoryModel):
    """Miss-optimized memory subsystem (Asiatici [2]) + row-buffer DRAM.

    * word addresses are grouped into ``line_words``-word cache lines;
    * a request to a line already in flight coalesces: it completes when
      the in-flight line lands (+1 cycle response serialization);
    * a small ``cache_kib`` FIFO cache of recently landed lines serves
      repeats at ``hit_latency``;
    * misses pay the DRAM model: per-bank open-row tracking, ``t_row_hit``
      vs ``t_row_miss``, plus bank busy time.
    """

    def __init__(
        self,
        data: Any,
        line_words: int = 16,
        cache_kib: int = 128,
        word_bytes: int = 4,
        hit_latency: int = 12,
        t_row_hit: int = 45,
        t_row_miss: int = 110,
        banks: int = 8,
        row_words: int = 256,
        max_outstanding: int = 64,
    ):
        super().__init__(data, max_outstanding)
        self.line_words = line_words
        self.hit_latency = hit_latency
        self.t_row_hit = t_row_hit
        self.t_row_miss = t_row_miss
        self.banks = banks
        self.row_words = row_words
        self.n_cache_lines = max(1, (cache_kib * 1024) // (line_words * word_bytes))
        self._inflight_lines: Dict[int, float] = {}
        self._cache: "deque[int]" = deque()
        self._cache_set: set = set()
        self._open_row: Dict[int, int] = {}
        self._bank_free: Dict[int, float] = {}
        self.stats = {"coalesced": 0, "hits": 0, "row_hits": 0, "row_misses": 0}

    def _dram_access(self, line: int, t: float) -> float:
        bank = line % self.banks
        row = (line * self.line_words) // self.row_words
        t_bank = max(t, self._bank_free.get(bank, 0.0))
        if self._open_row.get(bank) == row:
            dt = self.t_row_hit
            self.stats["row_hits"] += 1
        else:
            dt = self.t_row_miss
            self.stats["row_misses"] += 1
            self._open_row[bank] = row
        self._bank_free[bank] = t_bank + 4  # burst occupancy
        return t_bank + dt

    def _cache_insert(self, line: int) -> None:
        if line in self._cache_set:
            return
        self._cache.append(line)
        self._cache_set.add(line)
        while len(self._cache) > self.n_cache_lines:
            old = self._cache.popleft()
            self._cache_set.discard(old)

    def access(self, addr: int, t: float) -> Tuple[float, Any]:
        self.reads += 1
        line = addr // self.line_words
        tf = self._inflight_lines.get(line)
        if tf is not None and tf > t:
            self.stats["coalesced"] += 1
            return tf + 1, self.read_value(addr)
        if line in self._cache_set:
            self.stats["hits"] += 1
            return t + self.hit_latency, self.read_value(addr)
        t_done = self._dram_access(line, t)
        self._commit(t_done)
        self._inflight_lines[line] = t_done
        self._cache_insert(line)
        return t_done, self.read_value(addr)

    def write_latency(self) -> float:
        return self.t_row_miss


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimResult:
    cycles: int
    stores: Dict[str, Dict[int, Any]]
    counts: Dict[str, int]
    mem_reads: Dict[str, int]

    def stored_array(self, port: str, n: int) -> List[Any]:
        s = self.stores.get(port, {})
        return [s.get(i) for i in range(n)]


# Channel state is the sim transport of the shared repro.channels
# protocol: a timed (ready_time, value) FIFO with the §5.1 conservation
# counters and the event engine's wake keys.  Both engines mutate it
# only through push_timed/pop_timed, which emit the shared occupancy
# vocabulary; the readiness oracles below still peek ``st.fifo``
# directly (scheduler hot path).
_ChanState = SimChannel


class _Proc:
    __slots__ = ("proc", "time", "effect", "send", "done", "blocked_on",
                 "pos", "inst", "iidx", "gen", "vsnap", "stamp", "waits",
                 "teff", "tkeys")

    def __init__(self, proc: Process):
        self.proc = proc
        self.time = 0.0
        self.effect: Any = None
        self.send: Any = None
        self.done = False
        self.blocked_on: Optional[str] = None
        # event-engine bookkeeping (unused by the polling scheduler)
        self.pos = 0                 # index into the engine's pairs list
        self.inst: Any = None        # owning _Inst
        self.iidx = 0                # owning instance index (arbitration)
        self.gen = proc.gen          # bound generator (pump hot path)
        self.vsnap: Any = None       # port-version snapshot under which
                                     # the cached retry was computed
        self.stamp = 0               # invalidates stale retry-heap entries
        self.waits: Any = None       # (wake_keys, dirty_keys) while parked
        self.teff: Any = None        # effect the trigger-key cache is for
        self.tkeys: Any = None       # cached (wake_keys, dirty_keys)


@dataclasses.dataclass
class EngineInstance:
    """One tenant of the engine: a program plus its *private* memory
    ports.  Ports not listed in ``memories`` resolve to the engine's
    shared memory system — the instance competes with every other tenant
    for those ports' issue slots and outstanding-request budget."""

    name: str
    program: DaeProgram
    memories: Dict[str, MemoryModel] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class EngineResult:
    """Per-instance results plus the shared-run aggregates.

    ``cycles`` is the makespan (slowest instance); ``instances`` holds
    one :class:`SimResult` per tenant in submission order.  ``trace`` is
    the :class:`repro.core.trace.TraceSummary` when a tracer was
    attached, else ``None``.  ``events`` counts executed effects and
    ``passes`` scheduler passes — identical across the event and
    polling engines (a parity invariant); events/second is the
    throughput ``benchmarks.engine_bench`` compares.
    """

    cycles: int
    instances: List[SimResult]
    trace: Optional[Any] = None
    events: int = 0
    passes: int = 0


class _Inst:
    """Engine-internal per-tenant state: its own channel namespace,
    store results, and store-completion tracking."""

    __slots__ = ("name", "index", "private", "procs", "chans",
                 "port_last_store", "stores", "port_reads", "portcache")

    def __init__(self, name: str, index: int, program: DaeProgram,
                 private: Dict[str, MemoryModel]):
        self.name = name
        self.index = index
        self.private = private
        self.procs = [_Proc(p) for p in program.processes]
        self.chans: Dict[str, _ChanState] = {}
        self.port_last_store: Dict[str, float] = {}
        self.stores: Dict[str, Dict[int, Any]] = {}
        self.port_reads: Dict[str, int] = {}
        # event-engine cache: port -> (mem, owner, pni_key, issue_key,
        # mem_key, store_key, trace_label); see _port_ev
        self.portcache: Dict[str, Tuple] = {}

    def chan(self, c: Channel) -> _ChanState:
        st = self.chans.get(c.name)
        if st is None:
            st = self.chans[c.name] = _ChanState()
        return st


class _Ctx:
    """Shared engine state: the shared memory system, per-physical-port
    issue serialization, and the (optional) tracer."""

    def __init__(self, memories: Dict[str, MemoryModel], trace: Any = None):
        self.memories = memories
        # keyed by (owner, port): owner "" for shared ports, else the
        # instance name — two tenants' private "out" ports must not
        # serialize against each other
        self.port_next_issue: Dict[Tuple, float] = {}
        self.trace = trace
        # side-channel from _ready_ev: a *blocked* Par evaluation sets
        # this when one of its Req or StoreWait subs was ready (the
        # non-monotone parks the event scheduler watches eagerly);
        # per-run state so concurrent engine runs in one process cannot
        # race on it
        self.par_ready_req = False

    def mem(self, inst: _Inst, port: str) -> Tuple[MemoryModel, str]:
        """Resolve ``port`` for ``inst``: private first, then shared.
        Returns ``(memory, owner)`` with owner "" for shared ports."""
        m = inst.private.get(port)
        if m is not None:
            return m, inst.name
        m = self.memories.get(port)
        if m is None:
            raise KeyError(
                f"program references port {port!r} with no memory model bound"
            )
        return m, ""


def _port_label(owner: str, port: str) -> str:
    return f"{owner}/{port}" if owner else port


def _readiness(ctx: _Ctx, inst: _Inst, eff: Any, t: float
               ) -> Tuple[bool, float, str]:
    """Can ``eff`` execute at time t?  -> (ok, retry_time, reason)."""
    if isinstance(eff, (Delay, Halt, Store)):
        return True, t, ""
    if isinstance(eff, Req):
        c = eff.channel
        st = inst.chan(c)
        if len(st.fifo) >= c.capacity:
            # clears only when the consumer takes a response (unknown time);
            # if the front entry is still in flight, its landing time is a
            # usable lower bound for the global-time jump.
            front_ready = st.fifo[0][0] if st.fifo else INF
            retry = front_ready if front_ready > t else INF
            return False, retry, f"cap:{c.name}"
        mem, owner = ctx.mem(inst, c.port)
        t_issue = max(t, ctx.port_next_issue.get((owner, c.port), 0.0))
        slot = mem.free_slot_at(t_issue)
        if slot > t:
            return False, slot, f"mshr:{c.port}"
        return True, t, ""
    if isinstance(eff, Resp):
        st = inst.chan(eff.channel)
        if not st.fifo:
            return False, INF, f"resp:{eff.channel.name}"
        ready = st.fifo[0][0]
        if ready > t:
            return False, ready, f"resp-wait:{eff.channel.name}"
        return True, t, ""
    if isinstance(eff, Enq):
        st = inst.chan(eff.channel)
        if len(st.fifo) >= eff.channel.capacity:
            return False, INF, f"full:{eff.channel.name}"
        return True, t, ""
    if isinstance(eff, Deq):
        st = inst.chan(eff.channel)
        if not st.fifo:
            return False, INF, f"empty:{eff.channel.name}"
        ready = st.fifo[0][0]
        if ready > t:
            return False, ready, f"deq-wait:{eff.channel.name}"
        return True, t, ""
    if isinstance(eff, StoreWait):
        done_at = inst.port_last_store.get(eff.port, 0.0)
        if done_at > t:
            return False, done_at, f"storewait:{eff.port}"
        return True, t, ""
    if isinstance(eff, Par):
        retries: List[float] = []
        reasons: List[str] = []
        for sub in eff.effects:
            ok, retry, reason = _readiness(ctx, inst, sub, t)
            if not ok:
                retries.append(retry)
                reasons.append(reason)
        if reasons:
            finite = [r for r in retries if r is not INF]
            # conservative: re-check at the earliest time any blocker could
            # clear; unknown (INF) blockers are re-checked whenever another
            # process makes progress.
            return False, (min(finite) if finite else INF), "&".join(reasons)
        return True, t, ""
    if isinstance(eff, Fused):
        return _readiness(ctx, inst, eff.first, t)
    raise TypeError(f"unknown effect {eff!r}")


def _execute(ctx: _Ctx, inst: _Inst, eff: Any, t: float) -> Any:
    """Execute a ready effect at time t; returns the value to send."""
    if isinstance(eff, (Delay, Halt)):
        return None
    if isinstance(eff, Req):
        c = eff.channel
        st = inst.chan(c)
        mem, owner = ctx.mem(inst, c.port)
        key = (owner, c.port)
        t_issue = max(t, ctx.port_next_issue.get(key, 0.0))
        t_done, value = mem.access(eff.addr, t_issue)
        ctx.port_next_issue[key] = t_issue + 1.0
        inst.port_reads[c.port] = inst.port_reads.get(c.port, 0) + 1
        if ctx.trace is not None:
            ctx.trace.on_request(inst.name, c.name,
                                 _port_label(owner, c.port), t_issue, t_done)
        st.push_timed(t_done, value, "req", ctx.trace, inst.name, c.name, t)
        return None
    if isinstance(eff, Resp):
        st = inst.chan(eff.channel)
        return st.pop_timed("resp", ctx.trace, inst.name,
                            eff.channel.name, t)
    if isinstance(eff, Enq):
        st = inst.chan(eff.channel)
        st.push_timed(t + 1.0, eff.value, "enq", ctx.trace, inst.name,
                      eff.channel.name, t)
        return None
    if isinstance(eff, Deq):
        st = inst.chan(eff.channel)
        return st.pop_timed("deq", ctx.trace, inst.name,
                            eff.channel.name, t)
    if isinstance(eff, Store):
        port = eff.port
        mem, owner = ctx.mem(inst, port)
        mem.writes += 1
        key = (owner, port)
        t_issue = max(t, ctx.port_next_issue.get(key, 0.0))
        ctx.port_next_issue[key] = t_issue + 1.0
        t_done = t_issue + mem.write_latency()
        inst.port_last_store[port] = max(
            inst.port_last_store.get(port, 0.0), t_done)
        inst.stores.setdefault(port, {})[eff.addr] = eff.value
        try:
            mem.data[eff.addr] = eff.value
        except (TypeError, IndexError, KeyError):
            pass
        if ctx.trace is not None:
            ctx.trace.on_store(inst.name, _port_label(owner, port), t_issue)
        return None
    if isinstance(eff, StoreWait):
        return None
    if isinstance(eff, Par):
        return tuple(_execute(ctx, inst, sub, t) for sub in eff.effects)
    if isinstance(eff, Fused):
        value = _execute(ctx, inst, eff.first, t)
        follow = eff.then(value)
        if follow is not None:
            _execute(ctx, inst, follow, t)
        return value
    raise TypeError(f"unknown effect {eff!r}")


# ---------------------------------------------------------------------------
# Event-engine fast path.  _ready_ev/_exec_ev are semantically identical
# to the legacy _readiness/_execute pair above (same retry values, reason
# strings, trace records, and state transitions — pinned against each
# other by tests/test_parity.py) but are restructured for the event
# scheduler's hot loop: exact-type dispatch instead of isinstance
# cascades, per-instance port-resolution caches, pre-built wake-event
# key tuples, and wake-event emission threaded through an explicit list.
# ---------------------------------------------------------------------------


_proc_pos = operator.attrgetter("pos")


def _chan_ev(inst: _Inst, c: Channel) -> _ChanState:
    st = inst.chans.get(c.name)
    if st is None:
        st = inst.chans[c.name] = _ChanState()
    if st.push_key is None:
        st.push_key = ("push", inst.index, c.name)
        st.pop_key = ("pop", inst.index, c.name)
    return st


def _port_ev(ctx: _Ctx, inst: _Inst, port: str) -> Tuple:
    """Cached port resolution: ``(mem, owner, pni_key, issue_key,
    mem_key, store_key, trace_label)``.  Safe to cache because port
    bindings are fixed for the lifetime of an engine run."""
    e = inst.portcache.get(port)
    if e is None:
        mem = inst.private.get(port)
        if mem is not None:
            owner = inst.name
        else:
            mem = ctx.memories.get(port)
            if mem is None:
                raise KeyError(
                    f"program references port {port!r} with no memory "
                    f"model bound"
                )
            owner = ""
        e = inst.portcache[port] = (
            mem, owner, (owner, port), ("issue", owner, port),
            ("mem", id(mem)), ("store", inst.index, port),
            _port_label(owner, port))
    return e


def _ready_ev(ctx: _Ctx, inst: _Inst, eff: Any, t: float) -> Optional[float]:
    """Can ``eff`` execute at time t?  ``None`` when ready, else the
    retry time (INF for state-change-only blockers).

    Unlike the legacy :func:`_readiness` oracle this does not build the
    blocked-reason string: in the event engine a reason is only ever
    observed inside a deadlock message, and a deadlock is a global
    fixpoint — no state can have changed since each process parked — so
    the messages are derived fresh through the legacy oracle at that
    point (see ``_deadlock_event``) and are guaranteed identical.
    """
    cls = eff.__class__
    while cls is Fused:
        eff = eff.first
        cls = eff.__class__
    if cls is Resp:
        fifo = _chan_ev(inst, eff.channel).fifo
        if not fifo:
            return INF
        ready = fifo[0][0]
        if ready > t:
            return ready
        return None
    if cls is Req:
        c = eff.channel
        fifo = _chan_ev(inst, c).fifo
        if len(fifo) >= c.capacity:
            front_ready = fifo[0][0] if fifo else INF
            return front_ready if front_ready > t else INF
        entry = _port_ev(ctx, inst, c.port)
        t_issue = ctx.port_next_issue.get(entry[2], 0.0)
        if t_issue < t:
            t_issue = t
        slot = entry[0].free_slot_at(t_issue)
        if slot > t:
            return slot
        return None
    if cls is Par:
        # blocked iff any sub is; retry = min finite over blocked subs
        blocked = False
        r_min: Optional[float] = None
        for sub in eff.effects:
            r = _ready_ev(ctx, inst, sub, t)
            if r is None:
                sc = sub.__class__
                while sc is Fused:
                    sub = sub.first
                    sc = sub.__class__
                if sc is Req or sc is StoreWait:
                    # a ready Req (or StoreWait) sub inside a blocked
                    # Par: someone else's issue (store) can later
                    # mshr-block (write-gate) it, handing the Par a new,
                    # possibly smaller finite retry — the non-monotone
                    # park the jump must watch eagerly
                    ctx.par_ready_req = True
                continue
            blocked = True
            if r is not INF and (r_min is None or r < r_min):
                r_min = r
        if not blocked:
            return None
        return r_min if r_min is not None else INF
    if cls is Deq:
        fifo = _chan_ev(inst, eff.channel).fifo
        if not fifo:
            return INF
        ready = fifo[0][0]
        if ready > t:
            return ready
        return None
    if cls is Enq:
        st = _chan_ev(inst, eff.channel)
        if len(st.fifo) >= eff.channel.capacity:
            return INF
        return None
    if cls is Delay or cls is Store or cls is Halt:
        return None
    if cls is StoreWait:
        done_at = inst.port_last_store.get(eff.port, 0.0)
        if done_at > t:
            return done_at
        return None
    raise TypeError(f"unknown effect {eff!r}")


def _exec_ev(ctx: _Ctx, inst: _Inst, eff: Any, t: float,
             ev: List[Tuple]) -> Any:
    """Execute a ready effect at time t, appending the wake-event keys
    of every state change to ``ev``; returns the value to send."""
    cls = eff.__class__
    if cls is Fused:
        value = _exec_ev(ctx, inst, eff.first, t, ev)
        follow = eff.then(value)
        if follow is not None:
            _exec_ev(ctx, inst, follow, t, ev)
        return value
    if cls is Resp:
        st = _chan_ev(inst, eff.channel)
        ev.append(st.pop_key)
        return st.pop_timed("resp", ctx.trace, inst.name,
                            eff.channel.name, t)
    if cls is Req:
        c = eff.channel
        st = _chan_ev(inst, c)
        mem, _, pni_key, issue_key, mem_key, _, label = \
            _port_ev(ctx, inst, c.port)
        pni = ctx.port_next_issue
        t_issue = pni.get(pni_key, 0.0)
        if t_issue < t:
            t_issue = t
        t_done, value = mem.access(eff.addr, t_issue)
        pni[pni_key] = t_issue + 1.0
        inst.port_reads[c.port] = inst.port_reads.get(c.port, 0) + 1
        ev.append(st.push_key)
        ev.append(issue_key)
        ev.append(mem_key)
        if ctx.trace is not None:
            ctx.trace.on_request(inst.name, c.name, label, t_issue, t_done)
        st.push_timed(t_done, value, "req", ctx.trace, inst.name, c.name, t)
        return None
    if cls is Par:
        return tuple([_exec_ev(ctx, inst, sub, t, ev)
                      for sub in eff.effects])
    if cls is Enq:
        st = _chan_ev(inst, eff.channel)
        ev.append(st.push_key)
        st.push_timed(t + 1.0, eff.value, "enq", ctx.trace, inst.name,
                      eff.channel.name, t)
        return None
    if cls is Deq:
        st = _chan_ev(inst, eff.channel)
        ev.append(st.pop_key)
        value = st.pop_timed("deq", ctx.trace, inst.name,
                             eff.channel.name, t)
        return value
    if cls is Store:
        port = eff.port
        mem, _, pni_key, issue_key, _, store_key, label = \
            _port_ev(ctx, inst, port)
        mem.writes += 1
        pni = ctx.port_next_issue
        t_issue = pni.get(pni_key, 0.0)
        if t_issue < t:
            t_issue = t
        pni[pni_key] = t_issue + 1.0
        t_done = t_issue + mem.write_latency()
        pls = inst.port_last_store
        prev = pls.get(port, 0.0)
        if t_done > prev:
            pls[port] = t_done
        inst.stores.setdefault(port, {})[eff.addr] = eff.value
        try:
            mem.data[eff.addr] = eff.value
        except (TypeError, IndexError, KeyError):
            pass
        ev.append(issue_key)
        ev.append(store_key)
        if ctx.trace is not None:
            ctx.trace.on_store(inst.name, label, t_issue)
        return None
    if cls is Delay or cls is Halt or cls is StoreWait:
        return None
    raise TypeError(f"unknown effect {eff!r}")


def _collect_triggers(ctx: _Ctx, inst: _Inst, eff: Any, wake: set,
                      dirty: set) -> None:
    """Wait-set keys for a blocked ``eff``, split by what the event can
    do to it.

    ``wake`` keys are state changes that could make the effect *ready*
    (a FIFO push for an empty-blocked consumer, a pop for a full-blocked
    producer or an in-order head swap) — they re-examine the process
    immediately, at its polling-scheduler position in the pass.

    ``dirty`` keys can only move the effect's *retry time* (a port issue
    pushes ``port_next_issue``/the MSHR heap later; a store pushes the
    write-response edge later) — they never unblock anything, so the
    re-examination is deferred to the next no-progress pass, where the
    clock jump needs fresh retries to stay in lockstep with the polling
    scheduler's freshly computed minimum.

    For a single ``Req``/``StoreWait`` a dirty event can only *increase*
    the retry, so the jump may validate cached values lazily from the
    heap minimum upward.  A ``Par`` with a ``Req`` or ``StoreWait`` sub
    that is *ready* at park time breaks that monotonicity: a port issue
    (store) can turn the ready sub into an mshr-blocked (write-gated)
    one, giving the Par a new, possibly much *smaller* finite retry.
    ``_ready_ev`` flags that case through ``ctx.par_ready_req`` and the
    scheduler puts such parks on an eager per-jump watch list.  (A sub
    *blocked* at park time cannot turn ready without a wake event, so
    its contribution stays monotone.)
    """
    cls = eff.__class__
    if cls is Req:
        st = _chan_ev(inst, eff.channel)
        wake.add(st.pop_key)
        entry = _port_ev(ctx, inst, eff.channel.port)
        dirty.add(entry[3])
        dirty.add(entry[4])
        return
    if cls is Resp or cls is Deq:
        st = _chan_ev(inst, eff.channel)
        wake.add(st.push_key)
        wake.add(st.pop_key)
    elif cls is Enq:
        wake.add(_chan_ev(inst, eff.channel).pop_key)
    elif cls is StoreWait:
        dirty.add(_port_ev(ctx, inst, eff.port)[5])
    elif cls is Par:
        for sub in eff.effects:
            _collect_triggers(ctx, inst, sub, wake, dirty)
    elif cls is Fused:
        _collect_triggers(ctx, inst, eff.first, wake, dirty)
    # Delay / Halt / Store are always ready and never park in a wait-set


ENGINES = ("event", "polling")


class SharedMemoryEngine:
    """Execute N concurrent DAE program instances against one shared
    memory system.

    * **Round-robin port arbitration** — live processes are scheduled in
      local-time order; among processes tied at the same time the
      starting instance rotates every scheduler pass, so no tenant can
      persistently win a shared port's issue slot.  With one instance
      the order degenerates to the legacy scheduler's, making
      :func:`simulate` bit-exact with the pre-engine implementation.
    * **Per-instance cycle accounting** — each tenant's cycle count is
      the completion time of its own processes and stores; the engine's
      ``cycles`` is the makespan.
    * **Shared outstanding-request budget** — a shared port's
      ``max_outstanding`` (the MOMS MSHR budget) is one pool all
      tenants draw from, which is exactly the §5.4 contention regime.

    Conservation (§5.1) is checked per instance at termination; a global
    scheduling fixpoint with no runnable process raises
    :class:`DeadlockError` naming every blocked process.

    ``engine`` selects the scheduler implementation: ``"event"`` (the
    default, event-driven) or ``"polling"`` (the legacy pass-based
    oracle).  Both produce bit-identical results; see the module
    docstring.
    """

    def __init__(self, instances: Sequence[EngineInstance],
                 shared_memories: Optional[Dict[str, MemoryModel]] = None,
                 *, tracer: Any = None, max_steps: int = 500_000_000,
                 engine: str = "event"):
        if not instances:
            raise ValueError("SharedMemoryEngine needs at least one instance")
        names = [i.name for i in instances]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate instance names: {names}")
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r} (choose from "
                             f"{ENGINES})")
        self.instances = list(instances)
        self.shared = dict(shared_memories or {})
        self.tracer = tracer
        self.max_steps = max_steps
        self.engine = engine

    def run(self) -> EngineResult:
        insts = [_Inst(spec.name, i, spec.program, spec.memories)
                 for i, spec in enumerate(self.instances)]
        pairs = [(inst, p) for inst in insts for p in inst.procs]
        ctx = _Ctx(self.shared, self.tracer)
        if self.engine == "polling":
            n_events, passes = self._run_polling(insts, pairs, ctx)
        else:
            n_events, passes = self._run_event(insts, pairs, ctx)
        results = [self._finalize(inst) for inst in insts]
        makespan = max([r.cycles for r in results] + [0])
        trace = self.tracer.summary() if self.tracer is not None else None
        return EngineResult(cycles=makespan, instances=results, trace=trace,
                            events=n_events, passes=passes)

    def _deadlock_event(self, ctx, live, floor) -> None:
        """Deadlock from the event scheduler: derive each blocked
        process's reason fresh through the legacy oracle (a deadlock is
        a fixpoint, so nothing has changed since each process parked and
        the strings match the polling scheduler's exactly)."""
        for inst, p in live:
            t = p.time
            _, _, reason = _readiness(ctx, inst, p.effect,
                                      t if t > floor else floor)
            p.blocked_on = reason
        self._deadlock(live)

    def _deadlock(self, live) -> None:
        n_inst = len(self.instances)
        if n_inst == 1:
            blocked = {p.proc.name: p.blocked_on for _, p in live}
            raise DeadlockError(
                f"deadlock in program "
                f"{self.instances[0].program.name!r}: {blocked}")
        blocked = {f"{inst.name}:{p.proc.name}": p.blocked_on
                   for inst, p in live}
        raise DeadlockError(
            f"deadlock across {n_inst} instances: {blocked}")

    def _run_polling(self, insts, pairs, ctx) -> Tuple[int, int]:
        """Legacy pass-based scheduler: every pass re-pumps, re-sorts,
        and re-checks readiness of every live process."""
        n_inst = len(insts)
        n_events = 0
        steps = 0
        rotation = 0
        while True:
            steps += 1
            if steps > self.max_steps:
                raise RuntimeError("simulation step limit exceeded")

            for inst, p in pairs:
                if not p.done and p.effect is None:
                    try:
                        p.effect = p.proc.gen.send(p.send)
                        p.send = None
                    except StopIteration:
                        p.done = True
            live = [(inst, p) for inst, p in pairs if not p.done]
            if not live:
                break

            if n_inst > 1:
                rot = rotation
                order = sorted(live, key=lambda ip: (
                    ip[1].time, (ip[0].index - rot) % n_inst))
            else:
                order = sorted(live, key=lambda ip: ip[1].time)
            rotation += 1

            progressed = False
            best_retry = INF
            for inst, p in order:
                eff, t, ii = p.effect, p.time, p.proc.ii
                ok, retry, reason = _readiness(ctx, inst, eff, t)
                if not ok:
                    best_retry = min(best_retry, retry)
                    p.blocked_on = reason
                    continue
                p.send = _execute(ctx, inst, eff, t)
                n_events += 1
                if isinstance(eff, Delay):
                    p.time = t + max(eff.cycles, 0)
                else:
                    p.time = t + ii
                if isinstance(eff, Halt):
                    p.done = True
                p.effect = None
                p.blocked_on = None
                progressed = True

            if not progressed:
                if best_retry is INF:
                    self._deadlock(live)
                for inst, p in pairs:
                    if not p.done and p.time < best_retry:
                        p.time = best_retry
        return n_events, steps

    def _run_event(self, insts, pairs, ctx) -> Tuple[int, int]:
        """Event-driven scheduler, bit-exact with :meth:`_run_polling`.

        Equivalence argument (verified cell-by-cell by
        ``tests/test_parity.py``):

        * **passes map 1:1** — each iteration of the outer loop below
          corresponds to one polling pass, so the round-robin rotation
          index (``pass_no - 1``) agrees with the polling scheduler's
          per-pass ``rotation`` counter, tie-breaking identically;
        * **candidate sufficiency** — a blocked process's readiness (and
          its retry time) can only change when a wait-set trigger from
          :func:`_collect_triggers` fires or when the no-progress jump
          reaches its cached retry, so processes outside the pass's
          candidate heap would re-block exactly as they did last time
          and are safe to skip;
        * **in-pass ordering** — candidates pop off a heap keyed
          ``(local_time, rotated_instance_index, pairs_position)``, the
          polling scheduler's stable sort key.  A process woken by an
          event *behind* the current key joins this pass's heap (the
          polling sweep would reach it later in the same pass); one
          woken *at or before* the current key waits for the next pass
          (the sweep already passed it);
        * **lazy clock floor** — the no-progress jump advances a global
          ``floor`` instead of rewriting every process's clock;
          effective time is ``max(local, floor)``, materialized on
          execution.  The jump target comes from a stamp-invalidated
          heap of cached retry times, which equals the polling
          scheduler's fresh minimum because every event that could
          change a retry also wakes its process for re-examination.
        """
        n_inst = len(insts)
        max_steps = self.max_steps
        procs: List[_Proc] = []
        for pos, (inst, p) in enumerate(pairs):
            p.pos = pos
            p.inst = inst
            p.iidx = inst.index
            procs.append(p)
        live_count = len(pairs)
        # wake-sets: state changes that can make a parked proc ready
        waiters: Dict[Tuple, Dict[_Proc, None]] = {}
        # port-state version counters: bumped O(1) per issue/store; a
        # parked proc snapshots the versions its retry was computed
        # under, and the jump refreshes any proc whose snapshot is stale
        vers: Dict[Tuple, int] = {}
        # parked procs whose retry is non-monotone under port events (a
        # Par with a Req sub — see _collect_triggers): version-checked
        # eagerly at every jump, because a stale cached retry may be
        # *larger* than the fresh one and the lazy heap validation below
        # would then miss the true minimum
        watch: Dict[_Proc, None] = {}
        retry_heap: List[Tuple[float, int, int]] = []  # (retry, pos, stamp)
        # stale entries (superseded stamps) are dropped lazily at jumps;
        # compact when they pile up so heap ops stay O(log live-entries)
        compact_at = max(64, 8 * len(pairs))
        floor = 0.0
        pass_no = 0
        n_events = 0
        to_pump: List[_Proc] = list(procs)
        next_cand: List[_Proc] = []
        ev: List[Tuple] = []
        heappush, heappop = heapq.heappush, heapq.heappop

        def unpark(w: _Proc) -> None:
            for k in w.waits[0]:
                d = waiters.get(k)
                if d is not None:
                    d.pop(w, None)
            w.waits = None
            w.stamp += 1
            watch.pop(w, None)

        def reblock(p: _Proc, retry: float, eff: Any,
                    eager: bool) -> None:
            p.stamp += 1
            if retry is not INF:
                heappush(retry_heap, (retry, p.pos, p.stamp))
                if len(retry_heap) > compact_at:
                    compact()
            if p.teff is eff:
                keys = p.tkeys
            else:
                wake_keys: set = set()
                dirty_keys: set = set()
                _collect_triggers(ctx, p.inst, eff, wake_keys, dirty_keys)
                keys = p.tkeys = (wake_keys, tuple(dirty_keys))
                p.teff = eff
            p.waits = keys
            dirty_keys = keys[1]
            if dirty_keys:
                p.vsnap = [(k, vers.get(k, 0)) for k in dirty_keys]
                if eager:
                    watch[p] = None
            else:
                p.vsnap = None
            for k in keys[0]:
                ws = waiters.get(k)
                if ws is None:
                    ws = waiters[k] = {}
                ws[p] = None

        def compact() -> None:
            nonlocal compact_at
            retry_heap[:] = [e for e in retry_heap
                             if procs[e[1]].stamp == e[2]]
            heapq.heapify(retry_heap)
            compact_at = max(64, 8 * len(pairs), 2 * len(retry_heap))

        def vers_stale(p: _Proc) -> bool:
            snap = p.vsnap  # [(dirty_key, version)] or None
            if snap is None:
                return False
            for k, v in snap:
                if vers.get(k, 0) != v:
                    return True
            return False

        def refresh(p: _Proc) -> Optional[float]:
            """Recompute a parked proc's retry in place (issues and
            stores can only delay a retry, never grant readiness)."""
            t = p.time
            ctx.par_ready_req = False
            retry = _ready_ev(ctx, p.inst, p.effect,
                              t if t > floor else floor)
            eager = ctx.par_ready_req
            p.stamp += 1
            if retry is None:
                # cannot happen (issues/stores never grant readiness);
                # defensively schedule an immediate retry at local time
                retry = t if t > floor else floor
            if retry is not INF:
                heappush(retry_heap, (retry, p.pos, p.stamp))
            dirty_keys = p.waits[1]
            if dirty_keys:
                p.vsnap = [(k, vers.get(k, 0)) for k in dirty_keys]
                if eager:
                    watch[p] = None
                else:
                    watch.pop(p, None)
            else:
                p.vsnap = None
            return retry

        while live_count > 0:
            pass_no += 1
            if pass_no > max_steps:
                raise RuntimeError("simulation step limit exceeded")
            rot = pass_no - 1

            heap: List[Tuple[float, int, int]] = []  # (time, rotidx, pos)
            if to_pump:
                # generator pump order is pairs order, as in polling
                if len(to_pump) > 1:
                    to_pump.sort(key=_proc_pos)
                for p in to_pump:
                    try:
                        p.effect = p.gen.send(p.send)
                        p.send = None
                    except StopIteration:
                        p.done = True
                        live_count -= 1
                        continue
                    t = p.time
                    heap.append((t if t > floor else floor,
                                 (p.iidx - rot) % n_inst, p.pos))
                to_pump = []
            for p in next_cand:
                t = p.time
                heap.append((t if t > floor else floor,
                             (p.iidx - rot) % n_inst, p.pos))
            next_cand = []
            if live_count == 0:
                break
            if len(heap) > 1:
                heapq.heapify(heap)

            progressed = False
            while heap:
                key = heappop(heap)
                t = key[0]
                p = procs[key[2]]
                inst = p.inst
                eff = p.effect
                ctx.par_ready_req = False
                retry = _ready_ev(ctx, inst, eff, t)
                if retry is not None:
                    reblock(p, retry, eff, ctx.par_ready_req)
                    continue
                p.send = _exec_ev(ctx, inst, eff, t, ev)
                n_events += 1
                cls = eff.__class__
                if cls is Delay:
                    p.time = t + (eff.cycles if eff.cycles > 0 else 0)
                else:
                    p.time = t + p.proc.ii
                if cls is Halt:
                    p.done = True
                    live_count -= 1
                else:
                    to_pump.append(p)
                p.effect = None
                p.blocked_on = None
                progressed = True
                if ev:
                    for k in ev:
                        kind = k[0]
                        if kind == "push" or kind == "pop":
                            ws = waiters.get(k)
                            if not ws:
                                continue
                            for w in list(ws):
                                unpark(w)
                                wt = w.time
                                wkey = (wt if wt > floor else floor,
                                        (w.iidx - rot) % n_inst, w.pos)
                                if wkey > key:
                                    heappush(heap, wkey)
                                else:
                                    next_cand.append(w)
                        else:  # issue / mem / store: O(1) version bump
                            vers[k] = vers.get(k, 0) + 1
                    ev.clear()

            if not progressed:
                # no-progress pass.  A port issue or store can only
                # *delay* a parked proc's retry, never unblock it, so
                # retry refreshes were deferred to here, where the clock
                # jump consumes them: refresh any proc whose port-version
                # snapshot went stale, lazily, starting from the heap
                # minimum — fresh retries are >= stale ones, so the first
                # version-valid minimum is the true fresh minimum.
                for p in list(watch):
                    # non-monotone parks first (a Par with a Req sub that
                    # was ready when it parked): their fresh retry may
                    # undercut every cached heap entry
                    if vers_stale(p):
                        refresh(p)
                while retry_heap:
                    r, pos, stamp = retry_heap[0]
                    p = procs[pos]
                    if stamp != p.stamp:
                        heappop(retry_heap)
                        continue
                    if vers_stale(p):
                        heappop(retry_heap)
                        refresh(p)
                        continue
                    break
                if not retry_heap:
                    self._deadlock_event(
                        ctx, [ip for ip in pairs if not ip[1].done], floor)
                best = retry_heap[0][0]
                while retry_heap and retry_heap[0][0] == best:
                    _, pos, stamp = heappop(retry_heap)
                    p = procs[pos]
                    if stamp != p.stamp:
                        continue
                    if vers_stale(p):
                        # fresh retry is >= best; requeue — if it still
                        # lands exactly on the jump the next iteration
                        # pops it again (now version-valid) and wakes it
                        refresh(p)
                        continue
                    unpark(p)
                    next_cand.append(p)
                floor = best

        # p.time is materialized at every execution (and a finishing
        # StopIteration is discovered on the pass right after its proc's
        # last execution, before any jump), so _finalize's per-instance
        # cycle accounting needs no floor catch-up here
        return n_events, pass_no

    def _finalize(self, inst: _Inst) -> SimResult:
        counts: Dict[str, int] = {}
        for name, st in inst.chans.items():
            if st.fifo:
                raise ConservationError(
                    f"channel {name!r} finished with {len(st.fifo)} "
                    f"undrained entries"
                )
            if st.reqs != st.resps:
                raise ConservationError(
                    f"channel {name!r}: {st.reqs} requests but "
                    f"{st.resps} responses"
                )
            if st.enqs != st.deqs:
                raise ConservationError(
                    f"channel {name!r}: {st.enqs} enqs but {st.deqs} deqs"
                )
            counts[name] = st.reqs + st.enqs

        t_end = max(
            [p.time for p in inst.procs]
            + list(inst.port_last_store.values()) + [0.0]
        )
        # per-instance attribution: only the reads THIS tenant issued —
        # a shared model's global .reads counter would credit every
        # tenant with the whole port's traffic
        visible = dict(self.shared)
        visible.update(inst.private)
        return SimResult(
            cycles=int(round(t_end)),
            stores=inst.stores,
            counts=counts,
            mem_reads={port: inst.port_reads.get(port, 0)
                       for port in visible},
        )


def simulate(
    program: DaeProgram,
    memories: Dict[str, MemoryModel],
    max_steps: int = 500_000_000,
    tracer: Any = None,
    engine: str = "event",
) -> SimResult:
    """Run ``program`` against ``memories`` (one entry per port name).

    Single-instance wrapper over :class:`SharedMemoryEngine`; all ports
    are bound as shared (with one tenant there is nobody to share with,
    so the timing is identical to the legacy single-program scheduler).
    ``engine`` selects the scheduler implementation (``"event"`` or the
    legacy ``"polling"`` oracle); both are bit-exact.
    """
    eng = SharedMemoryEngine(
        [EngineInstance("", program)], memories,
        tracer=tracer, max_steps=max_steps, engine=engine)
    return eng.run().instances[0]
