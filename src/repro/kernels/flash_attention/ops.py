"""Jit'd public wrappers for flash attention."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import (cdiv, resolve_interpret, ring_rif,
                                  round_up, tuned_knobs)
from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention.ref import attention_ref, decode_ref


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bk", "interpret", "method"))
def _flash_impl(q, k, v, *, causal, window, bq, bk, interpret, method):
    b, h, sq, d = q.shape
    sk = k.shape[2]
    scale = d ** -0.5
    if method == "ref":
        return attention_ref(q, k, v, causal=causal, window=window)
    sqp, skp = round_up(sq, bq), round_up(sk, bk)
    if sqp != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sqp - sq), (0, 0)))
    if skp != sk:
        pad = ((0, 0), (0, 0), (0, skp - sk), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    out = _k.flash(q, k, v, causal=causal, window=window, scale=scale,
                   s_real=sk, bq=bq, bk=bk, interpret=interpret)
    return out[:, :, :sq, :]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: Optional[int] = None, bk: Optional[int] = None,
                    method: str = "pallas",
                    interpret: Optional[bool] = None) -> jax.Array:
    """q (B,H,S,D); k,v (B,KVH,S,D) with H % KVH == 0 (GQA).

    ``bq``/``bk`` left ``None`` resolve via the tune cache (128 default).
    """
    interp = resolve_interpret(interpret)
    if bq is None or bk is None:
        knobs = tuned_knobs("flash_attention",
                            (q.shape[2], k.shape[2], q.shape[3]), q.dtype,
                            interp, bq=(bq, 128), bk=(bk, 128))
        bq, bk = knobs["bq"], knobs["bk"]
    return _flash_impl(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                       interpret=interp, method=method)


@functools.partial(jax.jit, static_argnames=("bk", "rif", "interpret",
                                              "method"))
def _decode_impl(q, k_cache, v_cache, lengths, *, bk, rif, interpret, method):
    b, h, d = q.shape
    kvh, s = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    scale = d ** -0.5
    if method == "ref":
        return decode_ref(q, k_cache, v_cache, lengths)
    sp = round_up(s, bk)
    if sp != s:
        pad = ((0, 0), (0, 0), (0, sp - s), (0, 0))
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)
    qg = q.reshape(b, kvh, g, d)
    out = _k.flash_decode(qg, k_cache, v_cache, lengths.astype(jnp.int32),
                          scale=scale, bk=bk, rif=rif, interpret=interpret)
    return out.reshape(b, h, d)


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 lengths: jax.Array, *, bk: Optional[int] = None,
                 rif: Optional[int] = None, method: str = "pallas",
                 interpret: Optional[bool] = None) -> jax.Array:
    """One-token decode: q (B,H,D) against caches (B,KVH,S,D).

    ``bk``/``rif`` left ``None`` resolve explicit → tune cache →
    analytic (bk 128; ``plan_rif`` over one (bk, d) block's byte
    size)."""
    interp = resolve_interpret(interpret)
    if bk is None or rif is None:
        knobs = tuned_knobs("flash_decode", (k_cache.shape[2], q.shape[2]),
                            q.dtype, interp, bk=(bk, 128), rif=(rif, None))
        bk, rif = knobs["bk"], knobs["rif"]
        rif = ring_rif(rif, bk * q.shape[2] * q.dtype.itemsize)
    return _decode_impl(q, k_cache, v_cache, lengths, bk=bk, rif=rif,
                        interpret=interp, method=method)


@functools.partial(jax.jit, static_argnames=("rif", "interpret", "method"))
def _decode_paged_impl(q, k_pages, v_pages, page_table, lengths, *,
                       rif, interpret, method):
    b, h, d = q.shape
    kvh = k_pages.shape[1]
    g = h // kvh
    scale = d ** -0.5
    if method == "ref":
        # reconstruct contiguous caches from pages for the oracle
        np_, _, page, _ = k_pages.shape
        kc = jnp.take(k_pages, page_table, axis=0)   # (B, NPB, KVH, PAGE, D)
        kc = kc.transpose(0, 2, 1, 3, 4).reshape(b, kvh, -1, d)
        vc = jnp.take(v_pages, page_table, axis=0)
        vc = vc.transpose(0, 2, 1, 3, 4).reshape(b, kvh, -1, d)
        return decode_ref(q, kc, vc, lengths)
    qg = q.reshape(b, kvh, g, d)
    out = _k.flash_decode_paged(qg, k_pages, v_pages,
                                page_table.astype(jnp.int32),
                                lengths.astype(jnp.int32), scale=scale,
                                rif=rif, interpret=interpret)
    return out.reshape(b, h, d)


def flash_decode_paged(q, k_pages, v_pages, page_table, lengths, *,
                       rif: Optional[int] = None, method: str = "pallas",
                       interpret: Optional[bool] = None) -> jax.Array:
    """Paged decode: pages (NP,KVH,PAGE,D), page_table (B, S/PAGE) int32.

    ``rif=None`` resolves the page-ring depth via the tune cache, then
    ``plan_rif`` over one page's byte size."""
    interp = resolve_interpret(interpret)
    if rif is None:
        rif = tuned_knobs("flash_decode_paged",
                          (k_pages.shape[2], q.shape[2]), q.dtype, interp,
                          rif=(None, None))["rif"]
        rif = ring_rif(rif, k_pages.shape[2] * q.shape[2]
                       * q.dtype.itemsize)
    return _decode_paged_impl(q, k_pages, v_pages, page_table, lengths,
                              rif=rif, interpret=interp, method=method)
