from repro.kernels.dae_gather.ops import dae_gather
from repro.kernels.dae_gather.ref import gather_ref

__all__ = ["dae_gather", "gather_ref"]
