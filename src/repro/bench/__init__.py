"""repro.bench — declarative benchmark matrix with a regression gate.

The paper's evaluation (§6) is a grid: workloads x decoupling configs,
kernel vs compiled lowering, one vs many tenants, tuned vs default
knobs.  This package turns the repo's benchmark scripts into that grid
explicitly:

  * :mod:`~repro.bench.registry` — cells keyed by ``(workload, kind,
    engine, backend, tenants, tuned)`` plus a ``run(ctx)`` closure;
  * :mod:`~repro.bench.matrix` — runs **every** cell of an axis (no
    cherry-picking) and writes one ``BENCH_<axis>.json``;
  * :mod:`~repro.bench.schema` — versioned structural validation of
    those files (v2: first-class ``cycles``, cold/warm timing split,
    run metadata);
  * :mod:`~repro.bench.timing` — the cold/warm measurement primitive;
  * :mod:`~repro.bench.diffing` — the baseline diff: exact on cycle
    counts and integer derived values, percentage-banded on warm
    wall-clock, fnmatch allowlist for intentional changes.

The benchmark definitions themselves live in ``benchmarks/`` (the
scripts declare cells; ``python -m benchmarks.run matrix`` assembles
and runs the axes, ``python -m benchmarks.diff`` gates a fresh run
against ``benchmarks/baseline/``).  See ``docs/benchmarks.md``.
"""

from repro.bench.diffing import (FAIL_KINDS, Finding, diff_reports,
                                 parse_allowlist, regressions)
from repro.bench.matrix import run_axis, run_cells
from repro.bench.registry import (COORD_KEYS, KINDS, BenchContext, Cell,
                                  CellResult, check_cells, coords)
from repro.bench.report import (bench_meta, bench_path, build_report,
                                cell_csv, load_report, write_report)
from repro.bench.schema import (SCHEMA_VERSION, SchemaError,
                                schema_problems, validate_report)
from repro.bench.timing import Timing, measure, percentile, percentiles

__all__ = [
    "BenchContext", "Cell", "CellResult", "COORD_KEYS", "KINDS",
    "check_cells", "coords",
    "run_axis", "run_cells",
    "SCHEMA_VERSION", "SchemaError", "schema_problems", "validate_report",
    "Timing", "measure", "percentile", "percentiles",
    "FAIL_KINDS", "Finding", "diff_reports", "parse_allowlist",
    "regressions",
    "bench_meta", "bench_path", "build_report", "cell_csv", "load_report",
    "write_report",
]
