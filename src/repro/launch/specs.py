"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape)`` returns the argument pytree for the step the
shape lowers: train_4k/prefill -> train_step/prefill_step inputs;
decode_* -> serve_step inputs (one new token + KV cache of seq_len).
Modality frontends ([audio]/[vlm]) are STUBS: precomputed frame/patch
embeddings appear here as dense inputs.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.shapes import InputShape
from repro.models.common import ModelConfig
from repro.models.registry import build_model

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {
        "tokens": SDS((b, s), jnp.int32),
        "labels": SDS((b, s), jnp.int32),
    }
    if cfg.family == "encdec":
        # audio frontend stub: precomputed frame embeddings (enc input);
        # frame count = seq_len (one frame embedding per target position)
        specs["frames"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    return specs


def decode_arg_specs(cfg: ModelConfig, shape: InputShape
                     ) -> Tuple[Any, Dict[str, Any]]:
    """Returns (cache_specs, other_arg_specs) for serve_step."""
    b, s = shape.global_batch, shape.seq_len
    bundle = build_model(cfg)
    cache_specs = jax.eval_shape(lambda: bundle.cache_init(b, s))
    args: Dict[str, Any] = {
        "token": SDS((b,), jnp.int32),
        "pos": SDS((b,), jnp.int32),
    }
    if cfg.family == "encdec":
        args["enc_out"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    return cache_specs, args


def param_specs(cfg: ModelConfig) -> Any:
    bundle = build_model(cfg)
    return jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
