from repro.kernels.dae_chase.ops import batched_searchsorted, hash_lookup
from repro.kernels.dae_chase.ref import searchsorted_ref, hash_lookup_ref

__all__ = ["batched_searchsorted", "hash_lookup", "searchsorted_ref",
           "hash_lookup_ref"]
