"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

R = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-5)


# -- dae_gather ---------------------------------------------------------------


@pytest.mark.parametrize("n,d,m", [(64, 128, 16), (100, 256, 33),
                                   (37, 130, 7), (512, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("method", ["pipelined", "rif"])
def test_gather_sweep(n, d, m, dtype, method):
    from repro.kernels.dae_gather import dae_gather, gather_ref
    table = jnp.asarray(R.standard_normal((n, d)), dtype)
    idx = jnp.asarray(R.integers(0, n, m), jnp.int32)
    out = dae_gather(table, idx, method=method, chunk=8, rif=4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gather_ref(table, idx), np.float32))


# -- dae_spmv -----------------------------------------------------------------


@pytest.mark.parametrize("n,m,nnz", [(16, 256, 64), (33, 300, 120),
                                     (8, 128, 0)])
def test_spmv_sweep(n, m, nnz):
    from repro.kernels.dae_spmv import (bsr_spmv_ref, csr_to_bsr, dae_spmv,
                                        spmv_ref)
    counts = R.multinomial(nnz, np.ones(n) / n) if nnz else np.zeros(n, int)
    rows = np.zeros(n + 1, np.int64)
    rows[1:] = np.cumsum(counts)
    cols = R.integers(0, m, nnz)
    val = R.standard_normal(nnz).astype(np.float32)
    vec = R.standard_normal(m).astype(np.float32)
    vb, ri, ci, _, nrb = csr_to_bsr(rows, cols, val, m)
    out = dae_spmv(jnp.asarray(vb), jnp.asarray(ri), jnp.asarray(ci),
                   jnp.asarray(vec), nrb)[:n]
    ref = spmv_ref(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(val),
                   jnp.asarray(vec)) if nnz else np.zeros(n, np.float32)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


# -- dae_merge ----------------------------------------------------------------


@pytest.mark.parametrize("n,m", [(256, 256), (100, 300), (17, 5), (64, 0),
                                 (1, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_merge_sweep(n, m, dtype):
    from repro.kernels.dae_merge import merge_ref, merge_sorted
    if dtype == jnp.int32:
        a = jnp.sort(jnp.asarray(R.integers(0, 50, n), dtype))
        b = jnp.sort(jnp.asarray(R.integers(0, 50, max(m, 1))[:m], dtype))
    else:
        a = jnp.sort(jnp.asarray(R.standard_normal(n), dtype))
        b = jnp.sort(jnp.asarray(R.standard_normal(max(m, 1))[:m], dtype))
    out = merge_sorted(a, b, tile=64)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(merge_ref(a, b)))


def test_merge_sort_full():
    from repro.kernels.dae_merge import merge_sort
    x = jnp.asarray(R.integers(0, 10_000, 777), jnp.int32)
    np.testing.assert_array_equal(np.asarray(merge_sort(x, tile=64)),
                                  np.sort(np.asarray(x)))


# -- dae_chase ----------------------------------------------------------------


@pytest.mark.parametrize("n,b", [(1000, 37), (130, 8), (5000, 256)])
def test_searchsorted_sweep(n, b):
    from repro.kernels.dae_chase import batched_searchsorted, searchsorted_ref
    table = jnp.sort(jnp.asarray(R.standard_normal(n), jnp.float32))
    keys = jnp.asarray(R.standard_normal(b), jnp.float32)
    out = batched_searchsorted(table, keys, block=128)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(searchsorted_ref(table, keys)))


def test_hash_lookup_chains():
    from repro.kernels.dae_chase import hash_lookup, hash_lookup_ref
    n, chains, L = 64, 16, 4
    ek = jnp.asarray(np.arange(n), jnp.int32)
    ev = jnp.asarray(R.integers(0, 1000, n), jnp.int32)
    en = jnp.asarray([(i + 1) if (i + 1) % L else -1 for i in range(n)],
                     jnp.int32)
    heads = jnp.asarray([L * c for c in range(chains)], jnp.int32)
    keys = jnp.asarray([L * c + L - 1 for c in range(chains)], jnp.int32)
    out = hash_lookup(ek, ev, en, heads, keys, max_steps=L)
    ref = hash_lookup_ref(ek, ev, en, heads, keys, L)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # missing key -> -1
    missing = hash_lookup(ek, ev, en, heads, heads * 0 + 10_000, max_steps=L)
    assert (np.asarray(missing) == -1).all()


# -- flash attention ----------------------------------------------------------


@pytest.mark.parametrize("b,h,kvh,s,d,causal,window", [
    (2, 4, 2, 256, 64, True, None),
    (1, 8, 1, 100, 32, True, None),
    (2, 4, 4, 128, 64, False, None),
    (1, 4, 2, 256, 64, True, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(b, h, kvh, s, d, causal, window, dtype):
    from repro.kernels.flash_attention import attention_ref, flash_attention
    q = jnp.asarray(R.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(R.standard_normal((b, kvh, s, d)), dtype)
    v = jnp.asarray(R.standard_normal((b, kvh, s, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_decode_and_paged():
    from repro.kernels.flash_attention import decode_ref, flash_decode
    from repro.kernels.flash_attention.ops import flash_decode_paged
    b, h, kvh, s, d = 2, 8, 2, 256, 64
    q = jnp.asarray(R.standard_normal((b, h, d)), jnp.float32)
    kc = jnp.asarray(R.standard_normal((b, kvh, s, d)), jnp.float32)
    vc = jnp.asarray(R.standard_normal((b, kvh, s, d)), jnp.float32)
    lens = jnp.asarray([100, 256], jnp.int32)
    out = flash_decode(q, kc, vc, lens, bk=64)
    ref = decode_ref(q, kc, vc, lens)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-5)
    page = 64
    npb = s // page
    kp = kc.transpose(0, 2, 1, 3).reshape(b * npb, page, kvh, d).transpose(0, 2, 1, 3)
    vp = vc.transpose(0, 2, 1, 3).reshape(b * npb, page, kvh, d).transpose(0, 2, 1, 3)
    pt = jnp.arange(b * npb, dtype=jnp.int32).reshape(b, npb)
    out2 = flash_decode_paged(q, kp, vp, pt, lens)
    np.testing.assert_allclose(out2, ref, rtol=2e-4, atol=2e-5)


def test_chunked_attention_matches_ref():
    from repro.kernels.flash_attention.ref import (attention_chunked,
                                                   attention_ref)
    q = jnp.asarray(R.standard_normal((2, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(R.standard_normal((2, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(R.standard_normal((2, 2, 256, 64)), jnp.float32)
    for caus, win in [(True, None), (True, 64), (False, None)]:
        out = attention_chunked(q, k, v, causal=caus, window=win, chunk=64)
        ref = attention_ref(q, k, v, causal=caus, window=win)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-5)


# -- grouped matmul -----------------------------------------------------------


@pytest.mark.parametrize("t,d,f,e,bt", [(256, 192, 160, 4, 64),
                                        (128, 128, 128, 2, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_sweep(t, d, f, e, bt, dtype):
    from repro.kernels.grouped_matmul import grouped_matmul, grouped_matmul_ref
    x = jnp.asarray(R.standard_normal((t, d)), dtype)
    w = jnp.asarray(R.standard_normal((e, d, f)), dtype)
    be = jnp.asarray(np.sort(R.integers(0, e, t // bt)), jnp.int32)
    out = grouped_matmul(x, w, be, bt=bt)
    ref = grouped_matmul_ref(x, w, be, bt)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_banded_attention_matches_ref():
    from repro.kernels.flash_attention.ref import (attention_banded,
                                                   attention_ref)
    q = jnp.asarray(R.standard_normal((1, 4, 256, 32)), jnp.float32)
    k = jnp.asarray(R.standard_normal((1, 2, 256, 32)), jnp.float32)
    v = jnp.asarray(R.standard_normal((1, 2, 256, 32)), jnp.float32)
    for w, c in [(64, 32), (64, 64), (200, 64)]:
        ref = attention_ref(q, k, v, causal=True, window=w)
        for unroll in (False, True):
            out = attention_banded(q, k, v, window=w, chunk=c, unroll=unroll)
            np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-5)


# -- dispatch helpers ---------------------------------------------------------


def test_decode_chunk_ref_matches_decode_ref():
    """The chunked-prefill oracle must be bit-identical to per-query
    decode_ref calls (serving parity depends on it)."""
    from repro.kernels.flash_attention.ref import decode_chunk_ref, decode_ref
    b, h, kvh, c, s, d = 2, 4, 2, 3, 32, 16
    q = jnp.asarray(R.standard_normal((b, h, c, d)), jnp.float32)
    kc = jnp.asarray(R.standard_normal((b, kvh, s, d)), jnp.float32)
    vc = jnp.asarray(R.standard_normal((b, kvh, s, d)), jnp.float32)
    lens = jnp.asarray(R.integers(1, s, (b, c)), jnp.int32)
    out = decode_chunk_ref(q, kc, vc, lens)
    for i in range(c):
        np.testing.assert_array_equal(
            np.asarray(out[:, :, i]),
            np.asarray(decode_ref(q[:, :, i], kc, vc, lens[:, i])))


@pytest.mark.parametrize("raw,expect", [
    (None, False),        # unset: fall through to the backend check
    ("0", False), ("false", False), ("", False), ("no", False),
    ("off", False), ("  FALSE  ", False),
    ("1", True), ("true", True), ("yes", True), ("interpret", True),
])
def test_resolve_interpret_env_parsing(raw, expect, monkeypatch):
    """Regression: REPRO_FORCE_INTERPRET=0/false/empty used to force
    interpret ON (any non-empty string was truthy)."""
    from repro.kernels.common import resolve_interpret
    if raw is None:
        monkeypatch.delenv("REPRO_FORCE_INTERPRET", raising=False)
    else:
        monkeypatch.setenv("REPRO_FORCE_INTERPRET", raw)
    # pretend we are on TPU so the backend fallback returns False and
    # the env var's parse is the only thing that can flip the result
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert resolve_interpret(None) is expect
    # an explicit caller value still always wins
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
