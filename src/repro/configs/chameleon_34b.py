"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536; early-fusion VQ image tokens (frontend STUB: token ids are
already fused) [arXiv:2405.09818; unverified].  Uses qk-norm."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22_016,
    vocab=65_536,
    qk_norm=True,
)
