"""Serving driver: batched decode with continuous batching.

Run: PYTHONPATH=src python examples/serve_decode.py --requests 6 --slots 2
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.registry import build_model
from repro.runtime.serve_loop import Request, ServeLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ns = ap.parse_args()

    cfg = get_config(ns.arch, smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, m, params, batch_slots=ns.slots, s_max=128)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=4),
                    max_new=ns.max_new)
            for i in range(ns.requests)]
    t0 = time.time()
    results = loop.run(reqs)
    dt = time.time() - t0
    total_toks = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {total_toks} tokens "
          f"in {dt:.1f}s on {ns.slots} slots")
    for rid in sorted(results):
        print(f"  req {rid}: {results[rid]}")
    assert len(results) == ns.requests


if __name__ == "__main__":
    main()
