"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps with the full substrate (synthetic data, prefetch, AdamW + cosine,
fault-tolerant loop with checkpoints + straggler monitor).

Run (full):  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
Run (demo):  PYTHONPATH=src python examples/train_lm.py --preset 20m --steps 50
"""

import argparse
import dataclasses
import logging
import time

import jax

from repro.configs import get_config
from repro.data import PrefetchLoader, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models.common import ModelConfig
from repro.models.registry import build_model
from repro.models.transformer import param_count
from repro.optim import AdamW, warmup_cosine
from repro.runtime import StragglerMonitor, TrainLoopConfig, fit

PRESETS = {
    # ~params: d^2*12*L + 2*V*d
    "20m": dict(n_layers=6, d_model=384, n_heads=6, n_kv_heads=2,
                d_ff=1536, vocab=8192),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab=32768),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ns = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = get_config("qwen3-4b", smoke=False, **PRESETS[ns.preset],
                     dtype="float32", head_dim=0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    n = param_count(params)
    print(f"arch=dense preset={ns.preset} params={n/1e6:.1f}M")

    opt = AdamW(lr=warmup_cosine(3e-4, 20, ns.steps), weight_decay=0.1)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))

    ds = SyntheticLM(vocab=cfg.vocab, seq_len=ns.seq, global_batch=ns.batch)
    mon = StragglerMonitor()
    t0 = time.time()
    out = fit(step, params, opt.init(params), ds.batch_at,
              TrainLoopConfig(total_steps=ns.steps, ckpt_every=25,
                              ckpt_dir=ns.ckpt_dir, log_every=10),
              monitor=mon)
    dt = time.time() - t0
    print(f"done: {out['steps']} steps in {dt:.1f}s "
          f"({dt / max(len(out['losses']), 1):.2f}s/step)")
    print(f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"(restarts={out['restarts']}, "
          f"stragglers={len(out['straggler_events'])})")
    assert out["losses"][-1] < out["losses"][0], "loss did not decrease"


if __name__ == "__main__":
    main()
