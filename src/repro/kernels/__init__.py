"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

``ring.py`` is the shared explicit-decoupling emitter: a ``RingChannel``
(``request``/``response`` on a rif-deep scratch+semaphore ring — the TPU
form of ``decouple_request``/``decouple_response``) plus the
``access_execute``/``ring_step`` loop scaffolds that generate the
prologue/steady-state/drain structure once.  Every irregular-access
kernel below is emitted through it.

Each subpackage has kernel.py (pl.pallas_call + BlockSpec), ops.py (the
jit'd public wrapper) and ref.py (the pure-jnp oracle used by tests and
the dry-run):

  dae_gather      decoupled row gather (scalar-prefetch + RIF DMA ring)
  dae_spmv        BSR sparse matvec (paper Listing 2, TPU block form)
  dae_merge       merge-path + bitonic merge (paper Listing 3)
  dae_chase       decoupled block binsearch + lock-step hash-chain walk
                  (paper Listings 4/5)
  flash_attention block-streamed attention + (paged) decode
  grouped_matmul  MoE expert GEMM with scalar-prefetched group stream
"""
