"""Data pipeline, optimizer, checkpointing, fault-tolerant runtime."""

import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.data import PrefetchLoader, SyntheticLM
from repro.optim import AdamW, warmup_cosine
from repro.runtime import StragglerMonitor, TrainLoopConfig, fit
from repro.runtime.train_loop import StepFailure


# -- data ---------------------------------------------------------------------


def test_synthetic_determinism():
    ds = SyntheticLM(vocab=100, seq_len=16, global_batch=4, seed=7)
    a = ds.batch_at(12)
    b = ds.batch_at(12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["labels"][0, -1] == -1
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_prefetch_loader_order_and_close():
    ds = SyntheticLM(vocab=50, seq_len=4, global_batch=2)

    def gen():
        for i in range(5):
            yield i

    loader = PrefetchLoader(gen(), capacity=2)
    assert list(loader) == [0, 1, 2, 3, 4]
    loader.close()
    del ds


# -- optimizer ----------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, gnorm = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1
    assert float(gnorm) >= 0


def test_grad_clip():
    opt = AdamW(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, state, gnorm = opt.update({"w": jnp.full(3, 100.0)}, state, params)
    assert float(gnorm) > 1.0  # reported pre-clip norm


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.array(0))) == 0.0
    assert abs(float(lr(jnp.array(10))) - 1.0) < 1e-5
    assert float(lr(jnp.array(100))) < float(lr(jnp.array(50)))


# -- checkpoint ---------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
            "seg": [jnp.zeros(2), jnp.full(2, 7.0)]}
    save_pytree(tmp_path / "x.npz", tree, meta={"step": 5})
    like = jax.eval_shape(lambda: tree)
    out, meta = load_pytree(tmp_path / "x.npz", like)
    assert meta["step"] == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert not list(tmp_path.glob("*.tmp"))  # atomic: no leftovers


def test_checkpoint_manager_retention_and_resume(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    state = {"w": jnp.zeros(4)}
    for s in (10, 20, 30):
        mgr.save(s, {"w": jnp.full(4, float(s))})
    assert mgr.latest_step() == 30
    assert len(list(Path(tmp_path).glob("step_*.npz"))) == 2  # retention
    step, restored, meta = mgr.restore_latest(jax.eval_shape(lambda: state))
    assert step == 30
    np.testing.assert_array_equal(np.asarray(restored["w"]), 30.0)


# -- fault-tolerant training loop ----------------------------------------------


def _tiny_setup():
    from repro.configs import get_config
    from repro.launch.steps import make_train_step
    from repro.models.registry import build_model
    cfg = get_config("qwen3-4b", smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3)
    step = jax.jit(make_train_step(cfg, opt))
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=16, global_batch=4)
    return params, opt.init(params), step, ds


def test_fit_loss_decreases(tmp_path):
    params, opt_state, step, ds = _tiny_setup()
    cfg = TrainLoopConfig(total_steps=30, ckpt_every=10,
                          ckpt_dir=str(tmp_path), async_ckpt=False)
    out = fit(step, params, opt_state, ds.batch_at, cfg)
    assert out["steps"] == 30
    assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5])


def test_fit_recovers_from_failures(tmp_path):
    params, opt_state, step, ds = _tiny_setup()
    cfg = TrainLoopConfig(total_steps=20, ckpt_every=5,
                          ckpt_dir=str(tmp_path), async_ckpt=False)
    tripped = {"done": False}

    def failure_hook(s):
        if s == 12 and not tripped["done"]:
            tripped["done"] = True
            raise StepFailure("injected node failure at step 12")

    out = fit(step, params, opt_state, ds.batch_at, cfg,
              failure_hook=failure_hook)
    assert out["steps"] == 20
    assert out["restarts"] == 1
    # resumed from step 10 checkpoint, so steps 10/11 were replayed


def test_fit_resumes_across_process_restarts(tmp_path):
    params, opt_state, step, ds = _tiny_setup()
    cfg = TrainLoopConfig(total_steps=10, ckpt_every=5,
                          ckpt_dir=str(tmp_path), async_ckpt=False)
    fit(step, params, opt_state, ds.batch_at, cfg)
    # "new process": fresh initial state, must resume at 10 and stop
    cfg2 = TrainLoopConfig(total_steps=15, ckpt_every=5,
                           ckpt_dir=str(tmp_path), async_ckpt=False)
    out = fit(step, params, opt_state, ds.batch_at, cfg2)
    assert out["steps"] == 15
    assert len(out["losses"]) == 5  # only 5 new steps run


# -- straggler monitor ---------------------------------------------------------


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0, warmup_steps=3)
    for s in range(20):
        dur = 1.0 if s != 15 else 5.0
        mon.stop(s, duration=dur)
    assert len(mon.events) == 1
    assert mon.events[0].step == 15
    assert mon.events[0].ratio > 2.0
    # EWMA not polluted by the outlier
    assert abs(mon.ewma - 1.0) < 0.05
