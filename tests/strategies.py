"""Randomized inputs shared by the test suites: DaeProgram specs for the
differential-parity harness (test_parity.py) and the property tests
(test_properties.py), plus shape/dtype/rif case strategies for the
ring-emitter kernel differential tests (test_ring_kernels.py).

Programs are generated as *specs* — plain dicts of op lists — so a spec
can be instantiated freshly for each engine run of a differential pair
(``build_program`` hands :class:`Process` generator *factories*, so the
built programs are also rebuildable/validatable in place).

The generator covers the scheduling-interleaving space: random channel
topologies (load + stream, shared producer/consumer processes), random
capacities small enough to block, random initiation intervals, delays,
stores, store-waits, and two memory ports with random latency and
outstanding-request budgets.  Composite effects are generated too:
``Par`` pairs drawn from two distinct channel streams of one process,
``Par`` of a channel op with a ``StoreWait`` (the non-monotone park
that once diverged the event scheduler from the polling oracle), and
``Fused`` response->store combinational paths — on top of the
workload-grid half of the parity harness, whose paper benchmarks lean
on fused/parallel effects throughout.

Specs keep per-channel op order (requests before their responses on the
same process) but interleave channels randomly across processes, so a
spec may deadlock (a consumer parked before its producer can run) or
violate §5.1 conservation — both are *valid* differential outcomes: the
two engines must raise identical errors.

Hypothesis strategies wrapping the same generator are exported when
hypothesis is installed (``program_specs()``); everything else works
without it.
"""

from __future__ import annotations

import random
from typing import Any, Dict, List, Tuple

from repro.core.dae import (DaeProgram, Delay, Deq, Enq, LoadChannel,
                            Process, Req, Resp, Store, StoreWait,
                            StreamChannel)
from repro.core.simulator import (EngineInstance, FixedLatencyMemory,
                                  Fused, Par)

PORTS = ("mem0", "mem1")
DATA_WORDS = 64


def random_spec(rng: random.Random) -> Dict[str, Any]:
    """One random program spec: channels, per-process op lists, timing."""
    n_procs = rng.randint(1, 4)
    n_chans = rng.randint(1, 4)
    chans = []
    for _ in range(n_chans):
        chans.append({
            "kind": rng.choice(("load", "stream")),
            "capacity": rng.randint(1, 5),
            "port": rng.choice(PORTS),
            "producer": rng.randrange(n_procs),
            "consumer": rng.randrange(n_procs),
            "count": rng.randint(1, 10),
        })

    # per-process: one op stream per channel role, merged in random order
    streams: List[List[List[Tuple]]] = [[] for _ in range(n_procs)]
    for ci, c in enumerate(chans):
        if c["kind"] == "load":
            prod = [("req", ci, rng.randrange(DATA_WORDS))
                    for _ in range(c["count"])]
            cons = [("resp", ci)] * c["count"]
        else:
            prod = [("enq", ci, rng.randrange(1000))
                    for _ in range(c["count"])]
            cons = [("deq", ci)] * c["count"]
        streams[c["producer"]].append(prod)
        streams[c["consumer"]].append(cons)

    procs = []
    store_addr = 0
    for pi in range(n_procs):
        pending = [list(s) for s in streams[pi] if s]
        ops: List[Tuple] = []
        while pending:
            s = rng.choice(pending)
            op = s.pop(0)
            if not s:
                pending.remove(s)
            r = rng.random()
            others = [x for x in pending if x is not s]
            if r < 0.12 and others:
                # Par of ops from two distinct streams (per-channel op
                # order is preserved: each op is its stream's head; two
                # ops of the SAME stream in one Par would double-pop a
                # single ready FIFO entry)
                s2 = rng.choice(others)
                op2 = s2.pop(0)
                if not s2:
                    pending.remove(s2)
                ops.append(("par", op, op2))
            elif r < 0.18:
                # Par with a StoreWait: the write-response edge inside a
                # parallel slot (the non-monotone eager-watch park)
                ops.append(("par", op, ("storewait",)))
            elif r < 0.26 and op[0] in ("resp", "deq"):
                # Fused combinational path: consume -> store in one slot
                ops.append(("fused_store", op, store_addr))
                store_addr += 1
            else:
                ops.append(op)
            r = rng.random()
            if r < 0.10:
                ops.append(("delay", rng.randint(0, 3)))
            elif r < 0.18:
                ops.append(("store", store_addr))
                store_addr += 1
        if ops and rng.random() < 0.3:
            ops.append(("storewait",))
        procs.append({"ops": ops, "ii": rng.randint(1, 3)})

    return {
        "chans": chans,
        "procs": procs,
        "latency": rng.choice((3, 17, 100)),
        "max_outstanding": rng.choice((2, 5, 64)),
        "n_stores": store_addr,
    }


def build_program(spec: Dict[str, Any], name: str = "rand"
                  ) -> Tuple[DaeProgram, Dict[str, FixedLatencyMemory]]:
    """Instantiate a spec as a fresh DaeProgram plus its memory models.

    Call once per simulation — the returned program's generators are
    consumed by a run.
    """
    chan_objs = []
    for ci, c in enumerate(spec["chans"]):
        if c["kind"] == "load":
            chan_objs.append(LoadChannel(f"c{ci}", capacity=c["capacity"],
                                         port=c["port"]))
        else:
            chan_objs.append(StreamChannel(f"c{ci}",
                                           capacity=c["capacity"]))

    def effect_of(op, last):
        kind = op[0]
        if kind == "req":
            return Req(chan_objs[op[1]], op[2])
        if kind == "resp":
            return Resp(chan_objs[op[1]])
        if kind == "enq":
            return Enq(chan_objs[op[1]], op[2])
        if kind == "deq":
            return Deq(chan_objs[op[1]])
        if kind == "delay":
            return Delay(op[1])
        if kind == "store":
            return Store("out", op[1], last)
        assert kind == "storewait", op
        return StoreWait("out")

    def make_gen(ops):
        def gen():
            last = 0
            for op in ops:
                kind = op[0]
                if kind == "par":
                    vals = yield Par([effect_of(sub, last)
                                      for sub in op[1:]])
                    for v in vals:
                        if v is not None:
                            last = v
                elif kind == "fused_store":
                    addr = op[2]
                    last = yield Fused(effect_of(op[1], last),
                                       lambda v, a=addr: Store("out", a, v))
                elif kind in ("resp", "deq"):
                    last = yield effect_of(op, last)
                else:
                    yield effect_of(op, last)
        return gen  # a factory: the built Process is rebuildable

    procs = [Process(f"p{pi}", make_gen(p["ops"]), ii=p["ii"])
             for pi, p in enumerate(spec["procs"])]
    lat, mo = spec["latency"], spec["max_outstanding"]
    mems = {
        "mem0": FixedLatencyMemory(list(range(DATA_WORDS)), lat,
                                   max_outstanding=mo),
        "mem1": FixedLatencyMemory(list(range(100, 100 + DATA_WORDS)), lat,
                                   max_outstanding=mo),
        "out": FixedLatencyMemory([None] * max(1, spec["n_stores"]), lat),
    }
    return DaeProgram(name, procs), mems


def build_engine_inputs(spec: Dict[str, Any], n_instances: int
                        ) -> Tuple[List[EngineInstance],
                                   Dict[str, FixedLatencyMemory]]:
    """N instances of one spec contending for a shared ``mem0`` port;
    ``mem1`` and ``out`` stay private per tenant."""
    lat, mo = spec["latency"], spec["max_outstanding"]
    shared = {"mem0": FixedLatencyMemory(list(range(DATA_WORDS)), lat,
                                         max_outstanding=mo)}
    instances = []
    for i in range(n_instances):
        prog, mems = build_program(spec, name=f"rand{i}")
        private = {p: m for p, m in mems.items() if p != "mem0"}
        instances.append(EngineInstance(f"t{i}", prog, private))
    return instances, shared


try:  # optional hypothesis strategies over the same generator
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised via importorskip
    st = None

if st is not None:
    def program_specs():
        """Hypothesis strategy: a random program spec (shrinks by seed)."""
        return st.integers(min_value=0, max_value=2**31 - 1).map(
            lambda seed: random_spec(random.Random(seed)))

    # -- ring-emitter kernel cases -----------------------------------------
    #
    # Shapes are kept small (every example runs a Pallas kernel in
    # interpret mode) but deliberately cover the ring's edge regimes:
    # rif=1 (a fully serialized ring), rif > chunk/tiles (prologue
    # clamped by the item count), and non-multiple tails (dispatcher
    # padding must not leak into results).

    def _rifs():
        return st.sampled_from((1, 2, 3, 8, 64))

    def float_dtypes():
        return st.sampled_from(("float32", "bfloat16"))

    def gather_cases():
        """(n, d, m, chunk, rif, dtype) for dae_gather method='rif'."""
        return st.fixed_dictionaries({
            "n": st.integers(1, 80),
            "d": st.sampled_from((8, 128, 130, 200)),
            "m": st.integers(1, 70),
            "chunk": st.sampled_from((1, 4, 8, 64)),
            "rif": _rifs(),
            "dtype": float_dtypes(),
        })

    def merge_cases():
        """(n, m, tile, rif, dtype) for merge_sorted."""
        return st.fixed_dictionaries({
            "n": st.integers(0, 200),
            "m": st.integers(1, 200),
            "tile": st.sampled_from((16, 64, 256)),
            "rif": _rifs(),
            "dtype": st.sampled_from(("float32", "int32")),
        })

    def spmv_cases():
        """(nrows, ncols, nnz, rif) for csr_to_bsr + dae_spmv."""
        return st.fixed_dictionaries({
            "nrows": st.integers(1, 40),
            "ncols": st.sampled_from((16, 100, 256)),
            "nnz": st.integers(0, 150),
            "rif": _rifs(),
        })

    def decode_cases():
        """(b, kvh, g, s, d, bk, rif) for flash_decode [+ paged]."""
        return st.fixed_dictionaries({
            "b": st.integers(1, 3),
            "kvh": st.sampled_from((1, 2)),
            "g": st.sampled_from((1, 4)),
            "nblk": st.integers(1, 4),      # cache length = nblk * bk
            "bk": st.sampled_from((16, 64)),
            "rif": _rifs(),
        })

    def searchsorted_cases():
        """(n, m, block, chunk, rif) for batched_searchsorted."""
        return st.fixed_dictionaries({
            "n": st.integers(1, 600),
            "m": st.integers(1, 100),
            "block": st.sampled_from((64, 128)),
            "chunk": st.sampled_from((1, 8, 64)),
            "rif": _rifs(),
            "dtype": st.sampled_from(("float32", "int32")),
        })

    def gmm_cases():
        """(t, d, f, e, bt, bf, bd, rif) for grouped_matmul."""
        return st.fixed_dictionaries({
            "t": st.integers(1, 300),
            "d": st.sampled_from((32, 64, 200)),
            "f": st.sampled_from((16, 64, 130)),
            "e": st.integers(1, 5),
            "bt": st.sampled_from((32, 128)),
            "bf": st.sampled_from((128, 256)),
            "bd": st.sampled_from((128, 256)),
            "rif": _rifs(),
        })

    def hash_cases():
        """(chains, chain_len, m, chunk, rif, max_steps) for hash_lookup."""
        return st.fixed_dictionaries({
            "chains": st.integers(1, 24),
            "chain_len": st.integers(1, 6),
            "m": st.integers(1, 50),
            "chunk": st.sampled_from((1, 8, 64)),
            "rif": _rifs(),
            "extra_steps": st.integers(-2, 2),  # walk short or long
            "miss_rate": st.sampled_from((0.0, 0.3, 1.0)),
        })
