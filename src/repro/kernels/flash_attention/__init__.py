from repro.kernels.flash_attention.ops import flash_attention, flash_decode
from repro.kernels.flash_attention.ref import attention_ref, decode_ref

__all__ = ["flash_attention", "flash_decode", "attention_ref", "decode_ref"]
