"""Shared model config, initializers and numeric primitives.

Pure-functional style: params are nested dicts of jnp arrays; every layer
is ``init(cfg, key) -> params`` + ``apply(cfg, params, ...) -> out``.
Param leaves are annotated for sharding by *path name convention*
(see repro.parallel.sharding): e.g. any leaf whose path ends in
``.../wq`` shards its output dim over the model axis.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """A run of ``count`` consecutive identical layers.

    kind: 'attn' (self-attn + mlp), 'moe' (self-attn + moe),
          'hymba' (parallel attn+ssm + mlp), 'hymba_global',
          'rwkv' (time-mix + channel-mix)
    """

    kind: str
    count: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                       # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # attention
    attn_kind: str = "gqa"            # gqa | mla
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: Optional[int] = None      # sliding-window size (local attn)
    # MLA (DeepSeek/MiniCPM3)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 0              # 0 -> head_dim
    v_head_dim: int = 0               # 0 -> head_dim

    # mlp
    mlp_kind: str = "swiglu"          # swiglu | relu | gelu

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden dim
    capacity_factor: float = 1.25
    first_dense_layers: int = 0       # leading dense-FFN layers (deepseek)
    pad_experts_to: int = 0           # pad expert dim for EP divisibility

    # SSM / hybrid
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0              # 0 -> d_model // 16
    global_attn_layers: Tuple[int, ...] = ()   # hymba full-attn layer ids

    # encoder-decoder
    n_enc_layers: int = 0
    enc_bidirectional: bool = True

    # rwkv
    rwkv_head_dim: int = 64

    # norms / embedding
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_soft_cap: float = 0.0

    # numerics / kernels
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"
    kernel_mode: str = "ref"          # ref | pallas (interpret on CPU)
    attn_impl: str = "ref"            # ref (S^2) | chunked (online softmax)
    attn_chunk: int = 1024
    remat: bool = True
    remat_policy: str = "full"        # full | dots (save matmul outputs)
    scan_layers: bool = True          # False -> unrolled (cost-model probes)
    act_sp: bool = False              # sequence-parallel residual stream
    mesh_dp_axes: Tuple[str, ...] = ("data",)   # set by launch/steps.py
    mesh_tp_axis: str = "model"
    # sharded paged serving: constrain KV page pools' page dim to this
    # mesh axis inside jit (None = leave placement to propagation)
    mesh_pool_axis: Optional[str] = None

    @property
    def n_experts_padded(self) -> int:
        return max(self.n_experts, self.pad_experts_to)

    # ----- derived -----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def qk_nope(self) -> int:
        return self.qk_nope_dim or self.hd

    @property
    def v_hd(self) -> int:
        return self.v_head_dim or self.hd

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(1, self.d_model // 16)

    def layer_specs(self) -> List[LayerSpec]:
        """Consecutive homogeneous segments for scan-over-layers."""
        if self.family == "ssm":
            return [LayerSpec("rwkv", self.n_layers)]
        if self.family == "hybrid":
            segs: List[LayerSpec] = []
            g = set(self.global_attn_layers)
            i = 0
            while i < self.n_layers:
                kind = "hymba_global" if i in g else "hymba"
                j = i
                while j < self.n_layers and (
                        ("hymba_global" if j in g else "hymba") == kind):
                    j += 1
                segs.append(LayerSpec(kind, j - i))
                i = j
            return segs
        if self.family == "moe":
            segs = []
            if self.first_dense_layers:
                segs.append(LayerSpec("attn", self.first_dense_layers))
            segs.append(LayerSpec("moe", self.n_layers - self.first_dense_layers))
            return segs
        return [LayerSpec("attn", self.n_layers)]


# ---------------------------------------------------------------------------
# Initializers / numeric primitives
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rmsnorm_init(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * g.astype(jnp.float32)).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (..., S, D_even); positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def activation(kind: str, x: jnp.ndarray) -> jnp.ndarray:
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind in ("silu", "swiglu"):
        return jax.nn.silu(x)
    raise ValueError(kind)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       ignore_id: int = -1) -> jnp.ndarray:
    """logits (..., V) f32; labels int; mean over non-ignored.

    The gold logit is extracted with an iota-mask reduction rather than
    take_along_axis: a gather along the vocab axis would force the SPMD
    partitioner to all-gather the (tokens, vocab) logits when vocab is
    model-sharded (~TB/step of ICI traffic at 4k x 256; see
    EXPERIMENTS.md §Perf iteration 1), while elementwise-mask + reduce
    keeps everything vocab-sharded and only psums scalars.
    """
    logits = logits.astype(jnp.float32)
    mask = labels != ignore_id
    safe = jnp.where(mask, labels, 0)
    m = jax.lax.stop_gradient(logits.max(axis=-1))
    logz = jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)) + m
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == safe[..., None], logits, 0.0),
                   axis=-1)
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
