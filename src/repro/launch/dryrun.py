import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: prove every (architecture x input shape) lowers,
SPMD-partitions, and fits on the production meshes — without hardware.

For each cell:
    with mesh:
        lowered = jax.jit(step, in_shardings=..., out_shardings=...)\
            .lower(*input_specs)
        compiled = lowered.compile()
        memory_analysis / cost_analysis / HLO collective scan

Results go to experiments/dryrun/<arch>__<shape>__<mesh>.json
(incremental; --force recomputes).  benchmarks/roofline.py turns these
into the §Roofline table.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, SHAPES, get_config, long_context_ok
from repro.configs.shapes import InputShape
from repro.launch.hlo_stats import collective_stats, count_ops
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (shard_prefill_step, shard_serve_step,
                                shard_train_step)
from repro.models.common import ModelConfig

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


# ---------------------------------------------------------------------------
# Cost probes: XLA counts a lax.scan body ONCE regardless of trip count, so
# FLOPs / bytes / collective bytes from the production (scanned) compile
# undercount the layer stack.  We therefore lower tiny UNROLLED variants —
# one per homogeneous layer segment with counts 1 vs 2 — and reconstruct:
#     total = base + sum_seg (L_seg - 1) * (probe_seg - base)
# which is exact for per-layer-replicated structure.  memory_analysis and
# the compile itself come from the real scanned artifact.
# ---------------------------------------------------------------------------


def segment_counts(cfg: ModelConfig):
    if cfg.family == "encdec":
        return [cfg.n_enc_layers, cfg.n_layers]
    return [s.count for s in cfg.layer_specs()]


def with_segment_counts(cfg: ModelConfig, counts):
    import dataclasses
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, n_enc_layers=counts[0],
                                   n_layers=counts[1], scan_layers=False)
    if cfg.family == "hybrid":
        kinds = [s.kind for s in cfg.layer_specs()]
        pos, globals_ = 0, []
        for kind, c in zip(kinds, counts):
            if kind == "hymba_global":
                globals_.extend(range(pos, pos + c))
            pos += c
        return dataclasses.replace(cfg, n_layers=pos,
                                   global_attn_layers=tuple(globals_),
                                   scan_layers=False)
    if cfg.family == "moe" and cfg.first_dense_layers:
        return dataclasses.replace(cfg, first_dense_layers=counts[0],
                                   n_layers=sum(counts), scan_layers=False)
    return dataclasses.replace(cfg, n_layers=counts[0], scan_layers=False)


def _probe_metrics(cfg, shape, mesh) -> dict:
    with mesh:
        jitted, args = build_cell(cfg, shape, mesh)
        compiled = jitted.lower(*args).compile()
        cost = compiled.cost_analysis()
        coll = collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "link_bytes": float(coll["_total"]["link_bytes"]),
        "coll_payload": float(coll["_total"]["payload_bytes"]),
    }


def corrected_cost(cfg: ModelConfig, shape: InputShape, mesh) -> dict:
    counts = segment_counts(cfg)
    nseg = len(counts)
    base_counts = [1] * nseg
    base = _probe_metrics(with_segment_counts(cfg, base_counts), shape, mesh)
    total = dict(base)
    deltas = []
    for i, li in enumerate(counts):
        probe_counts = list(base_counts)
        probe_counts[i] = 2
        probe = _probe_metrics(with_segment_counts(cfg, probe_counts), shape,
                               mesh)
        delta = {k: probe[k] - base[k] for k in base}
        deltas.append(delta)
        for k in total:
            total[k] += (li - 1) * delta[k]
    return {"total": total, "base": base,
            "per_segment_delta": deltas, "segment_counts": counts}


def cell_should_run(arch: str, shape: InputShape) -> bool:
    if shape.name == "long_500k" and not long_context_ok(arch):
        return False
    return True


def skip_reason(arch: str, shape: InputShape) -> str:
    return ("long_500k needs sub-quadratic attention; this arch is pure "
            "full-attention (docs/architecture.md §\"Model families and "
            "input shapes\")")


def build_cell(cfg: ModelConfig, shape: InputShape, mesh):
    if shape.kind == "train":
        jitted, args = shard_train_step(cfg, mesh, shape)
        flat_args = args
    elif shape.kind == "prefill":
        jitted, args = shard_prefill_step(cfg, mesh, shape)
        flat_args = args
    else:  # decode
        jitted, args = shard_serve_step(cfg, mesh, shape)
        flat_args = args
    return jitted, flat_args


def run_cell(arch: str, shape_name: str, mesh_kind: str, force: bool = False,
             out_dir: Path = OUT_DIR, overrides: dict | None = None,
             variant: str = "") -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_kind}"
    if variant:
        tag += f"__{variant}"
    out_path = out_dir / f"{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind, "seq_len": shape.seq_len,
           "global_batch": shape.global_batch, "variant": variant,
           "overrides": overrides or {}}

    if not cell_should_run(arch, shape):
        rec["status"] = "skipped"
        rec["reason"] = skip_reason(arch, shape)
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    cfg = get_config(arch, kernel_mode="ref", **(overrides or {}))
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        with mesh:
            jitted, args = build_cell(cfg, shape, mesh)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t1

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        cost_corr = corrected_cost(cfg, shape, mesh)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
                "code_bytes": int(mem.generated_code_size_in_bytes),
            },
            cost={k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float)) and
                  ("flops" in k or "bytes" in k or "utilization" in k)},
            cost_corrected=cost_corr,
            collectives=collective_stats(hlo),
            op_counts=count_ops(hlo),
            n_devices=int(mesh.devices.size),
        )
        print(f"[dryrun] {tag}: OK lower={t_lower:.1f}s "
              f"compile={t_compile:.1f}s "
              f"flops/dev={cost_corr['total']['flops']:.3e} "
              f"link_bytes/dev={cost_corr['total']['link_bytes']:.3e}")
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug to record
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {tag}: FAIL {type(e).__name__}: {str(e)[:200]}")

    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="", help="tag for override runs")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. attn_impl=chunked)")
    ns = ap.parse_args()

    overrides = {}
    for kv in ns.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            v = {"true": True, "false": False}.get(v.lower(), v)
        overrides[k] = v

    archs = [ns.arch] if ns.arch else list(ARCHS)
    shapes = [ns.shape] if ns.shape else list(SHAPES)
    meshes = ["single", "multi"] if ns.mesh == "both" else [ns.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind, force=ns.force,
                               overrides=overrides, variant=ns.variant)
                s = rec["status"]
                n_ok += s == "ok"
                n_fail += s == "error"
                n_skip += s == "skipped"
    print(f"[dryrun] done: ok={n_ok} skipped={n_skip} failed={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
