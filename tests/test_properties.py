"""Hypothesis property tests on the system's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' extra")
from hypothesis import given, strategies as st  # noqa: E402

from repro.core.dae import (ConservationError, DaeProgram, Deq, Enq,
                            LoadChannel, Process, Req, Resp, StreamChannel)
from repro.core.simulator import DeadlockError, FixedLatencyMemory, simulate

import strategies


# -- stream semantics: order preserved, conservation enforced ----------------


@given(st.lists(st.integers(-1000, 1000), min_size=1, max_size=40),
       st.integers(1, 8))
def test_stream_fifo_order(values, cap):
    stc = StreamChannel("s", capacity=cap)

    def prod():
        for v in values:
            yield Enq(stc, v)

    got = []

    def cons():
        for _ in values:
            got.append((yield Deq(stc)))

    simulate(DaeProgram("t", [Process("p", prod()), Process("c", cons())]),
             {"mem": FixedLatencyMemory([0])})
    assert got == values


@given(st.integers(1, 30), st.integers(0, 29), st.integers(1, 16))
def test_request_response_conservation(n_req, n_missing, cap):
    """n_req requests with fewer responses must raise ConservationError."""
    n_resp = n_req - (n_missing % n_req) if n_missing % n_req else n_req
    ch = LoadChannel("c", capacity=max(cap, n_req + 1))

    def gen():
        for i in range(n_req):
            yield Req(ch, i % 10)
        for _ in range(n_resp):
            yield Resp(ch)

    prog = DaeProgram("t", [Process("p", gen())])
    mems = {"mem": FixedLatencyMemory(list(range(10)), 5)}
    if n_resp == n_req:
        simulate(prog, mems)
    else:
        try:
            simulate(prog, mems)
            raised = False
        except ConservationError:
            raised = True
        assert raised


# -- randomized DAE programs (shared generator with test_parity) --------------


@given(spec=strategies.program_specs())
def test_random_program_conservation(spec):
    """Any generated program either deadlocks (detected, never hangs) or
    completes with exact per-channel request/response conservation."""
    prog, mems = strategies.build_program(spec)
    try:
        r = simulate(prog, mems)
    except DeadlockError:
        return
    for ci, chan in enumerate(spec["chans"]):
        assert r.counts.get(f"c{ci}", 0) == chan["count"]


@given(spec=strategies.program_specs())
def test_random_program_latency_floor(spec):
    """Completion can never beat the issue/compute critical path: at
    least one cycle per executed effect divided across processes."""
    prog, mems = strategies.build_program(spec)
    try:
        r = simulate(prog, mems)
    except DeadlockError:
        return
    total_ops = sum(len(p["ops"]) for p in spec["procs"])
    if total_ops:
        n_procs = len(spec["procs"])
        assert r.cycles >= total_ops // n_procs // 2


# -- decoupled == coupled: latency never changes values -----------------------


@given(st.integers(1, 200), st.integers(2, 64))
def test_latency_invariance(latency, rif):
    from repro.core.workloads import run_workload
    r = run_workload("hashtable", "rhls_dec", scale="small", latency=latency,
                     rif=rif)
    assert r.correct


# -- merge-path: merging sorted arrays == sort of concat ----------------------


@given(st.lists(st.integers(-50, 50), min_size=1, max_size=200),
       st.lists(st.integers(-50, 50), min_size=1, max_size=200))
def test_merge_property(xs, ys):
    from repro.kernels.dae_merge import merge_sorted
    a = jnp.sort(jnp.asarray(xs, jnp.int32))
    b = jnp.sort(jnp.asarray(ys, jnp.int32))
    out = np.asarray(merge_sorted(a, b, tile=32))
    ref = np.sort(np.concatenate([np.asarray(a), np.asarray(b)]))
    np.testing.assert_array_equal(out, ref)


# -- gather == take ------------------------------------------------------------


@given(st.integers(1, 60), st.integers(1, 40), st.data())
def test_gather_property(n, m, data):
    from repro.kernels.dae_gather import dae_gather
    idx = data.draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    table = jnp.arange(n * 8, dtype=jnp.float32).reshape(n, 8)
    out = dae_gather(table, jnp.asarray(idx, jnp.int32), method="pipelined")
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(table)[np.asarray(idx)])


# -- searchsorted == jnp.searchsorted -----------------------------------------


@given(st.lists(st.integers(-100, 100), min_size=1, max_size=300),
       st.lists(st.integers(-120, 120), min_size=1, max_size=32))
def test_searchsorted_property(table_vals, keys):
    from repro.kernels.dae_chase import batched_searchsorted
    table = jnp.sort(jnp.asarray(table_vals, jnp.int32))
    k = jnp.asarray(keys, jnp.int32)
    out = np.asarray(batched_searchsorted(table, k, block=64))
    ref = np.searchsorted(np.asarray(table), np.asarray(k), side="right")
    np.testing.assert_array_equal(out, ref)


# -- CSR/BSR: conversion preserves the matvec ---------------------------------


@given(st.integers(1, 12), st.integers(1, 100), st.integers(0, 60))
def test_csr_bsr_property(nrows, ncols, nnz):
    from repro.kernels.dae_spmv import csr_to_bsr, dae_spmv
    r = np.random.default_rng(nrows * 1000 + ncols * 10 + nnz)
    counts = r.multinomial(nnz, np.ones(nrows) / nrows) if nnz else \
        np.zeros(nrows, int)
    rows = np.zeros(nrows + 1, np.int64)
    rows[1:] = np.cumsum(counts)
    cols = r.integers(0, ncols, nnz)
    val = r.standard_normal(nnz)
    vec = r.standard_normal(ncols)
    dense = np.zeros((nrows, ncols))
    for i in range(nrows):
        for p in range(rows[i], rows[i + 1]):
            dense[i, cols[p]] += val[p]
    ref = dense @ vec
    vb, ri, ci, _, nrb = csr_to_bsr(rows, cols, val.astype(np.float32),
                                    ncols)
    out = dae_spmv(jnp.asarray(vb), jnp.asarray(ri), jnp.asarray(ci),
                   jnp.asarray(vec, dtype=jnp.float32), nrb)[:nrows]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-3, atol=1e-3)


# -- gradient compression: bounded error, unbiased with feedback --------------


@given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=4,
                max_size=64))
def test_quantize_error_bound(vals):
    from repro.parallel.compress import dequantize, quantize
    g = jnp.asarray(vals, jnp.float32)
    q, scale = quantize(g)
    err = np.abs(np.asarray(dequantize(q, scale) - g))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    from repro.parallel.compress import dequantize, quantize
    r = np.random.default_rng(0)
    g = jnp.asarray(r.standard_normal(256) * 0.01 + 3.0, jnp.float32)
    residual = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    steps = 50
    for _ in range(steps):
        gf = g + residual
        q, s = quantize(gf)
        deq = dequantize(q, s)
        residual = gf - deq
        acc = acc + deq
    bias = np.abs(np.asarray(acc / steps - g)).mean()
    q1, s1 = quantize(g)
    one_shot = np.abs(np.asarray(dequantize(q1, s1) - g)).mean()
    assert bias < one_shot  # feedback averages out quantization error
