"""Compiled-workload grid: every `repro.compile` target end-to-end.

For each registered compile target the bench (1) runs the staged pass
pipeline and reports its wall time, (2) runs the compiled Pallas kernel
and *asserts* bit-identity against the event-driven simulator oracle —
parity is gated, not just reported — and (3) records the inferred
per-channel chunk/RIF plans, so a tune-cache or planner regression
shows up in the artifact diff.

Emits ``BENCH_compile.json`` at the repo root (uploaded as a CI
artifact next to ``BENCH_kernels.json``).  ``--smoke`` keeps the small
problem scale and is what CI runs; the full mode uses the paper-scale
inputs.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_compile.json"


def run(csv_print, smoke: bool = False) -> None:
    from repro.compile.targets import (COMPILE_TARGETS, assert_parity,
                                       compile_target)

    scale = "small" if smoke else "paper"
    rows = []

    def emit(name: str, us: float, derived: str) -> None:
        csv_print(f"{name},{us:.0f},{derived}")
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})

    report = {"schema": 1, "smoke": smoke, "scale": scale, "rows": rows,
              "targets": {}}

    for name in sorted(COMPILE_TARGETS):
        t0 = time.perf_counter()
        ck, t = compile_target(name, scale)
        compile_ms = (time.perf_counter() - t0) * 1e3

        t0 = time.perf_counter()
        outs = ck()
        call_us = (time.perf_counter() - t0) * 1e6
        assert_parity(outs, t.simulate_oracle())   # gated, not reported

        plans = {c: {"chunk": p.chunk, "rif": p.rif, "source": p.source}
                 for c, p in ck.plans.items()}
        plan_s = ";".join(f"{c}:chunk={p['chunk']},rif={p['rif']}"
                          for c, p in sorted(plans.items()))
        emit(f"compile/{name}/pipeline", compile_ms * 1e3,
             f"shape={ck.shape};parity=ok")
        emit(f"compile/{name}/kernel", call_us, plan_s or "no-channels")
        report["targets"][name] = {
            "shape": ck.shape, "compile_ms": round(compile_ms, 1),
            "call_us": round(call_us, 1), "parity": "ok", "plans": plans,
            "outputs": {p: list(np.asarray(a).shape)
                        for p, a in outs.items()},
        }

    BENCH_JSON.write_text(json.dumps(report, indent=1, sort_keys=True)
                          + "\n")
    csv_print(f"compile/bench_json,0,path={BENCH_JSON.name}")
