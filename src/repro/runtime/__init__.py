from repro.runtime.train_loop import TrainLoopConfig, fit
from repro.runtime.straggler import StragglerMonitor

__all__ = ["TrainLoopConfig", "fit", "StragglerMonitor"]
