"""Persistent JSON cache of tuned decoupling configurations.

Winners are keyed by ``(op, shape, dtype, backend, memory model)`` so a
config tuned for one problem size / memory system never leaks into
another.  The cache is a single JSON file (atomic replace on save) whose
location is, in order of precedence:

  1. ``$REPRO_TUNE_CACHE`` (explicit path),
  2. ``$XDG_CACHE_HOME/repro/tune_cache.json``,
  3. ``~/.cache/repro/tune_cache.json``.

Dispatchers consult the process-wide :func:`default_cache` singleton;
lookups after the first are dictionary gets, so consulting the tuner on
every kernel call is free.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional, Sequence, Tuple

Config = Dict[str, Any]

__all__ = ["TuneCache", "CacheEntry", "make_key", "default_cache",
           "cache_path", "reset_default_cache"]

_SCHEMA_VERSION = 1


def cache_path() -> Path:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return Path(env).expanduser()
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = Path(xdg).expanduser() if xdg else Path.home() / ".cache"
    return base / "repro" / "tune_cache.json"


def make_key(op: str, shape: Sequence[int] | Tuple[int, ...], dtype: str,
             backend: str, mem: str) -> str:
    """Canonical cache key.  ``mem`` names the measurement model, e.g.
    ``wallclock``, ``sim:fixed:lat=100`` or ``sim:moms:lat=100``."""
    shape_s = "x".join(str(int(s)) for s in shape) or "scalar"
    return "|".join((op, shape_s, str(dtype), backend, mem))


@dataclasses.dataclass
class CacheEntry:
    config: Config
    score: float                  # lower is better (seconds or cycles)
    baseline_score: Optional[float] = None   # seed (plan_rif) config score
    evals: int = 0
    note: str = ""

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "CacheEntry":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


class TuneCache:
    """Load-once JSON store of :class:`CacheEntry`; ``save()`` re-reads
    the file and merges before the atomic replace, so concurrent tuner
    processes sharing one path keep each other's winners (best score
    wins on conflicts).  The read-merge-replace is not locked, so a
    write landing in the short window between another process's re-read
    and replace can still be lost — acceptable for tuning results,
    which the loser simply re-derives."""

    def __init__(self, path: Optional[Path | str] = None):
        self.path = Path(path) if path is not None else cache_path()
        self._entries: Optional[Dict[str, CacheEntry]] = None
        self.hits = 0
        self.misses = 0

    # -- loading / saving ---------------------------------------------------

    def _read_disk(self) -> Dict[str, CacheEntry]:
        entries: Dict[str, CacheEntry] = {}
        try:
            raw = json.loads(self.path.read_text())
            if raw.get("version") == _SCHEMA_VERSION:
                for k, v in raw.get("entries", {}).items():
                    entries[k] = CacheEntry.from_json(v)
        except (OSError, ValueError, TypeError):
            pass  # missing or corrupt cache == empty cache
        return entries

    def _load(self) -> Dict[str, CacheEntry]:
        if self._entries is None:
            self._entries = self._read_disk()
        return self._entries

    def save(self) -> Path:
        entries = self._load()
        # merge entries another process persisted since our load: the
        # whole-file atomic replace would otherwise silently drop a
        # concurrent tuner's winners.  Disk-only keys are adopted; on a
        # key both sides tuned, the better (lower) score wins.
        for k, disk in self._read_disk().items():
            ours = entries.get(k)
            if ours is None or disk.score < ours.score:
                entries[k] = disk
        payload = {
            "version": _SCHEMA_VERSION,
            "entries": {k: e.to_json() for k, e in sorted(entries.items())},
        }
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                   prefix=self.path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return self.path

    # -- access -------------------------------------------------------------

    def get(self, key: str) -> Optional[CacheEntry]:
        e = self._load().get(key)
        if e is None:
            self.misses += 1
        else:
            self.hits += 1
        return e

    def put(self, key: str, entry: CacheEntry, save: bool = True) -> None:
        self._load()[key] = entry
        if save:
            self.save()

    def __contains__(self, key: str) -> bool:
        return key in self._load()

    def __len__(self) -> int:
        return len(self._load())

    def keys(self):
        return self._load().keys()


_DEFAULT: Optional[TuneCache] = None


def default_cache() -> TuneCache:
    """Process-wide cache singleton (honours ``$REPRO_TUNE_CACHE``)."""
    global _DEFAULT
    if _DEFAULT is None or _DEFAULT.path != cache_path():
        _DEFAULT = TuneCache()
    return _DEFAULT


def reset_default_cache() -> None:
    """Drop the singleton (tests; or after changing the env var)."""
    global _DEFAULT
    _DEFAULT = None
