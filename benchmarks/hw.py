"""Target hardware constants (TPU v5e-class chip) used by the roofline."""

PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_LINK_BW = 50e9            # bytes/s per ICI link (term uses one link,
                              # per the assignment's roofline formula)
CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
VMEM_BYTES = 128 * 2**20
