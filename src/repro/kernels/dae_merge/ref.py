"""Pure-jnp oracles for the decoupled merge kernel."""

from __future__ import annotations

import jax.numpy as jnp


def merge_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Merge two sorted 1-D arrays into one sorted array."""
    return jnp.sort(jnp.concatenate([a, b]))


def sort_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sort(x)
