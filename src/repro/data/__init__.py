from repro.data.synthetic import SyntheticLM
from repro.data.loader import PrefetchLoader

__all__ = ["SyntheticLM", "PrefetchLoader"]
