"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H MLA (kv_lora=512)
d_ff=1408 per expert, vocab=102400; 2 shared + 64 routed experts top-6;
first layer dense FFN [arXiv:2405.04434; hf]."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10_944,            # the single leading dense-FFN layer
    moe_d_ff=1_408,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    first_dense_layers=1,
    vocab=102_400,
    attn_kind="mla",
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
)
