"""Jit'd wrappers + CSR->BSR conversion for the decoupled SPMV kernel."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import (cdiv, resolve_interpret, ring_rif,
                                  round_up, tuned_knobs)
from repro.kernels.dae_spmv import kernel as _k
from repro.kernels.dae_spmv.ref import bsr_spmv_ref


def csr_to_bsr(rows: np.ndarray, cols: np.ndarray, val: np.ndarray,
               ncols: int, bm: Optional[int] = None, bk: Optional[int] = None
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, int]:
    """Convert scalar CSR to BSR blocks of (bm, bk).

    Returns (val_blocks (NB,bm,bk), row_ids (NB,), col_ids (NB,),
    vec_pad_to (KB*bk,), nrows_blocks).  Every block-row gets at least one
    (possibly zero) block so the kernel's output-initialization contract
    holds; blocks are emitted in (block_row, block_col) order.

    ``bm``/``bk`` left ``None`` resolve via the tune cache — the block
    shape is a conversion-time decoupling knob — falling back to (8, 128).
    """
    nrows = len(rows) - 1
    if bm is None or bk is None:
        knobs = tuned_knobs("dae_spmv", (nrows, ncols, len(val)), val.dtype,
                            resolve_interpret(None), bm=(bm, 8),
                            bk=(bk, 128))
        bm, bk = knobs["bm"], knobs["bk"]
    nrb = cdiv(nrows, bm)
    nkb = cdiv(ncols, bk)
    blocks = {}
    for i in range(nrows):
        for p in range(int(rows[i]), int(rows[i + 1])):
            j = int(cols[p])
            key = (i // bm, j // bk)
            blk = blocks.get(key)
            if blk is None:
                blk = blocks[key] = np.zeros((bm, bk), dtype=val.dtype)
            blk[i % bm, j % bk] += val[p]
    # ensure every block-row appears
    for rb in range(nrb):
        if not any(k[0] == rb for k in blocks):
            blocks[(rb, 0)] = np.zeros((bm, bk), dtype=val.dtype)
    keys = sorted(blocks.keys())
    val_blocks = np.stack([blocks[k] for k in keys])
    row_ids = np.array([k[0] for k in keys], dtype=np.int32)
    col_ids = np.array([k[1] for k in keys], dtype=np.int32)
    return val_blocks, row_ids, col_ids, nkb * bk, nrb


@functools.partial(jax.jit, static_argnames=("nrows_blocks", "rif",
                                              "interpret", "method"))
def _spmv_impl(val_blocks, row_ids, col_ids, vec_tiles, *, nrows_blocks,
               rif, interpret, method):
    if method == "ref":
        return bsr_spmv_ref(val_blocks, row_ids, col_ids, vec_tiles,
                            nrows_blocks)
    return _k.bsr_spmv(val_blocks, row_ids, col_ids, vec_tiles,
                       nrows_blocks, rif=rif, interpret=interpret)


def dae_spmv(val_blocks: jax.Array, row_ids: jax.Array, col_ids: jax.Array,
             vec: jax.Array, nrows_blocks: int, *, rif: Optional[int] = None,
             method: str = "pallas",
             interpret: Optional[bool] = None) -> jax.Array:
    """BSR matvec: returns (nrows_blocks * BM,) flattened result.

    ``vec`` is the dense vector, padded here to a multiple of BK and
    tiled.  ``rif=None`` resolves the vec-tile ring depth via the tune
    cache, then ``plan_rif`` over one tile's byte size.
    """
    nb, bm, bk = val_blocks.shape
    interp = resolve_interpret(interpret)
    if rif is None:
        rif = tuned_knobs("dae_spmv", (nrows_blocks * bm, vec.shape[0], nb),
                          val_blocks.dtype, interp,
                          rif=(None, None))["rif"]
        rif = ring_rif(rif, bk * val_blocks.dtype.itemsize)
    kp = round_up(vec.shape[0], bk)
    if kp != vec.shape[0]:
        vec = jnp.pad(vec, (0, kp - vec.shape[0]))
    vec_tiles = vec.reshape(-1, bk)
    out = _spmv_impl(val_blocks, row_ids.astype(jnp.int32),
                     col_ids.astype(jnp.int32), vec_tiles,
                     nrows_blocks=nrows_blocks, rif=rif,
                     interpret=interp, method=method)
    return out.reshape(-1)
