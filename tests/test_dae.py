"""DaeProgram.validate_channels: functional dry-run channel discovery."""

import pytest

from repro.core.dae import (ConservationError, DaeProgram, Deq, Enq,
                            LoadChannel, Process, Req, Resp, Store,
                            StreamChannel)


def _pipeline(load, stream, n):
    def producer():
        for i in range(n):
            yield Req(load, i)
            v = yield Resp(load)
            yield Enq(stream, v)

    def consumer():
        for i in range(n):
            v = yield Deq(stream)
            yield Store("out", i, v)

    return [Process("prod", producer()), Process("cons", consumer())]


def test_validate_collects_channels():
    load = LoadChannel("ld", capacity=4, port="mem")
    stream = StreamChannel("st", capacity=2)
    prog = DaeProgram("ok", _pipeline(load, stream, 3))
    seen = prog.validate_channels({"mem": [10, 20, 30]})
    assert set(seen) == {"ld", "st"}
    assert seen["ld"] is load and seen["st"] is stream


def test_validate_rejects_conflicting_capacity():
    a = LoadChannel("dup", capacity=4, port="mem")
    b = LoadChannel("dup", capacity=8, port="mem")

    def gen():
        yield Req(a, 0)
        yield Resp(a)
        yield Req(b, 0)
        yield Resp(b)

    prog = DaeProgram("bad", [Process("p", gen())])
    with pytest.raises(ValueError, match="dup"):
        prog.validate_channels({"mem": [1]})


def test_validate_rejects_conflicting_type():
    a = StreamChannel("x", capacity=4)
    b = LoadChannel("x", capacity=4, port="mem")

    def gen():
        yield Enq(a, 1)
        yield Deq(a)
        yield Req(b, 0)
        yield Resp(b)

    with pytest.raises(ValueError, match="x"):
        DaeProgram("bad", [Process("p", gen())]).validate_channels({"mem": [1]})


def test_validate_same_object_or_equal_decl_ok():
    # two *equal* declarations (same type+capacity) are fine
    a = LoadChannel("same", capacity=4, port="mem")
    b = LoadChannel("same", capacity=4, port="mem")

    def gen():
        yield Req(a, 0)
        yield Resp(a)
        yield Req(b, 0)
        yield Resp(b)

    seen = DaeProgram("ok", [Process("p", gen())]).validate_channels(
        {"mem": [7]})
    assert set(seen) == {"same"}


def test_validate_detects_stall():
    st = StreamChannel("never", capacity=1)

    def gen():
        yield Deq(st)

    with pytest.raises(ConservationError, match="stalled"):
        DaeProgram("stall", [Process("p", gen())]).validate_channels()


def test_validate_detects_undrained():
    st = StreamChannel("left", capacity=4)

    def gen():
        yield Enq(st, 1)

    with pytest.raises(ConservationError, match="undrained"):
        DaeProgram("left", [Process("p", gen())]).validate_channels()


def test_validate_rejects_blocking_fused_followup():
    from repro.core.simulator import Fused
    ld = LoadChannel("ld", capacity=2, port="mem")
    st = StreamChannel("st", capacity=2)

    def gen():
        yield Req(ld, 0)
        # the follow-up Deq blocks (st never enqueued): contract violation
        yield Fused(Resp(ld), lambda v: Deq(st))

    with pytest.raises(ConservationError, match="non-blocking"):
        DaeProgram("bad-fused", [Process("p", gen())]).validate_channels(
            {"mem": [1]})


def test_validate_real_workload_program():
    # a freshly built paper benchmark program validates cleanly
    from repro.core.workloads import (_hashtable_phases, _mem_factory_for,
                                      make_hashtable_data)
    data = make_hashtable_data("small")
    mf = _mem_factory_for("fixed", 1, None, ())
    progs, mems, _, _ = _hashtable_phases(data, "rhls_dec", 1, 8, mf)
    seen = progs[0].validate_channels({p: m.data for p, m in mems.items()})
    assert set(seen) == {"ht_load", "ht_state"}
    assert seen["ht_load"].capacity == 9  # rif + 1


def test_factory_process_validate_then_simulate_no_rebuild():
    """Factory-built programs survive validation: the dry run pumps
    fresh generator instances, so the same object simulates correctly
    afterwards — no manual rebuild."""
    from repro.core.simulator import FixedLatencyMemory, simulate

    load = LoadChannel("ld", capacity=4, port="mem")
    stream = StreamChannel("st", capacity=2)
    n = 3

    def producer():
        for i in range(n):
            yield Req(load, i)
            v = yield Resp(load)
            yield Enq(stream, v)

    def consumer():
        for i in range(n):
            v = yield Deq(stream)
            yield Store("out", i, v)

    prog = DaeProgram("ok", [Process("prod", producer),
                             Process("cons", consumer)])
    assert prog.rebuildable
    # validate twice: factories make the dry run repeatable
    prog.validate_channels({"mem": [10, 20, 30]})
    prog.validate_channels({"mem": [10, 20, 30]})
    mems = {"mem": FixedLatencyMemory([10, 20, 30], latency=3),
            "out": FixedLatencyMemory([None] * n, latency=3)}
    res = simulate(prog, mems)
    assert res.stored_array("out", n) == [10, 20, 30]


def test_live_generator_process_not_rebuildable():
    def gen():
        yield Enq(StreamChannel("s", capacity=1), 1)
        yield Deq(StreamChannel("s", capacity=1))

    p = Process("p", gen())  # legacy: pass a live generator
    assert not p.rebuildable
    with pytest.raises(ValueError, match="live generator"):
        p.fresh()
    assert not DaeProgram("legacy", [p]).rebuildable


def test_workload_programs_are_rebuildable():
    """Every migrated workloads.py builder hands Process a factory, so
    validate-then-simulate works on the paper benchmarks directly."""
    from repro.core.simulator import simulate
    from repro.core.workloads import (_binsearch_phases, _mem_factory_for,
                                      make_binsearch_data)
    data = make_binsearch_data("small")
    mf = _mem_factory_for("fixed", 1, None, ())
    progs, mems, _, check = _binsearch_phases(data, "rhls_dec", True, 1, 8,
                                              mf)
    assert all(p.rebuildable for p in progs)
    progs[0].validate_channels({p: m.data for p, m in mems.items()})
    result = simulate(progs[0], mems)
    assert check(result)
