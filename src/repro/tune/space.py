"""Search spaces for the decoupling parameters (paper §4.2, §5.3/§5.4).

A :class:`SearchSpace` is an ordered mapping from parameter name to the
discrete values the tuner may try.  Every space ships with a *seed
configuration* derived from the analytic planner (`plan_rif`), so the
empirical search starts from the paper's latency×bandwidth heuristic and
only has to correct it, not rediscover it.

Spaces are deliberately small (tens to a few hundred points): the
measurement backends (wall-clock on interpret-mode Pallas, cycle counts
from the DAE simulator) cost milliseconds-to-seconds per point, and the
hill-climber visits only a local neighbourhood of the seed.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, Iterator, Mapping, Tuple

from repro.core.pipeline import plan_rif

Config = Dict[str, Any]

__all__ = ["SearchSpace", "Config", "kernel_space", "workload_space",
           "compiled_space", "KERNEL_SPACES"]


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Ordered discrete search space with a seed point.

    ``params`` maps name -> tuple of allowed values (each tuple sorted in
    the natural "increasing resource" order so the hill-climber's ±1-step
    neighbourhood is meaningful).  ``seed`` must use only listed values —
    :meth:`snap` projects an arbitrary config onto the grid.
    """

    name: str
    params: Mapping[str, Tuple[Any, ...]]
    seed: Config

    def __post_init__(self) -> None:
        for k, vs in self.params.items():
            if not vs:
                raise ValueError(f"space {self.name}: param {k!r} is empty")

    @property
    def size(self) -> int:
        n = 1
        for vs in self.params.values():
            n *= len(vs)
        return n

    def snap(self, cfg: Config) -> Config:
        """Project ``cfg`` onto the grid (nearest listed value per param;
        unknown params dropped, missing params filled from the seed)."""
        out: Config = {}
        for k, vs in self.params.items():
            want = cfg.get(k, self.seed.get(k, vs[0]))
            if want in vs:
                out[k] = want
            elif all(isinstance(v, (int, float)) for v in vs) and isinstance(
                    want, (int, float)):
                out[k] = min(vs, key=lambda v: abs(v - want))
            else:
                out[k] = vs[0]
        return out

    def neighbours(self, cfg: Config) -> Iterator[Config]:
        """±1 grid step along each axis (the hill-climb neighbourhood)."""
        for k, vs in self.params.items():
            i = vs.index(cfg[k])
            for j in (i - 1, i + 1):
                if 0 <= j < len(vs):
                    yield {**cfg, k: vs[j]}

    def grid(self) -> Iterator[Config]:
        keys = list(self.params)
        for combo in itertools.product(*(self.params[k] for k in keys)):
            yield dict(zip(keys, combo))


# ---------------------------------------------------------------------------
# Kernel spaces (wall-clock backend)
# ---------------------------------------------------------------------------


def _pow2_range(lo: int, hi: int) -> Tuple[int, ...]:
    out = []
    v = lo
    while v <= hi:
        out.append(v)
        v *= 2
    return tuple(out)


def _snapped(sp: SearchSpace) -> SearchSpace:
    return dataclasses.replace(sp, seed=sp.snap(sp.seed))


def _gather_space(n: int, d: int, m: int, itemsize: int = 4) -> SearchSpace:
    """Decoupled gather: dispatch method plus the RIF-ring knobs.

    ``method`` is part of the space — 'pipelined' (scalar-prefetch
    BlockSpec, RIF = pipeline double-buffering) vs 'rif' (explicit
    multi-buffer DMA ring).  ``chunk``/``rif`` only act under 'rif' and
    ``block_d`` only under 'pipelined'; the space is small enough that
    the redundant cross-terms cost a handful of evals.
    """
    chunks = tuple(c for c in _pow2_range(16, 256) if c <= max(16, m))
    rifs = _pow2_range(2, 64)
    block_ds = tuple(b for b in (128, 256, 512, 1024) if b <= max(128, d))
    chunk0 = chunks[min(len(chunks) - 1, 2)]
    # analytic seed: one chunk of rows is the DMA block of the ring
    plan = plan_rif(chunk0 * max(d, 1) * itemsize)
    seed = {"method": "pipelined", "chunk": chunk0,
            "rif": min(plan.rif, chunk0), "block_d": 512}
    return _snapped(SearchSpace("dae_gather", {
        "method": ("pipelined", "rif"),
        "chunk": chunks,
        "rif": rifs,
        "block_d": block_ds,
    }, seed))


def _merge_space(n: int, m: int) -> SearchSpace:
    tiles = tuple(t for t in _pow2_range(64, 1024) if t <= max(64, n + m))
    plan = plan_rif(256 * 4)
    return _snapped(SearchSpace("dae_merge", {
        "tile": tiles,
        "rif": _pow2_range(1, 16),
    }, {"tile": 256, "rif": plan.rif}))


def _flash_space(sq: int, sk: int, d: int) -> SearchSpace:
    bqs = tuple(b for b in (128, 256, 512) if b <= max(128, sq))
    bks = tuple(b for b in (128, 256, 512) if b <= max(128, sk))
    return _snapped(SearchSpace("flash_attention", {"bq": bqs, "bk": bks},
                                {"bq": 128, "bk": 128}))


def _flash_decode_space(s: int, d: int) -> SearchSpace:
    """Decode K/V block stream: block size plus the K/V ring depth."""
    bks = tuple(b for b in (64, 128, 256) if b <= max(64, s))
    plan = plan_rif(128 * max(d, 1) * 4)
    return _snapped(SearchSpace("flash_decode", {
        "bk": bks,
        "rif": _pow2_range(1, 16),
    }, {"bk": 128, "rif": plan.rif}))


def _flash_decode_paged_space(page: int, d: int) -> SearchSpace:
    """Paged decode: the page size is fixed by the cache layout, so only
    the page-ring depth is searchable."""
    plan = plan_rif(max(page, 1) * max(d, 1) * 4)
    return _snapped(SearchSpace("flash_decode_paged", {
        "rif": _pow2_range(1, 16),
    }, {"rif": plan.rif}))


def _gmm_space(t: int, d: int, f: int, itemsize: int = 4) -> SearchSpace:
    """Grouped expert matmul: MXU block shapes plus the expert-weight
    ring depth (§4.2's RIF, one (bd, bf) weight tile per request)."""
    bfs = tuple(b for b in (128, 256, 512) if b <= max(128, f))
    bds = tuple(b for b in (128, 256, 512, 1024) if b <= max(128, d))
    bf0, bd0 = 128, min(512, max(128, d))
    plan = plan_rif(bd0 * bf0 * itemsize)
    return _snapped(SearchSpace("grouped_matmul", {
        "bf": bfs,
        "bd": bds,
        "rif": _pow2_range(1, 16),
    }, {"bf": bf0, "bd": 512, "rif": plan.rif}))


def _searchsorted_space(n: int, m: int) -> SearchSpace:
    """Decoupled block binary search: probe block size plus the keys-
    per-grid-step chunk and the probe-ring depth (§4.2's RIF)."""
    blocks = tuple(b for b in (64, 128, 256, 512) if b <= max(64, n))
    chunks = tuple(c for c in _pow2_range(16, 256) if c <= max(16, m))
    plan = plan_rif(128 * 4)
    return _snapped(SearchSpace("batched_searchsorted", {
        "block": blocks,
        "chunk": chunks,
        "rif": _pow2_range(1, 64),
    }, {"block": 128, "chunk": 64, "rif": plan.rif}))


def _hash_lookup_space(n: int, m: int) -> SearchSpace:
    """Lock-step chain walk: chains per grid step and chains in flight
    (the paper's central knob for the hashtable benchmark)."""
    chunks = tuple(c for c in _pow2_range(16, 256) if c <= max(16, m))
    plan = plan_rif(128 * 4)
    return _snapped(SearchSpace("hash_lookup", {
        "chunk": chunks,
        "rif": _pow2_range(1, 64),
    }, {"chunk": 64, "rif": plan.rif}))


def _spmv_space(nrows: int, ncols: int, nnz: int) -> SearchSpace:
    """BSR block shape (conversion-time knob consulted by csr_to_bsr)
    plus the vec-tile ring depth of the matvec kernel."""
    return _snapped(SearchSpace("dae_spmv", {
        "bm": (8, 16, 32),
        "bk": (128, 256),
        "rif": _pow2_range(1, 16),
    }, {"bm": 8, "bk": 128, "rif": 2}))


def compiled_space(total_requests: int, width: int, itemsize: int = 4,
                   name: str = "compiled") -> SearchSpace:
    """Chunk × ring-depth space for a `repro.compile` program.

    One space per *program* (not per channel): the compiler applies the
    winning chunk/rif to every ring it emits, matching the one-key-per-
    program cache contract of ``program_key_parts``.
    """
    chunks = tuple(c for c in _pow2_range(8, 256)
                   if c <= max(8, total_requests))
    plan = plan_rif(max(width, 1) * itemsize)
    return _snapped(SearchSpace(name, {
        "chunk": chunks,
        "rif": _pow2_range(1, 64),
    }, {"chunk": 64, "rif": plan.rif}))


KERNEL_SPACES = {
    "dae_gather": _gather_space,
    "dae_merge": _merge_space,
    "flash_attention": _flash_space,
    "flash_decode": _flash_decode_space,
    "flash_decode_paged": _flash_decode_paged_space,
    "grouped_matmul": _gmm_space,
    "batched_searchsorted": _searchsorted_space,
    "hash_lookup": _hash_lookup_space,
    "dae_spmv": _spmv_space,
}


def kernel_space(op: str, *dims: int) -> SearchSpace:
    """Search space for kernel ``op`` at the given problem dimensions."""
    try:
        builder = KERNEL_SPACES[op]
    except KeyError:
        raise KeyError(f"no search space registered for kernel {op!r}")
    return builder(*dims)


# ---------------------------------------------------------------------------
# Workload (simulator backend) space
# ---------------------------------------------------------------------------


def workload_space(benchmark: str, latency: int = 100,
                   word_bytes: int = 8) -> SearchSpace:
    """RIF × channel-capacity-slack space for a simulated DAE workload.

    ``cap_slack`` is the channel capacity headroom over the ring depth:
    load/stream channels get ``capacity = rif + cap_slack``.  Negative
    slack (capacity below the ring depth) is the §5.3 danger zone — a
    round-robin chase deadlocks there, which the searcher maps to an
    infinite score via the deadlock penalty; large slack burns buffer
    resources for no speedup (§5.4).
    """
    rifs = _pow2_range(2, 256)
    slacks = (-4, 0, 1, 4, 16, 64)
    # seed: cover `latency` cycles of 1-word/cycle issue (§4.2): feed the
    # planner a 1-second-per-cycle latency and 1-word-per-second bandwidth
    plan = plan_rif(word_bytes, latency_s=float(latency),
                    bandwidth=float(word_bytes), max_rif=rifs[-1])
    seed = {"rif": plan.rif, "cap_slack": 1}
    return _snapped(SearchSpace(f"workload:{benchmark}",
                                {"rif": rifs, "cap_slack": slacks}, seed))
