"""Straggler detection: per-step wall-time EWMA + outlier flagging.

On a real pod this feeds the controller that triggers slice re-formation
(drop the slow host, re-mesh, restore from the last checkpoint — the
elastic path exercised in tests via CheckpointManager).  Here it logs and
counts, and is unit-tested against synthetic timings.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    ewma: float
    ratio: float


class StragglerMonitor:
    def __init__(self, threshold: float = 2.5, alpha: float = 0.1,
                 warmup_steps: int = 5,
                 on_straggler: Optional[Callable[[StragglerEvent], None]] = None):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup_steps = warmup_steps
        self.on_straggler = on_straggler
        self.ewma: Optional[float] = None
        self.events: List[StragglerEvent] = []
        self._n = 0
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int, duration: Optional[float] = None) -> bool:
        """Record a step duration; returns True if flagged as straggler."""
        if duration is None:
            if self._t0 is None:
                raise RuntimeError("stop() without start()")
            duration = time.perf_counter() - self._t0
            self._t0 = None
        self._n += 1
        if self.ewma is None:
            self.ewma = duration
            return False
        flagged = (self._n > self.warmup_steps and
                   duration > self.threshold * self.ewma)
        if flagged:
            ev = StragglerEvent(step, duration, self.ewma,
                                duration / self.ewma)
            self.events.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
            # do not fold outliers into the EWMA (keeps the baseline clean)
            return True
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * duration
        return False
