"""Host-side prefetching loader — the decoupled host->device feed.

The background thread is the Access loop (it issues batch construction
ahead of consumption); the bounded queue is the stream FIFO; the train
loop is the Execute loop.  Capacity bounds (queue size) make it
deadlock-free by construction, exactly like the paper's §5.1 rule.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional


class PrefetchLoader:
    def __init__(self, it: Iterator[Any], capacity: int = 2,
                 transform: Optional[Callable[[Any], Any]] = None):
        self._it = it
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=capacity)
        self._transform = transform
        self._done = object()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                if self._transform is not None:
                    item = self._transform(item)
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
        finally:
            try:
                self._q.put(self._done, timeout=1.0)
            except queue.Full:
                pass

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item

    def close(self) -> None:
        self._stop.set()
