"""RWKV6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Attention-free: the WKV state is a per-head (hd × hd) matrix updated
recurrently — O(S) time, O(1) state — so long_500k decode runs with a
constant-size state (docs/architecture.md §"Model families and input
shapes").  Structure follows arXiv:2404.05892
(data-dependent decay via a LoRA on w; token-shift mixes), with the
low-rank mix interpolation simplified to per-channel static mixes.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init


def _mix_param(key, d, dtype):
    return jax.random.uniform(key, (d,), jnp.float32).astype(dtype)


def rwkv_time_init(cfg: ModelConfig, key) -> Dict[str, Any]:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    ks = jax.random.split(key, 12)
    lora = max(32, d // 32)
    return {
        "mix_r": _mix_param(ks[0], d, cfg.pdtype),
        "mix_k": _mix_param(ks[1], d, cfg.pdtype),
        "mix_v": _mix_param(ks[2], d, cfg.pdtype),
        "mix_w": _mix_param(ks[3], d, cfg.pdtype),
        "mix_g": _mix_param(ks[4], d, cfg.pdtype),
        "wr": dense_init(ks[5], d, d, cfg.pdtype),
        "wk": dense_init(ks[6], d, d, cfg.pdtype),
        "wv": dense_init(ks[7], d, d, cfg.pdtype),
        "wg": dense_init(ks[8], d, d, cfg.pdtype),
        "w0": jnp.full((d,), -6.0, cfg.pdtype),       # base decay (slow)
        "w_lora_a": dense_init(ks[9], d, lora, cfg.pdtype),
        "w_lora_b": dense_init(ks[10], lora, d, cfg.pdtype),
        "u_bonus": (jax.random.normal(ks[11], (h, hd), jnp.float32) * 0.1
                    ).astype(cfg.pdtype),
        "wo": dense_init(jax.random.fold_in(key, 99), d, d, cfg.pdtype),
        "ln_g": jnp.ones((d,), cfg.pdtype),           # per-head groupnorm gain
    }


def rwkv_channel_init(cfg: ModelConfig, key) -> Dict[str, Any]:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "mix_k": _mix_param(ks[0], d, cfg.pdtype),
        "mix_r": _mix_param(ks[1], d, cfg.pdtype),
        "wk": dense_init(ks[2], d, cfg.d_ff, cfg.pdtype),
        "wv": dense_init(ks[3], cfg.d_ff, d, cfg.pdtype),
        "wr": dense_init(jax.random.fold_in(key, 7), d, d, cfg.pdtype),
    }


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """x (B,S,D) -> x shifted right by one token; ``prev`` is the last
    token of the previous chunk (decode)."""
    if prev is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = prev[:, None, :].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def rwkv_time_apply(cfg: ModelConfig, p, x,
                    state: Optional[Dict[str, Any]] = None,
                    valid: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]]]:
    """WKV6 time mix.  state = {"shift": (B,D), "wkv": (B,H,hd,hd)}.

    ``valid`` (B, S) gates the recurrence for chunked cache fill: rows
    advance their WKV/shift state only through their valid tokens (a row
    with none keeps its state bit-for-bit — the serve loop's masked
    decode relies on that)."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    dt = cfg.adtype

    xs = _token_shift(x, None if state is None else state["shift"])

    def mixed(name):
        m = p["mix_" + name].astype(dt)
        return x * m + xs * (1 - m)

    r = (mixed("r") @ p["wr"].astype(dt)).reshape(b, s, h, hd)
    k = (mixed("k") @ p["wk"].astype(dt)).reshape(b, s, h, hd)
    v = (mixed("v") @ p["wv"].astype(dt)).reshape(b, s, h, hd)
    g = jax.nn.silu(mixed("g") @ p["wg"].astype(dt))

    # data-dependent decay (the Finch contribution): w = exp(-exp(w0 + lora))
    wln = (p["w0"].astype(jnp.float32)
           + ((mixed("w") @ p["w_lora_a"].astype(dt))
              @ p["w_lora_b"].astype(dt)).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(wln)).reshape(b, s, h, hd)             # in (0,1)

    u = p["u_bonus"].astype(jnp.float32)                         # (H, hd)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))

    wkv0 = (jnp.zeros((b, h, hd, hd), jnp.float32) if state is None
            else state["wkv"].astype(jnp.float32))

    vmask = (jnp.ones((b, s), bool) if valid is None else valid)

    def step(wkv, inp):
        rt, kt, vt, wt, valid_t = inp                            # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]                 # (B,H,hd,hd)
        out = jnp.einsum("bhi,bhij->bhj", rt, wkv + u[None, :, :, None] * kv)
        wkv = jnp.where(valid_t[:, None, None, None],
                        wt[..., :, None] * wkv + kv, wkv)
        return wkv, out

    seq = (rf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
           vf.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3), vmask.T)
    wkv_fin, outs = jax.lax.scan(step, wkv0, seq)
    y = outs.transpose(1, 0, 2, 3).reshape(b, s, d)              # (B,S,D)

    # per-head groupnorm
    yh = y.reshape(b, s, h, hd)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(b, s, d) * p["ln_g"].astype(jnp.float32)

    y = (y.astype(dt) * g) @ p["wo"].astype(dt)
    new_state = None
    if state is not None:
        new_state = {"shift": _last_valid(x, state["shift"], valid),
                     "wkv": wkv_fin.astype(state["wkv"].dtype)}
    return y, new_state


def _last_valid(x: jnp.ndarray, prev: jnp.ndarray,
                valid: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Shift-state update: x (B,S,D) -> the last *valid* token per row,
    falling back to ``prev`` (B,D) for rows with no valid token."""
    if valid is None:
        return x[:, -1, :]
    n_valid = valid.sum(-1).astype(jnp.int32)
    idx = jnp.clip(n_valid - 1, 0)[:, None, None]
    last = jnp.take_along_axis(x, idx, axis=1)[:, 0]
    return jnp.where((n_valid > 0)[:, None], last, prev.astype(x.dtype))


def rwkv_channel_apply(cfg: ModelConfig, p, x,
                       state: Optional[jnp.ndarray] = None,
                       valid: Optional[jnp.ndarray] = None
                       ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    dt = cfg.adtype
    xs = _token_shift(x, state)
    mk = p["mix_k"].astype(dt)
    mr = p["mix_r"].astype(dt)
    k = jax.nn.relu((x * mk + xs * (1 - mk)) @ p["wk"].astype(dt)) ** 2
    r = jax.nn.sigmoid((x * mr + xs * (1 - mr)) @ p["wr"].astype(dt))
    y = r * (k @ p["wv"].astype(dt))
    return y, (_last_valid(x, state, valid) if state is not None else None)


def rwkv_state_init(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    return {
        "time_shift": jnp.zeros((batch, d), cfg.adtype),
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "chan_shift": jnp.zeros((batch, d), cfg.adtype),
    }
