"""The paper's irregular-workload suite through the decoupled JAX ops —
binsearch, hashtable, spmv and mergesort running on the TPU-native
kernels (interpret mode on CPU), checked against oracles, next to the
cycle-simulator reproduction of Table 1.

Run: PYTHONPATH=src python examples/irregular_suite.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.decouple import (csr_to_bsr, decoupled_hash_lookup,
                                 decoupled_merge_sort, decoupled_searchsorted,
                                 decoupled_spmv)
from repro.core.workloads import run_workload


def main() -> None:
    r = np.random.default_rng(0)

    print("== TPU-native decoupled ops (the paper's four workloads) ==")
    # binsearch: block-probe searchsorted
    table = jnp.sort(jnp.asarray(r.integers(0, 1 << 20, 5000), jnp.int32))
    keys = table[r.integers(0, 5000, 64)]
    idx = decoupled_searchsorted(table, keys)
    ok = bool((table[jnp.maximum(idx - 1, 0)] == keys).all())
    print(f" binsearch  : 64 lookups in 5000-elem table  correct={ok}")

    # hashtable: lock-step chain walk
    n, L = 256, 4
    ek = jnp.arange(n, dtype=jnp.int32)
    ev = jnp.asarray(r.integers(0, 1 << 20, n), jnp.int32)
    en = jnp.asarray([(i + 1) if (i + 1) % L else -1 for i in range(n)],
                     jnp.int32)
    heads = jnp.asarray([L * c for c in range(n // L)], jnp.int32)
    want = jnp.asarray([L * c + L - 1 for c in range(n // L)], jnp.int32)
    vals = decoupled_hash_lookup(ek, ev, en, heads, want, max_steps=L)
    print(f" hashtable  : {n // L} chains walked          "
          f"correct={bool((vals == ev[want]).all())}")

    # spmv: BSR with decoupled vec-tile fetch
    nrows, ncols, nnz = 64, 4096, 512
    counts = r.multinomial(nnz, np.ones(nrows) / nrows)
    rows = np.zeros(nrows + 1, np.int64)
    rows[1:] = np.cumsum(counts)
    cols = r.integers(0, ncols, nnz)
    val = r.standard_normal(nnz).astype(np.float32)
    vec = r.standard_normal(ncols).astype(np.float32)
    vb, ri, ci, _, nrb = csr_to_bsr(rows, cols, val, ncols)
    out = decoupled_spmv(jnp.asarray(vb), jnp.asarray(ri), jnp.asarray(ci),
                         jnp.asarray(vec), nrb)[:nrows]
    dense = np.zeros((nrows, ncols), np.float32)
    for i in range(nrows):
        for p in range(rows[i], rows[i + 1]):
            dense[i, cols[p]] += val[p]
    print(f" spmv       : {nrows}x{ncols}, nnz={nnz}        "
          f"correct={np.allclose(out, dense @ vec, rtol=1e-4, atol=1e-4)}")

    # mergesort: merge-path + bitonic
    x = jnp.asarray(r.integers(0, 1 << 30, 1000), jnp.int32)
    s = decoupled_merge_sort(x, tile=128)
    print(f" mergesort  : 1000 elems                  "
          f"correct={bool((s == jnp.sort(x)).all())}")

    print("== Cycle-simulator Table 1 (paper scale, 100-cycle latency) ==")
    for bench in ("binsearch", "hashtable", "spmv", "mergesort_opt"):
        base = run_workload(bench, "vitis", scale="paper")
        dec = run_workload(bench, "rhls_dec", scale="paper")
        print(f" {bench:13s}: {base.cycles:>9d} -> {dec.cycles:>7d} cycles "
              f"({base.cycles / dec.cycles:5.1f}x)")


if __name__ == "__main__":
    main()
