"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finite values; decode path; attn-impl equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.registry import build_model
from repro.models.transformer import param_count

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    tok = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((b, 8, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list(ARCHS))
def test_forward_loss(arch):
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    assert param_count(params) > 0
    loss = m.loss(params, _batch(cfg))
    assert jnp.isfinite(loss), f"{arch} loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", list(ARCHS))
def test_train_step_updates(arch):
    from repro.launch.steps import make_train_step
    from repro.optim import AdamW
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    new_params, opt_state, metrics = step(params, opt.init(params),
                                          _batch(cfg))
    assert jnp.isfinite(metrics["loss"])
    # at least one leaf actually changed
    changed = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert changed


@pytest.mark.parametrize("arch", list(ARCHS))
def test_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    b, smax = 2, 32
    tok = jnp.array([3, 5], jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    if cfg.family == "encdec":
        enc_out = m.encode(params, jnp.ones((b, 8, cfg.d_model), jnp.float32))
        cache = m.cache_init(b, smax)
        logits, cache = m.decode_step(params, enc_out, cache, tok, pos)
        logits, _ = m.decode_step(params, enc_out, cache, tok, pos + 1)
    else:
        cache = m.cache_init(b, smax)
        logits, cache = m.decode_step(params, cache, tok, pos)
        logits, _ = m.decode_step(params, cache, tok, pos + 1)
    assert logits.shape == (b, cfg.vocab)
    assert jnp.isfinite(logits).all()


def test_decode_matches_prefill():
    """Teacher-forced decode must reproduce prefill logits (GQA cache)."""
    cfg = get_config("qwen3-4b", smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    b, s = 1, 8
    tok = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    full = m.apply(params, tok)                       # (B, S, V)
    cache = m.cache_init(b, s)
    outs = []
    for t in range(s):
        logits, cache = m.decode_step(params, cache, tok[:, t],
                                      jnp.full((b,), t, jnp.int32))
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_prefill_mla():
    cfg = get_config("deepseek-v2-lite-16b", smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    b, s = 1, 6
    tok = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    full = m.apply(params, tok)
    cache = m.cache_init(b, s)
    outs = []
    for t in range(s):
        logits, cache = m.decode_step(params, cache, tok[:, t],
                                      jnp.full((b,), t, jnp.int32))
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=5e-3, atol=5e-3)


def test_attn_impl_equivalence():
    """chunked online-softmax == naive S^2 at the model level."""
    cfg_ref = get_config("qwen3-4b", smoke=True, attn_impl="ref")
    cfg_chk = get_config("qwen3-4b", smoke=True, attn_impl="chunked",
                         attn_chunk=8)
    m_ref, m_chk = build_model(cfg_ref), build_model(cfg_chk)
    params = m_ref.init(KEY)
    tok = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, cfg_ref.vocab)
    np.testing.assert_allclose(
        np.asarray(m_ref.apply(params, tok), np.float32),
        np.asarray(m_chk.apply(params, tok), np.float32),
        rtol=2e-3, atol=2e-3)


def test_scan_unroll_equivalence():
    """The dry-run cost probes (unrolled) compute the same function."""
    cfg_s = get_config("qwen3-4b", smoke=True)
    cfg_u = dataclasses.replace(cfg_s, scan_layers=False)
    m_s, m_u = build_model(cfg_s), build_model(cfg_u)
    params = m_s.init(KEY)
    tok = jax.random.randint(jax.random.PRNGKey(5), (2, 12), 0, cfg_s.vocab)
    np.testing.assert_allclose(
        np.asarray(m_s.apply(params, tok), np.float32),
        np.asarray(m_u.apply(params, tok), np.float32),
        rtol=2e-3, atol=2e-3)


def test_moe_pallas_dispatch_matches_xla():
    cfg_x = get_config("granite-moe-3b-a800m", smoke=True, kernel_mode="ref",
                       capacity_factor=8.0)  # ample capacity: no drops
    cfg_p = get_config("granite-moe-3b-a800m", smoke=True,
                       kernel_mode="pallas")
    from repro.models.moe import moe_apply, moe_init
    p = moe_init(cfg_x, KEY)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 8, cfg_x.d_model),
                          jnp.float32)
    yx = moe_apply(cfg_x, p, x, capacity_factor=8.0)
    yp = moe_apply(cfg_p, p, x)
    np.testing.assert_allclose(np.asarray(yx), np.asarray(yp),
                               rtol=2e-3, atol=2e-3)


def test_banded_attn_impl_model_level():
    """banded window attention == ref at the model level (hymba)."""
    cfg_ref = get_config("hymba-1.5b", smoke=True, attn_impl="ref")
    cfg_bnd = get_config("hymba-1.5b", smoke=True, attn_impl="banded",
                         attn_chunk=16)
    m_ref, m_bnd = build_model(cfg_ref), build_model(cfg_bnd)
    params = m_ref.init(KEY)
    tok = jax.random.randint(jax.random.PRNGKey(7), (2, 64), 0,
                             cfg_ref.vocab)
    np.testing.assert_allclose(
        np.asarray(m_ref.apply(params, tok), np.float32),
        np.asarray(m_bnd.apply(params, tok), np.float32),
        rtol=3e-3, atol=3e-3)
