"""Arch-family -> model builder registry.

``build_model(cfg)`` returns a uniform interface:
  init(key) -> params
  loss(params, batch) -> scalar                      (train objective)
  apply(params, tokens) -> logits                    (decoder families)
  cache_init(batch, s_max), decode_step(params, cache, token, pos)
plus ``input_specs(cfg, shape)`` lives in repro.launch.specs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

from repro.models import encdec as _encdec
from repro.models import transformer as _t
from repro.models.common import ModelConfig


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    apply: Optional[Callable] = None
    cache_init: Optional[Callable] = None
    decode_step: Optional[Callable] = None
    encode: Optional[Callable] = None


def build_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.family == "encdec":
        return ModelBundle(
            cfg=cfg,
            init=lambda key: _encdec.encdec_init(cfg, key),
            loss=lambda p, batch: _encdec.encdec_loss(cfg, p, batch),
            encode=lambda p, frames: _encdec.encode(cfg, p, frames),
            cache_init=lambda b, s: _encdec.encdec_cache_init(cfg, b, s),
            decode_step=lambda p, enc_out, cache, tok, pos:
                _encdec.encdec_decode_step(cfg, p, enc_out, cache, tok, pos),
        )
    # decoder-only families (dense, moe, ssm, hybrid, vlm)
    return ModelBundle(
        cfg=cfg,
        init=lambda key: _t.lm_init(cfg, key),
        loss=lambda p, batch: _t.lm_loss(cfg, p, batch),
        apply=lambda p, tokens: _t.lm_apply(cfg, p, tokens),
        cache_init=lambda b, s: _t.lm_cache_init(cfg, b, s),
        decode_step=lambda p, cache, tok, pos:
            _t.lm_decode_step(cfg, p, cache, tok, pos),
    )
