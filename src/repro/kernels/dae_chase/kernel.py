"""Decoupled pointer-chase kernels (paper §4.2, Listings 4/5) on TPU.

These are the dependent-load workloads where the paper's 10–79×
speedups live; both kernels are emitted through
:mod:`repro.kernels.ring`, so the request/response pairing and the
prologue/steady-state/drain loop structure are the shared emitter's,
not hand-rolled here.

* ``searchsorted_blocks`` — block binary search.  ops.py resolves each
  key to a table *block* id with a VMEM-resident summary search (the top
  of the B-tree); the kernel then keeps ``rif`` independent block probes
  in flight per grid step (the block-id stream is scalar-prefetched —
  the Access loop's address stream) and resolves log2(block) levels of
  the search per response with one vectorized compare-reduce.

* ``hash_probe`` — lock-step chain walk over a separate-chaining hash
  table.  Each grid step owns ``chunk`` chains whose current positions
  live in SMEM; every level runs a full :func:`access_execute` pass over
  the chunk, so ``rif`` *independent dependent-load chains* stay in
  flight while each individual chain waits on its own pointer — exactly
  Listing 5's fixed-length lock-step variant, including the redundant
  tail re-loads for resolved chains (masking instead of
  conditional-issue circuitry).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ring import RingChannel, access_execute, \
    clamp_rif, ring_scratch_shapes

# packed hash-table entry rows are padded to one DMA-aligned lane group
ENTRY_LANES = 128


# ---------------------------------------------------------------------------
# Block binary search
# ---------------------------------------------------------------------------


def _searchsorted_kernel(blk_ref, keys_ref, tiles_hbm, out_ref, scratch,
                         sems, *, chunk: int, rif: int, block: int, n: int):
    """``chunk`` key probes per grid step, ``rif`` block fetches in
    flight.  Each response resolves a whole block: the 'right' insertion
    point is blk*block + |{x in block : x <= key}| (padding sentinels are
    +inf/intmax, so they never count below a real key)."""
    c = pl.program_id(0)
    base = c * chunk

    ring = RingChannel(
        scratch, sems, rif,
        src=lambda k: tiles_hbm.at[pl.ds(blk_ref[base + k], 1), :])

    def execute(k, row):
        key = pl.load(keys_ref, (pl.ds(k, 1),))            # (1,)
        within = jnp.sum((row <= key[0]).astype(jnp.int32))
        idx = blk_ref[base + k] * block + within
        pl.store(out_ref, (pl.ds(k, 1),),
                 jnp.minimum(idx, n).astype(jnp.int32)[None])

    access_execute([ring], chunk, execute)


def searchsorted_blocks(tiles: jax.Array, blk: jax.Array, keys: jax.Array,
                        n: int, *, chunk: int, rif: int,
                        interpret: bool = True) -> jax.Array:
    """tiles (NB, block) is the padded sorted table; blk (M,) int32 maps
    each key to the block holding its insertion point (ops.py's summary
    search); keys (M,) padded to a multiple of ``chunk``.  Returns (M,)
    int32 'right' insertion points clipped to ``n``."""
    m = keys.shape[0]
    nb, block = tiles.shape
    assert m % chunk == 0, (m, chunk)
    rif = clamp_rif(rif, chunk)
    grid = (m // chunk,)

    kernel = functools.partial(_searchsorted_kernel, chunk=chunk, rif=rif,
                               block=block, n=n)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((chunk,), lambda c, b_: (c,)),
                pl.BlockSpec(memory_space=pl.ANY),
            ],
            out_specs=pl.BlockSpec((chunk,), lambda c, b_: (c,)),
            scratch_shapes=[
                *ring_scratch_shapes(rif, (1, block), tiles.dtype),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=interpret,
    )(blk, keys, tiles)


# ---------------------------------------------------------------------------
# Lock-step hash-chain walk
# ---------------------------------------------------------------------------


def _hash_probe_kernel(heads_ref, keys_ref, packed_hbm, out_ref, idx_s,
                       found_v, val_v, scratch, sems, *, chunk: int,
                       rif: int, max_steps: int, n: int):
    c = pl.program_id(0)
    base = c * chunk

    # Only the chain cursor needs per-scalar SMEM (the ring's src reads
    # it back one scalar at a time); found/val state lives as VMEM
    # vectors so init and emit are single vector ops, not chunk-long
    # scalar loops.
    def init(k, _):
        idx_s[k] = heads_ref[base + k]
        return 0

    jax.lax.fori_loop(0, chunk, init, 0)
    found_v[...] = jnp.zeros((1, chunk), jnp.int32)
    val_v[...] = jnp.full((1, chunk), -1, jnp.int32)

    # the Access stream reads the per-chain cursor back out of SMEM: a
    # resolved or dead chain keeps re-requesting a clipped address
    # (Listing 5's redundant loads) so the request/response pairing
    # stays structural across the whole level
    ring = RingChannel(
        scratch, sems, rif,
        src=lambda k: packed_hbm.at[
            pl.ds(jnp.clip(idx_s[k], 0, n - 1), 1), :])

    def execute(k, ent):
        ek, ev, nxt = ent[0, 0], ent[0, 1], ent[0, 2]
        cur = idx_s[k]
        found_k = pl.load(found_v, (pl.ds(0, 1), pl.ds(k, 1)))[0, 0]
        alive = (cur >= 0) & (found_k == 0)
        hit = alive & (ek == keys_ref[base + k])
        val_k = pl.load(val_v, (pl.ds(0, 1), pl.ds(k, 1)))[0, 0]
        pl.store(val_v, (pl.ds(0, 1), pl.ds(k, 1)),
                 jnp.where(hit, ev, val_k)[None, None])
        pl.store(found_v, (pl.ds(0, 1), pl.ds(k, 1)),
                 jnp.where(hit, 1, found_k)[None, None])
        idx_s[k] = jnp.where(alive & ~hit, nxt, cur)

    def level(_, carry):
        # one full prologue/steady-state/drain pass over the chunk per
        # chain level: rif chains in flight, every chain one step deeper
        access_execute([ring], chunk, execute)
        return carry

    jax.lax.fori_loop(0, max_steps, level, 0)

    out_ref[...] = jnp.where(found_v[0, :] == 1, val_v[0, :], -1)


def hash_probe(packed: jax.Array, heads: jax.Array, keys: jax.Array, *,
               chunk: int, rif: int, max_steps: int,
               interpret: bool = True) -> jax.Array:
    """packed (N, ENTRY_LANES) int32 rows [key, val, next, 0...]; heads /
    keys (M,) int32 padded to a multiple of ``chunk``.  Returns (M,)
    int32 lookup values (-1 when not found within ``max_steps``)."""
    m = heads.shape[0]
    n = packed.shape[0]
    assert m % chunk == 0, (m, chunk)
    rif = clamp_rif(rif, chunk)
    grid = (m // chunk,)

    kernel = functools.partial(_hash_probe_kernel, chunk=chunk, rif=rif,
                               max_steps=max_steps, n=n)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec((chunk,), lambda c, h_, k_: (c,)),
            scratch_shapes=[
                pltpu.SMEM((chunk,), jnp.int32),
                pltpu.VMEM((1, chunk), jnp.int32),
                pltpu.VMEM((1, chunk), jnp.int32),
                *ring_scratch_shapes(rif, (1, packed.shape[1]),
                                     packed.dtype),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=interpret,
    )(heads, keys, packed)
