"""Tests for the ``repro.bench`` matrix/schema/diff layer.

Four concerns:

  * **schema** — valid reports pass; each way a report can lie (folded
    cold-without-warm timing, unknown coords, duplicate names, wrong
    version) is rejected with the offending path named;
  * **diff discipline** — on synthetic reports: cycle changes fail in
    *both* directions, wall-clock gates only past the percent band,
    removed cells fail, new cells are notes, allowlisting downgrades a
    failure without hiding it, mode mismatches short-circuit;
  * **committed artifacts** — the baselines under ``benchmarks/baseline``
    must validate against the live schema and self-diff clean (the CI
    gate's no-op case), and every axis declared by ``benchmarks.matrix``
    must have one;
  * **enumeration** — the matrix declares every cell without executing
    any (cells are closures), and the registry rejects dup names/bad
    coords up front.
"""

from __future__ import annotations

import copy
import json
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))  # benchmarks.* is a root package

from repro.bench import (BenchContext, Cell, CellResult, Timing, build_report,
                         cell_csv, check_cells, coords, diff_reports,
                         parse_allowlist, regressions)
from repro.bench.schema import SchemaError, schema_problems, validate_report


def _report(cells=None, axis="sim", smoke=True):
    """A minimal schema-valid report to mutate in tests."""
    if cells is None:
        cells = [_cell("table1/binsearch/rhls_dec", cycles=3104),
                 _cell("kernel/gather/tuned", cycles=None, us_cold=900.0,
                       us_warm=120.0, tuned=True),
                 _cell("table2/binsearch/rhls_dec", cycles=None,
                       derived={"channels": 2, "note": "x"})]
    return {"schema": 2, "axis": axis, "smoke": smoke,
            "meta": {"git_sha": "deadbeef", "backend": "cpu", "seed": 0,
                     "python": "3.11.0"},
            "cells": cells}


def _cell(name, *, cycles=3104, us_cold=None, us_warm=None, status="ok",
          derived=None, tuned=None, replay=None):
    out = {"name": name, "group": name.split("/")[0],
           "coords": coords(name.split("/")[1], "sim", tuned=tuned),
           "status": status, "cycles": cycles, "us_cold": us_cold,
           "us_warm": us_warm, "derived": derived or {}}
    if replay is not None:
        out["replay"] = replay
    return out


# -- schema -------------------------------------------------------------------


def test_valid_report_passes():
    assert schema_problems(_report()) == []
    validate_report(_report())


@pytest.mark.parametrize("mutate,needle", [
    (lambda r: r.update(schema=1), "schema"),
    (lambda r: r.update(axis=""), "axis"),
    (lambda r: r.update(smoke="yes"), "smoke"),
    (lambda r: r["meta"].pop("git_sha"), "git_sha"),
    (lambda r: r["meta"].update(seed="0"), "seed"),
    (lambda r: r.update(cells=[]), "cells"),
    (lambda r: r["cells"].append(dict(r["cells"][0])), "duplicate"),
    (lambda r: r["cells"][0].update(status="crashed"), "status"),
    (lambda r: r["cells"][0].update(cycles=-1), "cycles"),
    (lambda r: r["cells"][0]["coords"].update(extra=1), "coords"),
    (lambda r: r["cells"][0]["coords"].pop("tenants"), "coords"),
    (lambda r: r["cells"][0].update(derived={"a": [1]}), "derived"),
    # the old folded-JIT shape: one timing number pretending to be both
    (lambda r: r["cells"][0].update(cycles=None, us_cold=5.0,
                                    us_warm=None, derived={}), "us_cold"),
    # an ok cell with no data at all measured nothing
    (lambda r: r["cells"][0].update(cycles=None, derived={}), "ok cell"),
])
def test_schema_rejects(mutate, needle):
    report = _report()
    mutate(report)
    problems = schema_problems(report)
    assert problems, f"mutation {needle!r} was not caught"
    assert any(needle in p for p in problems), problems
    with pytest.raises(SchemaError):
        validate_report(report)


# -- diff discipline ----------------------------------------------------------


def _diff(base, fresh, **kw):
    return diff_reports(base, fresh, **kw)


def test_identical_reports_diff_clean():
    assert _diff(_report(), _report()) == []


@pytest.mark.parametrize("delta", [+7, -7])
def test_cycle_change_fails_both_directions(delta):
    fresh = _report()
    fresh["cells"][0]["cycles"] += delta
    regs = regressions(_diff(_report(), fresh))
    assert len(regs) == 1 and regs[0].kind == "cycles"
    assert regs[0].cell == "table1/binsearch/rhls_dec"
    word = "regressed" if delta > 0 else "improved"
    assert word in regs[0].detail and "refresh the baseline" in regs[0].detail


def test_wall_clock_gates_on_percent_band():
    fresh = _report()
    fresh["cells"][1]["us_warm"] = 120.0 * 1.2       # +20% under a 25% gate
    assert regressions(_diff(_report(), fresh, wall_pct=25.0)) == []
    fresh["cells"][1]["us_warm"] = 120.0 * 1.6       # +60% over it
    regs = regressions(_diff(_report(), fresh, wall_pct=25.0))
    assert [f.kind for f in regs] == ["wall-clock"]
    # improvements are notes, never failures (wall time is noisy)
    fresh["cells"][1]["us_warm"] = 10.0
    findings = _diff(_report(), fresh, wall_pct=25.0)
    assert regressions(findings) == []
    assert [f.kind for f in findings] == ["wall-clock-improved"]


def test_us_cold_is_never_gated():
    fresh = _report()
    fresh["cells"][1]["us_cold"] = 900.0 * 50
    assert _diff(_report(), fresh) == []


def test_removed_cell_fails_new_cell_notes():
    fresh = _report()
    removed = fresh["cells"].pop(0)
    fresh["cells"].append(_cell("table1/spmv/rhls_dec"))
    findings = _diff(_report(), fresh)
    kinds = {f.cell: f.kind for f in findings}
    assert kinds[removed["name"]] == "removed-cell"
    assert kinds["table1/spmv/rhls_dec"] == "new-cell"
    assert [f.cell for f in regressions(findings)] == [removed["name"]]


def test_status_flip_fails_and_short_circuits_timing():
    fresh = _report()
    fresh["cells"][0].update(status="deadlock", cycles=None)
    regs = regressions(_diff(_report(), fresh))
    assert [f.kind for f in regs] == ["status"]   # no trailing cycles noise


def test_integer_derived_exact_floats_informational():
    fresh = _report()
    fresh["cells"][2]["derived"]["channels"] = 3
    fresh["cells"][2]["derived"]["note"] = "y"
    fresh["cells"][2]["derived"]["ratio"] = 1.5
    regs = regressions(_diff(_report(), fresh))
    assert [f.kind for f in regs] == ["derived"]
    assert "channels" in regs[0].detail


def test_coords_drift_is_a_finding():
    fresh = _report()
    fresh["cells"][0]["coords"]["engine"] = "polling"
    regs = regressions(_diff(_report(), fresh))
    assert [f.kind for f in regs] == ["coords"]


def test_mode_mismatch_short_circuits():
    findings = _diff(_report(smoke=True), _report(smoke=False))
    assert [f.kind for f in findings] == ["mode"]
    assert findings[0].fails
    findings = _diff(_report(axis="sim"), _report(axis="kernels"))
    assert [f.kind for f in findings] == ["mode"]


def test_allowlist_downgrades_without_hiding():
    fresh = _report()
    fresh["cells"][0]["cycles"] += 1
    allow = parse_allowlist(
        "# scheduler change lands this PR\nsim/table1/binsearch/*\n")
    findings = _diff(_report(), fresh, allowlist=allow)
    assert regressions(findings) == []            # gate passes...
    assert len(findings) == 1 and findings[0].allowed
    assert "ALLOWED" in findings[0].render()      # ...but the diff still talks
    # the pattern is cell-scoped: other cells still fail
    fresh["cells"][2]["derived"]["channels"] = 9
    assert len(regressions(_diff(_report(), fresh, allowlist=allow))) == 1


# -- registry + report assembly ----------------------------------------------


def test_check_cells_rejects_dupes_and_bad_coords():
    ok = Cell("sim", "a", coords("w", "sim"), run=lambda ctx: CellResult())
    check_cells([ok], "sim")
    with pytest.raises(ValueError, match="duplicate"):
        check_cells([ok, Cell("sim", "a", coords("w", "sim"),
                              run=lambda ctx: CellResult())], "sim")
    with pytest.raises(ValueError, match="axis"):
        check_cells([ok], "kernels")
    with pytest.raises(ValueError, match="kind"):
        coords("w", "simulator")
    with pytest.raises(ValueError, match="tenants"):
        coords("w", "sim", tenants=0)


def test_build_report_validates_and_rounds():
    cell = Cell("sim", "a/b", coords("b", "sim"),
                run=lambda ctx: CellResult(), group="a")
    rep = build_report("sim", [(cell, CellResult(cycles=5,
                                                 us_cold=1.23456,
                                                 us_warm=0.98765))],
                       smoke=True, seed=7)
    row = rep["cells"][0]
    assert (row["us_cold"], row["us_warm"]) == (1.2, 1.0)
    assert rep["meta"]["seed"] == 7
    with pytest.raises(SchemaError):
        build_report("sim", [(cell, CellResult(us_cold=1.0))],
                     smoke=True, seed=0)


def test_cell_csv_keeps_legacy_shape():
    cell = Cell("sim", "table1/binsearch/rhls_dec", coords("binsearch", "sim"),
                run=lambda ctx: CellResult(), group="table1")
    row = cell_csv(cell, CellResult(cycles=3104, derived={"golden": 3104}))
    assert row == "table1/binsearch/rhls_dec,0,cycles=3104;golden=3104"
    row = cell_csv(cell, CellResult(status="deadlock"))
    assert row.endswith(",0,status=deadlock")


def test_timing_split_measures_cold_then_warm():
    calls = []

    def fn():
        calls.append(1)
        return 0

    from repro.bench import measure
    t = measure(fn, warm_reps=3)
    assert isinstance(t, Timing)
    assert len(calls) == 4                      # 1 cold + 3 warm
    assert t.us_cold >= 0 and t.us_warm >= 0


# -- committed artifacts + enumeration ---------------------------------------


def _baseline(axis):
    path = REPO_ROOT / "benchmarks" / "baseline" / f"BENCH_{axis}.json"
    assert path.exists(), f"missing committed baseline {path.name}"
    return json.loads(path.read_text())


def test_committed_baselines_are_schema_valid_and_self_diff_clean():
    from benchmarks.matrix import AXES
    for axis in AXES:
        report = validate_report(_baseline(axis))
        assert report["axis"] == axis
        assert report["smoke"] is True, "baselines are committed from smoke"
        assert diff_reports(report, copy.deepcopy(report)) == []


def test_matrix_enumerates_without_executing():
    from benchmarks.matrix import AXES, collect
    ctx = BenchContext(smoke=True)
    for axis in AXES:
        cells = collect(axis, ctx)
        assert cells, axis
        check_cells(cells, axis)  # unique names, complete coords


def test_matrix_cells_match_committed_baseline_names():
    """Every declared cell appears in the committed baseline and vice
    versa — a cell added without a baseline refresh (or removed without
    shrinking it) fails here before CI even runs the matrix."""
    from benchmarks.matrix import AXES, collect
    ctx = BenchContext(smoke=True)
    for axis in AXES:
        declared = {c.name for c in collect(axis, ctx)}
        committed = {c["name"] for c in _baseline(axis)["cells"]}
        assert declared == committed, (
            f"axis {axis}: declared-vs-baseline cell mismatch "
            f"(+{sorted(declared - committed)} "
            f"-{sorted(committed - declared)})")
