"""Config helpers: smoke-config reduction shared by all arch files."""

from __future__ import annotations

import dataclasses

from repro.models.common import ModelConfig


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small widths, few
    layers/experts, tiny vocab — one forward/train step must run on CPU."""
    kw = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 1,
        d_ff=128,
        vocab=512,
        head_dim=16 if cfg.head_dim else 0,
        dtype="float32",
    )
    if cfg.n_kv_heads == 1:
        kw["n_kv_heads"] = 1
    if cfg.family == "moe":
        kw.update(n_experts=8, top_k=min(cfg.top_k, 2), moe_d_ff=64,
                  n_layers=2 + cfg.first_dense_layers,
                  first_dense_layers=cfg.first_dense_layers,
                  capacity_factor=8.0)  # dropless at smoke scale
    if cfg.attn_kind == "mla":
        kw.update(kv_lora_rank=32, q_lora_rank=min(cfg.q_lora_rank, 32),
                  qk_rope_dim=16, qk_nope_dim=16, v_head_dim=16)
    if cfg.family == "hybrid":
        kw.update(global_attn_layers=(0,), window=32, ssm_state=8,
                  ssm_expand=2)
    if cfg.family == "ssm":
        kw.update(rwkv_head_dim=16, d_ff=128)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2)
    if cfg.window:
        kw.setdefault("window", 32)
    return dataclasses.replace(cfg, **kw)
