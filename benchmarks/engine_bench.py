"""Scheduler micro-benchmark: event engine vs the legacy polling oracle.

Times ``SharedMemoryEngine.run()`` in isolation (construction excluded)
on the N-tenant hashtable cell of the scale sweep and reports events/sec
for both scheduler implementations plus their ratio.  This is the
perf-regression guard for the event-driven scheduler: the polling
scheduler re-checks every live process on every pass, so its wall-clock
grows superlinearly with tenant count while the event engine's grows
roughly with executed events — the ratio therefore *rises* with N
(measured on this container: ~2x at N=8, ~5.4x at N=64, ~6.4x at N=96).

``--smoke`` runs the N=8 cell (reported, sanity-gated at >=1.2x) and the
N=96 cell, which must show the event engine >=5x faster or the run exits
nonzero — CI fails if the event scheduler regresses toward pass-based
cost.
"""

from __future__ import annotations

import time

# engine_bench times the engine alone, so it builds tenants through the
# same internal phase constructors run_workload_multi uses rather than
# timing the whole public entry point
from repro.core.simulator import EngineInstance, SharedMemoryEngine
from repro.core.workloads import (MOMS_PORTS, MULTI_SHARED_PORTS,
                                  _hashtable_phases, _mem_factory_for,
                                  _tenant_hashtable_data,
                                  make_hashtable_data)

SMOKE_CELLS = ((8, None), (96, 5.0))       # (n_instances, min_speedup_gate)
FULL_CELLS = ((8, None), (16, None), (32, None), (64, None), (96, 5.0))
SANITY_MIN_SPEEDUP = 1.2                   # event must never be slower


def _build_hashtable_tenants(n: int, *, scale: str = "small",
                             latency: int = 100, rif: int = 32,
                             max_outstanding: int = 64, seed: int = 0):
    """N hashtable tenants sharing the table port — one scale-sweep cell,
    freshly constructed (program generators are consumed by a run)."""
    mem_factory = _mem_factory_for("fixed", latency, max_outstanding,
                                   MOMS_PORTS["hashtable"])
    data0 = make_hashtable_data(scale, seed)
    shared = None
    instances = []
    for i in range(n):
        data = _tenant_hashtable_data(data0, i, seed)
        progs, mems, _, _ = _hashtable_phases(
            data, "rhls_dec", latency, rif, mem_factory, shared_mems=shared)
        if shared is None:
            shared = {p: mems[p] for p in MULTI_SHARED_PORTS["hashtable"]}
        private = {p: m for p, m in mems.items()
                   if p not in MULTI_SHARED_PORTS["hashtable"]}
        instances.append(EngineInstance(f"t{i}", progs[0], private))
    return instances, shared


def _time_engines(n: int, reps: int) -> dict:
    """Best-of-``reps`` wall time of engine.run() per scheduler on the
    N-tenant cell.  Reps are interleaved (polling, event, polling, ...)
    so a noisy-neighbor burst or frequency throttle on a shared CI
    runner lands on both engines rather than skewing their ratio."""
    best = {"polling": float("inf"), "event": float("inf")}
    res = {}
    for _ in range(reps):
        for engine in ("polling", "event"):
            instances, shared = _build_hashtable_tenants(n)
            eng = SharedMemoryEngine(instances, shared, engine=engine)
            t0 = time.perf_counter()
            res[engine] = eng.run()
            dt = time.perf_counter() - t0
            if dt < best[engine]:
                best[engine] = dt
    return {e: (best[e], res[e]) for e in best}


def run(csv_print, smoke: bool = False) -> dict:
    cells = SMOKE_CELLS if smoke else FULL_CELLS
    results = {}
    for n, gate in cells:
        # small cells finish in ~10ms, where shared-runner noise is
        # proportionally largest — buy margin with extra reps there
        reps = 5 if n <= 32 else 3
        speedup = 0.0
        # a gate miss gets one full re-measurement before failing: a
        # noisy-neighbor burst won't repeat across both rounds, a real
        # scheduler regression will
        for attempt in (0, 1):
            timed = _time_engines(n, reps)
            t_poll, r_poll = timed["polling"]
            t_event, r_event = timed["event"]
            # parity sanity alongside the timing (results are in hand);
            # plain raise so it fires under python -O too
            if r_event.cycles != r_poll.cycles:
                raise AssertionError(
                    f"engine parity violation at n{n}: "
                    f"event={r_event.cycles} polling={r_poll.cycles}")
            speedup = t_poll / t_event
            floor = max(SANITY_MIN_SPEEDUP, gate or 0.0)
            if speedup >= floor or attempt:
                break
        results[n] = (t_poll, t_event, speedup, r_event.events)
        csv_print(
            f"engine-bench/hashtable/rhls_dec/n{n},{t_event * 1e6:.0f},"
            f"event_evps={r_event.events / t_event:.0f};"
            f"polling_evps={r_poll.events / t_poll:.0f};"
            f"speedup={speedup:.2f};events={r_event.events}")
        if speedup < SANITY_MIN_SPEEDUP:
            raise AssertionError(
                f"event engine slower than polling at n{n}: "
                f"{speedup:.2f}x < {SANITY_MIN_SPEEDUP}x")
        if gate is not None and speedup < gate:
            raise AssertionError(
                f"event-engine perf regression: {speedup:.2f}x < {gate}x "
                f"on the n{n} hashtable cell")
    return results
