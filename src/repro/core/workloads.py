"""The paper's seven benchmarks as explicit-decoupling DAE programs (§4, §6).

Each benchmark is expressed in the paper's five configurations:

  * ``vitis``       — statically scheduled baseline: dependent loads block
                      for the full memory latency plus a schedule overhead
                      (``VITIS_OVH``); FP accumulation loops carry an
                      II=8 initiation-interval floor (Vivado FP-add chain).
  * ``vitis_dec``   — explicit decoupling via repurposed burst interfaces
                      (§5.2): decoupled request/execute loops, but the
                      static schedule holds the execute loop at II=3 and
                      only ONE request/response pair may be outstanding
                      per pointer argument for data-dependent consumption
                      order (the Mergesort limitation).
  * ``rhls``        — dynamic HLS without decoupling: dataflow operators
                      pipeline independent loads at II=1, but request
                      generation stays gated by program dependencies
                      (e.g. SPMV's ``rows`` loads), and stores gate the
                      state edge (§5.4).
  * ``rhls_stream`` — loads + streams approximating decoupling (§3.2);
                      same steady-state throughput as decoupling but
                      with an extra stream hop, and a structural deadlock
                      for mergesort (two fetch loops share the
                      disambiguation queue — reproduced here).
  * ``rhls_dec``    — full explicit decoupling in dynamic HLS (§5.3).

Cycle-model calibration constants are module-level and documented; the
goal is to reproduce the paper's Table 1 speedup bands and the Fig. 4
golden-overhead structure, not RTL-exact cycle counts (see
EXPERIMENTS.md §Repro for the side-by-side comparison).

Every program also *computes the real result* through the simulated
memory system; results are checked against a NumPy reference, and the
simulator enforces the paper's §5.1 conservation rules.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dae import (
    DaeProgram,
    Delay,
    Deq,
    Enq,
    LoadChannel,
    Process,
    Req,
    Resp,
    Store,
    StoreWait,
    StreamChannel,
)
from repro.core.simulator import (
    DeadlockError,
    EngineInstance,
    FixedLatencyMemory,
    Fused,
    MemoryModel,
    MomsMemory,
    Par,
    SharedMemoryEngine,
    SimResult,
    simulate,
)
from repro.core.trace import Tracer, TraceSummary

__all__ = ["BENCHMARKS", "CONFIGS", "MULTI_BENCHMARKS", "run_workload",
           "run_workload_multi", "WorkloadReport", "MultiWorkloadReport",
           "make_gather_data", "gather_ref", "gather_phases",
           "make_frontier_data", "frontier_ref", "frontier_phases",
           "make_gmm_data", "gmm_ref", "gmm_phases",
           "spmv_gather_ref", "spmv_gather_phases"]

CONFIGS = ("vitis", "vitis_dec", "rhls", "rhls_stream", "rhls_dec")
BENCHMARKS = (
    "binsearch",
    "binsearch_for",
    "hashtable",
    "mergesort",
    "mergesort_opt",
    "spmv",
    "multispmv",
)

# --- calibration constants (documented in EXPERIMENTS.md §Repro) -----------
VITIS_OVH = 10       # static-schedule overhead per dependent-load iteration
VITIS_DEC_II = 3     # Vitis Decoupled execute-loop initiation interval
VITIS_FP_II = 8      # Vivado FP accumulate loop-carried II
VITIS_ROW_FILL = 30  # static pipeline fill/drain per outer-loop iteration
RHLS_STORE_GATE = 50 # R-HLS (non-decoupled) store state-edge release delay


# ---------------------------------------------------------------------------
# Dataset construction
# ---------------------------------------------------------------------------


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def make_binsearch_data(scale: str, seed: int = 0) -> Dict[str, Any]:
    n, lookups = {
        "paper": (1_234_567, 1_000),
        "fig4": (1_234_567, 4_000),
        "small": (1_021, 24),
    }[scale]
    r = _rng(seed)
    arr = np.unique(r.integers(0, n * 8, size=n * 2))[:n].astype(np.int64)
    assert len(arr) == n
    keys = arr[r.integers(0, n, size=lookups)]
    return {"arr": arr, "keys": keys, "n": n}


def make_hashtable_data(scale: str, seed: int = 1) -> Dict[str, Any]:
    chains, chain_len = {
        "paper": (1_024, 16),
        "fig4": (4_096, 16),
        "small": (16, 4),
    }[scale]
    n_entries = chains * chain_len
    # entry = (key, value, next_idx); chain c occupies [c*L, (c+1)*L)
    entries: List[Tuple[int, int, int]] = []
    r = _rng(seed)
    values = r.integers(0, 1 << 30, size=n_entries)
    for c in range(chains):
        for k in range(chain_len):
            idx = c * chain_len + k
            nxt = idx + 1 if k + 1 < chain_len else -1
            entries.append((idx, int(values[idx]), nxt))
    # look up the LAST key of each chain -> walks the full chain
    lookup_keys = [c * chain_len + (chain_len - 1) for c in range(chains)]
    heads = [c * chain_len for c in range(chains)]
    return {
        "entries": entries,
        "keys": lookup_keys,
        "heads": heads,
        "chains": chains,
        "chain_len": chain_len,
    }


def make_spmv_data(scale: str, seed: int = 2) -> Dict[str, Any]:
    nrows, ncols, nnz = {
        "paper": (1_024, 16_777_216, 17_221),
        "fig4_sparse": (16_384, 16_777_216, 17_221),
        "fig4_dense": (128, 65_536, 65_536),
        "small": (16, 256, 64),
    }[scale]
    r = _rng(seed)
    counts = r.multinomial(nnz, np.ones(nrows) / nrows)
    rows = np.zeros(nrows + 1, dtype=np.int64)
    rows[1:] = np.cumsum(counts)
    cols = r.integers(0, ncols, size=nnz).astype(np.int64)
    val = r.standard_normal(nnz).astype(np.float64)
    vec = r.standard_normal(ncols).astype(np.float64)
    return {"rows": rows, "cols": cols, "val": val, "vec": vec, "nrows": nrows,
            "ncols": ncols, "nnz": nnz}


def make_mergesort_data(scale: str, seed: int = 3) -> Dict[str, Any]:
    n = {"paper": 234, "fig4": 8_192, "small": 37}[scale]
    r = _rng(seed)
    table = r.integers(0, 1 << 31, size=n).astype(np.int64)
    return {"table": table, "n": n}


def make_multispmv_data(scale: str, seed: int = 4) -> Dict[str, Any]:
    nrows, nnz, iters = {
        "paper": (128, 1_639, 10),
        "small": (8, 24, 3),
    }[scale]
    r = _rng(seed)
    counts = r.multinomial(nnz, np.ones(nrows) / nrows)
    rows = np.zeros(nrows + 1, dtype=np.int64)
    rows[1:] = np.cumsum(counts)
    cols = r.integers(0, nrows, size=nnz).astype(np.int64)
    val = (r.standard_normal(nnz) * 0.3).astype(np.float64)
    vec = r.standard_normal(nrows).astype(np.float64)
    return {"rows": rows, "cols": cols, "val": val, "vec": vec,
            "nrows": nrows, "nnz": nnz, "iters": iters, "alpha": 0.9}


# ---------------------------------------------------------------------------
# NumPy references + golden cycle models (paper Fig. 4)
# ---------------------------------------------------------------------------


def binsearch_ref(arr: np.ndarray, keys: np.ndarray, early: bool) -> Tuple[List[int], int]:
    """Returns (result index per key, total loads).  ``early`` is the
    early-exit variant; the _for variant runs EXACTLY ceil(log2 n)
    iterations (loads included — redundant once the range collapses, as
    the paper notes for the constant-iteration version)."""
    n = len(arr)
    iters_fixed = int(math.ceil(math.log2(n)))
    results, loads = [], 0
    for key in keys:
        lo, hi = 0, n
        if early:
            res = -1
            while lo < hi:
                mid = (lo + hi) // 2
                v = arr[mid]
                loads += 1
                if v == key:
                    res = mid
                    break
                if v <= key:
                    lo = mid + 1
                else:
                    hi = mid
            results.append(int(res))
        else:
            for _ in range(iters_fixed):
                mid = (lo + hi) // 2 if lo < hi else min(lo, n - 1)
                v = arr[mid]
                loads += 1
                if lo < hi:
                    if v <= key:
                        lo = mid + 1
                    else:
                        hi = mid
            results.append(int(lo))
    return results, loads


def hashtable_ref(entries: Sequence[Tuple[int, int, int]], keys: Sequence[int],
                  heads: Sequence[int]) -> Tuple[List[int], int]:
    results, loads = [], 0
    for key, head in zip(keys, heads):
        idx = head
        res = -1
        while idx >= 0:
            k, v, nxt = entries[idx]
            loads += 1
            if k == key:
                res = v
                break
            idx = nxt
        results.append(res)
    return results, loads


def spmv_ref(rows, cols, val, vec) -> np.ndarray:
    nrows = len(rows) - 1
    out = np.zeros(nrows, dtype=np.float64)
    for i in range(nrows):
        s = 0.0
        for j in range(rows[i], rows[i + 1]):
            s += val[j] * vec[cols[j]]
        out[i] = s
    return out


def multispmv_ref(rows, cols, val, vec, iters, alpha) -> np.ndarray:
    v = vec.copy()
    for _ in range(iters):
        out = spmv_ref(rows, cols, val, v)
        v = out * alpha
    return v


# ---------------------------------------------------------------------------
# Shared program fragments
# ---------------------------------------------------------------------------


def _blocking_load(ch: LoadChannel, addr: int, overhead: int = 0):
    """Coupled load: request + blocking response (+ schedule overhead)."""
    yield Req(ch, addr)
    v = yield Resp(ch)
    if overhead:
        yield Delay(overhead)
    return v


# -- parallel pointer chasing (paper Listings 4 & 5) ------------------------


def _roundrobin_chase(
    load_ch: LoadChannel,
    state_st: StreamChannel,
    n_items: int,
    init_state: Callable[[int], Tuple[Any, int]],
    step: Callable[[Any, Any], Tuple[bool, int, Any, Any, int]],
    out_port: str,
    rif: int,
):
    """Listing 4 (right): RIF pointer chains processed round-robin.

    ``init_state(i) -> (state, first_addr)``
    ``step(state, loaded) -> (done, out_idx, out_val, new_state, next_addr)``
    Every loop iteration is a single issue slot (II=1).
    """

    def gen():
        counters = {"started": 0, "inflight": 0, "finished": 0}

        def on_state_factory():
            def on_resp(v):
                def on_state(s):
                    done, oi, ov, ns, na = step(s, v)
                    if done:
                        counters["finished"] += 1
                        counters["inflight"] -= 1
                        return Store(out_port, oi, ov)
                    return Par([Req(load_ch, na), Enq(state_st, ns)])
                return Fused(Deq(state_st), on_state)
            return on_resp

        while counters["finished"] < n_items:
            if counters["inflight"] < rif and counters["started"] < n_items:
                s0, a0 = init_state(counters["started"])
                counters["started"] += 1
                counters["inflight"] += 1
                yield Par([Req(load_ch, a0), Enq(state_st, s0)])
            else:
                yield Fused(Resp(load_ch), on_state_factory())

    return gen


def _lockstep_chase(
    load_ch: LoadChannel,
    state_st: StreamChannel,
    n_items: int,
    iters: int,
    init_state: Callable[[int], Tuple[Any, int]],
    fixed_step: Callable[[Any, Any], Tuple[int, Any, Any, int]],
    out_port: str,
    chunk: int,
):
    """Listing 5: fixed-length chains, CHUNK-wide lock-step.

    ``fixed_step(state, loaded) -> (out_idx, out_val, new_state, next_addr)``
    — always produces a next address (redundant loads once resolved, as
    the paper notes), and out_val is stored only after the final
    iteration.
    """

    def gen():
        for c0 in range(0, n_items, chunk):
            c1 = min(c0 + chunk, n_items)
            # iteration 0: issue all requests for the chunk
            for i in range(c0, c1):
                s0, a0 = init_state(i)
                yield Par([Req(load_ch, a0), Enq(state_st, s0)])
            # iterations 1..iters-1: consume + re-request
            for j in range(1, iters):
                for _ in range(c0, c1):
                    def on_resp(v):
                        def on_state(s):
                            _, _, ns, na = fixed_step(s, v)
                            return Par([Req(load_ch, na), Enq(state_st, ns)])
                        return Fused(Deq(state_st), on_state)
                    yield Fused(Resp(load_ch), on_resp)
            # final consume round: store results
            for _ in range(c0, c1):
                def on_resp_last(v):
                    def on_state(s):
                        oi, ov, _, _ = fixed_step(s, v)
                        return Store(out_port, oi, ov)
                    return Fused(Deq(state_st), on_state)
                yield Fused(Resp(load_ch), on_resp_last)

    return gen


def _stream_chase(
    load_ch: LoadChannel,
    val_st: StreamChannel,
    state_st: StreamChannel,
    n_items: int,
    total_loads: int,
    init_state: Callable[[int], Tuple[Any, int]],
    step: Callable[[Any, Any], Tuple[bool, int, Any, Any, int]],
    out_port: str,
    rif: int,
):
    """R-HLS Stream: a separate Access unit forwards load responses into a
    value stream (paper §3.2 / Fig 2a); requires the exact load count up
    front — the streaming precision requirement the paper highlights."""

    def access_gen():
        for _ in range(total_loads):
            yield Fused(Resp(load_ch), lambda v: Enq(val_st, v))

    def exec_gen():
        counters = {"started": 0, "inflight": 0, "finished": 0}
        while counters["finished"] < n_items:
            if counters["inflight"] < rif and counters["started"] < n_items:
                s0, a0 = init_state(counters["started"])
                counters["started"] += 1
                counters["inflight"] += 1
                yield Par([Req(load_ch, a0), Enq(state_st, s0)])
            else:
                def on_v(v):
                    def on_state(s):
                        done, oi, ov, ns, na = step(s, v)
                        if done:
                            counters["finished"] += 1
                            counters["inflight"] -= 1
                            return Store(out_port, oi, ov)
                        return Par([Req(load_ch, na), Enq(state_st, ns)])
                    return Fused(Deq(state_st), on_state)
                yield Fused(Deq(val_st), on_v)

    return access_gen, exec_gen


# ---------------------------------------------------------------------------
# Benchmark: binsearch / binsearch_for
# ---------------------------------------------------------------------------


def _chan_cap(rif: int, cap: Optional[int]) -> int:
    """Channel capacity: explicit override (the tuner's knob) or the
    legacy rif+1 sizing."""
    return cap if cap is not None else rif + 1


def _binsearch_phases(data, config, early, latency, rif, mem_factory,
                      cap=None, shared_mems=None):
    arr, keys, n = data["arr"], data["keys"], data["n"]
    iters_fixed = int(math.ceil(math.log2(n)))
    shared_mems = shared_mems or {}
    mems = {
        "table": shared_mems.get("table")
        or mem_factory("table", list(arr)),
        "out": FixedLatencyMemory([None] * len(keys), latency),
    }

    def _mid(lo, hi):
        return (lo + hi) // 2 if lo < hi else min(lo, n - 1)

    def init_state(i):
        key = int(keys[i])
        lo, hi = 0, n
        return (i, key, lo, hi, -1, 1), _mid(lo, hi)

    def step(s, v):
        i, key, lo, hi, res, it = s
        mid = _mid(lo, hi)
        v = int(v)
        if early and v == key:
            return True, i, mid, None, 0
        if lo < hi:
            if v <= key:
                lo = mid + 1
            else:
                hi = mid
        if early:
            if lo >= hi:
                return True, i, -1, None, 0
        elif it >= iters_fixed:
            return True, i, lo, None, 0
        return False, 0, 0, (i, key, lo, hi, res, it + 1), _mid(lo, hi)

    def fixed_step(s, v):
        i, key, lo, hi, res, it = s
        mid = _mid(lo, hi)
        v = int(v)
        if early and v == key and res < 0:
            res = mid
        if lo < hi:
            if v <= key:
                lo = mid + 1
            else:
                hi = mid
        out = res if early else lo
        return i, out, (i, key, lo, hi, res, it + 1), _mid(lo, hi)

    ch = LoadChannel("bs_load", capacity=_chan_cap(rif, cap), port="table")
    st = StreamChannel("bs_state", capacity=_chan_cap(rif, cap))

    if config in ("vitis", "rhls"):
        ovh = VITIS_OVH if config == "vitis" else 0

        def gen():
            for i in range(len(keys)):
                s, addr = init_state(i)
                while True:
                    v = yield from _blocking_load(ch, addr, ovh)
                    done, oi, ov, s, addr = step(s, v)
                    if done:
                        yield Store("out", oi, ov)
                        break
        procs = [Process("coupled", gen)]
    elif config == "vitis_dec":
        gen = _lockstep_chase(ch, st, len(keys), iters_fixed, init_state,
                              fixed_step, "out", chunk=min(64, rif))
        procs = [Process("lockstep", gen, ii=VITIS_DEC_II)]
    elif config == "rhls_dec":
        gen = _roundrobin_chase(ch, st, len(keys), init_state, step, "out", rif)
        procs = [Process("roundrobin", gen)]
    elif config == "rhls_stream":
        if early:
            res, loads = binsearch_ref(arr, keys, True)
        else:
            res, loads = binsearch_ref(arr, keys, False)
        vst = StreamChannel("bs_vals", capacity=_chan_cap(rif, cap))
        a, e = _stream_chase(ch, vst, st, len(keys), loads, init_state, step,
                             "out", rif)
        procs = [Process("access", a), Process("execute", e)]
    else:
        raise ValueError(config)

    expected, golden_loads = binsearch_ref(arr, keys, early)

    def check(result: SimResult) -> bool:
        got = result.stored_array("out", len(keys))
        return all(g == e for g, e in zip(got, expected))

    return [DaeProgram(f"binsearch[{config}]", procs)], mems, golden_loads, check


# ---------------------------------------------------------------------------
# Compile-target workloads: gather / frontier_gather
#
# These are not Fig. 4 benchmarks; they exist as inputs to the
# repro.compile pipeline (see repro/compile/targets.py).  gather mirrors
# the hand-written dae_gather kernel family so compiled-vs-handwritten
# cells are comparable; frontier_gather — one BFS frontier expansion
# step, out[k] = dist[adj[u_k, j]] — has NO hand-written kernel and
# lands end-to-end through the compiler alone.
# ---------------------------------------------------------------------------


def make_gather_data(scale: str, seed: int = 5) -> Dict[str, Any]:
    n, d, lookups = {
        "paper": (4_096, 128, 2_048),
        "small": (128, 8, 33),
    }[scale]
    r = _rng(seed)
    table = r.standard_normal((n, d)).astype(np.float32)
    idx = r.integers(0, n, size=lookups).astype(np.int64)
    return {"table": table, "idx": idx, "n": n, "d": d}


def gather_ref(table: np.ndarray, idx: np.ndarray) -> np.ndarray:
    return table[idx]


def gather_phases(data, latency, rif, mem_factory, cap=None):
    """Decoupled row gather: a static Access stream + a copy Execute.

    Same ([programs], mems, golden_loads, check) shape as the
    benchmark ``_phases`` builders, so the simulator drives it
    unchanged; `repro.compile` stages the identical program.
    """
    table, idx = data["table"], data["idx"]
    m = len(idx)
    mems = {
        "table": mem_factory("table", [row for row in table]),
        "out": FixedLatencyMemory([None] * m, latency),
    }
    ch = LoadChannel("ga_load", capacity=_chan_cap(rif, cap), port="table")

    def access():
        for a in idx:
            yield Req(ch, int(a))

    def execute():
        for j in range(m):
            yield Fused(Resp(ch), lambda v, j=j: Store("out", j, v))

    progs = [DaeProgram("gather[rhls_dec]",
                        [Process("access", access),
                         Process("execute", execute)])]
    expected = gather_ref(table, idx)

    def check(result: SimResult) -> bool:
        got = result.stored_array("out", m)
        return all(np.array_equal(g, e) for g, e in zip(got, expected))

    return progs, mems, m, check


def make_frontier_data(scale: str, seed: int = 6) -> Dict[str, Any]:
    n, deg, frontier_n = {
        "paper": (4_096, 16, 512),
        "small": (96, 4, 17),
    }[scale]
    r = _rng(seed)
    # Padded degree-`deg` adjacency; missing edges point at the sentinel
    # node n, whose dist entry is -1 (so the compiled kernel never needs
    # a divergent "skip this lane" branch — the paper's fixed-length
    # redundant-work trick applied to graph irregularity).
    adj = r.integers(0, n, size=(n, deg)).astype(np.int64)
    adj[r.random((n, deg)) < 0.25] = n
    dist = np.concatenate([r.integers(0, 64, size=n), [-1]]).astype(np.int64)
    frontier = r.choice(n, size=frontier_n, replace=False).astype(np.int64)
    return {"adj": adj, "dist": dist, "frontier": frontier, "n": n,
            "deg": deg}


def frontier_ref(adj: np.ndarray, dist: np.ndarray,
                 frontier: np.ndarray) -> np.ndarray:
    """One frontier-expansion step: the neighbour distances of every
    frontier node, in (node, edge-slot) order."""
    return dist[adj[frontier].ravel()]


def frontier_phases(data, latency, rif, mem_factory, cap=None):
    """BFS frontier expansion as a two-channel DAE program.

    Access issues the (static) flattened adjacency addresses of the
    frontier; a deref stage turns each landed neighbour id into a
    ``dist`` request (the one-hop indirect load, ``dist[adj[...]]``);
    Execute stores the landed distances.
    """
    adj, dist, frontier = data["adj"], data["dist"], data["frontier"]
    deg = data["deg"]
    m = len(frontier) * deg
    mems = {
        "adj": mem_factory("adj", [int(v) for v in adj.ravel()]),
        "dist": mem_factory("dist", [int(v) for v in dist]),
        "out": FixedLatencyMemory([None] * m, latency),
    }
    adj_ch = LoadChannel("fg_adj", capacity=_chan_cap(rif, cap),
                         port="adj")
    dist_ch = LoadChannel("fg_dist", capacity=_chan_cap(rif, cap),
                          port="dist")

    def access():
        for u in frontier:
            for j in range(deg):
                yield Req(adj_ch, int(u) * deg + j)

    def deref():
        for _ in range(m):
            v = yield Resp(adj_ch)
            yield Req(dist_ch, int(v))

    def execute():
        for k in range(m):
            yield Fused(Resp(dist_ch), lambda v, k=k: Store("out", k, v))

    progs = [DaeProgram("frontier_gather[rhls_dec]",
                        [Process("access", access),
                         Process("deref", deref),
                         Process("execute", execute)])]
    expected = frontier_ref(adj, dist, frontier)

    def check(result: SimResult) -> bool:
        got = result.stored_array("out", m)
        return all(int(g) == int(e) for g, e in zip(got, expected))

    return progs, mems, 2 * m, check


def spmv_gather_ref(cols: np.ndarray, vec: np.ndarray) -> np.ndarray:
    """The decoupled vec-gather phase of SPMV: vec[cols[p]] per nnz."""
    return vec[cols]


def spmv_gather_phases(data, latency, rif, mem_factory, cap=None):
    """SPMV's decoupled vector fetch as a two-channel DAE program.

    The paper's Listing 2 decouples the *products* from the row-pointer
    loads; the irregular half of that kernel is the ``vec[cols[p]]``
    gather, which is what lowers onto the ring emitter (the accumulation
    is a dense reduction the compiler's store checker rejects — see
    ``repro.compile``).  Access issues the (static) ``cols`` addresses;
    a deref stage turns each landed column id into a ``vec`` request;
    Execute stores the landed vector values in nnz order.
    """
    # float32: the compiled kernel stages port data through float32
    # VMEM, so the staged values must survive that cast exactly
    cols, vec = data["cols"], data["vec"].astype(np.float32)
    m = len(cols)
    mems = {
        "cols": mem_factory("cols", [int(c) for c in cols]),
        "vec": mem_factory("vec", [float(v) for v in vec]),
        "out": FixedLatencyMemory([None] * m, latency),
    }
    cols_ch = LoadChannel("sg_cols", capacity=_chan_cap(rif, cap),
                          port="cols")
    vec_ch = LoadChannel("sg_vec", capacity=_chan_cap(rif, cap),
                         port="vec")

    def access():
        for p in range(m):
            yield Req(cols_ch, p)

    def deref():
        for _ in range(m):
            c = yield Resp(cols_ch)
            yield Req(vec_ch, int(c))

    def execute():
        for p in range(m):
            yield Fused(Resp(vec_ch), lambda v, p=p: Store("out", p, v))

    progs = [DaeProgram("spmv_gather[rhls_dec]",
                        [Process("access", access),
                         Process("deref", deref),
                         Process("execute", execute)])]
    expected = spmv_gather_ref(cols, vec)

    def check(result: SimResult) -> bool:
        got = result.stored_array("out", m)
        return all(float(g) == float(e) for g, e in zip(got, expected))

    return progs, mems, 2 * m, check


def make_gmm_data(scale: str, seed: int = 8) -> Dict[str, Any]:
    nblocks, d, f, e = {
        "paper": (256, 8, 8, 16),
        "small": (24, 4, 4, 6),
    }[scale]
    r = _rng(seed)
    block_expert = r.integers(0, e, size=nblocks).astype(np.int64)
    # force at least one empty expert group — the routing edge the
    # kernel (and its Pallas twin) must survive without special-casing
    block_expert[block_expert == e - 1] = 0
    x = r.standard_normal((nblocks, d))
    w = r.standard_normal((e, d, f))
    return {"x": x, "w": w, "block_expert": block_expert, "e": e}


def gmm_ref(x: np.ndarray, w: np.ndarray,
            block_expert: np.ndarray) -> np.ndarray:
    """Per-block expert matmul: out[i] = x[i] @ w[block_expert[i]]."""
    return np.stack([x[i] @ w[int(eid)]
                     for i, eid in enumerate(block_expert)])


def gmm_phases(data, latency, rif, mem_factory, cap=None):
    """Grouped expert matmul as a two-channel DAE program — the
    simulator twin of ``repro.kernels.grouped_matmul``.

    Access issues the (static) routing-stream addresses; a deref stage
    turns each landed expert id into a weight-table request (the
    irregular, data-dependent load — the same address stream the Pallas
    kernel's weight ring fetches ``rif`` tiles ahead); Execute multiplies
    the landed expert weights into the block's tokens and stores the
    block product.
    """
    x, w, block_expert = data["x"], data["w"], data["block_expert"]
    nb = len(block_expert)
    mems = {
        "route": mem_factory("route", [int(v) for v in block_expert]),
        "wtab": mem_factory("wtab", [w[j] for j in range(len(w))]),
        "out": FixedLatencyMemory([None] * nb, latency),
    }
    route_ch = LoadChannel("gm_route", capacity=_chan_cap(rif, cap),
                           port="route")
    w_ch = LoadChannel("gm_w", capacity=_chan_cap(rif, cap), port="wtab")

    def access():
        for i in range(nb):
            yield Req(route_ch, i)

    def deref():
        for _ in range(nb):
            v = yield Resp(route_ch)
            yield Req(w_ch, int(v))

    def execute():
        for i in range(nb):
            yield Fused(Resp(w_ch),
                        lambda wt, i=i: Store("out", i, x[i] @ wt))

    progs = [DaeProgram("grouped_matmul[rhls_dec]",
                        [Process("access", access),
                         Process("deref", deref),
                         Process("execute", execute)])]
    expected = gmm_ref(x, w, block_expert)

    def check(result: SimResult) -> bool:
        got = result.stored_array("out", nb)
        return all(np.array_equal(g, e) for g, e in zip(got, expected))

    return progs, mems, 2 * nb, check


# ---------------------------------------------------------------------------
# Benchmark: hashtable
# ---------------------------------------------------------------------------


def _hashtable_phases(data, config, latency, rif, mem_factory, cap=None,
                      shared_mems=None):
    entries, keys, heads = data["entries"], data["keys"], data["heads"]
    chain_len = data["chain_len"]
    shared_mems = shared_mems or {}
    mems = {
        "table": shared_mems.get("table")
        or mem_factory("table", list(entries)),
        "out": FixedLatencyMemory([None] * len(keys), latency),
    }

    def init_state(i):
        # hash computation -> head bucket
        return (i, keys[i]), heads[i]

    def step(s, entry):
        i, key = s
        k, v, nxt = entry
        if k == key:
            return True, i, v, None, 0
        if nxt < 0:
            return True, i, -1, None, 0
        return False, 0, 0, (i, key), nxt

    def fixed_step(s, entry):
        # lock-step variant: walk exactly chain_len steps; keep re-loading
        # the tail once resolved (redundant loads, paper §4.2)
        if len(s) == 2:
            s = (s[0], s[1], -1, heads[s[0]])
        i, key, res, idx = s
        k, v, nxt = entry
        if k == key and res < 0:
            res = v
        naddr = nxt if nxt >= 0 else idx
        return i, res, (i, key, res, naddr), naddr

    ch = LoadChannel("ht_load", capacity=_chan_cap(rif, cap), port="table")
    st = StreamChannel("ht_state", capacity=_chan_cap(rif, cap))

    if config in ("vitis", "rhls"):
        ovh = VITIS_OVH if config == "vitis" else 0

        def gen():
            for i in range(len(keys)):
                yield Delay(1)  # hash computation
                s, addr = init_state(i)
                while True:
                    v = yield from _blocking_load(ch, addr, ovh)
                    done, oi, ov, s, addr = step(s, v)
                    if done:
                        yield Store("out", oi, ov)
                        break
        procs = [Process("coupled", gen)]
    elif config == "vitis_dec":
        gen = _lockstep_chase(ch, st, len(keys), chain_len, init_state,
                              fixed_step, "out", chunk=min(64, rif))
        procs = [Process("lockstep", gen, ii=VITIS_DEC_II)]
    elif config == "rhls_dec":
        gen = _roundrobin_chase(ch, st, len(keys), init_state, step, "out", rif)
        procs = [Process("roundrobin", gen)]
    elif config == "rhls_stream":
        expected, loads = hashtable_ref(entries, keys, heads)
        vst = StreamChannel("ht_vals", capacity=_chan_cap(rif, cap))
        a, e = _stream_chase(ch, vst, st, len(keys), loads, init_state, step,
                             "out", rif)
        procs = [Process("access", a), Process("execute", e)]
    else:
        raise ValueError(config)

    expected, golden_loads = hashtable_ref(entries, keys, heads)

    def check(result: SimResult) -> bool:
        got = result.stored_array("out", len(keys))
        return all(g == e for g, e in zip(got, expected))

    return [DaeProgram(f"hashtable[{config}]", procs)], mems, golden_loads, check


# ---------------------------------------------------------------------------
# Benchmark: spmv (paper Listing 2) — also used by multispmv
# ---------------------------------------------------------------------------


def _spmv_program(rows, cols, val, vec_data, out_data, config, latency, rif,
                  mem_factory, tag="spmv", store_gate=0, cap=None,
                  shared_mems=None):
    """Build one SPMV DaeProgram writing results to out_data via port 'out'."""
    nrows = len(rows) - 1
    nnz = int(rows[-1])
    row_cnt = [int(rows[i + 1] - rows[i]) for i in range(nrows)]

    # Buffer sizing mirrors the paper's profile-guided approach (§6): the
    # val responses are consumed one val->vec round trip (~2x latency)
    # after issue, so that channel's buffer must cover the lag.
    c = _chan_cap(rif, cap)
    # with an explicit capacity the tuner owns the profile floors too
    val_cap = c if cap is not None else max(c, 2 * latency + 8)
    vec_cap = c if cap is not None else max(c, latency + 8)
    rows_ch = LoadChannel(f"{tag}_rows", capacity=c, port="rows")
    val_ch = LoadChannel(f"{tag}_val", capacity=val_cap, port="val")
    cols_ch = LoadChannel(f"{tag}_cols", capacity=c, port="cols")
    vec_ch = LoadChannel(f"{tag}_vec", capacity=vec_cap, port="vec")
    bounds_exec = StreamChannel(f"{tag}_bexec", capacity=nrows + 2)
    bounds_addr = StreamChannel(f"{tag}_baddr", capacity=nrows + 2)

    shared_mems = shared_mems or {}

    def _mem(port, build_data):
        return shared_mems.get(port) or mem_factory(port, build_data())

    mems = {
        "rows": _mem("rows", lambda: list(int(x) for x in rows)),
        "val": _mem("val", lambda: list(float(x) for x in val)),
        "cols": _mem("cols", lambda: list(int(x) for x in cols)),
        "vec": _mem("vec", lambda: vec_data),
        "out": FixedLatencyMemory(out_data, latency),
    }

    if config == "vitis":
        # static schedule: blocking row-pointer loads, FP-II-bound inner loop,
        # pipeline fill per row; values computed through the arrays.
        def gen():
            prev = yield from _blocking_load(rows_ch, 0, 0)
            for i in range(nrows):
                b = yield from _blocking_load(rows_ch, i + 1, 0)
                yield Delay(VITIS_ROW_FILL)
                s = 0.0
                for j in range(int(prev), int(b)):
                    s += val[j] * vec_data[int(cols[j])]
                    yield Delay(VITIS_FP_II)
                yield Store("out", i, s)
                prev = b
        return DaeProgram(f"{tag}[vitis]", [Process("spmv", gen)]), mems

    gated_addr = config in ("rhls",)  # request loop gated by rows (false dep)
    exec_ii = VITIS_DEC_II if config == "vitis_dec" else 1

    def p_rows():
        for i in range(nrows + 1):
            yield Req(rows_ch, i)

    def p_bounds():
        prev_cell = {"v": None}
        for i in range(nrows + 1):
            def on(v, prev_cell=prev_cell):
                if prev_cell["v"] is None:
                    prev_cell["v"] = int(v)
                    return None
                cnt = int(v) - prev_cell["v"]
                prev_cell["v"] = int(v)
                if gated_addr:
                    return Par([Enq(bounds_exec, cnt), Enq(bounds_addr, cnt)])
                return Enq(bounds_exec, cnt)
            yield Fused(Resp(rows_ch), on)

    def p_addr_gated():
        # rhls: address generation consumes a row-boundary token per row
        for i in range(nrows):
            cnt_cell = {}
            def on(c, cnt_cell=cnt_cell):
                cnt_cell["c"] = int(c)
                return None
            yield Fused(Deq(bounds_addr), on)
            for j in range(int(rows[i]), int(rows[i + 1])):
                yield Par([Req(val_ch, j), Req(cols_ch, j)])

    def p_addr_free():
        # decoupled: the false dependency through rows is gone (Listing 2 right)
        for j in range(nnz):
            yield Par([Req(val_ch, j), Req(cols_ch, j)])

    def p_vec():
        for j in range(nnz):
            yield Fused(Resp(cols_ch), lambda c: Req(vec_ch, int(c)))

    def p_exec():
        for i in range(nrows):
            cnt = row_cnt[i]
            if cnt == 0:
                yield Fused(Deq(bounds_exec), lambda _b, i=i: Store("out", i, 0.0))
                if store_gate:
                    yield Delay(store_gate)
                continue
            acc = {"s": 0.0}
            for j in range(cnt):
                first, lastj = j == 0, j == cnt - 1
                def on(vals, acc=acc, i=i, lastj=lastj):
                    v, x = float(vals[0]), float(vals[1])
                    acc["s"] += v * x
                    if lastj:
                        return Store("out", i, acc["s"])
                    return None
                subs = [Resp(val_ch), Resp(vec_ch)]
                if first:
                    subs.append(Deq(bounds_exec))
                yield Fused(Par(subs), on)
            if store_gate:
                yield Delay(store_gate)

    procs = [
        Process("rows_req", p_rows),
        Process("bounds", p_bounds),
        Process("addr", p_addr_gated if gated_addr else p_addr_free),
        Process("vec_req", p_vec),
        Process("exec", p_exec, ii=exec_ii),
    ]
    return DaeProgram(f"{tag}[{config}]", procs), mems


def _spmv_phases(data, config, latency, rif, mem_factory, cap=None,
                 shared_mems=None):
    rows, cols, val, vec = data["rows"], data["cols"], data["val"], data["vec"]
    if shared_mems and "vec" in shared_mems:
        vec_data = shared_mems["vec"].data
    else:
        vec_data = list(float(x) for x in vec)
    out_data = [0.0] * data["nrows"]
    prog, mems = _spmv_program(rows, cols, val, vec_data, out_data, config,
                               latency, rif, mem_factory, cap=cap,
                               shared_mems=shared_mems)
    expected = spmv_ref(rows, cols, val, vec)

    def check(result: SimResult) -> bool:
        got = np.array(out_data, dtype=np.float64)
        return bool(np.allclose(got, expected, rtol=1e-9, atol=1e-12))

    golden = data["nnz"]
    return [(prog, mems)], golden, check


# ---------------------------------------------------------------------------
# Benchmark: mergesort / mergesort_opt (paper Listing 3)
# ---------------------------------------------------------------------------


def _merge_pass_program(src_data, dst_data, n, width, config, latency, rif,
                        mem_factory, src_port, dst_port, cap=None, base=0,
                        mems=None):
    """One bottom-up pass: merge width-runs of src into 2*width-runs of dst.

    ``base`` offsets every address by a fixed amount so multiple tenants
    can sort disjoint ranges of one shared array; ``mems`` supplies
    pre-built (shared) memory models instead of creating private ones.
    """
    merges = []
    lo = 0
    while lo < n:
        merges.append((base + lo, base + min(lo + width, n),
                       base + min(lo + 2 * width, n)))
        lo += 2 * width

    # Vitis burst_maxi: only one request/response pair outstanding per
    # pointer at a time for data-dependent consumption order (§5.2)
    ch_cap = 1 if config == "vitis_dec" else _chan_cap(rif, cap)
    i_ch = LoadChannel(f"ms_i_{src_port}", capacity=ch_cap, port=src_port)
    j_ch = LoadChannel(f"ms_j_{src_port}", capacity=ch_cap, port=src_port)

    if mems is None:
        mems = {
            src_port: mem_factory(src_port, src_data),
            dst_port: mem_factory(dst_port, dst_data),
        }

    if config in ("vitis", "rhls"):
        ovh = VITIS_OVH if config == "vitis" else 0

        def gen():
            for (l, r, e) in merges:
                i, j = l, r
                for k in range(l, e):
                    reqs, resps = [], []
                    if i < r:
                        reqs.append(Req(i_ch, i))
                        resps.append(Resp(i_ch))
                    if j < e:
                        reqs.append(Req(j_ch, j))
                        resps.append(Resp(j_ch))
                    yield Par(reqs)
                    vals = yield Par(resps)
                    if ovh:
                        yield Delay(ovh)
                    vi = vals[0] if i < r else None
                    vj = vals[-1] if j < e else None
                    if j >= e or (i < r and vi <= vj):
                        yield Store(dst_port, k, vi)
                        i += 1
                    else:
                        yield Store(dst_port, k, vj)
                        j += 1
        return DaeProgram(f"merge[{config}]", [Process("merge", gen)]), mems

    # decoupled variants: request loops run ahead across the whole pass
    def p_req_i():
        for (l, r, e) in merges:
            for idx in range(l, r):
                yield Req(i_ch, idx)

    def p_req_j():
        for (l, r, e) in merges:
            for idx in range(r, e):
                yield Req(j_ch, idx)

    def p_merge():
        for (l, r, e) in merges:
            ni, nj = r - l, e - r
            state = {"hi": None, "hj": None, "ti": 0, "tj": 0}

            def pick_and_store(k, state=state):
                hi, hj = state["hi"], state["hj"]
                i_alive = hi is not None
                j_alive = hj is not None
                if i_alive and (not j_alive or hi <= hj):
                    state["hi"] = None
                    return Store(dst_port, k, hi)
                state["hj"] = None
                return Store(dst_port, k, hj)

            for k in range(l, e):
                need_i = state["hi"] is None and state["ti"] < ni
                need_j = state["hj"] is None and state["tj"] < nj
                if need_i and need_j:
                    def on_both(vals, k=k, state=state):
                        state["hi"], state["hj"] = vals
                        state["ti"] += 1
                        state["tj"] += 1
                        return pick_and_store(k)
                    yield Fused(Par([Resp(i_ch), Resp(j_ch)]), on_both)
                elif need_i:
                    def on_i(v, k=k, state=state):
                        state["hi"] = v
                        state["ti"] += 1
                        return pick_and_store(k)
                    yield Fused(Resp(i_ch), on_i)
                elif need_j:
                    def on_j(v, k=k, state=state):
                        state["hj"] = v
                        state["tj"] += 1
                        return pick_and_store(k)
                    yield Fused(Resp(j_ch), on_j)
                else:
                    yield pick_and_store(k)

    ii = VITIS_DEC_II if config == "vitis_dec" else 1
    procs = [
        Process("req_i", p_req_i),
        Process("req_j", p_req_j),
        Process("merge", p_merge, ii=ii),
    ]
    return DaeProgram(f"merge[{config}]", procs), mems


def _copy_pass_program(src_data, dst_data, n, config, latency, rif,
                       mem_factory, src_port, dst_port, cap=None, base=0,
                       mems=None):
    ch = LoadChannel(f"cp_{src_port}", capacity=_chan_cap(rif, cap),
                     port=src_port)
    if mems is None:
        mems = {
            src_port: mem_factory(src_port, src_data),
            dst_port: mem_factory(dst_port, dst_data),
        }
    if config in ("vitis",):
        def gen():
            yield Delay(latency)  # burst fill
            for k in range(base, base + n):
                yield Delay(2)
                yield Store(dst_port, k, src_data[k])
        return DaeProgram("copy[vitis]", [Process("copy", gen)]), mems

    def p_req():
        for k in range(base, base + n):
            yield Req(ch, k)

    def p_copy():
        for k in range(base, base + n):
            yield Fused(Resp(ch), lambda v, k=k: Store(dst_port, k, v))

    ii = VITIS_DEC_II if config == "vitis_dec" else 1
    return (
        DaeProgram(f"copy[{config}]",
                   [Process("req", p_req), Process("copy", p_copy, ii=ii)]),
        mems,
    )


def _mergesort_stream_deadlock() -> None:
    # The disambiguation scheme couples the two fetch loops through one
    # shared in-order queue; once run width exceeds the queue capacity
    # the merge needs the j-run head while i-run values block the
    # queue -> structural deadlock (paper §6).  We reproduce the
    # detection rather than modelling the hang.
    raise DeadlockError(
        "R-HLS Stream mergesort: shared disambiguation queue between "
        "the two fetch loops deadlocks (paper §6)")


def _mergesort_plan(table, result, n, opt):
    """Bottom-up phase plan over two buffers: a list of
    ``(kind, src, dst, width, src_port, dst_port)`` tuples plus the
    buffer that holds the sorted data afterwards and the merge-pass
    count.  The non-opt variant copies back after every merge; the opt
    variant ping-pongs the buffers instead (§4.2)."""
    phases = []
    width = 1
    src, dst = table, result
    src_port, dst_port = "table", "result"
    while width < n:
        phases.append(("merge", src, dst, width, src_port, dst_port))
        if opt:
            src, dst = dst, src
            src_port, dst_port = dst_port, src_port
        else:
            phases.append(("copy", dst, src, None, dst_port, src_port))
        width *= 2
    passes = len([p for p in phases if p[0] == "merge"])
    return phases, src, passes


def _mergesort_phases(data, config, opt, latency, rif, mem_factory, cap=None):
    n = data["n"]
    table = [int(x) for x in data["table"]]
    result = [0] * n

    if config == "rhls_stream":
        return _mergesort_stream_deadlock, None, None

    phases, final_holder, passes = _mergesort_plan(table, result, n, opt)
    golden = n * passes
    expected = np.sort(data["table"])

    def build():
        out = []
        for kind, s, d, w, sp, dp in phases:
            if kind == "merge":
                out.append(_merge_pass_program(s, d, n, w, config, latency,
                                               rif, mem_factory, sp, dp,
                                               cap=cap))
            else:
                out.append(_copy_pass_program(s, d, n, config, latency, rif,
                                              mem_factory, sp, dp, cap=cap))
        return out

    def check(_result) -> bool:
        got = np.array(final_holder, dtype=np.int64)
        return bool(np.array_equal(got, expected))

    return build, golden, check


# ---------------------------------------------------------------------------
# Benchmark: multispmv
# ---------------------------------------------------------------------------


def _multispmv_phases(data, config, latency, rif, mem_factory, cap=None):
    rows, cols, val = data["rows"], data["cols"], data["val"]
    nrows, nnz, iters, alpha = (data["nrows"], data["nnz"], data["iters"],
                                data["alpha"])
    vec_data = [float(x) for x in data["vec"]]
    out_data = [0.0] * nrows
    store_gate = RHLS_STORE_GATE if config == "rhls" else 0

    def build():
        progs = []
        for it in range(iters):
            progs.append(_spmv_program(rows, cols, val, vec_data, out_data,
                                       config, latency, rif, mem_factory,
                                       tag=f"mspmv{it}", store_gate=store_gate,
                                       cap=cap))
            progs.append(_scale_copy_program(out_data, vec_data, nrows, alpha,
                                             config, latency, rif, mem_factory,
                                             cap=cap))
        return progs

    expected = multispmv_ref(rows, cols, val, data["vec"], iters, alpha)
    golden = iters * nnz

    def check(_r) -> bool:
        got = np.array(vec_data, dtype=np.float64)
        return bool(np.allclose(got, expected, rtol=1e-9, atol=1e-12))

    return build, golden, check


def _scale_copy_program(out_data, vec_data, n, alpha, config, latency, rif,
                        mem_factory, cap=None):
    ch = LoadChannel("msc_out", capacity=_chan_cap(rif, cap), port="outr")
    mems = {
        "outr": mem_factory("outr", out_data),
        "vecw": mem_factory("vecw", vec_data),
    }
    if config == "vitis":
        def gen():
            yield Delay(latency)
            for k in range(n):
                yield Delay(2)
                yield Store("vecw", k, out_data[k] * alpha)
            yield StoreWait("vecw")
        return DaeProgram("scalecopy[vitis]", [Process("copy", gen)]), mems

    def p_req():
        for k in range(n):
            yield Req(ch, k)

    def p_copy():
        for k in range(n):
            yield Fused(Resp(ch), lambda v, k=k: Store("vecw", k, float(v) * alpha))
        yield StoreWait("vecw")

    ii = VITIS_DEC_II if config == "vitis_dec" else 1
    extra_hop = 1 if config == "rhls_stream" else 0

    def p_copy_stream():
        vst = StreamChannel("msc_vst", capacity=_chan_cap(rif, cap))
        # emulated as II=2: resp->enq then deq->store in one unit
        for k in range(n):
            v = yield Resp(ch)
            yield Store("vecw", k, float(v) * alpha)
        yield StoreWait("vecw")

    copy_proc = (Process("copy", p_copy_stream) if extra_hop
                 else Process("copy", p_copy, ii=ii))
    return (DaeProgram(f"scalecopy[{config}]",
                       [Process("req", p_req), copy_proc]), mems)


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WorkloadReport:
    benchmark: str
    config: str
    scale: str
    cycles: int
    golden: int
    overhead: float          # cycles/golden - 1
    correct: bool
    mem_reads: Dict[str, int]
    trace: Optional[TraceSummary] = None

    @property
    def speedup_base(self) -> Optional[float]:
        return None


def _mem_factory_for(kind: str, latency: int, max_outstanding: Optional[int],
                     moms_ports: Sequence[str]):
    """``max_outstanding=None`` -> the paper's defaults: the abstract
    fixed-latency Verilator model is unbounded, the MOMS AXI interface
    allows 64 outstanding reads (§6)."""

    def make(port: str, data: Any) -> MemoryModel:
        if kind == "moms" and port in moms_ports:
            return MomsMemory(data, max_outstanding=max_outstanding or 64)
        return FixedLatencyMemory(
            data, latency=latency,
            max_outstanding=max_outstanding or 1_000_000_000)
    return make


# ports holding the irregularly accessed data (paper: MOMS only for these)
MOMS_PORTS = {
    "binsearch": ("table",),
    "binsearch_for": ("table",),
    "hashtable": ("table",),
    "spmv": ("vec",),
    "multispmv": ("vec",),
    "mergesort": ("table", "result"),
    "mergesort_opt": ("table", "result"),
}


def run_workload(
    benchmark: str,
    config: str,
    scale: str = "paper",
    mem: str = "fixed",
    latency: int = 100,
    rif: int = 128,
    max_outstanding: Optional[int] = None,
    seed: int = 0,
    cap_slack: Optional[int] = None,
    engine: str = "event",
    trace: bool = False,
    trace_bin_cycles: int = 64,
    tracer: Optional[Tracer] = None,
) -> WorkloadReport:
    """Build and simulate one (benchmark, config) cell of Table 1/3.

    ``cap_slack`` overrides the channel-capacity sizing: when given,
    load/stream channels get ``capacity = rif + cap_slack`` instead of
    the legacy per-benchmark defaults.  This is the knob ``repro.tune``
    sweeps; too-small values reproduce the §5.3 deadlocks.

    ``engine`` selects the scheduler implementation (``"event"`` or the
    legacy ``"polling"`` oracle — bit-exact, see
    :mod:`repro.core.simulator`).  With ``trace=True`` the report
    carries a :class:`repro.core.trace.TraceSummary`; multi-phase
    benchmarks (mergesort, multispmv) accumulate across phases with
    per-phase clocks restarting at zero.

    An explicit ``tracer`` instance (e.g. a
    :class:`repro.core.waveform.WaveformTracer` for full per-cycle
    timelines and VCD export) overrides the ``trace``/``trace_bin_cycles``
    construction and is driven through the same hooks.
    """
    if config not in CONFIGS:
        raise ValueError(f"unknown config {config!r}")
    cap = None if cap_slack is None else max(1, rif + cap_slack)
    mem_factory = _mem_factory_for(mem, latency, max_outstanding,
                                   MOMS_PORTS.get(benchmark, ()))
    if tracer is None:
        tracer = Tracer(trace_bin_cycles) if trace else None

    def _sim(prog, mems):
        return simulate(prog, mems, tracer=tracer, engine=engine)

    def _summary():
        return tracer.summary() if tracer is not None else None

    if benchmark in ("binsearch", "binsearch_for"):
        data = make_binsearch_data(scale, seed)
        early = benchmark == "binsearch"
        progs, mems, golden, check = _binsearch_phases(
            data, config, early, latency, rif, mem_factory, cap=cap)
        total = 0
        result = None
        for prog in progs:
            result = _sim(prog, mems)
            total += result.cycles
        reads = {p: m.reads for p, m in mems.items()}
        return WorkloadReport(benchmark, config, scale, total, golden,
                              total / golden - 1, check(result), reads,
                              _summary())

    if benchmark == "hashtable":
        data = make_hashtable_data(scale, seed)
        progs, mems, golden, check = _hashtable_phases(
            data, config, latency, rif, mem_factory, cap=cap)
        total = 0
        result = None
        for prog in progs:
            result = _sim(prog, mems)
            total += result.cycles
        reads = {p: m.reads for p, m in mems.items()}
        return WorkloadReport(benchmark, config, scale, total, golden,
                              total / golden - 1, check(result), reads,
                              _summary())

    if benchmark == "spmv":
        data = make_spmv_data(scale if scale != "paper" else "paper", seed)
        cells, golden, check = _spmv_phases(data, config, latency, rif,
                                            mem_factory, cap=cap)
        total = 0
        reads: Dict[str, int] = {}
        for prog, mems in cells:
            r = _sim(prog, mems)
            total += r.cycles
            for p, m in mems.items():
                reads[p] = reads.get(p, 0) + m.reads
        return WorkloadReport(benchmark, config, scale, total, golden,
                              total / golden - 1, check(None), reads,
                              _summary())

    if benchmark in ("mergesort", "mergesort_opt"):
        data = make_mergesort_data(scale, seed)
        opt = benchmark == "mergesort_opt"
        build, golden, check = _mergesort_phases(data, config, opt, latency,
                                                 rif, mem_factory, cap=cap)
        if golden is None:  # rhls_stream structural deadlock
            build()  # raises DeadlockError
        total = 0
        reads = {}
        for prog, mems in build():
            r = _sim(prog, mems)
            total += r.cycles
            for p, m in mems.items():
                reads[p] = reads.get(p, 0) + m.reads
        return WorkloadReport(benchmark, config, scale, total, golden,
                              total / golden - 1, check(None), reads,
                              _summary())

    if benchmark == "multispmv":
        data = make_multispmv_data("paper" if scale in ("paper", "fig4") else scale,
                                   seed)
        build, golden, check = _multispmv_phases(data, config, latency, rif,
                                                 mem_factory, cap=cap)
        total = 0
        reads = {}
        for prog, mems in build():
            r = _sim(prog, mems)
            total += r.cycles
            for p, m in mems.items():
                reads[p] = reads.get(p, 0) + m.reads
        return WorkloadReport(benchmark, config, scale, total, golden,
                              total / golden - 1, check(None), reads,
                              _summary())

    raise ValueError(f"unknown benchmark {benchmark!r}")


# ---------------------------------------------------------------------------
# Multi-tenant variants: N program instances, one shared memory system
# ---------------------------------------------------------------------------

# ports the tenants share (contended) per benchmark; every other port
# referenced by a program is private to its instance
MULTI_SHARED_PORTS = {
    "binsearch": ("table",),
    "binsearch_for": ("table",),
    "hashtable": ("table",),
    "spmv": ("rows", "val", "cols", "vec"),
    "mergesort": ("table", "result"),
    "mergesort_opt": ("table", "result"),
}
MULTI_BENCHMARKS = tuple(MULTI_SHARED_PORTS)


@dataclasses.dataclass
class MultiWorkloadReport:
    """One multi-tenant simulation: N instances of a benchmark sharing
    the irregular-data memory port(s)."""

    benchmark: str
    config: str
    scale: str
    n_instances: int
    cycles: int                      # makespan across instances
    per_instance_cycles: List[int]
    golden: int                      # golden loads summed over instances
    correct: bool
    mem_reads: Dict[str, int]
    trace: Optional[TraceSummary] = None

    @property
    def throughput_per_instance(self) -> float:
        """Golden work items retired per cycle per tenant — the quantity
        whose degradation with N the ``scale`` benchmark reports."""
        return (self.golden / self.n_instances) / max(1, self.cycles)


def _tenant_binsearch_data(data0: Dict[str, Any], i: int,
                           seed: int) -> Dict[str, Any]:
    """Tenant i queries the SAME sorted table with its own key set."""
    if i == 0:
        return data0
    r = _rng(seed + 7919 * i)
    keys = data0["arr"][r.integers(0, data0["n"], size=len(data0["keys"]))]
    return {**data0, "keys": keys}


def _tenant_hashtable_data(data0: Dict[str, Any], i: int,
                           seed: int) -> Dict[str, Any]:
    """Tenant i walks the SAME chains in its own (permuted) order."""
    if i == 0:
        return data0
    r = _rng(seed + 7919 * i)
    perm = r.permutation(data0["chains"])
    return {**data0,
            "keys": [data0["keys"][p] for p in perm],
            "heads": [data0["heads"][p] for p in perm]}


def _merge_reads(shared: Dict[str, MemoryModel],
                 privates: List[Dict[str, MemoryModel]]) -> Dict[str, int]:
    reads = {p: m.reads for p, m in shared.items()}
    for mems in privates:
        for p, m in mems.items():
            reads[p] = reads.get(p, 0) + m.reads
    return reads


def _multi_run_single_phase(instances, shared, checks, tracer, engine):
    res = SharedMemoryEngine(instances, shared, tracer=tracer,
                             engine=engine).run()
    correct = all(chk(r) for chk, r in zip(checks, res.instances))
    return res, correct


def run_workload_multi(
    benchmark: str,
    config: str,
    n_instances: int,
    *,
    scale: str = "small",
    mem: str = "fixed",
    latency: int = 100,
    rif: int = 128,
    max_outstanding: Optional[int] = None,
    seed: int = 0,
    cap_slack: Optional[int] = None,
    trace: bool = False,
    trace_bin_cycles: int = 64,
    engine: str = "event",
    tracer: Optional[Tracer] = None,
) -> MultiWorkloadReport:
    """Simulate ``n_instances`` concurrent tenants of one benchmark
    sharing the irregular-data port(s) of a single memory system.

    Tenants are independent program instances (own channels, own ``out``
    port) contending for the shared ports' issue slots and — under
    ``max_outstanding`` — one outstanding-request budget.  Read-only
    benchmarks (binsearch/hashtable/spmv) share the actual data arrays;
    the mergesorts give each tenant a disjoint range of one shared
    array.  ``n_instances == 1`` reproduces :func:`run_workload`'s cycle
    counts exactly.

    With ``trace=True`` the report carries a
    :class:`repro.core.trace.TraceSummary` of per-channel occupancy,
    request-latency histograms, and shared-port utilization.  For
    multi-pass benchmarks (mergesort) the tracer accumulates across
    passes; pass-local times restart at zero, so port timelines overlay
    the passes rather than concatenating them.  ``engine`` selects the
    scheduler implementation (``"event"`` default, ``"polling"`` the
    bit-exact legacy oracle).
    """
    if config not in CONFIGS:
        raise ValueError(f"unknown config {config!r}")
    if benchmark not in MULTI_SHARED_PORTS:
        raise ValueError(
            f"benchmark {benchmark!r} has no multi-tenant variant "
            f"(supported: {MULTI_BENCHMARKS})")
    if n_instances < 1:
        raise ValueError("n_instances must be >= 1")
    cap = None if cap_slack is None else max(1, rif + cap_slack)
    mem_factory = _mem_factory_for(mem, latency, max_outstanding,
                                   MOMS_PORTS.get(benchmark, ()))
    if tracer is None:
        tracer = Tracer(trace_bin_cycles) if trace else None
    shared_ports = MULTI_SHARED_PORTS[benchmark]

    if benchmark in ("binsearch", "binsearch_for", "hashtable"):
        early = benchmark == "binsearch"
        if benchmark == "hashtable":
            data0 = make_hashtable_data(scale, seed)
            tenant = _tenant_hashtable_data
        else:
            data0 = make_binsearch_data(scale, seed)
            tenant = _tenant_binsearch_data
        shared: Optional[Dict[str, MemoryModel]] = None
        instances, checks, goldens, privates = [], [], [], []
        for i in range(n_instances):
            data = tenant(data0, i, seed)
            if benchmark == "hashtable":
                progs, mems, golden, check = _hashtable_phases(
                    data, config, latency, rif, mem_factory, cap=cap,
                    shared_mems=shared)
            else:
                progs, mems, golden, check = _binsearch_phases(
                    data, config, early, latency, rif, mem_factory, cap=cap,
                    shared_mems=shared)
            if shared is None:
                shared = {p: mems[p] for p in shared_ports}
            private = {p: m for p, m in mems.items() if p not in shared_ports}
            instances.append(EngineInstance(f"t{i}", progs[0], private))
            privates.append(private)
            checks.append(check)
            goldens.append(golden)
        res, correct = _multi_run_single_phase(instances, shared, checks,
                                               tracer, engine)
        return MultiWorkloadReport(
            benchmark, config, scale, n_instances, res.cycles,
            [r.cycles for r in res.instances], sum(goldens), correct,
            _merge_reads(shared, privates), res.trace)

    if benchmark == "spmv":
        data = make_spmv_data(scale, seed)
        shared = None
        instances, checks, privates = [], [], []
        for i in range(n_instances):
            cells, golden, check = _spmv_phases(data, config, latency, rif,
                                                mem_factory, cap=cap,
                                                shared_mems=shared)
            prog, mems = cells[0]
            if shared is None:
                shared = {p: mems[p] for p in shared_ports}
            private = {p: m for p, m in mems.items() if p not in shared_ports}
            instances.append(EngineInstance(f"t{i}", prog, private))
            privates.append(private)
            checks.append(lambda _r, chk=check: chk(None))
        res, correct = _multi_run_single_phase(instances, shared, checks,
                                               tracer, engine)
        return MultiWorkloadReport(
            benchmark, config, scale, n_instances, res.cycles,
            [r.cycles for r in res.instances],
            n_instances * data["nnz"], correct,
            _merge_reads(shared, privates), res.trace)

    # mergesort / mergesort_opt: each tenant sorts its own n-element range
    # of one shared table/result array pair; passes run phase-aligned
    # (every tenant's pass-k programs share one engine run)
    opt = benchmark == "mergesort_opt"
    if config == "rhls_stream":
        _mergesort_stream_deadlock()
    datas = [make_mergesort_data(scale, seed + i) for i in range(n_instances)]
    n = datas[0]["n"]
    big_table = [int(x) for d in datas for x in d["table"]]
    big_result = [0] * (n * n_instances)

    phases, final_holder, passes = _mergesort_plan(big_table, big_result, n,
                                                   opt)
    expected = [np.sort(d["table"]) for d in datas]

    total = 0
    per_inst = [0] * n_instances
    reads: Dict[str, int] = {}
    for kind, s, d, w, sp, dp in phases:
        shared = {sp: mem_factory(sp, s), dp: mem_factory(dp, d)}
        instances = []
        for i in range(n_instances):
            if kind == "merge":
                prog, _ = _merge_pass_program(s, d, n, w, config, latency,
                                              rif, mem_factory, sp, dp,
                                              cap=cap, base=i * n,
                                              mems=shared)
            else:
                prog, _ = _copy_pass_program(s, d, n, config, latency, rif,
                                             mem_factory, sp, dp, cap=cap,
                                             base=i * n, mems=shared)
            instances.append(EngineInstance(f"t{i}", prog))
        res = SharedMemoryEngine(instances, shared, tracer=tracer,
                                 engine=engine).run()
        total += res.cycles
        for i, r in enumerate(res.instances):
            per_inst[i] += r.cycles
        for p, m in shared.items():
            reads[p] = reads.get(p, 0) + m.reads

    correct = all(
        np.array_equal(np.array(final_holder[i * n:(i + 1) * n],
                                dtype=np.int64), expected[i])
        for i in range(n_instances))
    return MultiWorkloadReport(
        benchmark, config, scale, n_instances, total, per_inst,
        n_instances * n * passes, correct, reads,
        tracer.summary() if tracer is not None else None)
