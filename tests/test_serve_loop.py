"""Decoupled serving pipeline: completion, parity with the legacy loop,
chunked-prefill teacher-forced equivalence, and the admission edge cases
(empty prompt, max_new=0, EOS during prefill, slot reuse)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.trace import TraceSummary, Tracer
from repro.models.registry import build_model
from repro.runtime.serve_loop import LegacyServeLoop, Request, ServeLoop

# two cheap-to-compile archs (dense attention + pure-recurrent) carry
# the fast tier; the full arch matrix rides the slow tier
FAST_ARCH = "qwen3-4b"
FAST_ARCHS = ("qwen3-4b", "rwkv6-1.6b")
FAMILY_ARCHS = FAST_ARCHS + ("granite-moe-3b-a800m", "hymba-1.5b")
ALL_ARCHS = FAMILY_ARCHS + ("minicpm3-4b", "granite-34b", "qwen2-72b",
                            "deepseek-v2-lite-16b", "chameleon-34b")

_MODELS = {}


def _model(arch):
    if arch not in _MODELS:
        cfg = get_config(arch, smoke=True)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        _MODELS[arch] = (cfg, m, params)
    return _MODELS[arch]


def _prompt(n, vocab, seed=0):
    return np.random.default_rng(seed).integers(0, vocab, size=n)


# -- basic serving ------------------------------------------------------------


def test_serve_loop_completes_all_requests():
    cfg, m, params = _model(FAST_ARCH)
    loop = ServeLoop(cfg, m, params, batch_slots=2, s_max=64)
    reqs = [Request(rid=i,
                    prompt=np.array([1 + i, 2 + i, 3 + i], np.int64),
                    max_new=4)
            for i in range(5)]  # 5 requests > 2 slots -> forces refill
    results = loop.run(reqs)
    assert set(results) == {0, 1, 2, 3, 4}
    for rid, toks in results.items():
        assert 1 <= len(toks) <= 4
        assert all(0 <= t < cfg.vocab for t in toks)
    assert loop.stats.admitted == 5
    assert set(loop.stats.ttft) == {0, 1, 2, 3, 4}


def test_serve_greedy_matches_apply():
    """Slot-pooled decode must equal unbatched greedy decoding."""
    cfg, m, params = _model(FAST_ARCH)
    prompt = np.array([5, 9, 2], np.int64)

    # reference: argmax continuation via full re-apply
    toks = list(prompt)
    for _ in range(3):
        logits = m.apply(params, jnp.asarray([toks], jnp.int32))
        toks.append(int(jnp.argmax(logits[0, -1])))
    ref = toks[len(prompt):]

    loop = ServeLoop(cfg, m, params, batch_slots=1, s_max=32)
    out = loop.run([Request(rid=0, prompt=prompt, max_new=3)])[0]
    assert out == ref


def test_serve_matches_legacy_on_parity_cell():
    """One slot, one request — the only regime where the legacy loop is
    correct — must produce bit-identical greedy outputs."""
    cfg, m, params = _model(FAST_ARCH)
    for plen, chunk in [(1, 4), (5, 4), (9, 4), (6, 32)]:
        prompt = _prompt(plen, cfg.vocab, seed=plen)
        new = ServeLoop(cfg, m, params, batch_slots=1, s_max=64, chunk=chunk)
        out_new = new.run([Request(rid=0, prompt=prompt, max_new=6)])[0]
        leg = LegacyServeLoop(cfg, m, params, batch_slots=1, s_max=64)
        out_leg = leg.run([Request(rid=0, prompt=prompt, max_new=6)])[0]
        assert out_new == out_leg, (plen, chunk)


def test_concurrent_admission_does_not_corrupt_decode():
    """The legacy loop's defining bug: admitting slot B's prompt stepped
    slot A's decode cache once per prompt token.  In the decoupled loop
    a slot's output must be independent of traffic on other slots."""
    cfg, m, params = _model(FAST_ARCH)
    long_a = _prompt(9, cfg.vocab, seed=1)
    long_b = _prompt(24, cfg.vocab, seed=2)

    solo = ServeLoop(cfg, m, params, batch_slots=2, s_max=64, chunk=4)
    ref = solo.run([Request(rid=0, prompt=long_a, max_new=8)])[0]

    both = ServeLoop(cfg, m, params, batch_slots=2, s_max=64, chunk=4)
    results = both.run([Request(rid=0, prompt=long_a, max_new=8),
                        Request(rid=1, prompt=long_b, max_new=8)])
    assert results[0] == ref


def test_slot_reuse_after_finish():
    """A recycled slot must serve a fresh request bit-identically to a
    fresh loop (cache length AND recurrent state reset on admission)."""
    for arch in (FAST_ARCH, "rwkv6-1.6b"):
        cfg, m, params = _model(arch)
        p1 = _prompt(5, cfg.vocab, seed=3)
        p2 = _prompt(7, cfg.vocab, seed=4)

        fresh = ServeLoop(cfg, m, params, batch_slots=1, s_max=32)
        ref = fresh.run([Request(rid=1, prompt=p2, max_new=4)])[1]

        reused = ServeLoop(cfg, m, params, batch_slots=1, s_max=32)
        results = reused.run([Request(rid=0, prompt=p1, max_new=4),
                              Request(rid=1, prompt=p2, max_new=4)])
        assert results[1] == ref, arch


# -- admission edge cases -----------------------------------------------------


def test_empty_prompt_regression():
    """Zero-length prompts crashed LegacyServeLoop._admit with
    UnboundLocalError; both loops now generate from an implicit BOS."""
    cfg, m, params = _model(FAST_ARCH)
    empty = np.zeros((0,), np.int64)
    out_leg = LegacyServeLoop(cfg, m, params, batch_slots=1, s_max=32).run(
        [Request(rid=0, prompt=empty, max_new=4)])[0]
    out_new = ServeLoop(cfg, m, params, batch_slots=1, s_max=32).run(
        [Request(rid=0, prompt=empty, max_new=4)])[0]
    assert len(out_leg) == 4
    assert out_new == out_leg
    # identical to an explicit single-BOS prompt
    out_bos = ServeLoop(cfg, m, params, batch_slots=1, s_max=32).run(
        [Request(rid=0, prompt=np.array([0], np.int64), max_new=4)])[0]
    assert out_new == out_bos


def test_max_new_zero_completes_without_tokens():
    cfg, m, params = _model(FAST_ARCH)
    reqs = lambda: [Request(rid=0, prompt=np.array([3, 1], np.int64),
                            max_new=0),
                    Request(rid=1, prompt=np.array([2, 5], np.int64),
                            max_new=3)]
    for loop in (ServeLoop(cfg, m, params, batch_slots=1, s_max=32),
                 LegacyServeLoop(cfg, m, params, batch_slots=1, s_max=32)):
        results = loop.run(reqs())
        assert results[0] == []
        assert len(results[1]) <= 3 and results[1]


def test_eos_during_prefill_frees_slot():
    """If the prompt's own prediction is EOS the request finishes inside
    the Access engine; the slot must recycle cleanly."""
    cfg, m, params = _model(FAST_ARCH)
    prompt = _prompt(4, cfg.vocab, seed=5)
    probe = ServeLoop(cfg, m, params, batch_slots=1, s_max=32)
    first = probe.run([Request(rid=0, prompt=prompt, max_new=4)])[0][0]

    loop = ServeLoop(cfg, m, params, batch_slots=1, s_max=32, eos_id=first)
    results = loop.run([Request(rid=0, prompt=prompt, max_new=4),
                        Request(rid=1, prompt=_prompt(3, cfg.vocab, seed=6),
                                max_new=3)])
    assert results[0] == [first]
    assert len(results[1]) >= 1
    assert loop.stats.decode_steps > 0 or len(results[1]) == 1


def test_request_overflowing_s_max_rejected():
    cfg, m, params = _model(FAST_ARCH)
    loop = ServeLoop(cfg, m, params, batch_slots=1, s_max=16)
    with pytest.raises(ValueError, match="s_max"):
        loop.run([Request(rid=0, prompt=_prompt(12, cfg.vocab), max_new=8)])


# -- chunked prefill: teacher-forced parity -----------------------------------


def _prefill_vs_stepwise(arch, chunk=3, plen=7):
    """Chunked bundle.prefill must match a per-token decode_step warmup:
    boundary logits, final cache, and the logits of a decode step taken
    from each cache — BIT-IDENTICAL for every family except the hymba
    hybrid, whose SSM discretization chain XLA fuses shape-dependently
    (straight-line S=1 vs scanned S=C differ from the eager oracle by
    ~1 ulp each, in different directions); there the greedy argmax must
    still match and logits must agree to ~1 ulp."""
    cfg, m, params = _model(arch)
    exact = cfg.family != "hybrid"

    def check(a, b):
        a, b = np.asarray(a), np.asarray(b)
        if exact:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
            np.testing.assert_array_equal(np.argmax(a, -1),
                                          np.argmax(b, -1))
    b, smax = 2, 32
    prompts = np.random.default_rng(8).integers(0, cfg.vocab, (b, plen))

    cache_a = m.cache_init(b, smax)
    for t in range(plen):
        la, cache_a = m.decode_step(params, cache_a,
                                    jnp.asarray(prompts[:, t], jnp.int32),
                                    jnp.full((b,), t, jnp.int32))
    cache_b = m.cache_init(b, smax)
    pos, ptr = np.zeros(b, np.int32), 0
    while ptr < plen:
        n = min(chunk, plen - ptr)
        tok = np.zeros((b, chunk), np.int32)
        tok[:, :n] = prompts[:, ptr:ptr + n]
        lb, cache_b = m.prefill(params, cache_b, jnp.asarray(tok),
                                jnp.asarray(pos),
                                jnp.full((b,), n, jnp.int32))
        pos += n
        ptr += n

    check(la, lb)
    nxt = jnp.asarray(np.argmax(np.asarray(lb), -1), jnp.int32)
    full = jnp.full((b,), plen, jnp.int32)
    da, _ = m.decode_step(params, cache_a, nxt, full)
    db, _ = m.prefill(params, cache_b, nxt[:, None], full,
                      jnp.ones((b,), jnp.int32))
    check(da, db)


@pytest.mark.parametrize("arch", FAST_ARCHS)
def test_prefill_parity_teacher_forced(arch):
    _prefill_vs_stepwise(arch)


@pytest.mark.slow
@pytest.mark.parametrize("arch", sorted(set(ALL_ARCHS) - set(FAST_ARCHS)))
def test_prefill_parity_teacher_forced_all_archs(arch):
    _prefill_vs_stepwise(arch)


@pytest.mark.slow
def test_prefill_parity_encdec():
    cfg, m, params = _model("seamless-m4t-large-v2")
    b, smax, plen, chunk = 2, 32, 6, 4
    rng = np.random.default_rng(9)
    frames = jnp.asarray(rng.standard_normal((b, 8, cfg.d_model)),
                         jnp.float32)
    enc_out = m.encode(params, frames)
    prompts = rng.integers(0, cfg.vocab, (b, plen))

    cache_a = m.cache_init(b, smax)
    for t in range(plen):
        la, cache_a = m.decode_step(params, enc_out, cache_a,
                                    jnp.asarray(prompts[:, t], jnp.int32),
                                    jnp.full((b,), t, jnp.int32))
    cache_b = m.cache_init(b, smax)
    pos, ptr = np.zeros(b, np.int32), 0
    while ptr < plen:
        n = min(chunk, plen - ptr)
        tok = np.zeros((b, chunk), np.int32)
        tok[:, :n] = prompts[:, ptr:ptr + n]
        lb, cache_b = m.prefill(params, enc_out, cache_b, jnp.asarray(tok),
                                jnp.asarray(pos),
                                jnp.full((b,), n, jnp.int32))
        pos += n
        ptr += n
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@pytest.mark.slow
def test_serve_encdec_end_to_end():
    """Encoder-decoder serving: requests carry frames, encoded once at
    admission; greedy output must match a manual decode_step rollout."""
    cfg, m, params = _model("seamless-m4t-large-v2")
    rng = np.random.default_rng(11)
    frames = rng.standard_normal((2, 8, cfg.d_model)).astype(np.float32)
    prompts = [_prompt(4, cfg.vocab, seed=12), _prompt(6, cfg.vocab, seed=13)]

    # reference rollout per request (batch 1, per-token prefill + decode)
    refs = []
    for fr, prompt in zip(frames, prompts):
        enc = m.encode(params, jnp.asarray(fr)[None])
        cache = m.cache_init(1, 32)
        for t, tok in enumerate(prompt):
            logits, cache = m.decode_step(
                params, enc, cache, jnp.asarray([tok], jnp.int32),
                jnp.asarray([t], jnp.int32))
        out = [int(np.argmax(np.asarray(logits)[0]))]
        pos = len(prompt)
        for _ in range(2):
            logits, cache = m.decode_step(
                params, enc, cache, jnp.asarray([out[-1]], jnp.int32),
                jnp.asarray([pos], jnp.int32))
            out.append(int(np.argmax(np.asarray(logits)[0])))
            pos += 1
        refs.append(out)

    loop = ServeLoop(cfg, m, params, batch_slots=2, s_max=32, chunk=4)
    results = loop.run([Request(rid=i, prompt=p, max_new=3, frames=fr)
                        for i, (p, fr) in enumerate(zip(prompts, frames))])
    assert results[0] == refs[0]
    assert results[1] == refs[1]


@pytest.mark.parametrize("arch", FAST_ARCHS)
def test_masked_step_leaves_inactive_rows_untouched(arch):
    """n_valid=0 rows must keep cache AND recurrent state bit-identical
    (the Execute engine decodes through mid-prefill slots every step)."""
    _assert_masked_rows_untouched(arch)


@pytest.mark.slow
def test_masked_step_leaves_inactive_rows_untouched_hybrid():
    _assert_masked_rows_untouched("hymba-1.5b")


def _assert_masked_rows_untouched(arch):
    cfg, m, params = _model(arch)
    b, smax = 2, 16
    cache = m.cache_init(b, smax)
    tok = jnp.asarray([[7], [9]], jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    _, cache = m.prefill(params, cache, tok, pos,
                         jnp.asarray([1, 1], jnp.int32))
    before = jax.tree.leaves(cache)
    _, cache2 = m.prefill(params, cache, tok,
                          jnp.asarray([1, 1], jnp.int32),
                          jnp.asarray([1, 0], jnp.int32))
    after = jax.tree.leaves(cache2)
    for x, y in zip(before, after):
        np.testing.assert_array_equal(np.asarray(x)[:, 1],
                                      np.asarray(y)[:, 1], arch)


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_serve_matches_legacy_all_families(arch):
    cfg, m, params = _model(arch)
    prompt = _prompt(6, cfg.vocab, seed=10)
    out_new = ServeLoop(cfg, m, params, batch_slots=1, s_max=32,
                        chunk=4).run(
        [Request(rid=0, prompt=prompt, max_new=5)])[0]
    out_leg = LegacyServeLoop(cfg, m, params, batch_slots=1, s_max=32).run(
        [Request(rid=0, prompt=prompt, max_new=5)])[0]
    assert out_new == out_leg


# -- channels and traces ------------------------------------------------------


def test_serve_channel_traces():
    cfg, m, params = _model(FAST_ARCH)
    tracer = Tracer()
    loop = ServeLoop(cfg, m, params, batch_slots=2, s_max=64, chunk=4,
                     tracer=tracer)
    loop.run([Request(rid=i, prompt=_prompt(5 + i, cfg.vocab, seed=i),
                      max_new=3) for i in range(4)])
    summary = tracer.summary()
    occ = summary.channel_occupancy()
    for name in ("serve/admit", "serve/free_slots", "serve/prefill_done"):
        assert name in occ, occ
        assert summary.channels[name].events > 0
    # admit saw all four requests queued behind two slots
    assert summary.channels["serve/admit"].occ_max >= 2
    # traces survive the JSON round trip like any DAE program trace
    rt = TraceSummary.from_json(summary.to_json())
    assert rt.channel_occupancy() == occ


def test_admit_capacity_backpressure():
    cfg, m, params = _model(FAST_ARCH)
    tracer = Tracer()
    loop = ServeLoop(cfg, m, params, batch_slots=1, s_max=32,
                     admit_capacity=2, tracer=tracer)
    results = loop.run([Request(rid=i, prompt=_prompt(3, cfg.vocab, seed=i),
                                max_new=2) for i in range(5)])
    assert set(results) == set(range(5))
    assert tracer.summary().channels["serve/admit"].occ_max <= 2


def test_decode_never_stalls_more_than_one_chunk():
    """Scheduler invariant: with decode-active slots present, prefill
    and decode steps alternate — so decode_steps must be within one of
    the rounds that had any decode-active slot.  Weak proxy: a long
    prompt admitted mid-decode adds ceil(P/chunk) prefill steps but the
    decode stream keeps stepping (total decode steps unchanged)."""
    cfg, m, params = _model(FAST_ARCH)
    chunk = 4

    solo = ServeLoop(cfg, m, params, batch_slots=2, s_max=96, chunk=chunk)
    solo.run([Request(rid=0, prompt=_prompt(4, cfg.vocab, seed=1),
                      max_new=12)])
    solo_decode_steps = solo.stats.decode_steps

    busy = ServeLoop(cfg, m, params, batch_slots=2, s_max=96, chunk=chunk)
    long_p = 32
    busy.run([Request(rid=0, prompt=_prompt(4, cfg.vocab, seed=1),
                      max_new=12),
              Request(rid=1, prompt=_prompt(long_p, cfg.vocab, seed=2),
                      max_new=4)])
    # decode performed the same number of steps for request 0 even while
    # request 1's long prompt was prefilling...
    assert busy.stats.decode_steps >= solo_decode_steps
    # ...and prefill advanced in chunks, not per token
    assert busy.stats.prefill_steps <= (4 + long_p) // chunk + 2
