"""Pass 3 — check: validate capacity/deadlock and expressibility.

Two layers:

* the program-level §5.3/§5.4 validation — the same
  :meth:`DaeProgram.validate_channels` dry run the simulator relies on
  (conflicting channel declarations, conservation, stalls);
* compiler-specific expressibility: can the classified IR actually be
  lowered onto the ring scaffolds?  Rejections raise
  :class:`CompileError` with *actionable* diagnostics — each one names
  the offending channel/store and says what would make the program
  compilable (usually: supply a :class:`~repro.compile.ir.ChaseSpec`).

The check also picks the codegen shape:

  ``gather``  every stream STATIC, every store a copy/const;
  ``deref``   as above plus one-hop INDIRECT streams (two-phase rings);
  ``chase``   a :class:`ChaseSpec` was supplied: exactly one load
              channel, and the spec must *reproduce the simulator's
              stores* in a numpy pre-run before codegen trusts it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.dae import ConservationError, DaeProgram
from repro.compile.ir import ChaseSpec, DaeIR, StreamKind

__all__ = ["CompileError", "CheckResult", "check"]


class CompileError(ValueError):
    """A program the compiler cannot (or must not) lower.

    ``pass_name`` says which pass rejected it; ``diagnostics`` is a list
    of per-finding messages, each naming the construct at fault.
    """

    def __init__(self, pass_name: str, diagnostics: List[str]):
        self.pass_name = pass_name
        self.diagnostics = list(diagnostics)
        lines = "\n".join(f"  - {d}" for d in self.diagnostics)
        super().__init__(f"[{pass_name}] program not compilable:\n{lines}")


@dataclasses.dataclass
class CheckResult:
    shape: str                                    # 'gather'|'deref'|'chase'
    # out port -> (length, width, dtype)
    out_specs: Dict[str, Tuple[int, int, Any]]
    notes: List[str]


def _norm_value(v: Any) -> Optional[np.ndarray]:
    """A store value as a 1-D numeric row, or None if non-numeric."""
    if v is None or isinstance(v, (bool, str)):
        return None
    if isinstance(v, np.ndarray):
        row = np.atleast_1d(v)
    elif isinstance(v, (int, np.integer)):
        row = np.array([int(v)])
    elif isinstance(v, (float, np.floating)):
        row = np.array([float(v)], dtype=np.float64)
    else:
        return None
    if not np.issubdtype(row.dtype, np.number):
        return None
    return row


def _out_specs(ir: DaeIR, diags: List[str]) -> Dict[str, Tuple[int, int, Any]]:
    specs: Dict[str, Tuple[int, int, Any]] = {}
    per_port: Dict[str, List] = {}
    for st in ir.stores:
        per_port.setdefault(st.port, []).append(st)
    read_ports = {c.port for c in ir.channels.values()}
    for port, sts in per_port.items():
        if port in read_ports:
            diags.append(
                f"store port {port!r} is also a load port: read-after-"
                f"write through memory is not expressible in one kernel "
                f"pass — split the program or store to a separate port")
            continue
        width = None
        dtype = np.int32
        for st in sts:
            row = _norm_value(st.value)
            if row is None:
                diags.append(
                    f"store to {port!r}[{st.addr}] carries a non-numeric "
                    f"value {st.value!r}; only int/float scalars or 1-D "
                    f"numeric rows can be staged")
                width = None
                break
            if np.issubdtype(row.dtype, np.floating):
                dtype = np.float32
            if width is None:
                width = len(row)
            elif width != len(row):
                diags.append(
                    f"store port {port!r} mixes value widths ({width} vs "
                    f"{len(row)}): one dense output array per port needs "
                    f"a single row shape")
                width = None
                break
        if width is None:
            continue
        raw = ir.raw_memories.get(port)
        length = len(raw) if raw is not None else \
            max(st.addr for st in sts) + 1
        bad = [st.addr for st in sts if not (0 <= st.addr < length)]
        if bad:
            diags.append(f"store port {port!r}: addresses {bad[:4]} fall "
                         f"outside the declared extent {length}")
            continue
        specs[port] = (length, width, dtype)
    return specs


def _check_ring_shapes(ir: DaeIR, diags: List[str]) -> str:
    """Expressibility of the spec-free shapes; returns 'gather'/'deref'."""
    has_indirect = False
    static_names = {c.name for c in ir.channels_of_kind(StreamKind.STATIC)}
    for c in ir.channels.values():
        port = ir.ports.get(c.port)
        if port is None:
            diags.append(
                f"channel {c.name!r} loads from port {c.port!r} which "
                f"could not be staged as a dense array (see elaborate "
                f"notes); provide numeric, rectangular port data")
            continue
        if any(a < 0 for a in c.addrs):
            diags.append(
                f"channel {c.name!r} issues negative addresses (Python "
                f"end-relative indexing); the kernel address space is "
                f"[0, N) — rebase the address stream")
            continue
        if c.kind is StreamKind.DEPENDENT:
            diags.append(
                f"channel {c.name!r} ({c.count} requests on port "
                f"{c.port!r}) has a DEPENDENT address stream — addresses "
                f"are functions of loaded values beyond one indirection. "
                f"Supply a ChaseSpec (compile_program(..., chase=...)) "
                f"carrying the chase semantics, as the binsearch target "
                f"does")
            continue
        if c.kind is StreamKind.INDIRECT:
            has_indirect = True
            src = ir.channels.get(c.source or "")
            if src is None or src.name not in static_names:
                diags.append(
                    f"channel {c.name!r} is INDIRECT through "
                    f"{c.source!r}, which is not itself STATIC — only "
                    f"one level of indirection lowers to the two-phase "
                    f"ring; deeper chains need a ChaseSpec")
                continue
            sport = ir.ports.get(src.port)
            if sport is None or sport.width != 1 or \
                    not np.issubdtype(sport.array.dtype, np.integer):
                diags.append(
                    f"channel {c.name!r} derives addresses from port "
                    f"{src.port!r} rows, which are not scalar integers")
                continue
            nb = port.n
            bad = [a for a in c.addrs if not (0 <= a < nb)]
            if bad:
                diags.append(
                    f"channel {c.name!r}: derived addresses {bad[:4]} "
                    f"fall outside port {c.port!r} (extent {nb}); the "
                    f"ring clips addresses, which would silently change "
                    f"semantics — add an in-range sentinel row instead")
    return "deref" if has_indirect else "gather"


def _check_copy_staging(ir: DaeIR, diags: List[str]) -> None:
    """Traced response values must survive the float32/int32 staging
    cast — otherwise the kernel's copies differ from the trace."""
    for c in ir.channels.values():
        port = ir.ports.get(c.port)
        if port is None or not c.addrs:
            continue
        got = port.array[np.asarray(c.addrs)]
        want = np.stack([np.atleast_1d(np.asarray(v)) for v in c.values])
        if not np.array_equal(got.astype(np.float64),
                              want.astype(np.float64)):
            diags.append(
                f"channel {c.name!r}: port {c.port!r} data does not "
                f"survive the {port.array.dtype} staging cast "
                f"(values overflow or lose precision)")


def _check_stores_explained(ir: DaeIR, diags: List[str]) -> None:
    open_stores = [s for s in ir.stores if not s.explained]
    if open_stores:
        ex = open_stores[0]
        diags.append(
            f"{len(open_stores)} store(s) (first: {ex.port!r}[{ex.addr}] "
            f"= {ex.value!r}) are neither copies of a channel response "
            f"nor run-invariant constants — the execute loop computes on "
            f"loaded values.  Supply a ChaseSpec with the loop semantics "
            f"(out_fn), or restructure the program as a data mover")


def _verify_chase(ir: DaeIR, spec: ChaseSpec, diags: List[str],
                  budget: int = 500_000) -> None:
    """Numpy pre-run: the spec must reproduce the traced stores'
    final-state effect before codegen is allowed to trust it."""
    port = ir.ports.get(spec.port)
    if port is None:
        diags.append(f"ChaseSpec walks port {spec.port!r}, which was not "
                     f"staged")
        return
    if not np.issubdtype(port.array.dtype, np.integer):
        diags.append(f"ChaseSpec port {spec.port!r} is "
                     f"{port.array.dtype}; the chase kernel state is "
                     f"int32 — integer port data only")
        return
    if np.abs(spec.state0).max(initial=0) > np.iinfo(np.int32).max:
        diags.append("ChaseSpec state0 does not fit int32")
        return
    off_spec = [s for s in ir.stores if s.port != spec.out_port]
    if off_spec:
        diags.append(
            f"stores on ports {sorted({s.port for s in off_spec})!r} are "
            f"not covered by the ChaseSpec (out_port={spec.out_port!r})")
        return
    if spec.n_items * max(spec.max_steps, 1) > budget:
        ir.notes.append(
            f"chase-spec verification skipped: {spec.n_items} items x "
            f"{spec.max_steps} steps exceeds the {budget}-op check "
            f"budget; codegen proceeds on the author's contract")
        return

    n = port.n
    arr = port.array
    got: Dict[int, int] = {}
    for i in range(spec.n_items):
        state = tuple(int(x) for x in spec.state0[i])
        for _ in range(spec.max_steps):
            addr = int(spec.addr_fn(state))
            row = arr[min(max(addr, 0), n - 1)]
            state = tuple(int(x) for x in spec.step_fn(state, row))
        oa, ov = spec.out_fn(state)
        got[int(oa)] = int(ov)

    want: Dict[int, int] = {}
    for s in ir.stores:
        row = _norm_value(s.value)
        if row is None or len(row) != 1:
            diags.append(f"traced store {s.port!r}[{s.addr}] = "
                         f"{s.value!r} is not a scalar; the chase kernel "
                         f"emits one int32 per item")
            return
        want[s.addr] = int(row[0])
    if got != want:
        wrong = [a for a in sorted(set(got) | set(want))
                 if got.get(a) != want.get(a)][:4]
        detail = ", ".join(
            f"[{a}] spec={got.get(a)!r} sim={want.get(a)!r}" for a in wrong)
        diags.append(
            f"ChaseSpec does not reproduce the simulator's stores on "
            f"{spec.out_port!r} ({len(want)} traced): first mismatches "
            f"{detail}.  The spec's lock-step fixed_step must agree with "
            f"the program's early-exit results (see docs/compiler.md)")


def check(prog: DaeProgram, ir: DaeIR, *,
          chase: Optional[ChaseSpec] = None) -> CheckResult:
    """Validate ``prog``/``ir`` and pick the codegen shape, or raise
    :class:`CompileError` with one diagnostic per finding."""
    diags: List[str] = []
    notes: List[str] = []

    # program-level §5.3/§5.4 validation (conflicts, conservation)
    try:
        prog.validate_channels(ir.raw_memories)
    except (ValueError, ConservationError) as e:
        raise CompileError("check", [f"validate_channels rejected the "
                                     f"program: {e}"])

    if chase is not None:
        if len(ir.channels) != 1:
            diags.append(
                f"a ChaseSpec lowers exactly one load channel; the "
                f"program has {sorted(ir.channels)} — split multi-"
                f"channel chases into separate programs")
        else:
            (c,) = ir.channels.values()
            if c.port != chase.port:
                diags.append(
                    f"ChaseSpec walks port {chase.port!r} but channel "
                    f"{c.name!r} loads from {c.port!r}")
        _verify_chase(ir, chase, diags)
        if diags:
            raise CompileError("check", diags)
        length = max(len(ir.raw_memories.get(chase.out_port, []) or ()),
                     max((s.addr for s in ir.stores), default=-1) + 1)
        out_specs = {chase.out_port: (length, 1, np.int32)}
        return CheckResult("chase", out_specs, notes)

    shape = _check_ring_shapes(ir, diags)
    _check_stores_explained(ir, diags)
    _check_copy_staging(ir, diags)
    out_specs = _out_specs(ir, diags)
    if diags:
        raise CompileError("check", diags)
    if not ir.perturbed_ok:
        # classification degraded; _check_ring_shapes already rejected
        # every stream as DEPENDENT, so reaching here means no channels
        notes.append("perturbed elaboration failed; compiled with no "
                     "load channels")
    return CheckResult(shape, out_specs, notes)
