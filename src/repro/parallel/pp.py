"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Layers are split into S stages along a ``stage`` mesh axis; a microbatch
stream flows through the stages with lax.ppermute moving activations to
the next stage each tick.  The schedule runs M + S - 1 ticks (fill +
steady + drain) — the classic GPipe bubble — with per-stage compute and
neighbor-only communication, which is what makes PP attractive across
pods (ICI-light, DCN-friendly).

This module is deliberately self-contained (stage_fn is any
params×activation function) and is exercised by tests/test_pp.py on a
forced-multi-device CPU mesh, plus a dry-run demo config.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                     stage_params: Any, x_microbatches: jnp.ndarray,
                     mesh: Mesh, axis: str = "stage") -> jnp.ndarray:
    """Run x (M, mb, ...) through S pipeline stages.

    stage_params: pytree whose leaves have leading dim S (one slice per
    stage); x_microbatches: (M, mb, ...) activations entering stage 0.
    Returns (M, mb, ...) outputs of the last stage.
    """
    s = mesh.shape[axis]
    m = x_microbatches.shape[0]

    def per_stage(params, xs):
        # params: this stage's slice (leading dim 1); xs: (M, mb, ...)
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        n_ticks = m + s - 1
        # carries become stage-varying inside the loop; mark them so
        buf = jax.lax.pvary(jnp.zeros_like(xs[0]), (axis,))
        outs = jax.lax.pvary(jnp.zeros_like(xs), (axis,))

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (when valid)
            mb_idx = jnp.clip(t, 0, m - 1)
            inject = jnp.where(stage == 0,
                               jnp.where(t < m, 1, 0), 0)
            cur = jnp.where(inject, xs[mb_idx], buf)
            # active window for this stage: t in [stage, stage + m)
            active = (t >= stage) & (t < stage + m)
            y = stage_fn(params, cur)
            y = jnp.where(active, y, cur)
            # completed microbatch index at the last stage
            done_idx = jnp.clip(t - stage, 0, m - 1)
            outs = jnp.where((stage == s - 1) & active,
                             outs.at[done_idx].set(y), outs)
            # shift to next stage
            perm = [(i, (i + 1) % s) for i in range(s)]
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # all-reduce outs across stages: only the last stage wrote them
        outs = jax.lax.psum(outs, axis)
        return outs

    fn = jax.shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )
    return fn(stage_params, x_microbatches)
