# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure.

    table1_perf       Table 1  (cycles, all benchmarks x HLS configs)
    table2_resources  Table 2  (buffer/channel resource analogue)
    table3_moms       Table 3  (MOMS + DRAM memory model subset)
    fig4_golden       Fig. 4   (overhead over the golden reference)
    kernel-bench      decoupled-kernel microbenches + RIF/capacity sweeps,
                      per-op tuned-vs-default and chase decoupled-vs-XLA
                      cells; writes BENCH_kernels.json at the repo root
                      (--smoke for the CI-sized subset)
    compile           repro.compile target grid: staged pipeline + compiled
                      kernel vs the simulator oracle (parity gated); writes
                      BENCH_compile.json (--smoke for the CI-sized subset)
    tune              autotune decoupling params, persist the config cache
    scale             N=1..64 tenants on one shared memory system
                      (throughput degradation + channel-occupancy traces;
                      --smoke for the CI-sized subset)
    engine-bench      event vs polling scheduler events/sec on the
                      N-tenant hashtable cell (--smoke gates the event
                      engine at >=5x on the contended N=96 cell)
    serve-bench       decoupled Access/Execute serving pipeline vs the
                      coupled legacy loop (batch_slots x prompt mixes x
                      archetypes, tokens/s + TTFT + channel occupancy;
                      --smoke gates >=5x on the mixed slots=8 cell) plus
                      the paged-KV open-loop cells: slots=64 seeded
                      Poisson/bursty arrival traces with prefix reuse,
                      TTFT p50/p95/p99 measured from arrival
    matrix            the declarative benchmark matrix (repro.bench):
                      runs EVERY registered cell of the sim/kernels/
                      compile/serve axes and writes one schema-validated
                      BENCH_<axis>.json per axis at the repo root;
                      gate a run against the committed baseline with
                      `python -m benchmarks.diff` (--smoke for CI scale;
                      --axes=serve,kernels restricts to those axes)

Run: PYTHONPATH=src python -m benchmarks.run [table1 table3 tune scale ...]
"""

from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def _csv(line: str) -> None:
    print(line, flush=True)


def main() -> None:
    flags = {a for a in sys.argv[1:] if a.startswith("-")}
    want = {a for a in sys.argv[1:] if not a.startswith("-")}
    if flags and not want:
        # a bare flag must not select the run-everything default
        print(f"error: flags {sorted(flags)} given without a benchmark "
              f"selector (e.g. 'scale --smoke')", file=sys.stderr)
        raise SystemExit(2)

    def on(name: str) -> bool:
        return not want or any(w in name for w in want)

    print("name,us_per_call,derived")
    if on("table1"):
        from benchmarks import table1_perf
        table1_perf.run(_csv)
    if on("table2"):
        from benchmarks import table2_resources
        table2_resources.run(_csv)
    if on("table3"):
        from benchmarks import table3_moms
        table3_moms.run(_csv)
    if on("fig4"):
        from benchmarks import fig4_golden
        fig4_golden.run(_csv)
    if on("kernel-bench"):
        from benchmarks import kernel_bench
        kernel_bench.run(_csv, smoke="--smoke" in flags)
    if on("compile"):
        from benchmarks import compile_bench
        compile_bench.run(_csv, smoke="--smoke" in flags)
    if on("tune"):
        from benchmarks import tune
        tune.run(_csv)
    if on("scale"):
        from benchmarks import scale
        scale.run(_csv, smoke="--smoke" in flags)
    if on("engine-bench"):
        from benchmarks import engine_bench
        engine_bench.run(_csv, smoke="--smoke" in flags)
    if on("serve-bench"):
        from benchmarks import serve_bench
        serve_bench.run(_csv, smoke="--smoke" in flags)
    if want and on("matrix"):
        # explicit-only: the bare run-everything default already covers
        # each table once; matrix would re-run them all a second time
        from benchmarks import matrix
        axes = matrix.AXES
        for f in flags:
            # --axes=serve,kernels restricts the matrix to those axes
            # (e.g. the CI multi-device job re-runs only `serve`)
            if f.startswith("--axes="):
                axes = tuple(a for a in f[len("--axes="):].split(",") if a)
                unknown = set(axes) - set(matrix.AXES)
                if unknown:
                    print(f"error: unknown matrix axes {sorted(unknown)}; "
                          f"valid: {matrix.AXES}", file=sys.stderr)
                    raise SystemExit(2)
        matrix.run(_csv, smoke="--smoke" in flags, axes=axes)


if __name__ == "__main__":
    main()
