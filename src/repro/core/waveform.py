"""Full-timeline tracing + VCD export for the DAE engine.

:class:`repro.core.trace.Tracer` keeps O(1)-per-event *aggregates*
(occupancy means, latency histograms, binned port utilization) — cheap
enough to leave on for multi-million-cycle runs, but a regression that
shifts *when* a channel fills is invisible in them until it moves a
mean.  This module keeps the whole timeline instead:

  * **channel-occupancy waveforms** — every enqueue/dequeue records
    ``(cycle, depth)``, so the exact FIFO depth at any named cycle is
    recoverable (the per-cycle ``check`` primitive of ``tests/dsl.py``);
  * **port-issue waveforms** — every read/write issue records its issue
    cycle, exposed both as a cumulative counter and as per-cycle counts;
  * **VCD export** — the timelines serialize to a Value Change Dump
    (IEEE 1364 §18) with one integer variable per channel/port, viewable
    in GTKWave/Surfer next to an RTL trace, which is how a scheduler
    regression becomes debuggable as a waveform instead of a diff.

The tracer is a strict superset of :class:`Tracer`: the summary
aggregates stay available (and stay byte-identical to a plain tracer's,
pinned by ``tests/test_dsl.py``), so a waveform run can still be
compared against the ``tests/golden/`` fixtures.

Cost discipline: one list append per event — O(run length) memory, which
is why this is a separate opt-in class and not the default tracer.
"""

from __future__ import annotations

import dataclasses
from bisect import bisect_right
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.trace import Tracer

__all__ = ["WaveformTracer", "vcd_identifier"]


def vcd_identifier(index: int) -> str:
    """Compact VCD id code for variable ``index`` (printable ASCII
    ``!``..``~``, little-endian multi-character beyond 94 variables)."""
    chars = []
    index += 1
    while index > 0:
        index -= 1
        chars.append(chr(33 + index % 94))
        index //= 94
    return "".join(chars)


def _sanitize(name: str) -> str:
    """A VCD reference name: no whitespace; ``/`` becomes the hierarchy
    separator ``.`` so multi-tenant signals group per instance."""
    out = name.replace("/", ".")
    return "".join(c if 33 <= ord(c) <= 126 else "_" for c in out)


@dataclasses.dataclass
class _Signal:
    """One recorded timeline: strictly ordered by (cycle, sequence)."""

    times: List[int] = dataclasses.field(default_factory=list)
    values: List[int] = dataclasses.field(default_factory=list)
    _sorted: bool = True

    def record(self, t: float, value: int) -> None:
        ti = int(round(t))
        if self.times and ti < self.times[-1]:
            # scheduler passes execute procs in local-time order, but
            # times can step backwards across instances within a pass
            self._sorted = False
        self.times.append(ti)
        self.values.append(value)

    def _ensure_sorted(self) -> None:
        if not self._sorted:
            pairs = sorted(zip(self.times, range(len(self.times))))
            self.times = [t for t, _ in pairs]
            self.values = [self.values[i] for _, i in pairs]
            self._sorted = True

    def value_at(self, cycle: int, default: int = 0) -> int:
        """Last recorded value at or before ``cycle`` (``default`` when
        nothing has happened yet)."""
        self._ensure_sorted()
        i = bisect_right(self.times, cycle)
        return self.values[i - 1] if i else default

    def changes(self) -> List[Tuple[int, int]]:
        """Deduplicated ``(cycle, value)`` change list: one entry per
        cycle (the last event of that cycle wins), leading no-op changes
        kept so the waveform starts where the run did."""
        self._ensure_sorted()
        out: List[Tuple[int, int]] = []
        for t, v in zip(self.times, self.values):
            if out and out[-1][0] == t:
                out[-1] = (t, v)
            else:
                out.append((t, v))
        return out


class WaveformTracer(Tracer):
    """Streaming collector keeping full per-cycle timelines.

    Drop-in wherever a :class:`Tracer` goes (``run_workload(...,
    tracer=WaveformTracer())``, ``SharedMemoryEngine(..., tracer=...)``);
    the engine hooks are inherited, so summary aggregates remain
    available via :meth:`summary`.
    """

    def __init__(self, bin_cycles: int = 64):
        super().__init__(bin_cycles)
        self._occ: Dict[str, _Signal] = {}
        self._issues: Dict[str, _Signal] = {}   # cumulative issue count
        self._issue_count: Dict[str, int] = {}

    # -- hooks ---------------------------------------------------------------

    def on_occupancy(self, instance: str, channel: str,
                     depth: int, t: float = 0.0) -> None:
        super().on_occupancy(instance, channel, depth, t)
        key = f"{instance}/{channel}" if instance else channel
        sig = self._occ.get(key)
        if sig is None:
            sig = self._occ[key] = _Signal()
        sig.record(t, depth)

    def _port_issue(self, port: str, t: float) -> None:
        # every read (on_request) and write (on_store) funnels through
        # here in the base class, so one override captures both
        super()._port_issue(port, t)
        sig = self._issues.get(port)
        if sig is None:
            sig = self._issues[port] = _Signal()
        n = self._issue_count.get(port, 0) + 1
        self._issue_count[port] = n
        sig.record(t, n)

    # -- per-cycle queries (the DSL's check primitives) ----------------------

    def channels(self) -> Tuple[str, ...]:
        return tuple(sorted(self._occ))

    def ports(self) -> Tuple[str, ...]:
        return tuple(sorted(self._issues))

    def occupancy_at(self, channel: str, cycle: int) -> int:
        """FIFO depth of ``channel`` at ``cycle`` (0 before any event).

        Raises :class:`KeyError` for a channel the run never touched —
        a typo'd check must fail loudly, not read as permanently empty.
        """
        return self._occ[channel].value_at(cycle, 0)

    def peak_occupancy(self, channel: str) -> int:
        sig = self._occ[channel]
        return max(sig.values) if sig.values else 0

    def issues_until(self, port: str, cycle: int) -> int:
        """Read+write issues on ``port`` at or before ``cycle``."""
        sig = self._issues.get(port)
        return sig.value_at(cycle, 0) if sig is not None else 0

    def occupancy_series(self, channel: str) -> List[Tuple[int, int]]:
        return self._occ[channel].changes()

    @property
    def end_cycle(self) -> int:
        last = 0
        for sig in list(self._occ.values()) + list(self._issues.values()):
            if sig.times:
                sig._ensure_sorted()
                last = max(last, sig.times[-1])
        return last

    # -- VCD export ----------------------------------------------------------

    def to_vcd(self, *, module: str = "dae",
               timescale: str = "1 ns",
               comment: Optional[str] = None) -> str:
        """Serialize every channel-occupancy and port-issue timeline as a
        Value Change Dump (integer variables, one simulated cycle per
        timescale unit).  The output is deterministic for a
        deterministic run: no wall-clock dates, stable signal order.
        """
        sigs: List[Tuple[str, _Signal]] = []
        for name in sorted(self._occ):
            sigs.append((f"{_sanitize(name)}_occ", self._occ[name]))
        for name in sorted(self._issues):
            sigs.append((f"{_sanitize(name)}_issues", self._issues[name]))

        lines: List[str] = []
        if comment:
            lines += ["$comment", f"  {comment}", "$end"]
        lines += [f"$timescale {timescale} $end",
                  f"$scope module {_sanitize(module)} $end"]
        ids = []
        for i, (name, _) in enumerate(sigs):
            ident = vcd_identifier(i)
            ids.append(ident)
            lines.append(f"$var integer 32 {ident} {name} $end")
        lines += ["$upscope $end", "$enddefinitions $end"]

        # merge all change lists into one time-ordered dump
        events: Dict[int, List[Tuple[str, int]]] = {}
        initial: List[str] = []
        for (name, sig), ident in zip(sigs, ids):
            first = True
            for t, v in sig.changes():
                if first and t == 0:
                    initial.append(f"b{v:b} {ident}")
                    first = False
                    continue
                first = False
                events.setdefault(t, []).append((ident, v))
        lines.append("$dumpvars")
        seeded = {line.split()[-1] for line in initial}
        lines += initial
        for ident in ids:
            if ident not in seeded:
                lines.append(f"b0 {ident}")
        lines.append("$end")
        for t in sorted(events):
            lines.append(f"#{t}")
            for ident, v in events[t]:
                lines.append(f"b{v:b} {ident}")
        end = self.end_cycle
        if end not in events:
            lines.append(f"#{end}")
        return "\n".join(lines) + "\n"

    def write_vcd(self, path, **kw) -> None:
        from pathlib import Path
        Path(path).write_text(self.to_vcd(**kw))
