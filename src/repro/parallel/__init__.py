"""Distribution: sharding rules (DP/FSDP/TP/EP/SP), pipeline stages,
gradient compression."""

from repro.parallel.sharding import (ShardingRules, param_shardings,
                                     batch_sharding, cache_shardings)

__all__ = ["ShardingRules", "param_shardings", "batch_sharding",
           "cache_shardings"]
