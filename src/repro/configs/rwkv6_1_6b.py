"""rwkv6-1.6b [ssm] "Finch": 24L d_model=2048 (attn-free) d_ff=7168
vocab=65536; data-dependent decay [arXiv:2404.05892; unverified]."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # d_model / rwkv_head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab=65_536,
    rwkv_head_dim=64,
)
