"""Mesh transport: a shard_map ring over a named mesh axis.

The decoupled serving pipeline's cross-chip edge.  Each channel owns a
fixed-size device ring buffer — one ``(capacity, width)`` int32 row per
device along ``axis`` — and ``push`` physically moves the payload from
the ``src`` device row to the ``dst`` device row with
``jax.lax.ppermute`` (collective_permute, the same neighbor-move that
drives ``parallel/pp.py``'s pipeline); ``pop`` reads the landed entry
out of the destination row.  With span 1 the permutation is the
identity ``[(0, 0)]`` and the transport degenerates to a single-device
queue — the serve parity tests pin that case bit-identical to
:class:`~repro.channels.local.LocalChannel`.

Division of labor: payload *values* travel the device ring; head/tail
cursors, occupancy (backpressure) and each entry's Python shape (bare
int vs tuple arity) are host-side control plane, exactly like the
serve scheduler that drives the channel.  Tracing follows the shared
vocabulary (post-event depth, see ``base.py``).
"""

from __future__ import annotations

from collections import deque
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                              # jax >= 0.5 re-exports at top level
    from jax import shard_map as _shard_map
except ImportError:               # jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.channels.base import ChannelBase

_I32 = 2 ** 31


class MeshChannel(ChannelBase):
    """Bounded FIFO whose entries travel ``src -> dst`` along a mesh
    axis via collective_permute.

    Entries are ints or (short) tuples of ints — the pipeline's control
    messages (slot ids, first tokens).  ``width`` bounds the tuple
    arity; ``capacity`` is the ring depth on every device.
    """

    transport = "mesh"

    def __init__(self, name: str, capacity: int, mesh: Mesh,
                 axis: str = "data", *, src: int = 0,
                 dst: Optional[int] = None, width: int = 2,
                 tracer=None, instance: str = "serve"):
        if capacity is None or capacity < 1:
            raise ValueError("MeshChannel needs a finite capacity >= 1 "
                             "(it is a fixed-size device ring buffer)")
        if axis not in mesh.axis_names:
            raise ValueError(
                f"axis {axis!r} not in mesh axes {mesh.axis_names}")
        super().__init__(name, capacity, tracer, instance)
        self.mesh = mesh
        self.axis = axis
        self.width = width
        self.span = int(mesh.shape[axis])
        self.src = int(src) % self.span
        self.dst = int(self.span - 1 if dst is None else dst) % self.span
        self._buf_sh = NamedSharding(mesh, P(axis, None, None))
        self._pay_sh = NamedSharding(mesh, P(axis, None))
        self._buf = jax.device_put(
            np.zeros((self.span, capacity, width), np.int32), self._buf_sh)
        self._send = self._build_send()
        self._head = 0
        self._tail = 0
        self._count = 0
        self._meta: deque = deque()      # (kind, arity) per in-flight entry

    def _build_send(self):
        axis, src, dst = self.axis, self.src, self.dst

        def body(buf, pay, tail):
            # per-device blocks: buf (1, capacity, width), pay (1, width)
            moved = jax.lax.ppermute(pay, axis, [(src, dst)])
            idx = jax.lax.axis_index(axis)
            row = buf[0].at[tail].set(moved[0])
            return jnp.where(idx == dst, row, buf[0])[None]

        sm = _shard_map(body, mesh=self.mesh,
                        in_specs=(P(axis), P(axis), P()),
                        out_specs=P(axis))
        return jax.jit(sm, donate_argnums=0)

    # -- wire format ---------------------------------------------------------

    def _encode(self, item: Any) -> Tuple[str, Tuple[int, ...]]:
        if isinstance(item, (int, np.integer)):
            vals: Tuple[int, ...] = (int(item),)
            kind = "i"
        elif isinstance(item, (tuple, list)):
            vals = tuple(int(v) for v in item)
            kind = "t"
        else:
            raise TypeError(
                f"mesh transport carries int / tuple-of-int control "
                f"messages, got {type(item).__name__}")
        if len(vals) > self.width:
            raise ValueError(f"entry arity {len(vals)} exceeds channel "
                             f"width {self.width}")
        for v in vals:
            if not -_I32 <= v < _I32:
                raise ValueError(f"entry value {v} does not fit int32")
        return kind, vals

    def _read(self, slot: int, kind: str, arity: int) -> Any:
        row = np.asarray(jax.device_get(self._buf))[self.dst, slot]
        if kind == "i":
            return int(row[0])
        return tuple(int(v) for v in row[:arity])

    # -- protocol surface ----------------------------------------------------

    def push(self, item: Any) -> bool:
        if self._count >= self.capacity:
            return False
        kind, vals = self._encode(item)
        pay = np.zeros((self.span, self.width), np.int32)
        pay[self.src, :len(vals)] = vals
        self._buf = self._send(self._buf,
                               jax.device_put(pay, self._pay_sh),
                               np.int32(self._tail))
        self._tail = (self._tail + 1) % self.capacity
        self._meta.append((kind, len(vals)))
        self._count += 1
        self._trace(self._count)
        return True

    def pop(self) -> Any:
        if not self._count:
            raise IndexError(f"pop from empty mesh channel {self.name!r}")
        kind, arity = self._meta.popleft()
        item = self._read(self._head, kind, arity)
        self._head = (self._head + 1) % self.capacity
        self._count -= 1
        self._trace(self._count)
        return item

    def peek(self) -> Any:
        if not self._count:
            raise IndexError(f"peek at empty mesh channel {self.name!r}")
        kind, arity = self._meta[0]
        return self._read(self._head, kind, arity)

    def __len__(self) -> int:
        return self._count
