from repro.kernels.dae_spmv.ops import dae_spmv, csr_to_bsr
from repro.kernels.dae_spmv.ref import spmv_ref, bsr_spmv_ref

__all__ = ["dae_spmv", "csr_to_bsr", "spmv_ref", "bsr_spmv_ref"]
