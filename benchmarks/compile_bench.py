"""Compiled-workload grid: every `repro.compile` target end-to-end, as
matrix cells on the ``compile`` axis.

For each registered compile target: a ``pipeline`` cell times the
staged pass pipeline (elaborate → infer → check → codegen), and a
``kernel`` cell runs the compiled Pallas kernel with the cold/warm
split — ``us_cold`` is the first call (JIT compile included),
``us_warm`` the best-of-k steady state.  The pre-matrix file folded JIT
into a single ``us_per_call``, which is how ``compile/binsearch/kernel``
shipped a ~701ms "call time"; the split makes that impossible by
schema (``us_cold`` without ``us_warm`` is a validation error).

Parity against the event-driven simulator oracle is *asserted*, not
reported.  Channels whose chunk/RIF plan came from the analytic
``plan_rif`` fallback also record those knobs as integer derived values
(exact-diffed: a planner regression shows up by cell name); knobs from
a tune cache or explicit override are environment-dependent and ride
along as an informational string instead.
"""

from __future__ import annotations

from typing import List

from repro.bench import (BenchContext, Cell, CellResult, coords, measure,
                         run_cells)


def _pipeline_cell(name: str):
    def run(ctx: BenchContext) -> CellResult:
        from repro.compile.targets import compile_target
        scale = "small" if ctx.smoke else "paper"
        # cold = first full pipeline, warm = rebuild with warm JAX caches
        t = measure(lambda: compile_target(name, scale), warm_reps=1)
        ck, _ = compile_target(name, scale)
        return CellResult(us_cold=t.us_cold, us_warm=t.us_warm,
                          derived={"shape": str(ck.shape)})
    return run


def _kernel_cell(name: str):
    def run(ctx: BenchContext) -> CellResult:
        from repro.compile.targets import assert_parity, compile_target
        scale = "small" if ctx.smoke else "paper"
        ck, t = compile_target(name, scale)
        timing = measure(lambda: ck())   # cold: first call, JIT included
        assert_parity(ck(), t.simulate_oracle())   # gated, not reported
        derived = {}
        plan_parts = []
        for c, p in sorted(ck.plans.items()):
            plan_parts.append(f"{c}:chunk={p.chunk},rif={p.rif},"
                              f"src={p.source}")
            if p.source == "plan_rif":  # analytic => deterministic => diffable
                derived[f"plan_{c}_chunk"] = int(p.chunk)
                derived[f"plan_{c}_rif"] = int(p.rif)
        derived["plans"] = ";".join(plan_parts) or "no-channels"
        return CellResult(us_cold=timing.us_cold, us_warm=timing.us_warm,
                          derived=derived)
    return run


def cells(ctx: BenchContext) -> List[Cell]:
    import jax

    from repro.compile.targets import COMPILE_TARGETS

    backend = jax.default_backend()
    out: List[Cell] = []
    for name in sorted(COMPILE_TARGETS):
        out.append(Cell(
            axis="compile", name=f"compile/{name}/pipeline",
            coords=coords(name, "compiled", engine="pallas",
                          backend=backend),
            run=_pipeline_cell(name), group="compile"))
        out.append(Cell(
            axis="compile", name=f"compile/{name}/kernel",
            coords=coords(name, "compiled", engine="pallas",
                          backend=backend),
            run=_kernel_cell(name), group="compile"))
    return out


def run(csv_print, smoke: bool = False) -> None:
    ctx = BenchContext(smoke=smoke)
    run_cells(cells(ctx), ctx, csv_print)
