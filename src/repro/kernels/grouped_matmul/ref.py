"""Pure-jnp oracle for the grouped (MoE expert) matmul."""

from __future__ import annotations

import jax.numpy as jnp


def grouped_matmul_ref(x: jnp.ndarray, w: jnp.ndarray,
                       block_expert: jnp.ndarray, bt: int) -> jnp.ndarray:
    """x (T, D); w (E, D, F); block_expert (ceil(T/bt),) expert id per
    token block (tokens pre-sorted by expert; a tail block shorter than
    ``bt`` keeps its block's expert)."""
    t = x.shape[0]
    e_t = jnp.repeat(block_expert, bt)[:t]            # (T,)
    w_t = jnp.take(w, e_t, axis=0)                    # (T, D, F)
    return jnp.einsum("td,tdf->tf", x.astype(jnp.float32),
                      w_t.astype(jnp.float32)).astype(x.dtype)
