"""Paged-KV serving: allocator/prefix-cache units, paged-vs-contiguous
bit-parity per attention family, page exhaustion -> preemption ->
completion, prefix reuse + copy-on-write divergence, open-loop arrival
semantics (t_arrival TTFT), and the request-validation sweep both loops
now share (duplicate rids, s_max overflow)."""

import time

import jax
import numpy as np
import pytest

from repro.bench import percentile, percentiles
from repro.configs import get_config
from repro.models.registry import build_model
from repro.runtime.serve_loop import (LegacyServeLoop, PageAllocator,
                                      PagedServeLoop, Request, ServeLoop)

FAST_ARCH = "qwen3-4b"
# one arch per attention family the paged cache supports (GQA dense,
# MoE, MLA) plus the recurrent fallback
PAGED_ARCHS = ("qwen3-4b", "granite-moe-3b-a800m", "minicpm3-4b")
FALLBACK_ARCHS = ("rwkv6-1.6b", "hymba-1.5b")

_MODELS = {}


def _model(arch, **over):
    key = (arch, tuple(sorted(over.items())))
    if key not in _MODELS:
        cfg = get_config(arch, smoke=True, **over)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        _MODELS[key] = (cfg, m, params)
    return _MODELS[key]


def _prompt(n, vocab, seed=0):
    return np.random.default_rng(seed).integers(0, vocab, size=n)


# -- allocator / percentile units ---------------------------------------------


def test_page_allocator_basics():
    a = PageAllocator(n_pages=4, page=8)
    assert a.free_count == 3            # page 0 is the pinned trash page
    p1, p2, p3 = a.alloc(), a.alloc(), a.alloc()
    assert sorted([p1, p2, p3]) == [1, 2, 3]
    assert a.alloc() is None            # exhausted, never raises
    a.incref(p2)
    a.decref(p2)
    assert a.free_count == 0            # still referenced by the incref
    a.decref(p2)
    assert a.free_count == 1 and a.alloc() == p2
    with pytest.raises(ValueError):
        PageAllocator(n_pages=1, page=8)


def test_percentile_linear_interpolation():
    xs = list(range(1, 11))             # 1..10
    assert percentile(xs, 0) == 1
    assert percentile(xs, 100) == 10
    assert percentile(xs, 50) == 5.5
    # the old biased index sorted(v)[int(.95*len)] returned the max for
    # n=10; the interpolated estimator must not
    assert percentile(xs, 95) == pytest.approx(9.55)
    assert percentile([7.0], 99) == 7.0
    assert set(percentiles(xs)) == {"p50", "p95", "p99"}
    with pytest.raises(ValueError):
        percentile([], 50)
    with pytest.raises(ValueError):
        percentile(xs, 101)


# -- paged vs contiguous bit-parity -------------------------------------------


def _parity(arch, expect_fallback):
    cfg, m, params = _model(arch)
    prompts = [_prompt(n, cfg.vocab, seed=n) for n in (1, 5, 9, 18, 3)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new=6)
                for i, p in enumerate(prompts)]

    contig = ServeLoop(cfg, m, params, batch_slots=2, s_max=32, chunk=4)
    r_c = contig.run(reqs())
    paged = PagedServeLoop(cfg, m, params, batch_slots=2, s_max=32,
                           chunk=4, page=8)
    r_p = paged.run(reqs())
    assert r_p == r_c, arch
    assert paged.paged is (not expect_fallback)
    if expect_fallback:
        assert paged.stats.page_allocs == 0
    else:
        assert paged.stats.page_allocs > 0


def test_paged_matches_contiguous_gqa():
    _parity(FAST_ARCH, expect_fallback=False)


def test_paged_fallback_recurrent():
    """Families with recurrent state expose no paged primitives; the
    paged loop must detect that and serve contiguously, bit-identical."""
    _parity("rwkv6-1.6b", expect_fallback=True)


@pytest.mark.slow
@pytest.mark.parametrize("arch", sorted(set(PAGED_ARCHS) - {FAST_ARCH}))
def test_paged_matches_contiguous_all_families(arch):
    _parity(arch, expect_fallback=False)


@pytest.mark.slow
def test_paged_fallback_hybrid():
    _parity("hymba-1.5b", expect_fallback=True)


@pytest.mark.slow
def test_paged_pallas_matches_ref_mode():
    """kernel_mode=pallas drives flash_decode_paged's ring gather over
    the scalar-prefetched page table (interpret mode on CPU); greedy
    outputs must match the ref-mode paged loop."""
    cfg_r, m_r, params = _model(FAST_ARCH)
    cfg_p, m_p, params_p = _model(FAST_ARCH, kernel_mode="pallas")
    prompt = _prompt(11, cfg_r.vocab, seed=3)
    ref = PagedServeLoop(cfg_r, m_r, params, batch_slots=1, s_max=32,
                         page=8).run([Request(rid=0, prompt=prompt,
                                              max_new=4)])[0]
    pal = PagedServeLoop(cfg_p, m_p, params_p, batch_slots=1, s_max=32,
                         page=8).run([Request(rid=0, prompt=prompt,
                                              max_new=4)])[0]
    assert pal == ref


# -- page pressure: preemption and recovery -----------------------------------


def test_page_exhaustion_preempts_and_completes():
    """Pool sized so one slot's decode growth must evict the younger
    slot's pages: the victim is preempted back to the admit queue, the
    older slot progresses (no deadlock), and every request still
    completes with outputs bit-identical to a generous pool."""
    cfg, m, params = _model(FAST_ARCH)
    reqs = lambda: [Request(rid=0, prompt=_prompt(10, cfg.vocab, seed=1),
                            max_new=6),
                    Request(rid=1, prompt=_prompt(6, cfg.vocab, seed=2),
                            max_new=6)]
    roomy = PagedServeLoop(cfg, m, params, batch_slots=2, s_max=16,
                           page=4, prefix_reuse=False)
    ref = roomy.run(reqs())
    assert roomy.stats.preemptions == 0

    tight = PagedServeLoop(cfg, m, params, batch_slots=2, s_max=16,
                           page=4, n_pages=6, prefix_reuse=False)
    out = tight.run(reqs())
    assert tight.stats.preemptions >= 1
    assert out == ref                   # resume is teacher-forced exact


def test_min_pool_serial_completion():
    """The floor pool (one slot's worth) can never hold two requests;
    the loop must degrade to serial service, not deadlock."""
    cfg, m, params = _model(FAST_ARCH)
    loop = PagedServeLoop(cfg, m, params, batch_slots=2, s_max=16,
                          page=4, n_pages=5, prefix_reuse=False)
    results = loop.run([Request(rid=i, prompt=_prompt(8, cfg.vocab, seed=i),
                                max_new=6) for i in range(3)])
    assert set(results) == {0, 1, 2}
    assert all(len(v) == 6 for v in results.values())


def test_pool_too_small_rejected():
    cfg, m, params = _model(FAST_ARCH)
    with pytest.raises(ValueError, match="page"):
        PagedServeLoop(cfg, m, params, batch_slots=1, s_max=16, page=4,
                       n_pages=4)      # needs 1 trash + 4 blocks


# -- prefix reuse and copy-on-write -------------------------------------------


def test_prefix_reuse_fewer_allocs_same_tokens():
    cfg, m, params = _model(FAST_ARCH)
    prompt = _prompt(18, cfg.vocab, seed=4)
    loop = PagedServeLoop(cfg, m, params, batch_slots=1, s_max=32, page=8)
    cold = loop.run([Request(rid=0, prompt=prompt, max_new=5)])
    allocs_cold = loop.stats.page_allocs
    warm = loop.run([Request(rid=1, prompt=prompt, max_new=5)])
    assert warm[1] == cold[0]
    assert loop.stats.prefix_hits == 1
    assert loop.stats.prefix_tokens_reused >= 8
    assert loop.stats.page_allocs - allocs_cold < allocs_cold


def test_cow_on_divergence_inside_shared_page():
    """Two prompts extending a registered 18-token prefix (18 % 8 != 0)
    adopt its partial page; each must copy it before writing (COW), and
    the donor's pages must stay byte-clean for a later re-serve."""
    cfg, m, params = _model(FAST_ARCH)
    base = _prompt(18, cfg.vocab, seed=5)
    ext_b = np.concatenate([base, [7, 3]])
    ext_c = np.concatenate([base, [9]])

    loop = PagedServeLoop(cfg, m, params, batch_slots=2, s_max=32, page=8)
    out_a = loop.run([Request(rid=0, prompt=base, max_new=4)])[0]
    res = loop.run([Request(rid=1, prompt=ext_b, max_new=4),
                    Request(rid=2, prompt=ext_c, max_new=4)])
    assert loop.stats.cow_copies >= 2
    assert loop.stats.prefix_hits >= 2

    # outputs match fresh loops with no sharing at all
    for rid, prompt in ((1, ext_b), (2, ext_c)):
        solo = PagedServeLoop(cfg, m, params, batch_slots=1, s_max=32,
                              page=8, prefix_reuse=False)
        assert res[rid] == solo.run([Request(rid=0, prompt=prompt,
                                             max_new=4)])[0], rid
    # the shared partial page was not polluted by either adopter
    assert loop.run([Request(rid=3, prompt=base, max_new=4)])[3] == out_a


def test_page_stats_accounting():
    cfg, m, params = _model(FAST_ARCH)
    loop = PagedServeLoop(cfg, m, params, batch_slots=2, s_max=32, page=8)
    loop.run([Request(rid=0, prompt=_prompt(12, cfg.vocab, seed=6),
                      max_new=4)])
    st = loop.page_stats()
    assert st["capacity_tokens"] == st["pages_used"] * 8
    assert 0 <= st["pages_used"] <= loop.alloc.n_pages - 1
    assert st["pages_used"] + st["pages_free"] == loop.alloc.n_pages - 1
    assert 0.0 <= st["fragmentation"] <= 1.0
    assert st["prefix_entries"] == len(loop.prefix)


# -- open-loop arrivals and TTFT ----------------------------------------------


def test_open_loop_arrivals_match_closed_loop():
    """Staggered t_arrival must change scheduling only, never outputs."""
    cfg, m, params = _model(FAST_ARCH)
    prompts = [_prompt(4 + i, cfg.vocab, seed=i) for i in range(4)]

    closed = PagedServeLoop(cfg, m, params, batch_slots=2, s_max=32, page=8)
    ref = closed.run([Request(rid=i, prompt=p, max_new=4)
                      for i, p in enumerate(prompts)])
    opened = PagedServeLoop(cfg, m, params, batch_slots=2, s_max=32, page=8)
    res = opened.run([Request(rid=i, prompt=p, max_new=4,
                              t_arrival=0.01 * i)
                      for i, p in enumerate(prompts)])
    assert res == ref
    assert set(opened.stats.ttft) == {0, 1, 2, 3}
    assert all(t >= 0.0 for t in opened.stats.ttft.values())


def test_ttft_measured_from_arrival_not_run_start():
    """A request arriving 50ms into the run must not have those 50ms
    billed to its TTFT (the old single-t0 bug billed queueing-before-
    arrival time that no client experienced)."""
    cfg, m, params = _model(FAST_ARCH)
    loop = ServeLoop(cfg, m, params, batch_slots=1, s_max=32)
    loop.run([Request(rid=0, prompt=_prompt(3, cfg.vocab), max_new=2)])

    delay = 0.05
    loop = ServeLoop(cfg, m, params, batch_slots=1, s_max=32)
    t0 = time.perf_counter()
    loop.run([Request(rid=0, prompt=_prompt(3, cfg.vocab), max_new=2,
                      t_arrival=delay)])
    total = time.perf_counter() - t0
    assert total >= delay               # the loop waited for the arrival
    assert loop.stats.ttft[0] <= total - delay + 0.01


# -- validation both loops share ----------------------------------------------


@pytest.mark.parametrize("cls", [ServeLoop, LegacyServeLoop,
                                 PagedServeLoop])
def test_duplicate_rid_rejected(cls):
    cfg, m, params = _model(FAST_ARCH)
    loop = cls(cfg, m, params, batch_slots=1, s_max=32)
    with pytest.raises(ValueError, match="duplicate"):
        loop.run([Request(rid=5, prompt=_prompt(3, cfg.vocab), max_new=2),
                  Request(rid=5, prompt=_prompt(4, cfg.vocab), max_new=2)])


@pytest.mark.parametrize("cls", [ServeLoop, LegacyServeLoop,
                                 PagedServeLoop])
def test_oversize_request_rejected(cls):
    """LegacyServeLoop used to skip this validation entirely and
    overflow the cache instead; all three loops now reject up front."""
    cfg, m, params = _model(FAST_ARCH)
    loop = cls(cfg, m, params, batch_slots=1, s_max=16)
    with pytest.raises(ValueError, match="s_max"):
        loop.run([Request(rid=0, prompt=_prompt(12, cfg.vocab),
                          max_new=8)])
