"""Paper Fig 4: overhead of the decoupled designs over the 'golden'
reference (zero latency, one request/cycle/port) at scaled-up datasets."""

from __future__ import annotations

from repro.core.workloads import run_workload

PAPER_FIG4 = {  # percent overhead over golden
    "binsearch": 11.9, "binsearch_for": 8.6, "hashtable": 17.6,
    "mergesort": 95.4, "mergesort_opt": 1.3, "multispmv": 33.7,
    "spmv_sparse": 55.3, "spmv_dense": 0.3,
}

CELLS = [
    ("binsearch", "fig4", "binsearch"),
    ("binsearch_for", "fig4", "binsearch_for"),
    ("hashtable", "fig4", "hashtable"),
    ("mergesort", "fig4", "mergesort"),
    ("mergesort_opt", "fig4", "mergesort_opt"),
    ("multispmv", "paper", "multispmv"),
    ("spmv", "fig4_sparse", "spmv_sparse"),
    ("spmv", "fig4_dense", "spmv_dense"),
]


def run(csv_print) -> None:
    for bench, scale, label in CELLS:
        r = run_workload(bench, "rhls_dec", scale=scale, latency=100,
                         rif=128)
        ovh = 100.0 * r.overhead
        paper = PAPER_FIG4[label]
        csv_print(f"fig4/{label},{r.cycles},golden={r.golden};"
                  f"overhead_pct={ovh:.1f};paper_pct={paper};"
                  f"correct={r.correct}")
