from repro.optim.adamw import AdamW, OptState
from repro.optim.schedule import warmup_cosine

__all__ = ["AdamW", "OptState", "warmup_cosine"]
