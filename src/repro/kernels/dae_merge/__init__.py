from repro.kernels.dae_merge.ops import merge_sorted, merge_sort
from repro.kernels.dae_merge.ref import merge_ref, sort_ref

__all__ = ["merge_sorted", "merge_sort", "merge_ref", "sort_ref"]
