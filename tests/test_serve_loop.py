"""Batched serving loop: continuous batching with slot refill."""

import jax
import numpy as np

from repro.configs import get_config
from repro.models.registry import build_model
from repro.runtime.serve_loop import Request, ServeLoop


def test_serve_loop_completes_all_requests():
    cfg = get_config("qwen3-4b", smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, m, params, batch_slots=2, s_max=64)
    reqs = [Request(rid=i,
                    prompt=np.array([1 + i, 2 + i, 3 + i], np.int64),
                    max_new=4)
            for i in range(5)]  # 5 requests > 2 slots -> forces refill
    results = loop.run(reqs)
    assert set(results) == {0, 1, 2, 3, 4}
    for rid, toks in results.items():
        assert 1 <= len(toks) <= 4
        assert all(0 <= t < cfg.vocab for t in toks)


def test_serve_greedy_matches_apply():
    """Slot-pooled decode must equal unbatched greedy decoding."""
    import jax.numpy as jnp
    cfg = get_config("qwen3-4b", smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompt = np.array([5, 9, 2], np.int64)

    # reference: argmax continuation via full re-apply
    toks = list(prompt)
    for _ in range(3):
        logits = m.apply(params, jnp.asarray([toks], jnp.int32))
        toks.append(int(jnp.argmax(logits[0, -1])))
    ref = toks[len(prompt):]

    loop = ServeLoop(cfg, m, params, batch_slots=1, s_max=32)
    out = loop.run([Request(rid=0, prompt=prompt, max_new=3)])[0]
    assert out == ref
