"""The Channel protocol shared by every transport.

One vocabulary joins the three decoupled-pipeline layers (paper §3:
access/execute engines joined by capacity-bounded channels):

  ====================  ==================  =======================
  DAE effect            serve loop          mesh ring
  (core/dae.py)         (runtime)           (channels/mesh.py)
  ====================  ==================  =======================
  ``Enq(ch, v)``        ``ch.push(v)``      ppermute src -> dst row
  ``Deq(ch)``           ``ch.pop()``        read dst device row
  ``Req``/``Resp``      (memory side)       (memory side)
  channel ``capacity``  ``capacity``        device ring slots
  ====================  ==================  =======================

Occupancy discipline (identical across transports, and the invariant
the golden traces pin): every mutation reports the **post-event depth**
to ``Tracer.on_occupancy(instance, name, depth, t)``.  A serve-loop
trace therefore reads exactly like a DAE program trace — same tracer,
same aggregation, same waveform export.
"""

from __future__ import annotations

import abc
from typing import Any, Optional

from repro.core.trace import Tracer


class ChannelBase(abc.ABC):
    """Bounded FIFO protocol: ``push`` refuses beyond ``capacity``
    (backpressure, returning False), ``pop`` takes from the front, and
    every mutation traces the post-event depth under ``instance``.

    ``capacity=None`` means unbounded (the serve admit queue's default).
    """

    __slots__ = ("name", "capacity", "tracer", "instance")

    transport: str = "abstract"

    def __init__(self, name: str, capacity: Optional[int] = None,
                 tracer: Optional[Tracer] = None,
                 instance: str = "serve"):
        self.name = name
        self.capacity = capacity
        self.tracer = tracer
        self.instance = instance

    # -- transport surface ---------------------------------------------------

    @abc.abstractmethod
    def push(self, item: Any) -> bool:
        """Append ``item``; False (and no side effects) when full."""

    @abc.abstractmethod
    def pop(self) -> Any:
        """Remove and return the front item (IndexError when empty)."""

    @abc.abstractmethod
    def peek(self) -> Any:
        """Front item without removing it."""

    @abc.abstractmethod
    def __len__(self) -> int:
        ...

    # -- shared behavior -----------------------------------------------------

    def _trace(self, depth: int, t: float = 0.0) -> None:
        if self.tracer is not None:
            self.tracer.on_occupancy(self.instance, self.name, depth, t)

    @property
    def occupancy(self) -> int:
        return len(self)

    @property
    def full(self) -> bool:
        return self.capacity is not None and len(self) >= self.capacity

    def __bool__(self) -> bool:
        return len(self) > 0
