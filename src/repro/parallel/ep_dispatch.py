"""All-to-all expert-parallel MoE dispatch (shard_map).

The §Perf analysis (EXPERIMENTS.md, granite-moe pair) showed the
XLA-level sort-based dispatch reshards (E, C, D) tables of *global*
capacity every layer (~1.1e11 link B/layer/device).  The fix the paper's
decoupling principle suggests — move the *request* (token) to the data,
bound the in-flight window — is the classic all-to-all EP dispatch:

  1. each data shard routes its LOCAL tokens (top-k);
  2. tokens are binned per destination expert-shard with a LOCAL
     capacity bound (deadlock/overflow-free by construction, like the
     paper's §5.1 capacity rule);
  3. one all-to-all along the expert axis moves ~T_loc·k·D bytes per
     device — ~2 orders of magnitude less than resharding the global
     einsum tables;
  4. each expert shard runs its local experts' FFN (the Pallas
     grouped_matmul on real TPU; dense einsum here);
  5. a reverse all-to-all returns outputs, combined with gates.

Numerically verified against the single-device oracle in
tests/test_ep_dispatch.py; kept standalone (not yet wired into
models/moe.py) so the measured framework baselines stay as reported.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def ep_moe_reference(x, router, w_gate, w_up, w_down, top_k: int):
    """Single-device oracle: dense top-k MoE (no drops)."""
    t, d = x.shape
    e = router.shape[1]
    logits = (x @ router).astype(jnp.float32)
    gates, experts = jax.lax.top_k(jax.nn.softmax(logits, -1), top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x, w_gate))
    h = h * jnp.einsum("td,edf->tef", x, w_up)
    y_all = jnp.einsum("tef,efd->ted", h, w_down)          # (T, E, D)
    onehot = jax.nn.one_hot(experts, e, dtype=jnp.float32)  # (T, K, E)
    w = (onehot * gates[..., None]).sum(1)                   # (T, E)
    return jnp.einsum("ted,te->td", y_all, w).astype(x.dtype)


def make_ep_moe(mesh: Mesh, *, ep_axis: str = "model", dp_axis: str = "data",
                top_k: int, n_experts: int, capacity_per_shard: int):
    """Build a shard_map'd MoE apply: x sharded over dp_axis (tokens),
    expert weights sharded over ep_axis (leading E dim)."""
    n_shards = mesh.shape[ep_axis]
    assert n_experts % n_shards == 0, (n_experts, n_shards)
    e_loc = n_experts // n_shards
    c = capacity_per_shard

    def local_fn(x, router, wg, wu, wd):
        # x (T_loc, D) tokens of this (dp, ep) coordinate's dp shard,
        # replicated along ep; weights (e_loc, D, F) local experts.
        t_loc, d = x.shape
        my_shard = jax.lax.axis_index(ep_axis)

        logits = (x @ router).astype(jnp.float32)
        gates, experts = jax.lax.top_k(jax.nn.softmax(logits, -1), top_k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        flat_e = experts.reshape(-1)                      # (T_loc*K,)
        flat_g = gates.reshape(-1).astype(jnp.float32)
        flat_t = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), top_k)
        dest = flat_e // e_loc                            # target shard

        # position of each routed token within its destination bin
        order = jnp.argsort(dest, stable=True)
        sd, se, sg, stk = dest[order], flat_e[order], flat_g[order], \
            flat_t[order]
        starts = jnp.searchsorted(sd, jnp.arange(n_shards, dtype=sd.dtype),
                                  side="left")
        pos = jnp.arange(t_loc * top_k, dtype=jnp.int32) - starts[sd]
        keep = pos < c                                     # capacity bound

        # send buffers: (n_shards, C, D) tokens + (n_shards, C) metadata
        send_x = jnp.zeros((n_shards, c, d), x.dtype)
        send_le = jnp.full((n_shards, c), 0, jnp.int32)    # local expert id
        send_valid = jnp.zeros((n_shards, c), jnp.float32)
        rows = jnp.where(keep, sd, 0)
        cols = jnp.where(keep, pos, 0)
        send_x = send_x.at[rows, cols].set(
            jnp.where(keep[:, None], jnp.take(x, stk, 0), 0), mode="drop")
        send_le = send_le.at[rows, cols].set(
            jnp.where(keep, se % e_loc, 0), mode="drop")
        send_valid = send_valid.at[rows, cols].max(
            jnp.where(keep, 1.0, 0.0), mode="drop")

        # all-to-all along the expert axis (the decoupled request stream)
        recv_x = jax.lax.all_to_all(send_x, ep_axis, 0, 0, tiled=False)
        recv_le = jax.lax.all_to_all(send_le, ep_axis, 0, 0, tiled=False)
        recv_valid = jax.lax.all_to_all(send_valid, ep_axis, 0, 0,
                                        tiled=False)

        # local expert FFN on (n_shards*C, D) received tokens
        rx = recv_x.reshape(-1, d)
        rle = recv_le.reshape(-1)
        rv = recv_valid.reshape(-1)
        sel = jax.nn.one_hot(rle, e_loc, dtype=rx.dtype) * rv[:, None]
        # dense-per-local-expert compute (grouped_matmul on real TPU)
        h = jax.nn.silu(jnp.einsum("td,edf->tef", rx, wg))
        h = h * jnp.einsum("td,edf->tef", rx, wu)
        y_all = jnp.einsum("tef,efd->ted", h, wd)
        y = jnp.einsum("ted,te->td", y_all, sel)           # (nS*C, D)

        # send results back (decoupled response stream)
        back = jax.lax.all_to_all(y.reshape(n_shards, c, d), ep_axis, 0, 0,
                                  tiled=False)

        # combine at the source with gates
        contrib = back[rows, cols]                          # (T_loc*K, D) sorted order
        contrib = jnp.where(keep[:, None], contrib, 0)
        out = jnp.zeros((t_loc, d), jnp.float32)
        out = out.at[stk].add(contrib.astype(jnp.float32) * sg[:, None])
        return out.astype(x.dtype)

    fn = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp_axis, None), P(), P(ep_axis, None, None),
                  P(ep_axis, None, None), P(ep_axis, None, None)),
        out_specs=P(dp_axis, None),
        # the output IS replicated along ep_axis (every ep coordinate of a
        # dp shard routes the same tokens and receives the same results),
        # but the checker cannot infer that through all_to_all.
        check_vma=False,
    )
    return fn
