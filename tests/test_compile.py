"""repro.compile: the staged DAE->Pallas compiler.

Three layers of coverage:

* target parity — every registered compile target (gather, the
  compile-only frontier_gather, both binsearch variants) must run
  bit-identical to the event-driven simulator oracle;
* differential compile-or-reject — the seeded random program generator
  shared with the parity harness (tests/strategies.py): every spec
  either compiles AND matches the simulator's stores, or is rejected
  with a CompileError carrying actionable diagnostics;
* plumbing — edge regimes (rif=1, empty request streams), the reject
  diagnostics themselves, the tune-cache -> infer dispatch path, and
  the dae_spmv CSR-vs-BSR cache-key regression.
"""

import random

import numpy as np
import pytest

from repro.compile import (ChaseSpec, CompileError, compile_program,
                           elaborate, program_key_parts, StreamKind)
from repro.compile.targets import (COMPILE_TARGETS, assert_parity,
                                   build_target, compile_target)
from repro.core.dae import (DaeProgram, LoadChannel, Process, Req, Resp,
                            Store)
from repro.core.simulator import DeadlockError, Fused, simulate
from tests.strategies import build_program, random_spec


# -- target parity ------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(COMPILE_TARGETS))
def test_target_compiles_bit_identical_to_simulator(name):
    ck, t = compile_target(name)
    assert_parity(ck(), t.simulate_oracle())


def test_compiled_kernel_is_rerunnable():
    ck, _t = compile_target("gather")
    a, b = ck(), ck()
    for port in a:
        np.testing.assert_array_equal(a[port], b[port])


def test_frontier_is_compile_only_and_indirect():
    """The compile-only proof: frontier_gather has no hand-written
    kernel family — the dist stream must classify INDIRECT and lower
    through the two-phase deref ring."""
    t = build_target("frontier_gather")
    ir = elaborate(t.prog, t.memories)
    kinds = {c.name: c.kind for c in ir.channels.values()}
    assert kinds["fg_adj"] is StreamKind.STATIC
    assert kinds["fg_dist"] is StreamKind.INDIRECT
    ck = compile_program(t.prog, t.memories)
    assert ck.shape == "deref"
    assert_parity(ck(), t.simulate_oracle())


# -- edge regimes -------------------------------------------------------------


def _tiny_gather(idx, table_len=16, cap=4):
    ch = LoadChannel("t_load", capacity=cap, port="table")

    def access():
        for a in idx:
            yield Req(ch, int(a))

    def execute():
        for j in range(len(idx)):
            yield Fused(Resp(ch), lambda v, j=j: Store("out", j, v))

    prog = DaeProgram("tiny", [Process("access", access),
                               Process("execute", execute)])
    mems = {"table": [10 * i for i in range(table_len)],
            "out": [None] * max(1, len(idx))}
    return prog, mems


def test_rif_one_fully_serialized_ring():
    prog, mems = _tiny_gather([3, 1, 2, 3])
    ck = compile_program(prog, mems, rif=1, chunk=1)
    assert all(p.rif == 1 and p.chunk == 1 for p in ck.plans.values())
    np.testing.assert_array_equal(ck()["out"], [30, 10, 20, 30])


def test_empty_request_stream_compiles_to_no_outputs():
    prog, mems = _tiny_gather([])
    ck = compile_program(prog, mems)
    assert ck() == {}


def test_rif_clamped_to_channel_capacity():
    """§5.3: a ring deeper than the channel capacity could deadlock the
    simulated program — infer must clamp an oversized explicit rif."""
    prog, mems = _tiny_gather([1, 2, 3, 0], cap=3)
    ck = compile_program(prog, mems, rif=64)
    (plan,) = ck.plans.values()
    assert plan.rif == 3 and "5.3" in plan.note
    np.testing.assert_array_equal(ck()["out"], [10, 20, 30, 0])


# -- reject-path diagnostics --------------------------------------------------


def test_dependent_stream_rejected_with_chasespec_hint():
    ch = LoadChannel("walk", capacity=4, port="table")

    def proc():
        a = 0
        for _ in range(4):
            yield Req(ch, a)
            a = int((yield Resp(ch)))
        yield Store("out", 0, a)

    prog = DaeProgram("chase", [Process("walk", proc)])
    mems = {"table": [3, 0, 1, 2], "out": [None]}
    with pytest.raises(CompileError) as ei:
        compile_program(prog, mems)
    assert ei.value.pass_name == "check"
    assert "DEPENDENT" in str(ei.value) and "ChaseSpec" in str(ei.value)


def test_store_to_load_port_rejected():
    ch = LoadChannel("ld", capacity=2, port="table")

    def proc():
        yield Req(ch, 0)
        v = yield Resp(ch)
        yield Store("table", 1, v)

    prog = DaeProgram("raw", [Process("p", proc)])
    with pytest.raises(CompileError) as ei:
        compile_program(prog, {"table": [5, 6], "out": [None]})
    assert "also a load port" in str(ei.value)


def test_out_of_range_load_rejected_at_elaborate():
    prog, mems = _tiny_gather([99])
    with pytest.raises(CompileError) as ei:
        compile_program(prog, mems)
    assert ei.value.pass_name == "elaborate"
    assert "address" in str(ei.value)


def test_wrong_chasespec_rejected_by_numpy_prerun():
    t = build_target("binsearch")
    good = t.chase
    bad = ChaseSpec(good.port, good.state0, good.max_steps, good.addr_fn,
                    good.step_fn, lambda s: (s[0], s[2] + 1))
    with pytest.raises(CompileError) as ei:
        compile_program(t.prog, t.memories, chase=bad)
    assert "does not reproduce" in str(ei.value)


# -- differential: random specs compile-or-reject -----------------------------


def test_random_programs_compile_or_reject_with_parity():
    """Every seeded random spec either raises CompileError (an explicit,
    diagnosed rejection) or yields a kernel whose stores match a fresh
    simulator run of the same spec."""
    compiled = rejected = 0
    for seed in range(40):
        spec = random_spec(random.Random(seed))
        prog, mems = build_program(spec, name=f"rand{seed}")
        try:
            ck = compile_program(prog, mems)
        except CompileError as e:
            assert e.diagnostics, f"seed {seed}: rejection without diagnostics"
            rejected += 1
            continue
        compiled += 1
        outs = ck()
        prog2, mems2 = build_program(spec, name=f"rand{seed}")
        try:
            res = simulate(prog2, mems2)
        except DeadlockError:
            # compilable dataflow, but the chosen capacities starve the
            # cycle-accurate engine — there is no oracle to compare to
            continue
        want = res.stored_array("out", max(1, spec["n_stores"]))
        got = outs.get("out")
        for addr, w in enumerate(want):
            if w is None:
                continue
            assert got is not None, f"seed {seed}: missing 'out'"
            np.testing.assert_array_equal(
                np.asarray(got[addr], dtype=np.float64),
                np.asarray(w, dtype=np.float64),
                err_msg=f"seed {seed} addr {addr}")
    # the generator must exercise both sides of the contract
    assert compiled >= 3, f"only {compiled} specs compiled"
    assert rejected >= 3, f"only {rejected} specs rejected"


# -- tune-cache -> infer dispatch ---------------------------------------------


def test_infer_picks_tuned_config_from_cache():
    from repro.kernels.common import resolve_interpret
    from repro.tune import CacheEntry, backend_tag, default_cache, make_key

    t = build_target("gather")
    ir = elaborate(t.prog, t.memories)
    op, dims, dtype = program_key_parts(ir)
    key = make_key(op, dims, dtype, backend_tag(resolve_interpret(None)),
                   "wallclock")
    default_cache().put(key, CacheEntry(config={"chunk": 16, "rif": 3},
                                        score=1.0))
    ck = compile_program(t.prog, t.memories)
    assert all(p.chunk == 16 and p.rif == 3 for p in ck.plans.values())
    assert all("cache" in p.source for p in ck.plans.values())
    assert_parity(ck(), t.simulate_oracle())


@pytest.mark.slow
def test_tune_compiled_end_to_end():
    from repro.tune import tune_compiled

    res = tune_compiled("gather", max_evals=2, reps=1)
    assert res.evals > 0 and np.isfinite(res.best_score)
    again = tune_compiled("gather", max_evals=2, reps=1)
    assert again.evals == 0 and again.best == res.best  # cache hit


# -- dae_spmv CSR-vs-BSR cache keying (regression) ----------------------------


def test_spmv_tuned_rif_dispatches_at_bsr_dims(monkeypatch):
    """Regression: csr_to_bsr resolves its block shape under the CSR
    dims the tuner stores the winner at, but dae_spmv's rif lookup sees
    the *converted* (BSR) operands — without the alias key the tuned
    rif never dispatched and every matvec fell back to plan_rif."""
    import jax.numpy as jnp
    from repro.kernels.dae_spmv import csr_to_bsr, dae_spmv
    from repro.kernels.dae_spmv import ops as spmv_ops
    from repro.tune import CacheEntry, default_cache, tune_kernel

    dims = (32, 128, 60)  # (nrows, ncols, nnz)
    tune_kernel("dae_spmv", dims, max_evals=2, reps=1)
    cache = default_cache()
    spmv_keys = [k for k in cache.keys() if k.startswith("dae_spmv|")]
    assert len(spmv_keys) >= 2, \
        f"tuner must persist the CSR key and its BSR alias, got {spmv_keys}"
    # bump every entry to a sentinel rif the search space seed can't
    # produce by coincidence, then check the dispatcher actually sees it
    for k in spmv_keys:
        e = cache.get(k)
        cache.put(k, CacheEntry(config={**e.config, "rif": 5}, score=e.score))

    seen = {}
    real_impl = spmv_ops._spmv_impl

    def spy(*args, **kwargs):
        seen["rif"] = kwargs.get("rif")
        return real_impl(*args, **kwargs)

    monkeypatch.setattr(spmv_ops, "_spmv_impl", spy)

    nrows, ncols, nnz = dims
    r = np.random.default_rng(0)
    counts = r.multinomial(nnz, np.ones(nrows) / nrows)
    rows = np.zeros(nrows + 1, np.int64)
    rows[1:] = np.cumsum(counts)
    cols = r.integers(0, ncols, nnz)
    val = r.standard_normal(nnz).astype(np.float32)
    vec = jnp.asarray(r.standard_normal(ncols), jnp.float32)

    vb, ri, ci, _, nrb = csr_to_bsr(rows, cols, val, ncols)  # tuned bm/bk
    out = dae_spmv(jnp.asarray(vb), jnp.asarray(ri), jnp.asarray(ci), vec,
                   nrb)  # rif=None -> must resolve from the BSR alias key
    assert seen.get("rif") == 5, \
        f"tuned rif did not dispatch at BSR dims (saw {seen.get('rif')})"
    dense = np.zeros((nrows, ncols), np.float32)
    for i in range(nrows):
        for p in range(int(rows[i]), int(rows[i + 1])):
            dense[i, int(cols[p])] += val[p]
    np.testing.assert_allclose(np.asarray(out)[:nrows], dense @ np.asarray(vec),
                               rtol=1e-5, atol=1e-5)
