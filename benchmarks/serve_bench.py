"""Serving benchmark: paged-KV decoupled pipeline, open-loop arrival
traces, and the legacy-loop comparison sweep.

Two consumers:

  * ``python -m benchmarks.run serve`` — the CSV sweep: decoupled
    Access/Execute loop vs the coupled legacy loop across batch_slots x
    prompt mixes x model archetypes, plus the paged open-loop cells;
  * ``cells(ctx)`` — the ``serve`` axis of the benchmark matrix
    (schema-v2 ``BENCH_serve.json``, gated by ``benchmarks.diff``).

The matrix cells are the load-bearing ones:

  * ``serve/open/{poisson,bursty}/paged/s64`` — slots=64 under a seeded
    open-loop arrival trace (Poisson / bursty) of prompts sharing a
    page-aligned system prefix.  The Poisson cell runs the *same trace*
    through the contiguous loop and asserts the paged loop (a) returns
    bit-identical outputs, (b) sustains >= the contiguous tokens/s, and
    (c) admitted a concurrent reservation footprint
    (sum of prompt+max_new over live slots) larger than its physical
    page pool — the oversubscription a contiguous reservation allocator
    cannot express.  TTFT p50/p95/p99 are measured from each request's
    arrival via the shared linear-interpolated percentile helper
    (``repro.bench.percentiles``).
  * ``serve/parity/<arch>`` — closed-loop paged-vs-contiguous greedy
    bit-parity per attention family (GQA dense, MoE, MLA) and the
    recurrent fallback (rwkv, where ``PagedServeLoop`` must detect the
    missing paged primitives and serve contiguously).
  * ``serve/prefix/qwen3-4b`` — the same prompt served twice: the
    second run must return identical tokens with strictly fewer page
    allocations (prefix adoption).

Determinism discipline: integer ``derived`` values (request/token
counts, prefix hits, page allocations) are exact-diffed against the
committed baseline, so every int reported here is structural —
timing-dependent measurements (tokens/s, TTFT quantiles) are floats,
which the diff treats as informational.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time
from pathlib import Path
from typing import List

import numpy as np

REPO_ROOT = Path(__file__).resolve().parents[1]

MIXES = {
    "short": (6, 6),       # uniform short prompts
    "long": (40, 48),      # uniform long prompts
    "mixed": (4, 48),      # alternating short/long — the stall workload
}
ARCHS = ("qwen3-4b", "granite-moe-3b-a800m", "rwkv6-1.6b", "hymba-1.5b")
SLOTS = (2, 8)
SMOKE_ARCHS = ("qwen3-4b",)
SMOKE_SLOTS = (8,)
SMOKE_MIXES = ("mixed",)
GATE_SPEEDUP = 5.0         # slots=8 mixed cell: decoupled >= 5x legacy
MAX_NEW = 16
N_REQUESTS = 12
CHUNK = 16

# paged open-loop cells (slots >= 64 is the ROADMAP's serving regime)
OPEN_SLOTS = 64
OPEN_N = 64                # trace length
PAGE = 8
S_LOG = 96                 # per-slot logical horizon (s_max)
S_PHYS = 48                # physical pool: OPEN_SLOTS * S_PHYS tokens
PREFIX_LEN = 64            # shared system prefix (page-aligned)
TAIL_LEN = 4               # unique per-request tail
OPEN_MAX_NEW = 8
# parity cells cover every attention family plus the recurrent fallback
PARITY_ARCHS = ("qwen3-4b", "granite-moe-3b-a800m", "minicpm3-4b",
                "rwkv6-1.6b")


def _prompts(mix: str, n: int, vocab: int, seed: int = 0):
    lo, hi = MIXES[mix]
    rng = np.random.default_rng(seed)
    lens = [lo if i % 2 == 0 else hi for i in range(n)]
    return [rng.integers(0, vocab, size=p) for p in lens]


def _requests(mix: str, vocab: int):
    from repro.runtime.serve_loop import Request
    return [Request(rid=i, prompt=p, max_new=MAX_NEW)
            for i, p in enumerate(_prompts(mix, N_REQUESTS, vocab))]


def poisson_arrivals(n: int, mean_gap_s: float, rng) -> List[float]:
    """Seeded open-loop Poisson process: exponential interarrivals."""
    return list(np.cumsum(rng.exponential(mean_gap_s, size=n)))


def bursty_arrivals(n: int, burst: int, gap_s: float, rng) -> List[float]:
    """Bursts of ``burst`` near-simultaneous arrivals, ``gap_s`` apart
    (with ~0.1ms in-burst jitter so arrival order is still seeded)."""
    out = []
    for i in range(n):
        out.append((i // burst) * gap_s + rng.uniform(0, 1e-4))
    return sorted(out)


def _occ_summary(trace) -> str:
    occ = trace.channel_occupancy()
    return ",".join(f"{name.rsplit('/', 1)[-1]}:{mean:.1f}/{mx}"
                    for name, (mean, mx) in sorted(occ.items()))


def _model(arch):
    import jax

    from repro.configs import get_config
    from repro.models.registry import build_model

    cfg = get_config(arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


def _bench_cell(cfg, bundle, params, mix, slots, s_max):
    from repro.bench import percentiles
    from repro.core.trace import Tracer
    from repro.runtime.serve_loop import LegacyServeLoop, Request, ServeLoop

    def warm():
        return [Request(rid=-1, prompt=np.array([1, 2], np.int64),
                        max_new=2)]

    # compile on a throwaway loop (the jit caches are shared per bundle
    # function), then measure a FRESH loop so the tracer and stats see
    # only workload traffic
    ServeLoop(cfg, bundle, params, batch_slots=slots, s_max=s_max,
              chunk=CHUNK).run(warm())
    tracer = Tracer()
    loop = ServeLoop(cfg, bundle, params, batch_slots=slots, s_max=s_max,
                     chunk=CHUNK, tracer=tracer)
    reqs = _requests(mix, cfg.vocab)
    t0 = time.perf_counter()
    results = loop.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in results.values())
    ttft = [loop.stats.ttft[r.rid] for r in reqs]
    pct = percentiles(ttft, (50.0, 95.0, 99.0))

    LegacyServeLoop(cfg, bundle, params, batch_slots=slots,
                    s_max=s_max).run(warm())
    legacy = LegacyServeLoop(cfg, bundle, params, batch_slots=slots,
                             s_max=s_max)
    reqs_l = _requests(mix, cfg.vocab)
    t0 = time.perf_counter()
    results_l = legacy.run(reqs_l)
    dt_l = time.perf_counter() - t0
    toks_l = sum(len(v) for v in results_l.values())

    return {
        "tok_s": toks / dt,
        "legacy_tok_s": toks_l / dt_l,
        "speedup": (toks / dt) / (toks_l / dt_l),
        "ttft_mean_ms": 1e3 * sum(ttft) / len(ttft),
        "ttft_p95_ms": 1e3 * pct["p95"],
        "occ": _occ_summary(tracer.summary()),
    }


def _parity_cell(cfg, bundle, params, s_max) -> None:
    """One slot, one request: legacy is correct here, so greedy outputs
    must be bit-identical between the loops."""
    from repro.runtime.serve_loop import LegacyServeLoop, Request, ServeLoop

    prompt = np.asarray(_prompts("mixed", 2, cfg.vocab, seed=7)[1])
    new = ServeLoop(cfg, bundle, params, batch_slots=1, s_max=s_max,
                    chunk=CHUNK)
    out_new = new.run([Request(rid=0, prompt=prompt, max_new=8)])[0]
    leg = LegacyServeLoop(cfg, bundle, params, batch_slots=1, s_max=s_max)
    out_leg = leg.run([Request(rid=0, prompt=prompt, max_new=8)])[0]
    if out_new != out_leg:  # must fire even under python -O
        raise AssertionError(
            f"{cfg.arch}: decoupled {out_new} != legacy {out_leg}")


# ---------------------------------------------------------------------------
# Paged open-loop cells
# ---------------------------------------------------------------------------


def _open_trace(vocab: int, arrivals: List[float], rng):
    """Shared system prefix + unique tails — the prefix-cache workload."""
    from repro.runtime.serve_loop import Request

    prefix = rng.integers(0, vocab, size=PREFIX_LEN)
    reqs = []
    for i, t in enumerate(arrivals):
        tail = rng.integers(0, vocab, size=TAIL_LEN)
        reqs.append(Request(rid=i, prompt=np.concatenate([prefix, tail]),
                            max_new=OPEN_MAX_NEW, t_arrival=float(t)))
    return prefix, reqs


def _clone(reqs):
    from repro.runtime.serve_loop import Request
    return [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                    t_arrival=r.t_arrival) for r in reqs]


def open_loop_cell(trace: str, seed: int = 0, compare: bool = True) -> dict:
    """Run the slots>=64 open-loop paged cell; ``compare`` also runs the
    contiguous loop on the same trace and enforces the gates."""
    from repro.bench import percentiles
    from repro.runtime.serve_loop import PagedServeLoop, Request, ServeLoop

    cfg, bundle, params = _model("qwen3-4b")
    rng = np.random.default_rng(seed)
    if trace == "poisson":
        arrivals = poisson_arrivals(OPEN_N, 2e-3, rng)
    elif trace == "bursty":
        arrivals = bursty_arrivals(OPEN_N, OPEN_SLOTS // 4, 0.1, rng)
    else:
        raise ValueError(f"unknown trace {trace!r}")
    prefix, reqs = _open_trace(cfg.vocab, arrivals, rng)
    n_pages = 1 + OPEN_SLOTS * S_PHYS // PAGE
    pool_tokens = (n_pages - 1) * PAGE

    paged = PagedServeLoop(cfg, bundle, params, batch_slots=OPEN_SLOTS,
                           s_max=S_LOG, chunk=CHUNK, page=PAGE,
                           n_pages=n_pages)
    # the warmup request is the system prompt itself: it compiles the
    # primitives AND registers the shared prefix, so every trace request
    # adopts it (prefill skips PREFIX_LEN of its PREFIX_LEN+TAIL tokens)
    paged.run([Request(rid=-1, prompt=prefix, max_new=OPEN_MAX_NEW)])
    base = paged.stats
    snap = (base.page_allocs, base.prefix_hits, base.prefix_tokens_reused,
            base.cow_copies, base.preemptions)
    t0 = time.perf_counter()
    res = paged.run(_clone(reqs))
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in res.values())
    ttft = [paged.stats.ttft[r.rid] for r in reqs]
    pct = percentiles(ttft, (50.0, 95.0, 99.0))
    pstats = paged.page_stats()
    cell = {
        "requests": len(reqs),
        "tokens": int(toks),
        "prefix_hits": base.prefix_hits - snap[1],
        "prefix_tokens_reused": base.prefix_tokens_reused - snap[2],
        "page_allocs": base.page_allocs - snap[0],
        "cow_copies": base.cow_copies - snap[3],
        "preemptions": base.preemptions - snap[4],
        "pinned_pages": int(pstats["pages_used"]),
        "tok_s": toks / dt,
        "ttft_p50_ms": 1e3 * pct["p50"],
        "ttft_p95_ms": 1e3 * pct["p95"],
        "ttft_p99_ms": 1e3 * pct["p99"],
        "peak_reserved_tokens": int(paged.stats.peak_reserved_tokens),
        "pool_tokens": pool_tokens,
        "dt_s": dt,
    }
    if not compare:
        return cell

    contig = ServeLoop(cfg, bundle, params, batch_slots=OPEN_SLOTS,
                       s_max=S_LOG, chunk=CHUNK)
    contig.run([Request(rid=-1, prompt=prefix, max_new=OPEN_MAX_NEW)])
    t0 = time.perf_counter()
    res_c = contig.run(_clone(reqs))
    dt_c = time.perf_counter() - t0
    toks_c = sum(len(v) for v in res_c.values())
    cell["contig_tok_s"] = toks_c / dt_c
    cell["speedup"] = cell["tok_s"] / cell["contig_tok_s"]
    # gates (must fire even under python -O)
    if res != res_c:
        raise AssertionError("open-loop paged outputs != contiguous")
    # static oversubscription witness (timing-independent, unlike the
    # peak_reserved_tokens sample): every request needs more KV than the
    # per-slot share of the physical pool, so a contiguous allocator
    # with the same memory (s_max = S_PHYS) could not admit ANY of them
    need = PREFIX_LEN + TAIL_LEN + OPEN_MAX_NEW
    if need <= pool_tokens // OPEN_SLOTS:
        raise AssertionError(
            f"trace does not oversubscribe: per-request KV {need} fits "
            f"the per-slot physical share {pool_tokens // OPEN_SLOTS}")
    if cell["speedup"] < 1.0:
        raise AssertionError(
            f"paged {cell['tok_s']:.1f} tok/s < contiguous "
            f"{cell['contig_tok_s']:.1f} tok/s")
    return cell


def paged_parity(arch: str, seed: int = 0) -> dict:
    """Closed-loop paged-vs-contiguous greedy bit-parity for one arch."""
    from repro.runtime.serve_loop import PagedServeLoop, Request, ServeLoop

    cfg, bundle, params = _model(arch)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, size=n)
               for n in (12, 3, 25, 7, 1, 18)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new=8)
                for i, p in enumerate(prompts)]

    contig = ServeLoop(cfg, bundle, params, batch_slots=4, s_max=40,
                       chunk=CHUNK)
    r_c = contig.run(reqs())
    paged = PagedServeLoop(cfg, bundle, params, batch_slots=4, s_max=40,
                           chunk=CHUNK, page=PAGE)
    r_p = paged.run(reqs())
    if r_p != r_c:  # must fire even under python -O
        raise AssertionError(f"{arch}: paged {r_p} != contiguous {r_c}")
    fallback = not paged.paged
    expected_fallback = bundle.cache_init_paged is None
    if fallback != expected_fallback:
        raise AssertionError(f"{arch}: fallback={fallback} but bundle "
                             f"paged primitives absent={expected_fallback}")
    return {"requests": len(prompts),
            "tokens": int(sum(len(v) for v in r_c.values())),
            "match": 1, "fallback": int(fallback),
            "page_allocs": paged.stats.page_allocs}


def prefix_reuse_cell(seed: int = 0) -> dict:
    """Same prompt twice: identical outputs, strictly fewer allocations
    the second time (prefix adoption)."""
    from repro.runtime.serve_loop import PagedServeLoop, Request

    cfg, bundle, params = _model("qwen3-4b")
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab, size=3 * PAGE + 2)
    loop = PagedServeLoop(cfg, bundle, params, batch_slots=2, s_max=64,
                          chunk=CHUNK, page=PAGE)
    cold = loop.run([Request(rid=0, prompt=prompt, max_new=8)])
    allocs_cold = loop.stats.page_allocs
    warmr = loop.run([Request(rid=1, prompt=prompt, max_new=8)])
    allocs_warm = loop.stats.page_allocs - allocs_cold
    if cold[0] != warmr[1]:  # must fire even under python -O
        raise AssertionError("prefix-reuse outputs diverge")
    if allocs_warm >= allocs_cold:
        raise AssertionError(
            f"prefix reuse saved nothing: {allocs_warm} >= {allocs_cold}")
    return {"allocs_cold": allocs_cold, "allocs_warm": allocs_warm,
            "prefix_hits": loop.stats.prefix_hits,
            "prefix_tokens_reused": loop.stats.prefix_tokens_reused,
            "match": 1}


# ---------------------------------------------------------------------------
# Sharded serving cells (runtime/mesh_serve.py)
# ---------------------------------------------------------------------------


def sharded_mesh1_cell(seed: int = 0) -> dict:
    """Single-device co-located placement: ShardedPagedServeLoop on
    mesh(n=1) must be bit-identical to PagedServeLoop — same outputs,
    same structural counters — with control messages riding the
    (degenerate, identity-permute) MeshChannel ring."""
    from repro.launch.mesh import make_serve_meshes
    from repro.runtime.mesh_serve import ShardedPagedServeLoop
    from repro.runtime.serve_loop import PagedServeLoop, Request

    cfg, bundle, params = _model("qwen3-4b")
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, size=n)
               for n in (12, 3, 25, 7, 1, 18)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new=8)
                for i, p in enumerate(prompts)]

    base = PagedServeLoop(cfg, bundle, params, batch_slots=4, s_max=40,
                          chunk=CHUNK, page=PAGE)
    r0 = base.run(reqs())
    sharded = ShardedPagedServeLoop(cfg, bundle, params, batch_slots=4,
                                    s_max=40, meshes=make_serve_meshes(1),
                                    chunk=CHUNK, page=PAGE)
    r1 = sharded.run(reqs())
    if r0 != r1:  # must fire even under python -O
        raise AssertionError(f"mesh1 sharded != single-host: {r1} vs {r0}")
    for k in ("prefill_tokens", "decode_tokens", "page_allocs",
              "cow_copies", "preemptions", "prefix_hits"):
        if getattr(base.stats, k) != getattr(sharded.stats, k):
            raise AssertionError(
                f"mesh1 counter {k}: sharded {getattr(sharded.stats, k)} "
                f"!= base {getattr(base.stats, k)}")
    return {"requests": len(prompts),
            "tokens": int(sum(len(v) for v in r0.values())),
            "match": 1, "page_allocs": sharded.stats.page_allocs,
            "migrations": sharded.stats.migrations}


# the mesh8 open-loop snippet runs in a subprocess so the cell is
# reproducible from any parent (normal CI sees 1 device, the
# multi-device job 8 — the child always forces 8)
_MESH8_SNIPPET = """
    import json, time
    import jax, numpy as np
    assert jax.device_count() == 8, jax.device_count()
    from repro.configs import get_config
    from repro.models.registry import build_model
    from repro.launch.mesh import make_serve_meshes
    from repro.runtime.serve_loop import PagedServeLoop, Request
    from repro.runtime.mesh_serve import ShardedPagedServeLoop

    seed = %d
    cfg = get_config("qwen3-4b", smoke=True)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    sizes = (12, 3, 25, 7, 1, 18, 9, 30)
    arrivals = np.cumsum(rng.exponential(2e-3, size=len(sizes)))
    prompts = [rng.integers(0, cfg.vocab, size=n) for n in sizes]
    def reqs():
        return [Request(rid=i, prompt=p, max_new=6, t_arrival=float(t))
                for i, (p, t) in enumerate(zip(prompts, arrivals))]
    # ample slots/pool + prefix off: every structural counter below is
    # arrival-timing independent (no preemption, no prefix adoption)
    kw = dict(batch_slots=8, s_max=40, chunk=16, page=8,
              prefix_reuse=False)
    base = PagedServeLoop(cfg, bundle, params, **kw)
    r0 = base.run(reqs())
    meshes = make_serve_meshes(8)
    assert meshes.disaggregated
    kw.pop("prefix_reuse")
    sh = ShardedPagedServeLoop(cfg, bundle, params, meshes=meshes, **kw)
    t0 = time.perf_counter()
    r1 = sh.run(reqs())
    dt = time.perf_counter() - t0
    assert r0 == r1, "disaggregated open-loop outputs diverge"
    toks = sum(len(v) for v in r1.values())
    print(json.dumps({
        "requests": len(sizes), "tokens": int(toks), "match": 1,
        "migrations": sh.stats.migrations,
        "page_allocs": sh.stats.page_allocs,
        "preemptions": sh.stats.preemptions,
        "prefix_hits": sh.stats.prefix_hits,
        "tok_s": toks / dt, "dt_s": dt}))
"""


def sharded_open_mesh8_cell(seed: int = 0) -> dict:
    """Disaggregated open-loop serving on 8 forced host devices:
    prefill and decode engines on disjoint 4-device submeshes, joined
    by mesh channels, with page migration between the pools.  Outputs
    must match the single-host paged loop on the same arrival trace."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_MESH8_SNIPPET % seed)],
        capture_output=True, text=True, env=env, timeout=600)
    if out.returncode != 0:  # must fire even under python -O
        raise AssertionError(
            f"mesh8 subprocess failed:\n{out.stderr[-3000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# Matrix axis
# ---------------------------------------------------------------------------

_FLOAT_KEYS = ("tok_s", "contig_tok_s", "speedup", "ttft_p50_ms",
               "ttft_p95_ms", "ttft_p99_ms", "dt_s",
               # a wall-clock *sample* of concurrency, not structural:
               # how many arrivals overlap depends on machine speed
               "peak_reserved_tokens")


def _derived(cell: dict) -> dict:
    """Ints exact-diff; floats informational (see module docstring)."""
    out = {}
    for key, val in cell.items():
        out[key] = round(float(val), 3) if key in _FLOAT_KEYS else int(val)
    return out


def cells(ctx) -> List:
    """The ``serve`` axis of the benchmark matrix."""
    from repro.bench import Cell, CellResult, coords

    out: List = []

    def open_cell(trace, compare):
        def run(c) -> CellResult:
            t0 = time.perf_counter()
            cell = open_loop_cell(trace, seed=c.seed, compare=compare)
            us = (time.perf_counter() - t0) * 1e6
            return CellResult(us_warm=us, derived=_derived(cell))
        return run

    for trace, compare in (("poisson", True), ("bursty", False)):
        out.append(Cell(
            axis="serve", name=f"serve/open/{trace}/paged/s{OPEN_SLOTS}",
            coords=coords(f"serve-open-{trace}", "serve", engine="event",
                          backend="xla", tenants=OPEN_SLOTS),
            run=open_cell(trace, compare), group="serve-open"))

    def parity_run(arch):
        def run(c) -> CellResult:
            return CellResult(derived=_derived(paged_parity(arch,
                                                            seed=c.seed)))
        return run

    for arch in PARITY_ARCHS:
        out.append(Cell(
            axis="serve", name=f"serve/parity/{arch}/paged-vs-contig",
            coords=coords(f"serve-parity-{arch}", "serve", backend="xla",
                          tenants=4),
            run=parity_run(arch), group="serve-parity"))

    def prefix_run(c) -> CellResult:
        return CellResult(derived=_derived(prefix_reuse_cell(seed=c.seed)))

    out.append(Cell(
        axis="serve", name="serve/prefix/qwen3-4b/reuse",
        coords=coords("serve-prefix", "serve", backend="xla", tenants=2),
        run=prefix_run, group="serve-prefix"))

    def mesh1_run(c) -> CellResult:
        return CellResult(derived=_derived(sharded_mesh1_cell(seed=c.seed)))

    out.append(Cell(
        axis="serve", name="serve/sharded/mesh1/qwen3-4b/paged",
        coords=coords("serve-sharded-mesh1", "serve", backend="xla",
                      tenants=4),
        run=mesh1_run, group="serve-sharded"))

    def mesh8_run(c) -> CellResult:
        t0 = time.perf_counter()
        cell = sharded_open_mesh8_cell(seed=c.seed)
        us = (time.perf_counter() - t0) * 1e6
        return CellResult(us_warm=us, derived=_derived(cell))

    out.append(Cell(
        axis="serve", name="serve/sharded/open/mesh8/qwen3-4b/disagg",
        coords=coords("serve-sharded-mesh8", "serve", backend="xla",
                      tenants=8),
        run=mesh8_run, group="serve-sharded"))
    return out


# ---------------------------------------------------------------------------
# CLI sweep
# ---------------------------------------------------------------------------


def run(csv_print, smoke: bool = False) -> dict:
    archs = SMOKE_ARCHS if smoke else ARCHS
    slots_sweep = SMOKE_SLOTS if smoke else SLOTS
    mixes = SMOKE_MIXES if smoke else tuple(MIXES)
    s_max = max(hi for _, hi in MIXES.values()) + MAX_NEW + 8

    results = {}
    for arch in archs:
        cfg, bundle, params = _model(arch)
        _parity_cell(cfg, bundle, params, s_max)
        for mix in mixes:
            for slots in slots_sweep:
                cell = _bench_cell(cfg, bundle, params, mix, slots, s_max)
                results[(arch, mix, slots)] = cell
                csv_print(
                    f"serve/{arch}/{mix}/s{slots},{1e6 / cell['tok_s']:.1f},"
                    f"tok_s={cell['tok_s']:.1f};"
                    f"legacy={cell['legacy_tok_s']:.1f};"
                    f"speedup={cell['speedup']:.2f};"
                    f"ttft_ms={cell['ttft_mean_ms']:.0f}/"
                    f"{cell['ttft_p95_ms']:.0f};"
                    f"occ={cell['occ']}")
                if mix == "mixed" and slots == 8 and \
                        cell["speedup"] < GATE_SPEEDUP:
                    raise AssertionError(
                        f"{arch} mixed/s8: decoupled speedup "
                        f"{cell['speedup']:.2f}x < {GATE_SPEEDUP}x gate")
    # paged open-loop cells (the ROADMAP's slots>=64 serving regime)
    for trace in ("poisson",) if smoke else ("poisson", "bursty"):
        cell = open_loop_cell(trace, compare=(trace == "poisson"))
        results[("paged", trace, OPEN_SLOTS)] = cell
        extra = (f";vs_contig={cell['speedup']:.2f}x"
                 if "speedup" in cell else "")
        csv_print(
            f"serve/open/{trace}/paged/s{OPEN_SLOTS},"
            f"{1e6 / cell['tok_s']:.1f},"
            f"tok_s={cell['tok_s']:.1f};"
            f"ttft_ms={cell['ttft_p50_ms']:.0f}/{cell['ttft_p95_ms']:.0f}/"
            f"{cell['ttft_p99_ms']:.0f};"
            f"hits={cell['prefix_hits']}/{cell['requests']};"
            f"reserved={cell['peak_reserved_tokens']}"
            f"/{cell['pool_tokens']}{extra}")
    return results
