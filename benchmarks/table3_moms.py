"""Paper Table 3: the read-only-compatible subset under a MOMS +
row-buffer DRAM model instead of fixed latency.

Matrix cells on the ``sim`` axis (group ``table3``): each cell runs the
same (benchmark, config) under both memory models; ``cycles`` is the
MOMS count and the fixed-latency count rides along as an integer
``derived`` value, so the gate pins both models at once.
"""

from __future__ import annotations

from typing import List

from repro.bench import BenchContext, Cell, CellResult, coords, run_cells
from repro.core.workloads import run_workload

PAPER_TABLE3 = {
    ("binsearch", "vitis"): 2_239_063, ("binsearch", "vitis_dec"): 65_011,
    ("binsearch", "rhls"): 677_274, ("binsearch", "rhls_dec"): 23_302,
    ("binsearch_for", "vitis"): 2_294_243,
    ("binsearch_for", "vitis_dec"): 83_937,
    ("binsearch_for", "rhls"): 701_472,
    ("binsearch_for", "rhls_dec"): 25_928,
    ("hashtable", "vitis"): 1_904_751, ("hashtable", "vitis_dec"): 53_887,
    ("hashtable", "rhls"): 1_008_246, ("hashtable", "rhls_dec"): 18_716,
    ("spmv", "vitis"): 283_829, ("spmv", "vitis_dec"): 55_037,
    ("spmv", "rhls"): 29_918, ("spmv", "rhls_dec"): 29_732,
}

SUBSET = ("binsearch", "binsearch_for", "hashtable", "spmv")  # read-only
TABLE3_CONFIGS = ("vitis", "vitis_dec", "rhls", "rhls_dec")


def _cell_run(bench: str, config: str):
    def run(ctx: BenchContext) -> CellResult:
        moms_kwargs = dict(scale=ctx.sim_scale, mem="moms",
                           max_outstanding=64)
        fixed = run_workload(bench, config, scale=ctx.sim_scale,
                             mem="fixed")
        moms = run_workload(bench, config, **moms_kwargs)
        assert moms.correct, f"{bench}/{config} incorrect under MOMS"
        derived = {"fixed": int(fixed.cycles),
                   "moms_vs_fixed": round(moms.cycles / fixed.cycles, 2)}
        paper = PAPER_TABLE3.get((bench, config), 0)
        if paper and not ctx.smoke:
            derived["paper_moms"] = paper
        return CellResult(cycles=int(moms.cycles), derived=derived,
                          replay={"benchmark": bench, "config": config,
                                  "kwargs": moms_kwargs})
    return run


def cells(ctx: BenchContext) -> List[Cell]:
    return [
        Cell(axis="sim", name=f"table3/{bench}/{config}", group="table3",
             coords=coords(bench, "sim"), run=_cell_run(bench, config))
        for bench in SUBSET for config in TABLE3_CONFIGS
    ]


def run(csv_print) -> None:
    ctx = BenchContext(smoke=False)
    run_cells(cells(ctx), ctx, csv_print)
