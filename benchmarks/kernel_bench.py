"""Decoupled-kernel microbenchmarks as matrix cells (``kernels`` axis).

Wall-clock on this CPU container is NOT TPU performance; the derived
metric that transfers is the simulator's cycle model (RIF sweeps showing
latency hiding) plus interpret-mode correctness-at-shape.  Every cell
therefore reports what is actually stable for it: simulator cells carry
first-class ``cycles`` (exact-diffed by ``benchmarks.diff``), kernel
cells carry the cold/warm wall-clock split from
:func:`repro.bench.measure` (warm gated with a generous percent band,
cold recorded but never gated).

Cell groups:

  * ``rif_sweep`` / ``cap_sweep`` — the paper's central RIF knob and the
    §5.3/§5.4 capacity sensitivity (negative slack is the *expected*
    deadlock, reported as ``status="deadlock"``);
  * ``gather`` — decoupled kernel (interpret) vs the XLA take;
  * per-op ``default`` / ``tuned`` pairs — the analytic plan_rif
    fallback vs the tune-cache winner, ``tuned`` coordinate set;
  * ``chase`` — decoupled Pallas vs XLA fallback, parity *gated*;
  * ``contended`` — the §5.4 wall-clock leg: the makespan of two
    concurrent gmm dispatches under the solo winner's knobs vs the
    ``tune_kernel(contenders=2)`` winner's knobs;
  * ``probe_vectorization`` — the hash_probe SMEM→VMEM vectorization
    win pinned against its pre-change wall-clock baseline;
  * ``compiled_vs_hand`` — the generic repro.compile lowering vs the
    hand-written kernel family on the same problem data.

``python -m benchmarks.run kernel-bench`` streams the legacy CSV;
``python -m benchmarks.run matrix`` runs the full axis and writes the
schema-validated ``BENCH_kernels.json``.
"""

from __future__ import annotations

from typing import List

from benchmarks.roofline import kernel_bound_us
from repro.bench import (BenchContext, Cell, CellResult, coords, measure,
                         run_cells)


def cells(ctx: BenchContext) -> List[Cell]:
    import math

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.pipeline import plan_rif
    from repro.kernels.dae_chase.kernel import ENTRY_LANES
    from repro.tune import KERNEL_DIMS

    backend = jax.default_backend()
    r = np.random.default_rng(ctx.seed)
    out: List[Cell] = []

    def add(name: str, c, run_fn, group: str = "kernel-bench") -> None:
        out.append(Cell(axis="kernels", name=name, coords=c, run=run_fn,
                        group=group))

    # -- RIF sweep (the paper's central knob) from the simulator ------------
    def rif_cell(rif):
        def run(c: BenchContext) -> CellResult:
            from repro.core.workloads import run_workload
            kwargs = dict(scale=c.sim_scale, latency=100, rif=rif)
            res = run_workload("hashtable", "rhls_dec", **kwargs)
            return CellResult(cycles=int(res.cycles),
                              derived={"golden": int(res.golden)},
                              replay={"benchmark": "hashtable",
                                      "config": "rhls_dec",
                                      "kwargs": kwargs})
        return run

    for rif in (2, 8, 32, 128):
        add(f"kernel/rif_sweep/hashtable/rif={rif}",
            coords("hashtable", "sim"), rif_cell(rif))

    # -- channel-capacity sensitivity sweep (§5.3/§5.4) ---------------------
    # capacity = rif+slack; negative slack starves the round-robin chase
    # into the deadlock the capacity bound exists to prevent
    def cap_cell(slack):
        def run(c: BenchContext) -> CellResult:
            from repro.core.simulator import DeadlockError
            from repro.core.workloads import run_workload
            kwargs = dict(scale=c.sim_scale, latency=100, rif=32,
                          cap_slack=slack)
            replay = {"benchmark": "hashtable", "config": "rhls_dec",
                      "kwargs": kwargs}
            try:
                res = run_workload("hashtable", "rhls_dec", **kwargs)
            except DeadlockError:
                return CellResult(status="deadlock", replay=replay)
            return CellResult(cycles=int(res.cycles),
                              derived={"golden": int(res.golden)},
                              replay=replay)
        return run

    for slack in (-4, 0, 1, 16, 64):
        add(f"kernel/cap_sweep/hashtable/slack={slack}",
            coords("hashtable", "sim"), cap_cell(slack))

    # -- grouped_matmul DaeProgram rif sweep --------------------------------
    # the simulator twin of the expert-weight ring in
    # kernels/grouped_matmul: route stream -> data-dependent weight fetch
    def gmm_sim_cell(rif):
        def run(c: BenchContext) -> CellResult:
            from repro.core.simulator import FixedLatencyMemory, simulate
            from repro.core.workloads import gmm_phases, make_gmm_data
            data = make_gmm_data(c.sim_scale)
            progs, mems, golden, check = gmm_phases(
                data, 100, rif,
                lambda port, vals: FixedLatencyMemory(vals, 100))
            res = simulate(progs[0], mems)
            assert check(res)
            return CellResult(cycles=int(res.cycles),
                              derived={"golden": int(golden)})
        return run

    for rif in (1, 8, 64):
        add(f"kernel/rif_sweep/grouped_matmul/rif={rif}",
            coords("grouped_matmul", "sim"), gmm_sim_cell(rif))

    # -- gather: decoupled kernel (interpret) vs XLA take -------------------
    # Knobs are passed explicitly so these baseline cells never pick up a
    # tuned config from a previous run's cache.
    from repro.kernels.dae_gather import dae_gather
    gn, gm = (1024, 128) if ctx.smoke else (4096, 512)
    table = jnp.asarray(r.standard_normal((gn, 256)), jnp.float32)
    idx = jnp.asarray(r.integers(0, gn, gm), jnp.int32)

    # gathered rows move once HBM->VMEM and once back out
    gather_bound = kernel_bound_us(0.0, 2 * gm * 256 * 4)

    def gather_cell(method):
        def run(c: BenchContext) -> CellResult:
            t = measure(lambda: dae_gather(table, idx, method=method,
                                           block_d=512, chunk=64, rif=8))
            derived = ({} if method == "ref"
                       else {"roofline_bound_us": gather_bound})
            return CellResult(us_cold=t.us_cold, us_warm=t.us_warm,
                              derived=derived)
        return run

    for method in ("pipelined", "rif", "ref"):
        add(f"kernel/gather/{method}",
            coords("dae_gather", "kernel",
                   engine="xla" if method == "ref" else "pallas",
                   backend=backend),
            gather_cell(method))

    # -- per-op tuned-vs-default --------------------------------------------
    # default: the analytic fallback the dispatcher resolves on a cold
    # cache (plan_rif-sized rings, documented default blocks — passed
    # explicitly so a warm cache cannot contaminate the baseline);
    # tuned: the tune-cache winner the dispatcher resolves after tuning.
    from repro.kernels.dae_chase import batched_searchsorted, hash_lookup
    from repro.kernels.dae_merge import merge_sorted

    evals = 4 if ctx.smoke else 16
    a = jnp.sort(jnp.asarray(r.standard_normal(2048), jnp.float32))
    b = jnp.sort(jnp.asarray(r.standard_normal(2048), jnp.float32))
    ss_n, ss_m = KERNEL_DIMS["batched_searchsorted"]
    ss_table = jnp.sort(jnp.asarray(r.integers(0, 1 << 30, ss_n), jnp.int32))
    ss_keys = jnp.asarray(r.integers(0, 1 << 30, ss_m), jnp.int32)
    hl_n, hl_m = KERNEL_DIMS["hash_lookup"]
    chain = 8
    hl_ek = jnp.asarray(np.arange(hl_n), jnp.int32)
    hl_ev = jnp.asarray(r.integers(0, 1 << 20, hl_n), jnp.int32)
    hl_en = jnp.asarray([(i + 1) if (i + 1) % chain else -1
                         for i in range(hl_n)], jnp.int32)
    hl_heads = jnp.asarray(r.integers(0, hl_n // chain, hl_m) * chain,
                           jnp.int32)
    hl_keys = hl_heads + jnp.asarray(r.integers(0, chain, hl_m), jnp.int32)

    from repro.kernels.grouped_matmul import grouped_matmul

    gt, gd, gf = KERNEL_DIMS["grouped_matmul"]
    g_e, g_bt = 4, 128
    gmm_x = jnp.asarray(r.standard_normal((gt, gd)), jnp.float32)
    gmm_w = jnp.asarray(r.standard_normal((g_e, gd, gf)), jnp.float32)
    gmm_blk = jnp.asarray(r.integers(0, g_e, gt // g_bt), jnp.int32)

    # the cold-cache fallback knobs, mirrored from each dispatcher
    gather_rif0 = plan_rif(64 * 256 * 4).rif          # chunk * dp * f32
    merge_rif0 = plan_rif(256 * 4).rif                # tile * f32
    ss_rif0 = plan_rif(128 * 4).rif                   # block * i32
    hl_rif0 = plan_rif(ENTRY_LANES * 4).rif           # packed entry row
    gmm_bd0 = min(512, gd)
    gmm_rif0 = plan_rif(gmm_bd0 * 128 * 4).rif        # one (bd, bf) tile

    # expected-on-hardware roofline bounds per decoupled op: the bytes
    # the rings actually move plus MXU compute where it matters (the
    # chase ops fetch one block per dependent step)
    roofline_us = {
        "dae_merge": kernel_bound_us(0.0, 2 * (2048 + 2048) * 4),
        "batched_searchsorted": kernel_bound_us(
            0.0, ss_m * math.ceil(math.log2(ss_n)) * 128 * 4),
        "hash_lookup": kernel_bound_us(
            0.0, hl_m * chain * ENTRY_LANES * 4),
        "grouped_matmul": kernel_bound_us(
            2.0 * gt * gd * gf,
            (gt * gd + (gt // g_bt) * gd * gf + gt * gf) * 4),
    }
    roofline_us["dae_gather"] = gather_bound

    tuned_cells = {
        # op -> (dims, dtype, cold-cache-default call, tuned call)
        "dae_gather": (
            (gn, 256, gm), jnp.float32.dtype,
            lambda: dae_gather(table, idx, method="pipelined", block_d=256,
                               chunk=64, rif=gather_rif0),
            lambda: dae_gather(table, idx)),
        "dae_merge": (
            (2048, 2048), jnp.float32.dtype,
            lambda: merge_sorted(a, b, tile=256, rif=merge_rif0),
            lambda: merge_sorted(a, b)),
        "batched_searchsorted": (
            (ss_n, ss_m), ss_table.dtype,
            lambda: batched_searchsorted(ss_table, ss_keys, block=128,
                                         chunk=64, rif=ss_rif0),
            lambda: batched_searchsorted(ss_table, ss_keys)),
        "hash_lookup": (
            (hl_n, hl_m), jnp.int32.dtype,
            lambda: hash_lookup(hl_ek, hl_ev, hl_en, hl_heads, hl_keys,
                                max_steps=chain, chunk=64, rif=hl_rif0),
            lambda: hash_lookup(hl_ek, hl_ev, hl_en, hl_heads, hl_keys,
                                max_steps=chain)),
        "grouped_matmul": (
            (gt, gd, gf), jnp.float32.dtype,
            lambda: grouped_matmul(gmm_x, gmm_w, gmm_blk, bt=g_bt, bf=128,
                                   bd=gmm_bd0, rif=gmm_rif0),
            lambda: grouped_matmul(gmm_x, gmm_w, gmm_blk, bt=g_bt)),
    }

    def default_cell(op, default_fn):
        def run(c: BenchContext) -> CellResult:
            t = measure(default_fn)
            return CellResult(us_cold=t.us_cold, us_warm=t.us_warm,
                              derived={"roofline_bound_us":
                                       roofline_us[op]})
        return run

    def tuned_cell(op, dims, dtype, tuned_fn):
        def run(c: BenchContext) -> CellResult:
            from repro.kernels.common import resolve_interpret
            from repro.tune import dispatch_config, tune_kernel
            res = tune_kernel(op, dims, max_evals=evals, reps=2)
            t = measure(tuned_fn)  # dispatcher consults the cache
            cfg = dispatch_config(op, dims, dtype, resolve_interpret(None))
            cfg_s = ";".join(f"{k}={v}" for k, v in sorted(cfg.items()))
            # config + evals are search outcomes scored by wall-clock, so
            # they are floats/strings here: informational, never diffed
            return CellResult(us_cold=t.us_cold, us_warm=t.us_warm,
                              derived={"config": cfg_s,
                                       "tune_evals": float(res.evals),
                                       "roofline_bound_us":
                                       roofline_us[op]})
        return run

    for op, (dims, dtype, default_fn, tuned_fn) in tuned_cells.items():
        add(f"kernel/{op}/plan_default",
            coords(op, "kernel", engine="pallas", backend=backend,
                   tuned=False),
            default_cell(op, default_fn))
        add(f"kernel/{op}/tuned",
            coords(op, "kernel", engine="pallas", backend=backend,
                   tuned=True),
            tuned_cell(op, dims, dtype, tuned_fn))

    # -- chase: decoupled Pallas kernel vs the XLA fallback -----------------
    # The paper's headline irregular workloads on the kernel path.
    # Wall-clock here is interpret-mode plumbing, so both sides are
    # recorded rather than gating a ratio; correctness IS gated.
    from repro.kernels.dae_chase import hash_lookup_ref, searchsorted_ref

    chase_cells = {
        "batched_searchsorted": (
            lambda m: batched_searchsorted(ss_table, ss_keys, block=128,
                                           chunk=64, rif=8, method=m),
            lambda: searchsorted_ref(ss_table, ss_keys)),
        "hash_lookup": (
            lambda m: hash_lookup(hl_ek, hl_ev, hl_en, hl_heads, hl_keys,
                                  max_steps=chain, chunk=64, rif=8,
                                  method=m),
            lambda: hash_lookup_ref(hl_ek, hl_ev, hl_en, hl_heads, hl_keys,
                                    chain)),
    }

    def chase_cell(op, fn, ref_fn, method):
        def run(c: BenchContext) -> CellResult:
            if method == "pallas":
                np.testing.assert_array_equal(np.asarray(fn("pallas")),
                                              np.asarray(ref_fn()))
            t = measure(lambda: fn(method))
            derived = {"parity": "ok"}
            if method == "pallas":
                derived["roofline_bound_us"] = roofline_us[op]
            return CellResult(us_cold=t.us_cold, us_warm=t.us_warm,
                              derived=derived)
        return run

    for op, (fn, ref_fn) in chase_cells.items():
        add(f"kernel/{op}/decoupled",
            coords(op, "kernel", engine="pallas", backend=backend),
            chase_cell(op, fn, ref_fn, "pallas"))
        add(f"kernel/{op}/xla_fallback",
            coords(op, "kernel", engine="xla", backend=backend),
            chase_cell(op, fn, ref_fn, "ref"))

    # -- contended-vs-solo (§5.4 on the wall clock) -------------------------
    # Both cells measure the SAME load — the makespan of two concurrent
    # gmm dispatches — differing only in whose winner supplies the
    # knobs: the solo tune-cache entry vs the ``contenders=2`` entry.
    from concurrent.futures import ThreadPoolExecutor

    def gmm_pair(kw):
        def one():
            return grouped_matmul(gmm_x, gmm_w, gmm_blk, bt=g_bt, **kw)

        def pair():
            with ThreadPoolExecutor(max_workers=2) as pool:
                futs = [pool.submit(one) for _ in range(2)]
                return [jax.block_until_ready(f.result()) for f in futs]
        return pair

    def gmm_contended_cell(contenders):
        def run(c: BenchContext) -> CellResult:
            from repro.kernels.common import resolve_interpret
            from repro.tune import (dispatch_config, tune_kernel,
                                    wallclock_tag)
            res = tune_kernel("grouped_matmul", (gt, gd, gf),
                              max_evals=evals, reps=2,
                              contenders=contenders)
            cfg = dispatch_config("grouped_matmul", (gt, gd, gf),
                                  jnp.float32.dtype,
                                  resolve_interpret(None),
                                  mem=wallclock_tag(contenders))
            kw = {k: cfg[k] for k in ("bf", "bd", "rif") if k in cfg}
            t = measure(gmm_pair(kw))
            cfg_s = ";".join(f"{k}={v}" for k, v in sorted(cfg.items()))
            return CellResult(us_cold=t.us_cold, us_warm=t.us_warm,
                              derived={"config": cfg_s,
                                       "tune_evals": float(res.evals),
                                       "roofline_bound_us":
                                       2 * roofline_us["grouped_matmul"]})
        return run

    add("kernel/grouped_matmul/contended/solo_winner",
        coords("grouped_matmul", "kernel", engine="pallas",
               backend=backend, tenants=2, tuned=True),
        gmm_contended_cell(1))
    add("kernel/grouped_matmul/contended/contended_winner",
        coords("grouped_matmul", "kernel", engine="pallas",
               backend=backend, tenants=2, tuned=True),
        gmm_contended_cell(2))

    # -- hash_probe vectorization pin ---------------------------------------
    # found/val state moved from per-scalar SMEM loops to VMEM vector
    # fills/emits; the baseline is the pre-vectorization wall time at this
    # exact cell (4096x256, chain=8, chunk=64, rif=8, best-of-5), so the
    # after-side is measured the same way.  The portable (cycle-level)
    # side of this pin lives in tests/test_tuned_dispatch_matrix.py.
    def probe_cell(c: BenchContext) -> CellResult:
        t = measure(lambda: hash_lookup(hl_ek, hl_ev, hl_en, hl_heads,
                                        hl_keys, max_steps=chain, chunk=64,
                                        rif=8), warm_reps=5)
        return CellResult(us_cold=t.us_cold, us_warm=t.us_warm,
                          derived={"scalar_smem_baseline_us": 3650.2})

    add("kernel/hash_lookup/probe_vectorization",
        coords("hash_lookup", "kernel", engine="pallas", backend=backend),
        probe_cell)

    # -- compiled-vs-handwritten --------------------------------------------
    # The generic repro.compile lowering vs the hand-written kernel family
    # on the same problem data.  Output conventions differ (the compiled
    # binsearch stores found-index-or--1 where batched_searchsorted
    # returns insertion points), so each side is asserted against its OWN
    # oracle — the simulator for the compiled kernel, the XLA reference
    # for the hand-written one — and wall-clock is the comparable number.
    def compiled_cell(target):
        def run(c: BenchContext) -> CellResult:
            from repro.compile.targets import assert_parity, compile_target
            ck, t = compile_target(target)
            timing = measure(lambda: ck())
            assert_parity(ck(), t.simulate_oracle())
            return CellResult(us_cold=timing.us_cold,
                              us_warm=timing.us_warm,
                              derived={"parity": "sim_oracle"})
        return run

    def hand_gather_cell(c: BenchContext) -> CellResult:
        from repro.core.workloads import make_gather_data
        g = make_gather_data("small")
        g_table = jnp.asarray(g["table"])
        g_idx = jnp.asarray(g["idx"], jnp.int32)

        def hand():
            return dae_gather(g_table, g_idx, method="rif", chunk=16, rif=8)

        np.testing.assert_array_equal(
            np.asarray(hand()), np.asarray(g_table)[np.asarray(g_idx)])
        t = measure(hand)
        return CellResult(us_cold=t.us_cold, us_warm=t.us_warm,
                          derived={"parity": "xla_take",
                                   "op": "dae_gather[rif]"})

    def hand_binsearch_cell(c: BenchContext) -> CellResult:
        from repro.core.workloads import make_binsearch_data
        bs = make_binsearch_data("small")
        bs_arr = jnp.asarray(bs["arr"], jnp.int32)
        bs_keys = jnp.asarray(bs["keys"], jnp.int32)

        def hand():
            return batched_searchsorted(bs_arr, bs_keys, block=128,
                                        chunk=16, rif=8)

        np.testing.assert_array_equal(
            np.asarray(hand()), np.asarray(searchsorted_ref(bs_arr,
                                                            bs_keys)))
        t = measure(hand)
        return CellResult(us_cold=t.us_cold, us_warm=t.us_warm,
                          derived={"parity": "xla_ref",
                                   "op": "batched_searchsorted"})

    add("kernel/compiled_vs_hand/gather/compiled",
        coords("gather", "compiled", engine="pallas", backend=backend),
        compiled_cell("gather"))
    add("kernel/compiled_vs_hand/gather/handwritten",
        coords("gather", "kernel", engine="pallas", backend=backend),
        hand_gather_cell)
    add("kernel/compiled_vs_hand/binsearch/compiled",
        coords("binsearch", "compiled", engine="pallas", backend=backend),
        compiled_cell("binsearch"))
    add("kernel/compiled_vs_hand/binsearch/handwritten",
        coords("binsearch", "kernel", engine="pallas", backend=backend),
        hand_binsearch_cell)

    # -- merge + flash single cells (plumbing-overhead indicators) ----------
    def merge_cell(c: BenchContext) -> CellResult:
        t = measure(lambda: merge_sorted(a, b, tile=256, rif=2))
        return CellResult(us_cold=t.us_cold, us_warm=t.us_warm)

    def flash_cell(c: BenchContext) -> CellResult:
        from repro.kernels.flash_attention import flash_attention
        q = jnp.asarray(r.standard_normal((1, 4, 512, 64)), jnp.float32)
        k = jnp.asarray(r.standard_normal((1, 2, 512, 64)), jnp.float32)
        v = jnp.asarray(r.standard_normal((1, 2, 512, 64)), jnp.float32)
        t = measure(lambda: flash_attention(q, k, v))
        return CellResult(us_cold=t.us_cold, us_warm=t.us_warm)

    add("kernel/merge/pallas",
        coords("dae_merge", "kernel", engine="pallas", backend=backend),
        merge_cell)
    add("kernel/flash/pallas",
        coords("flash_attention", "kernel", engine="pallas",
               backend=backend),
        flash_cell)

    return out


def run(csv_print, smoke: bool = False) -> None:
    ctx = BenchContext(smoke=smoke)
    run_cells(cells(ctx), ctx, csv_print)
