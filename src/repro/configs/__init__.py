"""Assigned-architecture configs (``--arch <id>``) + smoke variants."""

from __future__ import annotations

from typing import Dict

from repro.configs.base import smoke_variant
from repro.configs.shapes import SHAPES, InputShape, long_context_ok
from repro.models.common import ModelConfig

from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.granite_moe_3b_a800m import CONFIG as _granite_moe
from repro.configs.deepseek_v2_lite_16b import CONFIG as _deepseek
from repro.configs.qwen2_72b import CONFIG as _qwen2
from repro.configs.minicpm3_4b import CONFIG as _minicpm3
from repro.configs.granite_34b import CONFIG as _granite34
from repro.configs.qwen3_4b import CONFIG as _qwen3
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.rwkv6_1_6b import CONFIG as _rwkv6
from repro.configs.chameleon_34b import CONFIG as _chameleon

ARCHS: Dict[str, ModelConfig] = {
    c.arch: c for c in (
        _seamless, _granite_moe, _deepseek, _qwen2, _minicpm3,
        _granite34, _qwen3, _hymba, _rwkv6, _chameleon,
    )
}


def get_config(arch: str, smoke: bool = False, **overrides) -> ModelConfig:
    import dataclasses
    cfg = ARCHS[arch]
    if smoke:
        cfg = smoke_variant(cfg)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


__all__ = ["ARCHS", "SHAPES", "InputShape", "get_config", "long_context_ok",
           "smoke_variant"]
