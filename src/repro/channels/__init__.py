"""One Channel abstraction from the simulator to shard_map.

The repo grew three divergent channel implementations: the simulator's
``Enq``/``Deq`` FIFO state (``core/simulator.py``), the serve loop's
traced bounded queue (``runtime/serve_loop.py``), and the VMEM ring
(``kernels/ring.py``).  This package is the unification seam for the
host-level two: one protocol (:class:`ChannelBase` — name, capacity,
push/pop/peek/occupancy, tracer hooks) with pluggable transports:

  * :class:`LocalChannel`  — in-process deque (the serve loop's
    original channel, bit-identical semantics);
  * :class:`SimChannel`    — the simulator's timed FIFO (ready-time
    entries, Req/Resp/Enq/Deq conservation counters);
  * :class:`MeshChannel`   — a ``shard_map`` ring over a named mesh
    axis using ``jax.lax.ppermute`` (collective_permute): payloads
    physically travel from a source to a destination device.

All transports report occupancy through the same
:class:`repro.core.trace.Tracer` vocabulary (see ``base.py``), so a
serve trace, a DAE program trace, and a sharded-pipeline trace read
identically.  The device-kernel ring (``kernels/ring.py``) stays
separate: it lives in VMEM inside a Pallas grid, below the host
protocol boundary.

Migration note: ``runtime.serve_loop.Channel`` is now an alias of
:class:`LocalChannel`; import channels from ``repro.channels`` — see
docs/serving.md.
"""

from repro.channels.base import ChannelBase
from repro.channels.local import LocalChannel
from repro.channels.sim import SimChannel
from repro.channels.mesh import MeshChannel

__all__ = ["ChannelBase", "LocalChannel", "SimChannel", "MeshChannel"]
