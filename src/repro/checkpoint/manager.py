"""Checkpoint manager: retention, async writes, auto-resume.

The async writer is another instance of the decoupled pattern: the train
loop issues a snapshot request (host copy of the sharded state) and keeps
stepping; the writer thread is the Execute side draining a bounded queue.
"""

from __future__ import annotations

import queue
import re
import threading
from pathlib import Path
from typing import Any, Optional, Tuple

import jax

from repro.checkpoint.io import load_pytree, save_pytree

_STEP_RE = re.compile(r"step_(\d+)\.npz$")


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._error: Optional[BaseException] = None
        self._thread = None
        if async_write:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # -- write ---------------------------------------------------------------
    def _path(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}.npz"

    def save(self, step: int, state: Any, meta: Optional[dict] = None,
             block: bool = False) -> None:
        if self._error:
            raise RuntimeError("checkpoint writer failed") from self._error
        meta = dict(meta or {}, step=step)
        # snapshot to host NOW so the donated buffers can be reused
        host_state = jax.tree.map(lambda a: jax.device_get(a), state)
        if self.async_write and not block:
            self._q.put((step, host_state, meta))
        else:
            self._write(step, host_state, meta)

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                self._write(*item)
            except BaseException as e:  # surfaced on next save()
                self._error = e

    def _write(self, step: int, state: Any, meta: dict) -> None:
        save_pytree(self._path(step), state, meta)
        self._gc()

    def _gc(self) -> None:
        ckpts = sorted(self.dir.glob("step_*.npz"))
        for old in ckpts[:-self.keep]:
            old.unlink(missing_ok=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._q.join() if hasattr(self._q, "join") else None
        # drain by queueing a barrier
        while not self._q.empty():
            import time
            time.sleep(0.01)

    # -- read ----------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for p in self.dir.glob("step_*.npz"):
            m = _STEP_RE.search(p.name)
            if m:
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore_latest(self, like: Any, shardings: Any = None
                       ) -> Optional[Tuple[int, Any, dict]]:
        step = self.latest_step()
        if step is None:
            return None
        state, meta = load_pytree(self._path(step), like, shardings)
        return step, state, meta
