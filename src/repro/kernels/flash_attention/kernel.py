"""Block-streamed flash attention — decoupled KV fetch on TPU.

The DAE view (docs/architecture.md §"TPU adaptation"): the KV block
stream is the *Access* side — the request for block k+rif is issued
while the MXU consumes block k (decoupled request/response with the
buffer ring as the RIF window).  Online softmax is the Execute loop's
bounded state, the same role as Listing 4's ``state`` stream.

Variants:
  * ``flash`` — prefill: causal / sliding-window, GQA via head mapping.
    The KV stream is regular, so the Pallas pipeline's own BlockSpec
    double-buffering is the ring (RIF = 2).
  * ``flash_decode`` — one new token against a KV cache; the q-head
    group of a KV head is folded into MXU rows.  The K/V block streams
    are two explicit :class:`~repro.kernels.ring.RingChannel`\\ s of
    depth ``rif`` spanning the ``nk`` grid dimension
    (:func:`~repro.kernels.ring.ring_step`).
  * paged decode — same rings, but the scalar-prefetched page table
    supplies the block addresses: an irregular, data-dependent block
    gather (exactly ``dae_gather`` fused into attention).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ring import (RingChannel, clamp_rif,
                                ring_scratch_shapes, ring_step)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
                  bq: int, bk: int, nk: int, scale: float, causal: bool,
                  window: Optional[int], s_real: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0, 0].astype(jnp.float32)              # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)              # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)              # (BK, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = cols < s_real
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols >= rows - window + 1
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * alpha + p.sum(axis=1, keepdims=True)
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


def flash(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
          window: Optional[int], scale: float, s_real: int, bq: int, bk: int,
          interpret: bool = True) -> jax.Array:
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    nq, nk = sq // bq, sk // bk
    grid = (b, h, nq, nk)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk, scale=scale,
                               causal=causal, window=window, s_real=s_real)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Decode (contiguous and paged KV)
# ---------------------------------------------------------------------------


def _decode_step(len_ref, q_ref, o_ref, acc, m_s, l_s, k_blk, v_blk, *,
                 bk: int, nk: int, scale: float):
    """Online-softmax update for one (BK, D) K/V block pair — the Execute
    side shared by the contiguous and paged decode kernels."""
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = k_blk.astype(jnp.float32)                    # (BK, D)
    v = v_blk.astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(cols < len_ref[b], s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * alpha + p.sum(axis=1, keepdims=True)
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


def _decode_kernel(len_ref, q_ref, k_hbm, v_hbm, o_ref, acc, m_s, l_s,
                   kscr, ksem, vscr, vsem, *, bk: int, nk: int, rif: int,
                   scale: float):
    b = pl.program_id(0)
    h = pl.program_id(1)
    ki = pl.program_id(2)
    ring_k = RingChannel(kscr, ksem, rif,
                         src=lambda k: k_hbm.at[b, h, pl.ds(k * bk, bk), :])
    ring_v = RingChannel(vscr, vsem, rif,
                         src=lambda k: v_hbm.at[b, h, pl.ds(k * bk, bk), :])

    def execute(k_blk, v_blk):
        _decode_step(len_ref, q_ref, o_ref, acc, m_s, l_s, k_blk, v_blk,
                     bk=bk, nk=nk, scale=scale)

    ring_step([ring_k, ring_v], ki, nk, execute)


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 lengths: jax.Array, *, scale: float, bk: int, rif: int = 2,
                 interpret: bool = True) -> jax.Array:
    """q (B, KVH, G, D); caches (B, KVH, S, D); lengths (B,) int32.
    ``rif`` K/V block pairs stream ahead of the MXU consume."""
    b, kvh, g, d = q.shape
    s = k_cache.shape[2]
    nk = s // bk
    rif = clamp_rif(rif, nk)
    grid = (b, kvh, nk)

    kernel = functools.partial(_decode_kernel, bk=bk, nk=nk, rif=rif,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d), lambda b_, h_, k_, L: (b_, h_, 0, 0)),
                pl.BlockSpec(memory_space=pl.ANY),
                pl.BlockSpec(memory_space=pl.ANY),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda b_, h_, k_, L: (b_, h_, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, d), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                *ring_scratch_shapes(rif, (bk, d), k_cache.dtype),
                *ring_scratch_shapes(rif, (bk, d), v_cache.dtype),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)


def _paged_decode_kernel(len_ref, pt_ref, q_ref, k_hbm, v_hbm, o_ref,
                         acc, m_s, l_s, kscr, ksem, vscr, vsem, *, bk: int,
                         nk: int, rif: int, scale: float):
    # identical math to _decode_kernel; the scalar-prefetched page table
    # supplies the ring's addresses (the decoupled request stream)
    b = pl.program_id(0)
    h = pl.program_id(1)
    ki = pl.program_id(2)
    ring_k = RingChannel(kscr, ksem, rif,
                         src=lambda k: k_hbm.at[pt_ref[b, k], h])
    ring_v = RingChannel(vscr, vsem, rif,
                         src=lambda k: v_hbm.at[pt_ref[b, k], h])

    def execute(k_blk, v_blk):
        _decode_step(len_ref, q_ref, o_ref, acc, m_s, l_s, k_blk, v_blk,
                     bk=bk, nk=nk, scale=scale)

    ring_step([ring_k, ring_v], ki, nk, execute)


def flash_decode_paged(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       page_table: jax.Array, lengths: jax.Array, *,
                       scale: float, rif: int = 2,
                       interpret: bool = True) -> jax.Array:
    """q (B, KVH, G, D); pages (NP, KVH, PAGE, D); page_table (B, S/PAGE).

    The page table is the decoupled request stream: the K/V rings consume
    it ahead of the MXU — a data-dependent block gather fused into
    attention (dae_gather's addressing inside flash).
    """
    b, kvh, g, d = q.shape
    n_pages, _, page, _ = k_pages.shape
    npb = page_table.shape[1]
    rif = clamp_rif(rif, npb)
    grid = (b, kvh, npb)

    kernel = functools.partial(_paged_decode_kernel, bk=page, nk=npb,
                               rif=rif, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d), lambda b_, h_, k_, L, pt: (b_, h_, 0, 0)),
                pl.BlockSpec(memory_space=pl.ANY),
                pl.BlockSpec(memory_space=pl.ANY),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda b_, h_, k_, L, pt: (b_, h_, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, d), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                *ring_scratch_shapes(rif, (page, d), k_pages.dtype),
                *ring_scratch_shapes(rif, (page, d), v_pages.dtype),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        interpret=interpret,
    )(lengths, page_table, q, k_pages, v_pages)
