"""Decoupled row gather — the TPU realization of the paper's decoupled load.

Two variants, mirroring the two decoupling mechanisms described in
docs/architecture.md §"TPU adaptation":

* ``gather_pipelined`` — the *scalar-prefetch* form.  The index vector is
  prefetched to SMEM (`PrefetchScalarGridSpec`), so the Pallas pipeline's
  DMA-issue stage knows the HBM address of step *i*'s row several grid
  steps before the compute stage consumes it.  This is
  ``decouple_request`` (issue) / ``decouple_response`` (kernel body)
  with the buffer ring as the RIF window — Pallas double-buffers, so
  RIF=2 blocks in flight.

* ``gather_rif`` — the *manual multi-buffer DMA* form (Listing 4's RIF
  generalization), emitted through :mod:`repro.kernels.ring`: a
  :class:`~repro.kernels.ring.RingChannel` keeps ``rif`` async HBM→VMEM
  copies in flight, and :func:`~repro.kernels.ring.access_execute`
  generates the prologue/steady-state/drain structure.  Every request is
  matched by exactly one wait (the paper's §5.1 conservation rule,
  structurally enforced), and capacity is the ring depth — deadlock-free
  by construction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv
from repro.kernels.ring import RingChannel, access_execute, \
    ring_scratch_shapes


# ---------------------------------------------------------------------------
# Variant 1: scalar-prefetch pipelined gather
# ---------------------------------------------------------------------------


def _gather_block_kernel(idx_ref, table_ref, out_ref):
    # The response side: the block for row idx[i] has already been DMA'd
    # into VMEM by the pipeline; consuming it is a plain copy.
    out_ref[...] = table_ref[...]


def gather_pipelined(table: jax.Array, idx: jax.Array, *, block_d: int,
                     rows_per_step: int = 1, interpret: bool = True) -> jax.Array:
    """Gather ``table[idx]`` with one (rows_per_step, block_d) block per
    grid step.  ``idx`` must already be padded to a multiple of
    rows_per_step (ops.py handles that); indices must be pre-scaled to
    *block-row* units when rows_per_step > 1."""
    m = idx.shape[0]
    n, d = table.shape
    assert d % block_d == 0, (d, block_d)
    assert m % rows_per_step == 0
    grid = (m // rows_per_step, d // block_d)

    return pl.pallas_call(
        _gather_block_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((rows_per_step, block_d),
                             lambda i, j, idx_ref: (idx_ref[i], j)),
            ],
            out_specs=pl.BlockSpec((rows_per_step, block_d),
                                   lambda i, j, idx_ref: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, d), table.dtype),
        interpret=interpret,
    )(idx, table)


# ---------------------------------------------------------------------------
# Variant 2: manual multi-buffer DMA gather (explicit RIF)
# ---------------------------------------------------------------------------


def _gather_rif_kernel(idx_ref, table_hbm, out_ref, scratch, sems, *,
                       chunk: int, rif: int):
    """Process ``chunk`` rows per grid step with ``rif`` copies in flight.

    ring.request = decouple_request (async start on slot k % rif)
    ring.response + copy-out = decouple_response
    """
    c = pl.program_id(0)
    base = c * chunk

    ring = RingChannel(
        scratch, sems, rif,
        src=lambda k: table_hbm.at[pl.ds(idx_ref[base + k], 1), :])

    def execute(k, row):
        pl.store(out_ref, (pl.ds(k, 1), slice(None)), row)

    access_execute([ring], chunk, execute)


def gather_rif(table: jax.Array, idx: jax.Array, *, chunk: int = 64,
               rif: int = 8, interpret: bool = True) -> jax.Array:
    m = idx.shape[0]
    n, d = table.shape
    assert m % chunk == 0
    grid = (m // chunk,)

    kernel = functools.partial(_gather_rif_kernel, chunk=chunk, rif=rif)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec((chunk, d), lambda c, idx_ref: (c, 0)),
            scratch_shapes=[*ring_scratch_shapes(rif, (1, d), table.dtype)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, d), table.dtype),
        interpret=interpret,
    )(idx, table)
