"""Multi-instance scaling sweep: N tenants sharing one memory system.

For each benchmark the sweep runs N in {1, 2, 4, 8, 16, 32, 64}
concurrent instances against one shared memory model (shared port issue slots plus
a shared 64-entry outstanding-request budget — the §5.4 contention
regime) and reports:

  * ``cycles``         — makespan of the N-tenant run;
  * ``thr_per_inst``   — golden work items per cycle per tenant;
  * ``rel``            — throughput-per-instance relative to N=1
                         (the degradation curve);
  * ``occ=...``        — mean/max occupancy of the busiest channels
                         (pooled across tenants) from the trace
                         subsystem;
  * ``util=...``       — mean utilization of the shared port(s).

``--smoke`` shrinks the sweep to one benchmark x N in {1, 2} so CI can
exercise the engine on every push in seconds.

N=64 became affordable with the event-driven scheduler: the legacy
polling scheduler re-checks every process of every tenant on every
pass, so large-N cells were quadratic-ish in practice (see
``benchmarks/engine_bench.py`` for the measured event-vs-polling gap).
"""

from __future__ import annotations

from repro.core.workloads import MULTI_SHARED_PORTS, run_workload_multi

NS = (1, 2, 4, 8, 16, 32, 64)
SWEEP = (
    ("binsearch", "rhls_dec"),
    ("hashtable", "rhls_dec"),
    ("spmv", "rhls_dec"),
    ("mergesort_opt", "rhls_dec"),
)
SMOKE_SWEEP = (("hashtable", "rhls_dec"),)
SMOKE_NS = (1, 2)


def _occ_summary(trace, top: int = 3) -> str:
    occ = trace.channel_occupancy(merge_instances=True)
    busiest = sorted(occ.items(), key=lambda kv: -kv[1][0])[:top]
    return ",".join(f"{name}:{mean:.1f}/{mx}" for name, (mean, mx) in busiest)


def _util_summary(trace, ports, cycles) -> str:
    # mean utilization = issues / elapsed cycles: exact over idle gaps,
    # and correct for multi-pass runs where per-pass clocks restart at 0
    # (issues and cycles both accumulate across passes)
    out = []
    for port in ports:
        issues = trace.port_issues(port)
        if issues:
            out.append(f"{port}:{min(1.0, issues / max(1, cycles)):.2f}")
    return ",".join(out)


def run(csv_print, smoke: bool = False) -> dict:
    sweep = SMOKE_SWEEP if smoke else SWEEP
    ns = SMOKE_NS if smoke else NS
    results = {}
    for bench, config in sweep:
        base_thr = None
        for n in ns:
            rep = run_workload_multi(bench, config, n, scale="small",
                                     latency=100, rif=32,
                                     max_outstanding=64, trace=True)
            if not rep.correct:  # must fire even under python -O
                raise AssertionError(f"{bench}/{config}/n{n} incorrect")
            thr = rep.throughput_per_instance
            if base_thr is None:
                base_thr = thr
            rel = thr / base_thr if base_thr else 0.0
            results[(bench, config, n)] = rep
            csv_print(
                f"scale/{bench}/{config}/n{n},{rep.cycles},"
                f"thr_per_inst={thr:.5f};rel={rel:.3f};"
                f"occ={_occ_summary(rep.trace)};"
                f"util={_util_summary(rep.trace, MULTI_SHARED_PORTS[bench], rep.cycles)}")
    return results
