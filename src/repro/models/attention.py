"""Attention layers: GQA (bias/qk-norm/sliding-window options) and MLA.

Both run in three modes:
  * prefill (full sequence, causal or bidirectional) — flash kernel or ref;
  * decode (one token against a KV cache);
  * cross-attention (encoder-decoder).

The KV block stream of the flash kernel is the decoupled-load path
(docs/architecture.md §"TPU adaptation"); MLA caches the *compressed
latent* so the decoupled
fetch reads kv_lora_rank + rope_dim bytes per token instead of
2 * KVH * head_dim.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, dense_init, rmsnorm,
                                 rmsnorm_init, rope)
from repro.kernels.flash_attention.ops import (flash_attention, flash_decode,
                                               flash_decode_paged)
from repro.kernels.flash_attention.ref import (attention_banded,
                                               attention_chunked,
                                               attention_ref,
                                               decode_chunk_ref, decode_ref)


def _prefill_attention(cfg: ModelConfig, q, k, v, *, causal, window):
    """Dispatch: Pallas flash kernel / banded window / chunked online-
    softmax / naive S^2.  ``unroll`` follows cfg.scan_layers so the
    dry-run cost probes count every chunk."""
    if cfg.kernel_mode == "pallas":
        return flash_attention(q, k, v, causal=causal, window=window)
    unroll = not cfg.scan_layers
    if cfg.attn_impl == "banded" and window and causal:
        return attention_banded(q, k, v, window=window, causal=True,
                                chunk=min(cfg.attn_chunk, window),
                                unroll=unroll)
    if cfg.attn_impl in ("banded", "chunked"):
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 chunk=cfg.attn_chunk, unroll=unroll)
    return attention_ref(q, k, v, causal=causal, window=window)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    hd, h, kvh, d = cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    p = {
        "wq": dense_init(ks[0], d, h * hd, cfg.pdtype),
        "wk": dense_init(ks[1], d, kvh * hd, cfg.pdtype),
        "wv": dense_init(ks[2], d, kvh * hd, cfg.pdtype),
        "wo": dense_init(ks[3], h * hd, d, cfg.pdtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((kvh * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((kvh * hd,), cfg.pdtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, cfg.pdtype)
        p["k_norm"] = rmsnorm_init(hd, cfg.pdtype)
    return p


def _project_qkv(cfg: ModelConfig, p, x, positions):
    b, s, d = x.shape
    hd, h, kvh = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    dt = cfg.adtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q.transpose(0, 2, 1, 3), positions[:, None, :], cfg.rope_theta)
    k = rope(k.transpose(0, 2, 1, 3), positions[:, None, :], cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)
    return q, k, v      # (B, H, S, hd), (B, KVH, S, hd) x2


def gqa_apply(cfg: ModelConfig, p, x, positions, *, causal: bool = True,
              window: Optional[int] = None,
              cache: Optional[Dict[str, Any]] = None,
              valid: Optional[jnp.ndarray] = None,
              page_table: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]]]:
    """Prefill path when cache is None; decode path updates the cache.

    cache = {"k": (B,KVH,Smax,hd), "v": ..., "len": (B,) int32}
      or the paged layout
    cache = {"kp": (NP,KVH,PAGE,hd), "vp": ..., "len": (B,) int32}

    With a cache and S > 1 (or an explicit ``valid`` (B, S) mask) this is
    the *chunked cache-fill* path: the S new tokens of each batch row are
    scattered at its ``cache["len"]``-onward positions, query i attends
    the prefix through position len+i, and rows whose ``valid`` count is
    0 leave both cache and length untouched — the serving loop's Access
    (prefill-chunk) and Execute (masked decode) engines both land here.

    The paged layout stores KV in a shared pool of fixed-size pages;
    ``page_table`` (B, NPB) int32 maps each row's logical block i to a
    pool page.  Invalid-token scatters are routed to the reserved trash
    page 0 (see runtime.serve_loop.PageAllocator).  In ``pallas`` mode a
    single-token step drives ``flash_decode_paged``'s ring gather over
    the scalar-prefetched table; the ref path gathers the table back to
    a contiguous (B, KVH, NPB*PAGE, hd) view and reuses the exact
    contiguous oracle, so paged and contiguous decode are bit-identical
    whenever NPB*PAGE equals the contiguous s_max.
    """
    b, s, d = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)

    if cache is None:
        out = _prefill_attention(cfg, q, k, v, causal=causal, window=window)
        new_cache = None
    elif "kp" in cache:
        if page_table is None:
            raise ValueError("paged KV cache requires a page_table")
        pos = cache["len"]                                     # (B,)
        if valid is None:
            valid = jnp.ones((b, s), bool)
        kp = _pool_constraint(cfg, _scatter_chunk_pages(
            cache["kp"], k, pos, valid, page_table))
        vp = _pool_constraint(cfg, _scatter_chunk_pages(
            cache["vp"], v, pos, valid, page_table))
        lens = pos + valid.sum(-1).astype(pos.dtype)
        qlens = pos[:, None] + jnp.arange(1, s + 1, dtype=pos.dtype)[None]
        if s == 1 and cfg.kernel_mode == "pallas":
            out = flash_decode_paged(q[:, :, 0, :], kp, vp, page_table,
                                     qlens[:, 0])
            out = out[:, :, None, :]
        else:
            kc = _gather_pages(kp, page_table)
            vc = _gather_pages(vp, page_table)
            out = decode_chunk_ref(q, kc, vc, qlens)           # (B,H,S,hd)
        new_cache = {"kp": kp, "vp": vp, "len": lens}
    elif s == 1 and valid is None:
        pos = cache["len"]                                     # (B,)
        # scatter the new K/V at each batch row's position
        kc = _scatter_token(cache["k"], k, pos)
        vc = _scatter_token(cache["v"], v, pos)
        lens = pos + 1
        qd = q[:, :, 0, :]                                     # (B,H,hd)
        if cfg.kernel_mode == "pallas":
            out = flash_decode(qd, kc, vc, lens)
        else:
            out = decode_ref(qd, kc, vc, lens)
        if window is not None:
            pass  # window decode handled by length mask upstream for now
        out = out[:, :, None, :]                               # (B,H,1,hd)
        new_cache = {"k": kc, "v": vc, "len": lens}
    else:
        pos = cache["len"]                                     # (B,)
        if valid is None:
            valid = jnp.ones((b, s), bool)
        kc = _scatter_chunk(cache["k"], k, pos, valid)
        vc = _scatter_chunk(cache["v"], v, pos, valid)
        lens = pos + valid.sum(-1).astype(pos.dtype)
        # query i of row b sees cache positions < pos_b + i + 1 (window
        # decode stays length-masked, matching the single-token path)
        qlens = pos[:, None] + jnp.arange(1, s + 1, dtype=pos.dtype)[None]
        if s == 1 and cfg.kernel_mode == "pallas":
            # masked decode keeps the optimized decode kernel (masked
            # rows produce garbage that the caller never reads)
            out = flash_decode(q[:, :, 0, :], kc, vc, qlens[:, 0])
            out = out[:, :, None, :]
        else:
            out = decode_chunk_ref(q, kc, vc, qlens)           # (B,H,S,hd)
        new_cache = {"k": kc, "v": vc, "len": lens}

    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
    out = out @ p["wo"].astype(cfg.adtype)
    return out, new_cache


def _scatter_token(cache: jnp.ndarray, new: jnp.ndarray,
                   pos: jnp.ndarray) -> jnp.ndarray:
    """cache (B, KVH, Smax, hd); new (B, KVH, 1, hd); pos (B,)."""
    smax = cache.shape[2]
    onehot = (jnp.arange(smax)[None, :] == pos[:, None])       # (B, Smax)
    upd = onehot[:, None, :, None] * new.astype(cache.dtype)
    keep = jnp.where(onehot[:, None, :, None], 0, 1).astype(cache.dtype)
    return cache * keep + upd


def _scatter_chunk(cache: jnp.ndarray, new: jnp.ndarray, pos: jnp.ndarray,
                   valid: jnp.ndarray) -> jnp.ndarray:
    """cache (B, KVH, Smax, hd); new (B, KVH, C, hd); pos (B,);
    valid (B, C).  Chunk token i of row b lands at position pos_b + i;
    invalid tokens write nothing."""
    smax, c = cache.shape[2], new.shape[2]
    tgt = pos[:, None] + jnp.arange(c, dtype=pos.dtype)[None, :]   # (B, C)
    onehot = ((tgt[:, :, None] == jnp.arange(smax)[None, None, :])
              & valid[:, :, None])                             # (B, C, Smax)
    oh = onehot.astype(cache.dtype)
    upd = jnp.einsum("bcs,bkcd->bksd", oh, new.astype(cache.dtype))
    keep = (1 - oh.sum(1))[:, None, :, None]                   # (B,1,Smax,1)
    return cache * keep + upd


# paged KV helpers ------------------------------------------------------------


def _page_targets(page: int, npb: int, pos, valid):
    """(page id is resolved by the caller) logical block + offset of each
    of the C new tokens per row; invalid tokens are rerouted to block 0
    (the allocator's reserved trash page)."""
    c = valid.shape[1]
    tgt = pos[:, None] + jnp.arange(c, dtype=pos.dtype)[None, :]   # (B, C)
    blk = jnp.clip(tgt // page, 0, npb - 1)
    return blk, tgt % page


def _pool_constraint(cfg: ModelConfig, pages: jnp.ndarray) -> jnp.ndarray:
    """Sharded paged serving: keep the page pool's page dim (dim 0 of
    the per-layer (NP, ...) view) on ``cfg.mesh_pool_axis`` across the
    scatter, so jit propagation cannot re-replicate the pool after each
    update (the pool dominates serve memory).  Follows the
    ``_sp_constraint`` precedent in transformer.py — needs an ambient
    mesh when set."""
    if cfg.mesh_pool_axis is None:
        return pages
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        pages, P(cfg.mesh_pool_axis, *([None] * (pages.ndim - 1))))


def _scatter_chunk_pages(pages: jnp.ndarray, new: jnp.ndarray,
                         pos: jnp.ndarray, valid: jnp.ndarray,
                         page_table: jnp.ndarray) -> jnp.ndarray:
    """pages (NP, KVH, PAGE, hd); new (B, KVH, C, hd); pos (B,);
    valid (B, C); page_table (B, NPB) int32.  Valid token i of row b
    lands at offset (pos_b + i) % PAGE of page
    table[b, (pos_b + i) // PAGE]; invalid tokens land in page 0, whose
    contents are never attended (lengths mask them)."""
    page = pages.shape[2]
    kvh, hd = pages.shape[1], pages.shape[3]
    blk, off = _page_targets(page, page_table.shape[1], pos, valid)
    pg = jnp.where(valid, jnp.take_along_axis(page_table, blk, axis=1), 0)
    vals = new.transpose(0, 2, 1, 3).reshape(-1, kvh, hd)      # (B*C, KVH, hd)
    return pages.at[pg.reshape(-1), :, off.reshape(-1), :].set(
        vals.astype(pages.dtype))


def _scatter_vec_pages(pages: jnp.ndarray, new: jnp.ndarray,
                       pos: jnp.ndarray, valid: jnp.ndarray,
                       page_table: jnp.ndarray) -> jnp.ndarray:
    """pages (NP, PAGE, D); new (B, C, D) — the MLA latent variant."""
    page = pages.shape[1]
    blk, off = _page_targets(page, page_table.shape[1], pos, valid)
    pg = jnp.where(valid, jnp.take_along_axis(page_table, blk, axis=1), 0)
    return pages.at[pg.reshape(-1), off.reshape(-1), :].set(
        new.reshape(-1, new.shape[-1]).astype(pages.dtype))


def _gather_pages(pages: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """(NP, KVH, PAGE, hd), (B, NPB) -> contiguous (B, KVH, NPB*PAGE, hd)."""
    g = jnp.take(pages, page_table, axis=0)        # (B, NPB, KVH, PAGE, hd)
    b, npb, kvh, page, hd = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(b, kvh, npb * page, hd)


def _gather_vec_pages(pages: jnp.ndarray, page_table: jnp.ndarray
                      ) -> jnp.ndarray:
    """(NP, PAGE, D), (B, NPB) -> contiguous (B, NPB*PAGE, D)."""
    g = jnp.take(pages, page_table, axis=0)        # (B, NPB, PAGE, D)
    b, npb, page, d = g.shape
    return g.reshape(b, npb * page, d)


# cross attention (enc-dec) ---------------------------------------------------


def cross_attn_apply(cfg: ModelConfig, p, x, enc_kv, positions,
                     per_query: bool = False):
    """x (B,S,D) queries; enc_kv precomputed (k, v) (B,KVH,Senc,hd).

    ``per_query`` (serving's chunked cache-fill path) computes the S
    queries sequentially with S=1 shapes so the result is bit-identical
    to S single-token decode steps — see decode_chunk_ref for why."""
    b, s, d = x.shape
    hd, h = cfg.hd, cfg.n_heads
    dt = cfg.adtype
    q = (x @ p["wq"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k, v = enc_kv
    if per_query:
        out = jax.lax.map(
            lambda qi: _prefill_attention(cfg, qi[:, :, None], k, v,
                                          causal=False, window=None),
            q.transpose(2, 0, 1, 3))                   # (S,B,H,1,hd)
        out = out[:, :, :, 0].transpose(1, 2, 0, 3)    # (B,H,S,hd)
    else:
        out = _prefill_attention(cfg, q, k, v, causal=False, window=None)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return out @ p["wo"].astype(dt)


def cross_kv(cfg: ModelConfig, p, enc_out):
    b, se, d = enc_out.shape
    kvh, hd = cfg.n_kv_heads, cfg.hd
    dt = cfg.adtype
    k = (enc_out @ p["wk"].astype(dt))
    v = (enc_out @ p["wv"].astype(dt))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    k = k.reshape(b, se, kvh, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, se, kvh, hd).transpose(0, 2, 1, 3)
    return k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 / MiniCPM3 multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_init(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope, cfg.qk_rope_dim, cfg.v_hd
    r = cfg.kv_lora_rank
    p: Dict[str, Any] = {
        "w_dkv": dense_init(ks[0], d, r, cfg.pdtype),          # latent down
        "kv_norm": rmsnorm_init(r, cfg.pdtype),
        "w_uk": dense_init(ks[1], r, h * dn, cfg.pdtype),      # k up (nope)
        "w_uv": dense_init(ks[2], r, h * dv, cfg.pdtype),      # v up
        "w_kr": dense_init(ks[3], d, dr, cfg.pdtype),          # shared k rope
        "wo": dense_init(ks[4], h * dv, d, cfg.pdtype),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = dense_init(ks[5], d, cfg.q_lora_rank, cfg.pdtype)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, cfg.pdtype)
        p["w_uq"] = dense_init(ks[6], cfg.q_lora_rank, h * (dn + dr), cfg.pdtype)
    else:
        p["wq"] = dense_init(ks[7], d, h * (dn + dr), cfg.pdtype)
    return p


def _mla_q(cfg, p, x):
    b, s, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.qk_nope, cfg.qk_rope_dim
    dt = cfg.adtype
    if cfg.q_lora_rank:
        ql = rmsnorm(x @ p["w_dq"].astype(dt), p["q_norm"], cfg.norm_eps)
        q = ql @ p["w_uq"].astype(dt)
    else:
        q = x @ p["wq"].astype(dt)
    q = q.reshape(b, s, h, dn + dr)
    return q[..., :dn], q[..., dn:]            # nope (B,S,H,dn), rope (B,S,H,dr)


def mla_apply(cfg: ModelConfig, p, x, positions, *, causal: bool = True,
              cache: Optional[Dict[str, Any]] = None,
              valid: Optional[jnp.ndarray] = None,
              page_table: Optional[jnp.ndarray] = None):
    """MLA attention.  cache = {"ckv": (B,Smax,r), "kr": (B,Smax,dr),
    "len": (B,)} — the compressed-latent cache (the MLA memory win) —
    or the paged layout {"ckvp": (NP,PAGE,r), "krp": (NP,PAGE,dr),
    "len": (B,)} with a ``page_table`` (B, NPB): MLA pages the *latents*
    (the decoupled fetch reads r + dr bytes per token from the pool),
    gathers them contiguous, and up-projects exactly as the contiguous
    path does, so paged decode is bit-identical in both kernel modes
    whenever NPB*PAGE equals the contiguous s_max.
    S > 1 (or an explicit ``valid`` mask) with a cache is the chunked
    cache-fill path; see :func:`gqa_apply`."""
    b, s, d = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope, cfg.qk_rope_dim, cfg.v_hd
    r = cfg.kv_lora_rank
    dt = cfg.adtype

    q_nope, q_rope = _mla_q(cfg, p, x)
    q_rope = rope(q_rope.transpose(0, 2, 1, 3), positions[:, None, :],
                  cfg.rope_theta)                               # (B,H,S,dr)
    q_nope = q_nope.transpose(0, 2, 1, 3)                       # (B,H,S,dn)

    ckv = rmsnorm(x @ p["w_dkv"].astype(dt), p["kv_norm"], cfg.norm_eps)
    kr = rope((x @ p["w_kr"].astype(dt))[:, None, :, :],
              positions[:, None, :], cfg.rope_theta)            # (B,1,S,dr)

    paged = cache is not None and "ckvp" in cache
    chunked = cache is not None and not (s == 1 and valid is None)
    if paged:
        if page_table is None:
            raise ValueError("paged MLA cache requires a page_table")
        pos = cache["len"]
        if valid is None:
            valid = jnp.ones((b, s), bool)
        ckv_p = _pool_constraint(cfg, _scatter_vec_pages(
            cache["ckvp"], ckv, pos, valid, page_table))
        kr_p = _pool_constraint(cfg, _scatter_vec_pages(
            cache["krp"], kr[:, 0], pos, valid, page_table))
        lens = pos + valid.sum(-1).astype(pos.dtype)
        ckv_full = _gather_vec_pages(ckv_p, page_table)         # (B,Slog,r)
        kr_full = _gather_vec_pages(kr_p, page_table)[:, None]  # (B,1,Slog,dr)
        new_cache = {"ckvp": ckv_p, "krp": kr_p, "len": lens}
        s_kv = ckv_full.shape[1]
        chunked = True      # paged decode always takes the masked-chunk path
    elif cache is not None and not chunked:
        pos = cache["len"]
        ckv_c = _scatter_vec(cache["ckv"], ckv, pos)            # (B,Smax,r)
        kr_c = _scatter_vec(cache["kr"], kr[:, 0], pos)         # (B,Smax,dr)
        lens = pos + 1
        ckv_full, kr_full = ckv_c, kr_c[:, None]
        new_cache = {"ckv": ckv_c, "kr": kr_c, "len": lens}
        s_kv = ckv_c.shape[1]
    elif chunked:
        pos = cache["len"]
        if valid is None:
            valid = jnp.ones((b, s), bool)
        ckv_c = _scatter_vec_chunk(cache["ckv"], ckv, pos, valid)
        kr_c = _scatter_vec_chunk(cache["kr"], kr[:, 0], pos, valid)
        lens = pos + valid.sum(-1).astype(pos.dtype)
        ckv_full, kr_full = ckv_c, kr_c[:, None]
        new_cache = {"ckv": ckv_c, "kr": kr_c, "len": lens}
        s_kv = ckv_c.shape[1]
    else:
        ckv_full, kr_full = ckv, kr
        new_cache = None
        s_kv = s

    # up-project latents to per-head K/V (decode recomputes from latents —
    # the decoupled fetch reads only r + dr per token)
    k_nope = (ckv_full @ p["w_uk"].astype(dt)).reshape(b, s_kv, h, dn)
    v = (ckv_full @ p["w_uv"].astype(dt)).reshape(b, s_kv, h, dv)
    k_nope = k_nope.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_full, (b, h, s_kv, dr)).astype(dt)], -1)
    qk = jnp.concatenate([q_nope, q_rope], -1)                  # (B,H,S,dn+dr)

    if cache is None:
        out = _prefill_attention(cfg, qk, k, v_pad_to(v, k.shape[-1]),
                                 causal=causal, window=None)[..., :dv]
    elif chunked:
        qlens = pos[:, None] + jnp.arange(1, s + 1, dtype=pos.dtype)[None]
        if s == 1 and cfg.kernel_mode == "pallas":
            out = flash_decode(qk[:, :, 0, :], k, v_pad_to(v, k.shape[-1]),
                               qlens[:, 0])[..., :dv][:, :, None, :]
        else:
            out = decode_chunk_ref(qk, k, v_pad_to(v, k.shape[-1]),
                                   qlens)[..., :dv]            # (B,H,S,dv)
    else:
        qd = qk[:, :, 0, :]
        if cfg.kernel_mode == "pallas":
            out = flash_decode(qd, k, v_pad_to(v, k.shape[-1]),
                               new_cache["len"])[..., :dv]
        else:
            out = decode_ref(qd, k, v_pad_to(v, k.shape[-1]),
                             new_cache["len"])[..., :dv]
        out = out[:, :, None, :]

    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * dv)
    return out @ p["wo"].astype(dt), new_cache


def v_pad_to(v: jnp.ndarray, d: int) -> jnp.ndarray:
    """Pad value head dim to match k head dim for the fused kernel."""
    if v.shape[-1] == d:
        return v
    pad = [(0, 0)] * (v.ndim - 1) + [(0, d - v.shape[-1])]
    return jnp.pad(v, pad)


def _scatter_vec(cache: jnp.ndarray, new: jnp.ndarray,
                 pos: jnp.ndarray) -> jnp.ndarray:
    """cache (B, Smax, D); new (B, 1, D); pos (B,)."""
    smax = cache.shape[1]
    onehot = (jnp.arange(smax)[None, :] == pos[:, None])[..., None]
    return jnp.where(onehot, new.astype(cache.dtype), cache)


def _scatter_vec_chunk(cache: jnp.ndarray, new: jnp.ndarray,
                       pos: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """cache (B, Smax, D); new (B, C, D); pos (B,); valid (B, C)."""
    smax, c = cache.shape[1], new.shape[1]
    tgt = pos[:, None] + jnp.arange(c, dtype=pos.dtype)[None, :]   # (B, C)
    onehot = ((tgt[:, :, None] == jnp.arange(smax)[None, None, :])
              & valid[:, :, None])                             # (B, C, Smax)
    upd = jnp.einsum("bcs,bcd->bsd", onehot.astype(cache.dtype),
                     new.astype(cache.dtype))
    return jnp.where(onehot.any(1)[..., None], upd, cache)
