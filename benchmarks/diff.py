"""Regression gate: diff fresh ``BENCH_*.json`` against the baseline.

Usage (CI runs exactly this after ``python -m benchmarks.run matrix
--smoke``)::

    python -m benchmarks.diff [axes...] [--baseline-dir benchmarks/baseline]
        [--fresh-dir .] [--wall-pct N] [--allowlist benchmarks/diff_allowlist.txt]
        [--vcd-dir vcd_failures] [--update-baseline]

Behavior:

  * cycle counts, ``status`` and integer ``derived`` values diff
    **exactly** (the simulator is deterministic across machines);
  * warm wall-clock diffs within ``--wall-pct`` percent (CI passes a
    deliberately lenient band — wall time on shared runners is noise;
    the cycle gate is the tight one);
  * cells *removed* from the fresh run fail (coverage must not shrink
    silently); new cells are notes until the baseline is refreshed;
  * intentional changes go in the allowlist (fnmatch patterns against
    ``axis/cell-name``, one per line) or through ``--update-baseline``,
    which validates the fresh reports and copies them over the
    committed baseline;
  * a failing simulator cell that recorded ``replay`` info is re-run
    under :class:`repro.core.waveform.WaveformTracer` and its VCD
    waveform written to ``--vcd-dir`` (uploaded as a CI artifact), so a
    cycle regression arrives as a viewable waveform, not just a number.

Exit status: 0 clean (or baseline updated), 1 regressions, 2 usage or
missing/invalid report files.
"""

from __future__ import annotations

import argparse
import shutil
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.bench import (Finding, bench_path, diff_reports, load_report,
                         parse_allowlist, regressions)
from repro.bench.schema import SchemaError

REPO_ROOT = Path(__file__).resolve().parents[1]
DEFAULT_AXES = ("sim", "kernels", "compile", "serve")
DEFAULT_BASELINE = REPO_ROOT / "benchmarks" / "baseline"
DEFAULT_ALLOWLIST = REPO_ROOT / "benchmarks" / "diff_allowlist.txt"


def _load(path: Path, role: str):
    if not path.exists():
        print(f"error: {role} report {path} does not exist", file=sys.stderr)
        raise SystemExit(2)
    try:
        return load_report(path)
    except (SchemaError, ValueError) as e:
        print(f"error: {role} report {path} is invalid:\n{e}",
              file=sys.stderr)
        raise SystemExit(2)


def _dump_vcd(report: dict, finding: Finding, vcd_dir: Path) -> Path | None:
    """Re-run a failing simulator cell under a WaveformTracer."""
    cell = next((c for c in report["cells"] if c["name"] == finding.cell),
                None)
    if not cell or not cell.get("replay"):
        return None
    replay = cell["replay"]
    try:
        from repro.core.waveform import WaveformTracer
        from repro.core.workloads import run_workload
        from repro.core.simulator import DeadlockError
        tracer = WaveformTracer()
        try:
            run_workload(replay["benchmark"], replay["config"],
                         tracer=tracer, **replay.get("kwargs", {}))
        except DeadlockError:
            pass  # the partial waveform up to the deadlock is the point
        vcd_dir.mkdir(parents=True, exist_ok=True)
        out = vcd_dir / (finding.cell.replace("/", "_") + ".vcd")
        tracer.write_vcd(out, comment=f"{finding.axis}/{finding.cell}: "
                                      f"{finding.detail}")
        return out
    except Exception as e:  # a broken replay must not mask the diff result
        print(f"  (vcd replay of {finding.cell} failed: {e})",
              file=sys.stderr)
        return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.diff",
        description="diff fresh BENCH_*.json against the committed baseline")
    ap.add_argument("axes", nargs="*", default=None,
                    help=f"axes to diff (default: {' '.join(DEFAULT_AXES)})")
    ap.add_argument("--baseline-dir", type=Path, default=DEFAULT_BASELINE)
    ap.add_argument("--fresh-dir", type=Path, default=REPO_ROOT,
                    help="where the fresh run wrote its BENCH files")
    ap.add_argument("--wall-pct", type=float, default=25.0,
                    help="warm wall-clock regression gate, percent")
    ap.add_argument("--allowlist", type=Path, default=DEFAULT_ALLOWLIST)
    ap.add_argument("--vcd-dir", type=Path,
                    default=REPO_ROOT / "vcd_failures",
                    help="where failing sim cells dump VCD waveforms")
    ap.add_argument("--update-baseline", action="store_true",
                    help="validate fresh reports and copy them over the "
                         "baseline instead of diffing")
    args = ap.parse_args(argv)
    axes = tuple(args.axes) or DEFAULT_AXES

    if args.update_baseline:
        args.baseline_dir.mkdir(parents=True, exist_ok=True)
        for axis in axes:
            fresh_path = bench_path(axis, args.fresh_dir)
            _load(fresh_path, "fresh")  # schema-validate before promoting
            dst = bench_path(axis, args.baseline_dir)
            shutil.copyfile(fresh_path, dst)
            print(f"baseline updated: {dst.relative_to(REPO_ROOT)}")
        return 0

    allow = ()
    if args.allowlist.exists():
        allow = parse_allowlist(args.allowlist.read_text())

    any_regression = False
    for axis in axes:
        baseline = _load(bench_path(axis, args.baseline_dir), "baseline")
        fresh = _load(bench_path(axis, args.fresh_dir), "fresh")
        findings = diff_reports(baseline, fresh, wall_pct=args.wall_pct,
                                allowlist=allow)
        regs = regressions(findings)
        status = f"{len(regs)} regression(s)" if regs else "clean"
        print(f"== axis {axis}: {len(fresh['cells'])} cells, {status}")
        for f in findings:
            print("  " + f.render())
        for f in regs:
            if f.kind in ("cycles", "status"):
                out = _dump_vcd(fresh, f, args.vcd_dir)
                if out:
                    print(f"  waveform: {out.relative_to(REPO_ROOT)}")
        any_regression |= bool(regs)

    if any_regression:
        print("\nFAIL: benchmark regressions above. If intentional, refresh "
              "with:\n  PYTHONPATH=src python -m benchmarks.diff "
              "--update-baseline\nor add an allowlist pattern to "
              f"{DEFAULT_ALLOWLIST.name}.", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
