"""Pure-jnp oracle for the decoupled gather kernel."""

from __future__ import annotations

import jax.numpy as jnp


def gather_ref(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Gather rows of ``table`` (N, D) at ``idx`` (M,) -> (M, D)."""
    return jnp.take(table, idx, axis=0)
