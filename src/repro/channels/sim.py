"""Sim transport: the DAE simulator's timed channel FIFO.

Entries are ``(ready_time, value)`` pairs: a ``Req`` lands when the
memory system delivers it, an ``Enq`` becomes visible one cycle after
it is issued, and the engines' readiness oracles peek ``front_ready``
before committing a ``Resp``/``Deq``.  Both scheduler engines
(polling and event) mutate channel state exclusively through
:meth:`push_timed`/:meth:`pop_timed`, which also emit the shared
occupancy vocabulary (post-event depth — see ``base.py``), so the
simulator's golden traces and the serve loop's traces are produced by
the same code path.

The conservation counters (``reqs``/``resps``/``enqs``/``deqs``) back
the §5.1 request/response conservation check in
``DaeProgram``/``validate``; ``push_key``/``pop_key`` are the event
engine's wake keys, stored here so one dict lookup fetches FIFO and
keys together (the scheduler hot path).
"""

from __future__ import annotations

from collections import deque
from typing import Any, Optional, Tuple

from repro.channels.base import ChannelBase


class SimChannel(ChannelBase):
    """Timed FIFO with simulator semantics plus the shared protocol.

    The protocol surface (``push``/``pop``/``peek``) treats the channel
    as an immediate-delivery queue (ready at push time) so transport-
    generic code and tests can drive it; the engines use the timed
    surface directly.
    """

    __slots__ = ("fifo", "reqs", "resps", "enqs", "deqs",
                 "push_key", "pop_key")

    transport = "sim"

    def __init__(self, name: str = "", capacity: Optional[int] = None,
                 tracer=None, instance: str = "sim"):
        super().__init__(name, capacity, tracer, instance)
        self.fifo: "deque[Tuple[float, Any]]" = deque()  # (ready_time, value)
        self.reqs = 0
        self.resps = 0
        self.enqs = 0
        self.deqs = 0
        # event-engine wake keys, filled lazily by the scheduler
        self.push_key: Optional[Tuple] = None
        self.pop_key: Optional[Tuple] = None

    # -- timed engine surface ------------------------------------------------

    def push_timed(self, ready: float, value: Any, kind: str,
                   trace=None, instance: str = "", name: str = "",
                   t: float = 0.0) -> None:
        """Append an entry landing at ``ready``; ``kind`` is ``"req"``
        (memory response in flight) or ``"enq"`` (producer enqueue).
        Capacity is enforced by the engines' readiness oracles *before*
        the effect executes, not here."""
        self.fifo.append((ready, value))
        if kind == "req":
            self.reqs += 1
        else:
            self.enqs += 1
        if trace is not None:
            trace.on_occupancy(instance, name or self.name,
                               len(self.fifo), t)

    def pop_timed(self, kind: str, trace=None, instance: str = "",
                  name: str = "", t: float = 0.0) -> Any:
        """Take the front entry's value; ``kind`` is ``"resp"`` or
        ``"deq"``.  Readiness (front entry landed, FIFO non-empty) is
        the engines' responsibility."""
        _, value = self.fifo.popleft()
        if kind == "resp":
            self.resps += 1
        else:
            self.deqs += 1
        if trace is not None:
            trace.on_occupancy(instance, name or self.name,
                               len(self.fifo), t)
        return value

    @property
    def front_ready(self) -> float:
        """Ready time of the front entry (IndexError when empty)."""
        return self.fifo[0][0]

    # -- shared protocol surface ---------------------------------------------

    def push(self, item: Any) -> bool:
        if self.capacity is not None and len(self.fifo) >= self.capacity:
            return False
        self.push_timed(0.0, item, "enq", self.tracer, self.instance,
                        self.name)
        return True

    def pop(self) -> Any:
        return self.pop_timed("deq", self.tracer, self.instance, self.name)

    def peek(self) -> Any:
        return self.fifo[0][1]

    def __len__(self) -> int:
        return len(self.fifo)
