"""Sharding rules: param-path patterns -> PartitionSpecs.

Axes (launch/mesh.py):
  * ``pod``   — data parallel across pods (multi-pod mesh only)
  * ``data``  — data parallel + FSDP (params' non-model dim)
  * ``model`` — tensor parallel (heads / ffn / vocab / experts)

Rules are *hints*: the steps run under jit with sharding propagation, so
any rule is correct; these pick the communication pattern the roofline
sees.  Name conventions come from the layer params:

  column-parallel (output dim on model): wq wk wv w_gate w_up w_uq w_uk
      w_uv wkq... ; row-parallel (input dim on model): wo w_down
  experts (E, D, F): E on model (expert parallelism)
  embed (V, D): vocab on model; unembed (D, V): vocab on model
  everything 1-D / small: replicated
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    fsdp: bool = True            # shard params' other big dim over `data`
    seq_shard_cache: bool = True  # shard decode KV caches over `data` (SP)

    def dp_axes(self, mesh: Mesh):
        axes = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
        return axes if len(axes) > 1 else (axes[0] if axes else None)


# param names that are column-parallel (model on last/output dim)
_COL = ("wq", "wk", "wv", "w_gate", "w_up", "w_uq", "w_uk", "w_uv",
        "wr", "wg", "w_in", "w_dt", "w_lora_b", "w_bcdt_T")
# row-parallel (model on first/input dim)
_ROW = ("wo", "w_down", "w_out", "wv_chan")
# per-output-dim 1-D params
_COL_BIAS = ("bq", "bk", "bv", "conv_b", "dt_bias", "d_skip")


def _divisible(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0


def param_pspec(path: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh,
                rules: ShardingRules) -> P:
    name = path[-1] if path else ""
    stacked = 0
    # stacked-segment params have a leading layer dim; detect via rule kinds
    # by matching expected ndim below and prepending None as needed.

    def spec(*dims):
        dims = list(dims)
        # pad to shape rank with leading None (layer-stack dims)
        while len(dims) < len(shape):
            dims.insert(0, None)
        # drop shardings that do not divide
        out = []
        for size, d in zip(shape[-len(dims):] if len(dims) == len(shape)
                           else shape, dims):
            if d is None:
                out.append(None)
            elif isinstance(d, str):
                out.append(d if _divisible(size, mesh, d) else None)
            else:
                sub = tuple(a for a in d if a in mesh.axis_names)
                tot = 1
                for a in sub:
                    tot *= mesh.shape[a]
                out.append(d if (sub == d and size % tot == 0) else None)
        return P(*out)

    fs = "data" if rules.fsdp else None

    if name == "embed":
        return spec("model", fs)
    if name == "unembed":
        return spec(fs, "model")
    if name == "router":
        return spec(None, None)
    is_expert = ("moe" in path and "shared" not in path
                 and name in ("w_gate", "w_up", "w_down"))
    if is_expert:
        # expert tensors (E, D, F): expert parallelism
        return spec("model", fs, None)
    if name in _COL:
        return spec(fs, "model")
    if name in _ROW:
        return spec("model", fs)
    if name in _COL_BIAS:
        return spec("model")
    if name == "conv_w":
        return spec(None, "model")
    if name == "a_log":
        return spec("model", None)
    if name == "u_bonus":
        return spec("model", None)
    # norms, mixes, small latent projections: replicated
    return P(*([None] * len(shape)))


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(str(e.idx))
        else:
            names.append(str(e))
    return tuple(names)


def param_shardings(params_shape: Any, mesh: Mesh,
                    rules: Optional[ShardingRules] = None) -> Any:
    """Map a params pytree (of ShapeDtypeStructs or arrays) to
    NamedShardings."""
    rules = rules or ShardingRules()

    def f(path, leaf):
        ps = param_pspec(_path_names(path), leaf.shape, mesh, rules)
        return NamedSharding(mesh, ps)

    return jax.tree_util.tree_map_with_path(f, params_shape)


def batch_sharding(mesh: Mesh, ndim: int, rules: Optional[ShardingRules] = None
                   ) -> NamedSharding:
    """Shard the leading (batch) dim over pod x data."""
    rules = rules or ShardingRules()
    dp = rules.dp_axes(mesh)
    return NamedSharding(mesh, P(dp, *([None] * (ndim - 1))))


# paged KV pool leaves: stacked (layer_count, n_pages, ...); dim 1 is
# the page-pool dim, the unit the paged serve loop allocates/migrates
_PAGED_POOL = ("kp", "vp", "ckvp", "krp")


def cache_shardings(cache_shape: Any, mesh: Mesh,
                    rules: Optional[ShardingRules] = None,
                    batch: int = 0) -> Any:
    """KV caches: batch over pod+data when divisible, else sequence over
    data (sequence parallelism for long-context decode).  Paged pool
    leaves shard their page dim over ``data`` (pages are
    batch-agnostic, so the batch rule never applies to them) and fall
    back to replication — never sequence sharding, which would split
    inside a page."""
    rules = rules or ShardingRules()
    dp = rules.dp_axes(mesh)
    dp_size = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp_size *= mesh.shape[a]

    def f(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        if names and names[-1] in _PAGED_POOL and len(shape) >= 3:
            if _divisible(shape[1], mesh, "data"):
                return NamedSharding(
                    mesh, P(None, "data", *([None] * (len(shape) - 2))))
            return NamedSharding(mesh, P(*([None] * len(shape))))
        # leading dims: (layers, batch, ...) after stacking
        if len(shape) >= 3:
            b = shape[1]
            if b % dp_size == 0 and b > 0:
                return NamedSharding(mesh, P(None, dp, *([None] * (len(shape) - 2))))
            # sequence-parallel fallback: shard the time axis over data
            if names and names[-1] in ("k", "v") and len(shape) == 5:
                s = shape[3]
                if rules.seq_shard_cache and _divisible(s, mesh, "data"):
                    return NamedSharding(mesh, P(None, None, None, "data", None))
            if names and names[-1] in ("ckv", "kr") and len(shape) == 4:
                s = shape[2]
                if rules.seq_shard_cache and _divisible(s, mesh, "data"):
                    return NamedSharding(mesh, P(None, None, "data", None))
        return NamedSharding(mesh, P(*([None] * len(shape))))

    return jax.tree_util.tree_map_with_path(f, cache_shape)


def page_table_sharding(mesh: Mesh, batch: int,
                        rules: Optional[ShardingRules] = None
                        ) -> NamedSharding:
    """Page tables (B, npb) int32: batch over pod+data when divisible,
    else replicated (tables are tiny; replication is never wrong)."""
    rules = rules or ShardingRules()
    dp = rules.dp_axes(mesh)
    dp_size = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp_size *= mesh.shape[a]
    if dp is not None and batch > 0 and batch % dp_size == 0:
        return NamedSharding(mesh, P(dp, None))
    return NamedSharding(mesh, P(None, None))
