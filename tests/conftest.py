import os
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

# Smoke tests and benches must see ONE device (the dry-run alone forces
# 512 via its own first lines); make sure nothing leaks in.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest  # noqa: E402


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="rewrite tests/golden/*.json trace fixtures from the current "
             "scheduler instead of comparing against them")


@pytest.fixture
def update_golden(request):
    return request.config.getoption("--update-golden")


@pytest.fixture(autouse=True)
def _isolated_tune_cache(tmp_path, monkeypatch):
    """Kernel dispatchers consult the persistent tune cache on None
    knobs; point it at a per-test temp file so a developer's
    ~/.cache/repro/tune_cache.json never changes what the tests run."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune_cache.json"))
    from repro.tune import reset_default_cache
    reset_default_cache()
    yield
    reset_default_cache()


try:
    from hypothesis import settings, HealthCheck  # noqa: E402
except ImportError:  # property tests skip cleanly without hypothesis
    settings = None
else:
    settings.register_profile(
        "repro",
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large],
    )
    settings.load_profile("repro")
