"""Streaming trace subsystem for the DAE engine.

Dávila-Guzmán et al.'s analytical model and the dataflow template of
Cheng & Wawrzynek (PAPERS.md) both predict decoupled performance from
two quantities the simulator previously discarded: per-channel buffer
occupancy and shared-port contention.  This module captures exactly
those, as structured records that survive a JSON round trip:

  * **per-channel occupancy** — every enqueue/dequeue on a channel FIFO
    records the post-event depth; the summary keeps event count, sum and
    max, so mean/max occupancy (the §5.4 buffer-sizing signal) come out
    without storing the full timeline;
  * **request-latency histograms** — per channel, the issue-to-land
    latency of each ``Req`` bucketed into powers of two (a coalesced or
    cached MOMS hit lands in a low bucket, a row miss behind a full
    outstanding-request budget in a high one);
  * **port-utilization timelines** — per memory port, issue events
    (reads and writes) counted into fixed-width time bins; utilization
    is issues per bin over the bin width, 1.0 meaning the port's
    one-request-per-cycle slot never idled.

Overhead discipline: the engine holds ``tracer=None`` by default and
guards every hook behind a single ``is not None`` check, so a run with
tracing disabled does no per-event work at all.  With tracing enabled
each hook is O(1) dict arithmetic (no allocation proportional to the
run length unless the run itself is long).

Channel and port keys are instance-qualified as ``"tenant/name"`` when
the engine runs more than one program instance (the empty instance name
of a plain :func:`repro.core.simulator.simulate` call keeps the bare
name), so multi-tenant traces separate per tenant while shared ports
aggregate all tenants' traffic under the one physical port name.

Traces are *scheduler-invariant*: the event-driven engine and the
legacy polling oracle drive these hooks with identical event streams
(same order, same timestamps), so a :class:`TraceSummary` is comparable
across engines byte-for-byte — ``tests/test_parity.py`` pins that, and
``tests/golden/*.json`` pins one summary per workload against
accidental timing-model drift (refresh via ``pytest --update-golden``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

__all__ = ["ChannelStats", "TraceSummary", "Tracer", "pow2_bucket"]


def pow2_bucket(latency: float) -> int:
    """Smallest power of two >= ``latency`` (floor 1): histogram bucket."""
    n = max(1, int(-(-latency // 1)))
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class ChannelStats:
    """Occupancy + request-latency statistics for one channel."""

    events: int = 0          # enq/deq/req/resp events observed
    occ_sum: int = 0         # sum of post-event FIFO depths
    occ_max: int = 0         # peak FIFO depth
    latency_hist: Dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def occ_mean(self) -> float:
        return self.occ_sum / self.events if self.events else 0.0

    @property
    def requests(self) -> int:
        return sum(self.latency_hist.values())

    def to_json(self) -> Dict:
        return {
            "events": self.events,
            "occ_sum": self.occ_sum,
            "occ_max": self.occ_max,
            "latency_hist": {str(k): v for k, v in
                             sorted(self.latency_hist.items())},
        }

    @classmethod
    def from_json(cls, d: Dict) -> "ChannelStats":
        return cls(events=int(d["events"]), occ_sum=int(d["occ_sum"]),
                   occ_max=int(d["occ_max"]),
                   latency_hist={int(k): int(v)
                                 for k, v in d.get("latency_hist", {}).items()})


@dataclasses.dataclass
class TraceSummary:
    """Everything a trace run collected, JSON-round-trippable.

    ``channels`` maps instance-qualified channel names to
    :class:`ChannelStats`; ``ports`` maps port names to
    ``{bin_index: issue_count}`` timelines with ``bin_cycles``-wide bins.
    """

    bin_cycles: int
    channels: Dict[str, ChannelStats]
    ports: Dict[str, Dict[int, int]]

    def utilization(self, port: str) -> List[Tuple[int, float]]:
        """``(bin_start_cycle, fraction_of_issue_slots_used)`` per bin.

        Only bins that saw at least one issue appear (the store is
        sparse); a whole-run mean must therefore be computed as
        ``port_issues(port) / elapsed_cycles``, not by averaging these
        fractions — averaging skips idle bins and overstates load.
        """
        bins = self.ports.get(port, {})
        return [(b * self.bin_cycles, min(1.0, c / self.bin_cycles))
                for b, c in sorted(bins.items())]

    def port_issues(self, port: str) -> int:
        """Total issue events (reads + writes) recorded on ``port``."""
        return sum(self.ports.get(port, {}).values())

    def channel_occupancy(self, merge_instances: bool = False
                          ) -> Dict[str, Tuple[float, int]]:
        """``{channel: (mean_occupancy, max_occupancy)}``.

        With ``merge_instances`` the per-tenant qualifier is stripped and
        stats for the same base channel name are pooled — the view the
        ``benchmarks.scale`` sweep reports.
        """
        out: Dict[str, List[ChannelStats]] = {}
        for name, cs in self.channels.items():
            base = name.rsplit("/", 1)[-1] if merge_instances else name
            out.setdefault(base, []).append(cs)
        return {
            name: (
                sum(c.occ_sum for c in group)
                / max(1, sum(c.events for c in group)),
                max(c.occ_max for c in group),
            )
            for name, group in out.items()
        }

    def to_json(self) -> Dict:
        return {
            "bin_cycles": self.bin_cycles,
            "channels": {k: v.to_json()
                         for k, v in sorted(self.channels.items())},
            "ports": {p: {str(b): c for b, c in sorted(bins.items())}
                      for p, bins in sorted(self.ports.items())},
        }

    @classmethod
    def from_json(cls, d: Dict) -> "TraceSummary":
        return cls(
            bin_cycles=int(d["bin_cycles"]),
            channels={k: ChannelStats.from_json(v)
                      for k, v in d.get("channels", {}).items()},
            ports={p: {int(b): int(c) for b, c in bins.items()}
                   for p, bins in d.get("ports", {}).items()},
        )


class Tracer:
    """Streaming collector the engine calls into; cheap enough to leave
    on for multi-million-cycle runs, absent entirely when disabled."""

    def __init__(self, bin_cycles: int = 64):
        if bin_cycles < 1:
            raise ValueError("bin_cycles must be >= 1")
        self.bin_cycles = bin_cycles
        self._channels: Dict[str, ChannelStats] = {}
        self._ports: Dict[str, Dict[int, int]] = {}

    # -- hooks (called from the engine's execute path) ----------------------

    def _chan(self, instance: str, channel: str) -> ChannelStats:
        key = f"{instance}/{channel}" if instance else channel
        cs = self._channels.get(key)
        if cs is None:
            cs = self._channels[key] = ChannelStats()
        return cs

    def _port_issue(self, port: str, t: float) -> None:
        bins = self._ports.get(port)
        if bins is None:
            bins = self._ports[port] = {}
        b = int(t // self.bin_cycles)
        bins[b] = bins.get(b, 0) + 1

    def on_request(self, instance: str, channel: str, port: str,
                   t_issue: float, t_done: float) -> None:
        cs = self._chan(instance, channel)
        bucket = pow2_bucket(t_done - t_issue)
        cs.latency_hist[bucket] = cs.latency_hist.get(bucket, 0) + 1
        self._port_issue(port, t_issue)

    def on_occupancy(self, instance: str, channel: str,
                     depth: int, t: float = 0.0) -> None:
        # ``t`` is the scheduler time of the enq/deq/req/resp event that
        # changed the depth; the summary aggregates are time-free, but
        # subclasses (repro.core.waveform.WaveformTracer) keep the full
        # (t, depth) timeline for per-cycle checks and VCD export.
        cs = self._chan(instance, channel)
        cs.events += 1
        cs.occ_sum += depth
        if depth > cs.occ_max:
            cs.occ_max = depth

    def on_store(self, instance: str, port: str, t_issue: float) -> None:
        self._port_issue(port, t_issue)

    # -- results ------------------------------------------------------------

    def summary(self) -> TraceSummary:
        return TraceSummary(bin_cycles=self.bin_cycles,
                            channels=dict(self._channels),
                            ports={p: dict(b)
                                   for p, b in self._ports.items()})
