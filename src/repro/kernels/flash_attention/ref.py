"""Pure-jnp oracle for flash attention (prefill + decode)."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """q (B,H,S,D); k,v (B,KVH,S,D); GQA by head repetition."""
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols >= rows - window + 1
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jnp.exp(logits - logits.max(-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def attention_chunked(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None,
                      scale: Optional[float] = None,
                      chunk: int = 1024, unroll: bool = False) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure XLA: scans KV chunks
    so no (S, S) tensor is ever materialized.  This is the beyond-paper
    §Perf lever for the dry-run (the Pallas flash kernel implements the
    same schedule with explicit DMA decoupling on real TPU).

    ``unroll=True`` replaces the lax.scan with a python loop so the
    dry-run cost probes count every chunk (XLA counts scan bodies once).
    """
    import jax

    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32) * scale
    chunk = min(chunk, sk)
    while sk % chunk:
        chunk -= 1
    nk = sk // chunk
    kc = k.reshape(b, h, nk, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, h, nk, chunk, d).transpose(2, 0, 1, 3, 4)
    rows = jnp.arange(sq)[:, None]

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        ki, kblk, vblk = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kblk.astype(jnp.float32))
        cols = ki * chunk + jnp.arange(chunk)[None, :]
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols >= rows - window + 1
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (jnp.full((b, h, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, h, sq, d), jnp.float32))
    if unroll:
        carry = init
        for ki in range(nk):
            carry, _ = step(carry, (jnp.asarray(ki), kc[ki], vc[ki]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(step, init,
                                      (jnp.arange(nk), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def attention_banded(q, k, v, *, window: int, causal: bool = True,
                     scale: Optional[float] = None, chunk: int = 1024,
                     unroll: bool = False) -> jnp.ndarray:
    """Sliding-window attention that only TOUCHES the band.

    For each q chunk [iC, iC+C), the causal window [row-W+1, row] lies in
    the fixed-width KV slice [iC+C-1-W+1-(C-1), iC+C) -> width W+C.  Per-
    chunk cost is C x (W+C): total S(W+C) instead of S^2 — both FLOPs and
    HBM bytes drop by ~S/(W+C).  This is the banded §Perf lever for the
    long-context window archs (hymba)."""
    import jax

    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    scale = scale if scale is not None else d ** -0.5
    chunk = min(chunk, sq)
    while sq % chunk:
        chunk -= 1
    nq = sq // chunk
    band = window + chunk          # fixed slice width
    # left-pad K/V so every band slice is in bounds
    pad = ((0, 0), (0, 0), (band - chunk, 0), (0, 0))
    kp = jnp.pad(k, pad)
    vp = jnp.pad(v, pad)
    qc = q.reshape(b, h, nq, chunk, d)

    def one_chunk(i):
        qi = (qc[:, :, i] if isinstance(i, int)
              else jax.lax.dynamic_index_in_dim(qc, i, 2, keepdims=False))
        start = (i * chunk if isinstance(i, int)
                 else i * chunk)           # padded start of the band
        kb = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=2)
        s = jnp.einsum("bhqd,bhkd->bhqk", qi.astype(jnp.float32) * scale,
                       kb.astype(jnp.float32))
        rows = i * chunk + jnp.arange(chunk)[:, None]          # global row
        cols = (start - (band - chunk)) + jnp.arange(band)[None, :]
        mask = cols >= 0
        if causal:
            mask &= cols <= rows
        mask &= cols >= rows - window + 1
        s = jnp.where(mask[None, None], s, NEG_INF)
        m = s.max(-1, keepdims=True)
        p = jnp.exp(s - m)
        out = jnp.einsum("bhqk,bhkd->bhqd", p, vb.astype(jnp.float32))
        return out / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)

    if unroll:
        outs = [one_chunk(i) for i in range(nq)]
        out = jnp.stack(outs, axis=2)
    else:
        out = jax.lax.map(lambda i: one_chunk(i), jnp.arange(nq))
        out = jnp.moveaxis(out, 0, 2)
    return out.reshape(b, h, sq, d).astype(q.dtype)


def decode_chunk_ref(q, k_cache, v_cache, lengths, *,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """Multi-query decode against a KV cache: the chunked-prefill oracle.

    q (B,H,C,D) — C new queries per batch row; caches (B,KVH,S,D);
    lengths (B,C) — per-query visible prefix (query i of row b attends
    cache positions < lengths[b, i]).

    Deliberately a sequential ``lax.map`` of :func:`decode_ref` over the
    C queries rather than one (C, S) GEMM: XLA's accumulation order
    depends on the GEMM shape, and the serving parity tests pin chunked
    prefill BIT-IDENTICAL to a run of single-token decode steps.  FLOPs
    are identical either way; only the K/V re-reads differ, which the
    ref oracle does not model.
    """
    import jax

    out = jax.lax.map(
        lambda ql: decode_ref(ql[0], k_cache, v_cache, ql[1], scale=scale),
        (q.transpose(2, 0, 1, 3), lengths.T))                  # (C,B,H,D)
    return out.transpose(1, 2, 0, 3)                           # (B,H,C,D)


def decode_ref(q, k_cache, v_cache, lengths, *,
               scale: Optional[float] = None) -> jnp.ndarray:
    """q (B,H,D); caches (B,KVH,S,D); lengths (B,) valid prefix lengths."""
    b, h, d = q.shape
    kvh, s = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    kc = jnp.repeat(k_cache, g, axis=1) if g > 1 else k_cache
    vc = jnp.repeat(v_cache, g, axis=1) if g > 1 else v_cache
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                        kc.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, :] < lengths[:, None]          # (B, S)
    logits = jnp.where(mask[:, None, :], logits, NEG_INF)
    p = jnp.exp(logits - logits.max(-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhk,bhkd->bhd", p, vc.astype(jnp.float32)).astype(q.dtype)
