"""The four assigned input shapes (LM transformer: seq_len x global_batch).

decode_* / long_* lower ``serve_step`` (one new token against a KV cache
of seq_len), NOT ``train_step``.  long_500k requires sub-quadratic
attention — skipped for pure full-attention archs (docs/architecture.md
§"Model families and input shapes").
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# archs whose attention is strictly full/quadratic skip long_500k
SUBQUADRATIC_ARCHS = ("hymba-1.5b", "rwkv6-1.6b")


def long_context_ok(arch: str) -> bool:
    return arch in SUBQUADRATIC_ARCHS
