"""Generic ring kernels that `repro.compile` lowers programs onto.

These sit beside the hand-written families (``dae_gather``,
``dae_chase``, ...) but are *shape-generic*: the compiler instantiates
them from an elaborated :class:`~repro.compile.ir.DaeIR` instead of a
human writing a kernel per workload.
"""

from repro.kernels.compiled.kernel import ring_chase, ring_deref, \
    ring_gather

__all__ = ["ring_gather", "ring_deref", "ring_chase"]
