"""The shared explicit-decoupling emitter for Pallas TPU kernels.

This module is the TPU-side twin of the simulator's programming model in
:mod:`repro.core.dae` (paper §3): one place that knows how to emit the
Listing-4 ring — a ``rif``-deep rotating VMEM scratch with per-slot DMA
semaphores — so individual kernels declare *what* they fetch, not *how*
the prologue/steady-state/drain loops are shaped.

Vocabulary map (simulator IR ↔ TPU emitter):

  ====================  =========================================
  ``decouple_request``  :meth:`RingChannel.request` (async start)
  ``decouple_response`` :meth:`RingChannel.response` (wait + read)
  channel capacity      the ring depth ``rif``
  Access loop           the request stream (prologue + reissues)
  Execute loop          the ``execute`` callback
  ====================  =========================================

The paper's §5.1 conservation rules hold *structurally*: the two loop
scaffolds below issue exactly one :meth:`~RingChannel.request` and one
:meth:`~RingChannel.response` per sequence index ``k`` in ``[0, n)``
(requests never run more than ``rif`` ahead of responses, so capacity
is bounded by construction — the deadlock-freedom argument of §5.4).
Both scaffolds generate the same three-phase structure:

  * **prologue** — fill the ring: request ``k = 0 .. min(rif, n)``;
  * **steady state** — for each ``k``: wait ``k``, consume it, request
    ``k + rif`` (the Access loop running ``rif`` ahead of Execute);
  * **drain** — implicit: no request is issued for ``k + rif >= n``,
    so the last ``min(rif, n)`` responses empty the ring.

Two emission forms cover every kernel in ``repro.kernels``:

  * :func:`access_execute` — the whole loop lives inside one grid step
    (``fori_loop``); used when a grid step owns a *chunk* of the request
    stream (``dae_gather``'s explicit-RIF variant, both ``dae_chase``
    kernels).
  * :func:`ring_step` — the loop spans grid steps along the innermost
    grid dimension; Pallas TPU scratch persists across grid iterations,
    so step ``i`` waits on the copy that step ``i - rif`` started
    (``dae_merge``, ``dae_spmv``'s vec-tile fetch, ``flash_decode``'s
    K/V streams).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence, Tuple

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["RingChannel", "ring_scratch_shapes", "clamp_rif",
           "access_execute", "ring_step"]


def clamp_rif(rif: int, n: int) -> int:
    """Clamp a requested ring depth to the request-stream length: a ring
    deeper than the stream never fills (its tail slots would hold copies
    no response ever waits on), and depth 0 cannot make progress."""
    return max(1, min(rif, n))


def ring_scratch_shapes(rif: int, item_shape: Tuple[int, ...], dtype
                        ) -> Tuple[Any, Any]:
    """The ``scratch_shapes`` pair backing one :class:`RingChannel`:
    a ``(rif, *item_shape)`` VMEM ring plus its per-slot DMA semaphores.
    Unpack into ``pl.pallas_call``'s ``scratch_shapes`` list."""
    if rif < 1:
        raise ValueError(f"ring depth must be >= 1, got rif={rif}")
    return (pltpu.VMEM((rif, *item_shape), dtype),
            pltpu.SemaphoreType.DMA((rif,)))


@dataclasses.dataclass(frozen=True)
class RingChannel:
    """A capacity-``rif`` decoupled-load channel inside a kernel body.

    ``scratch``/``sems`` are the kernel refs allocated via
    :func:`ring_scratch_shapes`; ``src`` maps a sequence index ``k`` to
    the HBM ref slice to fetch (the Access loop's address stream — e.g.
    a scalar-prefetched index, a merge-path split, or a pointer read
    back out of kernel state).  ``src(k)`` must return a ref of exactly
    ``scratch.shape[1:]``.

    ``request``/``response`` map 1:1 onto the paper's
    ``decouple_request``/``decouple_response``: a request starts the
    async HBM→VMEM copy into slot ``k % rif``, a response waits on that
    slot's semaphore and returns the landed value.  Because the wait
    rebuilds the same copy descriptor, a response cannot be paired with
    any request but ``k``'s — the §5.1 one-request/one-response rule is
    not a convention here, it is the only thing the API can express.
    """

    scratch: Any
    sems: Any
    rif: int
    src: Callable[[Any], Any]

    def __post_init__(self) -> None:
        depth = self.scratch.shape[0]
        if depth != self.rif:
            raise ValueError(
                f"ring scratch holds {depth} slots but rif={self.rif}; "
                f"allocate via ring_scratch_shapes(rif, ...)")

    def slot(self, k: Any) -> Any:
        return jax.lax.rem(k, self.rif)

    def _copy(self, k: Any):
        s = self.slot(k)
        return pltpu.make_async_copy(self.src(k), self.scratch.at[s],
                                     self.sems.at[s])

    def request(self, k: Any) -> None:
        """``decouple_request``: start the async copy for index ``k``."""
        self._copy(k).start()

    def response(self, k: Any) -> Any:
        """``decouple_response``: wait for index ``k``'s copy and return
        the landed value (shape ``scratch.shape[1:]``)."""
        self._copy(k).wait()
        return self.scratch[self.slot(k)]


def _prologue(rings: Sequence[RingChannel], n: int) -> None:
    for r in rings:
        def issue(k, _, r=r):
            r.request(k)
            return 0
        jax.lax.fori_loop(0, min(r.rif, n), issue, 0)


def _reissue(rings: Sequence[RingChannel], k: Any, n: int) -> None:
    for r in rings:
        @pl.when(k + r.rif < n)
        def _(r=r):
            r.request(k + r.rif)


def access_execute(rings: Sequence[RingChannel], n: int,
                   execute: Callable[..., None]) -> None:
    """Emit a complete access/execute loop over ``n`` sequence indices
    inside the current grid step.

    ``execute(k, *values)`` receives one landed value per ring, in ring
    order, after every ring's response for ``k``; requests for
    ``k + rif`` are issued *after* ``execute`` returns, so an execute
    that writes the address state consumed by ``src`` (the dependent-
    load pattern of ``dae_chase``) observes its own updates exactly one
    ring-depth later — the same ordering the simulator's round-robin
    chase scheduler guarantees.
    """
    rings = tuple(rings)
    _prologue(rings, n)

    def consume(k, _):
        vals = tuple(r.response(k) for r in rings)
        execute(k, *vals)
        _reissue(rings, k, n)
        return 0

    jax.lax.fori_loop(0, n, consume, 0)


def ring_step(rings: Sequence[RingChannel], i: Any, n: int,
              execute: Callable[..., None]) -> None:
    """Emit one grid step of an access/execute loop that spans the
    innermost grid dimension: call with ``i = pl.program_id(innermost)``
    and ``n`` = that dimension's extent.

    Relies on Pallas TPU semantics: scratch (and therefore the ring and
    its semaphores) persists across grid iterations, so the copy started
    here for ``i + rif`` is the one step ``i + rif`` waits on.  When the
    innermost dimension restarts (an outer grid index advanced), ``i``
    is 0 again and the prologue refills the ring — the previous
    sequence's requests were fully drained because no request is ever
    issued for an index ``>= n``.
    """
    rings = tuple(rings)

    @pl.when(i == 0)
    def _():
        _prologue(rings, n)

    vals = tuple(r.response(i) for r in rings)
    execute(*vals)
    _reissue(rings, i, n)
