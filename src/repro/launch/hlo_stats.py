"""Parse collective ops + payload bytes out of compiled HLO text.

``collective_bytes`` is not in cost_analysis, so we scan the HLO for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instructions, sum their result payload bytes, and model per-device link
traffic with the standard ring formulas:

  all-reduce       2 * S * (g-1)/g        (S = payload bytes)
  all-gather       S * (g-1)/g            (S = gathered result bytes)
  reduce-scatter   S * (g-1)/g            (S = input bytes ~ result * g)
  all-to-all       S * (g-1)/g
  collective-permute  S
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    bsz = _DTYPE_BYTES.get(dtype)
    if bsz is None:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * bsz


def _line_collective(line: str):
    """Return (op, payload_bytes, group_size) or None."""
    stripped = line.strip()
    m = re.search(r"=\s*(.*?)\s+(" + "|".join(_COLLECTIVES) +
                  r")(-start|-done)?\(", stripped)
    if not m:
        return None
    result_types, op, phase = m.group(1), m.group(2), m.group(3)
    if phase == "-done":
        return None  # counted at -start
    payload = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result_types))
    g = 1
    mg = _GROUPS_RE.search(stripped)
    if mg:
        g = int(mg.group(2))
    else:
        mg2 = _GROUPS_LIST_RE.search(stripped)
        if mg2:
            first = mg2.group(1).split("}")[0].split("{")[-1]
            g = max(1, len([x for x in first.split(",") if x.strip()]))
    return op, payload, g


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Aggregate payload + ring-model per-device link bytes by op kind."""
    out: Dict[str, Dict[str, float]] = {}
    total_link = 0.0
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        parsed = _line_collective(line)
        if parsed is None:
            continue
        op, payload, g = parsed
        if op == "all-reduce":
            link = 2 * payload * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            link = payload * (g - 1)  # result bytes * (g-1) ~ input*(g-1)/g
        elif op == "collective-permute":
            link = float(payload)
        else:  # all-gather, all-to-all
            link = payload * (g - 1) / max(g, 1)
        d = out.setdefault(op, {"count": 0, "payload_bytes": 0.0,
                                "link_bytes": 0.0})
        d["count"] += 1
        d["payload_bytes"] += payload
        d["link_bytes"] += link
        total_link += link
    out["_total"] = {"count": sum(d["count"] for k, d in out.items()
                                  if not k.startswith("_")),
                     "payload_bytes": sum(d["payload_bytes"]
                                          for k, d in out.items()
                                          if not k.startswith("_")),
                     "link_bytes": total_link}
    return out


def count_ops(hlo_text: str, names: Tuple[str, ...] = ("fusion", "custom-call",
                                                       "while", "dot",
                                                       "convolution")):
    counts = {}
    for n in names:
        counts[n] = len(re.findall(rf"\b{n}\(", hlo_text))
    return counts
