"""Serving benchmark: decoupled Access/Execute pipeline vs the coupled
legacy loop.

Sweeps batch_slots x prompt-length mixes x model archetypes (dense,
moe, rwkv, hymba hybrid) on CPU/interpret and reports, per cell:

  * ``tok_s``     — generated tokens per second of the decoupled loop;
  * ``legacy``    — the same workload through the coupled loop (which
                    prefills one token per full-batch step);
  * ``speedup``   — tok_s over legacy;
  * ``ttft_ms``   — mean / p95 time-to-first-token of the decoupled
                    loop (the latency the chunked interleave protects);
  * ``occ``       — mean/max occupancy of the serve channels (admit,
                    prefill_done, free_slots) from the trace subsystem.

A parity cell per arch (one slot, one request — the only regime where
the legacy loop computes correct logits) asserts the two loops'
greedy outputs are bit-identical, and the slots=8 mixed cell gates the
decoupled loop at >= 5x legacy tokens/s (the ISSUE 4 acceptance bar).
``--smoke`` shrinks the sweep to the dense arch so CI exercises the
gate on every push in seconds.
"""

from __future__ import annotations

import time

import numpy as np

MIXES = {
    "short": (6, 6),       # uniform short prompts
    "long": (40, 48),      # uniform long prompts
    "mixed": (4, 48),      # alternating short/long — the stall workload
}
ARCHS = ("qwen3-4b", "granite-moe-3b-a800m", "rwkv6-1.6b", "hymba-1.5b")
SLOTS = (2, 8)
SMOKE_ARCHS = ("qwen3-4b",)
SMOKE_SLOTS = (8,)
SMOKE_MIXES = ("mixed",)
GATE_SPEEDUP = 5.0         # slots=8 mixed cell: decoupled >= 5x legacy
MAX_NEW = 16
N_REQUESTS = 12
CHUNK = 16


def _prompts(mix: str, n: int, vocab: int, seed: int = 0):
    lo, hi = MIXES[mix]
    rng = np.random.default_rng(seed)
    lens = [lo if i % 2 == 0 else hi for i in range(n)]
    return [rng.integers(0, vocab, size=p) for p in lens]


def _requests(mix: str, vocab: int):
    from repro.runtime.serve_loop import Request
    return [Request(rid=i, prompt=p, max_new=MAX_NEW)
            for i, p in enumerate(_prompts(mix, N_REQUESTS, vocab))]


def _occ_summary(trace) -> str:
    occ = trace.channel_occupancy()
    return ",".join(f"{name.rsplit('/', 1)[-1]}:{mean:.1f}/{mx}"
                    for name, (mean, mx) in sorted(occ.items()))


def _bench_cell(cfg, bundle, params, mix, slots, s_max):
    from repro.core.trace import Tracer
    from repro.runtime.serve_loop import LegacyServeLoop, Request, ServeLoop

    def warm():
        return [Request(rid=-1, prompt=np.array([1, 2], np.int64),
                        max_new=2)]

    # compile on a throwaway loop (the jit caches are shared per bundle
    # function), then measure a FRESH loop so the tracer and stats see
    # only workload traffic
    ServeLoop(cfg, bundle, params, batch_slots=slots, s_max=s_max,
              chunk=CHUNK).run(warm())
    tracer = Tracer()
    loop = ServeLoop(cfg, bundle, params, batch_slots=slots, s_max=s_max,
                     chunk=CHUNK, tracer=tracer)
    reqs = _requests(mix, cfg.vocab)
    t0 = time.perf_counter()
    results = loop.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in results.values())
    ttft = sorted(loop.stats.ttft[r.rid] for r in reqs)
    ttft_mean = 1e3 * sum(ttft) / len(ttft)
    ttft_p95 = 1e3 * ttft[min(len(ttft) - 1, int(0.95 * len(ttft)))]

    LegacyServeLoop(cfg, bundle, params, batch_slots=slots,
                    s_max=s_max).run(warm())
    legacy = LegacyServeLoop(cfg, bundle, params, batch_slots=slots,
                             s_max=s_max)
    reqs_l = _requests(mix, cfg.vocab)
    t0 = time.perf_counter()
    results_l = legacy.run(reqs_l)
    dt_l = time.perf_counter() - t0
    toks_l = sum(len(v) for v in results_l.values())

    return {
        "tok_s": toks / dt,
        "legacy_tok_s": toks_l / dt_l,
        "speedup": (toks / dt) / (toks_l / dt_l),
        "ttft_mean_ms": ttft_mean,
        "ttft_p95_ms": ttft_p95,
        "occ": _occ_summary(tracer.summary()),
    }


def _parity_cell(cfg, bundle, params, s_max) -> None:
    """One slot, one request: legacy is correct here, so greedy outputs
    must be bit-identical between the loops."""
    from repro.runtime.serve_loop import LegacyServeLoop, Request, ServeLoop

    prompt = np.asarray(_prompts("mixed", 2, cfg.vocab, seed=7)[1])
    new = ServeLoop(cfg, bundle, params, batch_slots=1, s_max=s_max,
                    chunk=CHUNK)
    out_new = new.run([Request(rid=0, prompt=prompt, max_new=8)])[0]
    leg = LegacyServeLoop(cfg, bundle, params, batch_slots=1, s_max=s_max)
    out_leg = leg.run([Request(rid=0, prompt=prompt, max_new=8)])[0]
    if out_new != out_leg:  # must fire even under python -O
        raise AssertionError(
            f"{cfg.arch}: decoupled {out_new} != legacy {out_leg}")


def run(csv_print, smoke: bool = False) -> dict:
    import jax

    from repro.configs import get_config
    from repro.models.registry import build_model

    archs = SMOKE_ARCHS if smoke else ARCHS
    slots_sweep = SMOKE_SLOTS if smoke else SLOTS
    mixes = SMOKE_MIXES if smoke else tuple(MIXES)
    s_max = max(hi for _, hi in MIXES.values()) + MAX_NEW + 8

    results = {}
    for arch in archs:
        cfg = get_config(arch, smoke=True)
        bundle = build_model(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        _parity_cell(cfg, bundle, params, s_max)
        for mix in mixes:
            for slots in slots_sweep:
                cell = _bench_cell(cfg, bundle, params, mix, slots, s_max)
                results[(arch, mix, slots)] = cell
                csv_print(
                    f"serve/{arch}/{mix}/s{slots},{1e6 / cell['tok_s']:.1f},"
                    f"tok_s={cell['tok_s']:.1f};"
                    f"legacy={cell['legacy_tok_s']:.1f};"
                    f"speedup={cell['speedup']:.2f};"
                    f"ttft_ms={cell['ttft_mean_ms']:.0f}/"
                    f"{cell['ttft_p95_ms']:.0f};"
                    f"occ={cell['occ']}")
                if mix == "mixed" and slots == 8 and \
                        cell["speedup"] < GATE_SPEEDUP:
                    raise AssertionError(
                        f"{arch} mixed/s8: decoupled speedup "
                        f"{cell['speedup']:.2f}x < {GATE_SPEEDUP}x gate")
    return results
