"""The compiler's kernel templates: three ring shapes cover the IR.

``repro.compile.codegen`` lowers every compilable :class:`DaeIR` onto
one of three Pallas templates, all emitted through the shared
:mod:`repro.kernels.ring` scaffolds (so the §5.1 conservation structure
and the §5.3 capacity bound are inherited, not re-implemented):

* :func:`ring_gather` — a STATIC address stream: the scalar-prefetched
  Access loop of ``dae_gather``'s explicit-RIF variant, generalized to
  any (N, W) port.
* :func:`ring_deref`  — one INDIRECT hop (``b[a[i]]``): phase 1 rings
  the index port and banks the landed scalars in SMEM, phase 2 rings
  the data port through them.  Two ``access_execute`` loops per grid
  step; the SMEM bank is the inter-loop channel.
* :func:`ring_chase`  — a DEPENDENT stream driven by a
  :class:`~repro.compile.ir.ChaseSpec`: per-item int32 state in SMEM, a
  lock-step level loop (Listing 5's fixed-length form — every item
  walks ``max_steps`` levels, redundant tail loads included), each
  level a full ``access_execute`` whose ``src`` reads the state the
  previous level wrote.

All three process ``chunk`` items per grid step with ``rif`` copies in
flight and expect item counts pre-padded to a chunk multiple (the
compiler pads with index 0 / replicated state and slices the pad off on
the host).  The templates are written for interpret-mode parity first;
lane-width alignment of ``W`` is the caller's concern on real TPUs.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ring import RingChannel, access_execute, \
    ring_scratch_shapes

__all__ = ["ring_gather", "ring_deref", "ring_chase"]


# ---------------------------------------------------------------------------
# shape 1: STATIC stream — scalar-prefetch gather over any (N, W) port
# ---------------------------------------------------------------------------


def _gather_kernel(addr_ref, port_hbm, out_ref, scratch, sems, *,
                   chunk: int, rif: int):
    c = pl.program_id(0)
    base = c * chunk
    ring = RingChannel(
        scratch, sems, rif,
        src=lambda k: port_hbm.at[pl.ds(addr_ref[base + k], 1), :])

    def execute(k, row):
        pl.store(out_ref, (pl.ds(k, 1), slice(None)), row)

    access_execute([ring], chunk, execute)


def ring_gather(port: jax.Array, addrs: jax.Array, *, chunk: int,
                rif: int, interpret: bool = True) -> jax.Array:
    """Fetch ``port[addrs]`` — ``port`` (N, W), ``addrs`` (M,) int32
    with M a multiple of ``chunk``.  Returns (M, W)."""
    m = addrs.shape[0]
    n, w = port.shape
    assert m % chunk == 0, (m, chunk)

    kernel = functools.partial(_gather_kernel, chunk=chunk, rif=rif)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(m // chunk,),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec((chunk, w), lambda c, a: (c, 0)),
            scratch_shapes=[*ring_scratch_shapes(rif, (1, w), port.dtype)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, w), port.dtype),
        interpret=interpret,
    )(addrs, port)


# ---------------------------------------------------------------------------
# shape 2: one INDIRECT hop — b[a[i] + offset] via an SMEM address bank
# ---------------------------------------------------------------------------


def _deref_kernel(addr_ref, a_hbm, b_hbm, out_a_ref, out_b_ref,
                  addr_s, scr_a, sem_a, scr_b, sem_b, *,
                  chunk: int, rif_a: int, rif_b: int, offset: int,
                  nb: int):
    c = pl.program_id(0)
    base = c * chunk

    ring_a = RingChannel(
        scr_a, sem_a, rif_a,
        src=lambda k: a_hbm.at[pl.ds(addr_ref[base + k], 1), :])

    def land_a(k, row):
        pl.store(out_a_ref, (pl.ds(k, 1), slice(None)), row)
        # The landed scalar IS the next address (check guarantees the
        # true-run addresses were in range; the clip only disciplines
        # the perturbed-ghost values a real run never produces).
        addr_s[k] = jnp.clip(row[0, 0] + offset, 0, nb - 1)

    access_execute([ring_a], chunk, land_a)

    ring_b = RingChannel(
        scr_b, sem_b, rif_b,
        src=lambda k: b_hbm.at[pl.ds(addr_s[k], 1), :])

    def land_b(k, row):
        pl.store(out_b_ref, (pl.ds(k, 1), slice(None)), row)

    access_execute([ring_b], chunk, land_b)


def ring_deref(port_a: jax.Array, port_b: jax.Array, addrs: jax.Array,
               *, chunk: int, rif_a: int, rif_b: int, offset: int = 0,
               interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Two-phase ring: ``va = a[addrs]`` then ``vb = b[va + offset]``.
    ``port_a`` is (NA, 1) int32; returns ((M, 1) int32, (M, WB))."""
    m = addrs.shape[0]
    na, wa = port_a.shape
    nb, wb = port_b.shape
    assert wa == 1, wa
    assert m % chunk == 0, (m, chunk)

    kernel = functools.partial(_deref_kernel, chunk=chunk, rif_a=rif_a,
                               rif_b=rif_b, offset=offset, nb=nb)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(m // chunk,),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                      pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=[
                pl.BlockSpec((chunk, 1), lambda c, a: (c, 0)),
                pl.BlockSpec((chunk, wb), lambda c, a: (c, 0)),
            ],
            scratch_shapes=[
                pltpu.SMEM((chunk,), jnp.int32),
                *ring_scratch_shapes(rif_a, (1, 1), port_a.dtype),
                *ring_scratch_shapes(rif_b, (1, wb), port_b.dtype),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((m, 1), port_a.dtype),
                   jax.ShapeDtypeStruct((m, wb), port_b.dtype)],
        interpret=interpret,
    )(addrs, port_a, port_b)


# ---------------------------------------------------------------------------
# shape 3: DEPENDENT stream — lock-step chase driven by a ChaseSpec
# ---------------------------------------------------------------------------


def _chase_kernel(state0_ref, port_hbm, out_addr_ref, out_val_ref,
                  state_s, scratch, sems, *, chunk: int, rif: int,
                  max_steps: int, n: int, s_width: int,
                  addr_fn: Callable, step_fn: Callable,
                  out_fn: Callable):
    c = pl.program_id(0)
    base = c * chunk

    def state_at(k):
        return tuple(state_s[k, j] for j in range(s_width))

    def init(k, _):
        for j in range(s_width):
            state_s[k, j] = state0_ref[(base + k) * s_width + j]
        return 0

    jax.lax.fori_loop(0, chunk, init, 0)

    ring = RingChannel(
        scratch, sems, rif,
        src=lambda k: port_hbm.at[
            pl.ds(jnp.clip(addr_fn(state_at(k)), 0, n - 1)
                  .astype(jnp.int32), 1), :])

    def execute(k, row):
        new = step_fn(state_at(k), row[0])
        for j in range(s_width):
            state_s[k, j] = jnp.asarray(new[j]).astype(jnp.int32)

    # Listing 5: every item walks exactly max_steps levels; finished
    # items issue redundant (clipped) tail loads, which is what buys
    # the lock-step schedule its full-RIF overlap.
    def level(_, carry):
        access_execute([ring], chunk, execute)
        return carry

    jax.lax.fori_loop(0, max_steps, level, 0)

    def emit(k, _):
        oa, ov = out_fn(state_at(k))
        pl.store(out_addr_ref, (pl.ds(k, 1),),
                 jnp.asarray(oa).astype(jnp.int32)[None])
        pl.store(out_val_ref, (pl.ds(k, 1),),
                 jnp.asarray(ov).astype(jnp.int32)[None])
        return 0

    jax.lax.fori_loop(0, chunk, emit, 0)


def ring_chase(port: jax.Array, state0_flat: jax.Array, *, chunk: int,
               rif: int, max_steps: int, s_width: int,
               addr_fn: Callable, step_fn: Callable, out_fn: Callable,
               interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Walk a dependent-load chase for M items (``state0_flat`` is the
    row-major (M*S,) int32 initial state, M a multiple of ``chunk``).
    Returns per-item ``(store_addr, store_value)`` int32 vectors."""
    n, _w = port.shape
    m = state0_flat.shape[0] // s_width
    assert state0_flat.shape[0] == m * s_width
    assert m % chunk == 0, (m, chunk)

    kernel = functools.partial(
        _chase_kernel, chunk=chunk, rif=rif, max_steps=max_steps, n=n,
        s_width=s_width, addr_fn=addr_fn, step_fn=step_fn, out_fn=out_fn)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(m // chunk,),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=[
                pl.BlockSpec((chunk,), lambda c, s: (c,)),
                pl.BlockSpec((chunk,), lambda c, s: (c,)),
            ],
            scratch_shapes=[
                pltpu.SMEM((chunk, s_width), jnp.int32),
                *ring_scratch_shapes(rif, (1, port.shape[1]), port.dtype),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((m,), jnp.int32),
                   jax.ShapeDtypeStruct((m,), jnp.int32)],
        interpret=interpret,
    )(state0_flat, port)
