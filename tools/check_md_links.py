#!/usr/bin/env python3
"""Check that relative Markdown links resolve to real files.

Usage: python tools/check_md_links.py [file-or-dir ...]
(defaults to README.md and docs/).  External links (http/https/mailto)
are skipped; everything else is resolved relative to the containing
file and must exist.  Anchored links (``path#section``) are checked for
the file part only.  Exits non-zero listing every broken reference.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links [text](target); images ![alt](target) match too
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def check_file(md: Path) -> list:
    errors = []
    text = md.read_text(encoding="utf-8")
    # fenced code blocks routinely contain (parenthesized) pseudo-links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for target in _LINK.findall(text):
        if target.startswith(_EXTERNAL):
            continue
        path = target.split("#", 1)[0]
        if not path:  # pure in-page anchor
            continue
        if not (md.parent / path).exists():
            errors.append(f"{md}: broken link -> {target}")
    return errors


def main(argv) -> int:
    roots = argv or ["README.md", "docs"]
    files = []
    for root in roots:
        p = Path(root)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            print(f"warning: {root} does not exist", file=sys.stderr)
    errors = [e for f in files for e in check_file(f)]
    for e in errors:
        print(e)
    print(f"checked {len(files)} markdown files: "
          f"{'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
