"""Grouped expert matmul — decoupled SPMV generalized to MoE (paper §4.1).

After top-k routing, the token→expert map is CSR-shaped (group offsets =
``rows``).  The false dependency the paper removes for SPMV — products
gated by row-pointer loads — appears here as expert GEMMs gated by the
routing result.  Decoupling: ops.py sorts tokens by expert and emits a
``block_expert`` stream (one expert id per token block); the kernel
scalar-prefetches it, so the *weight* block fetch for step i+1 (an
irregular, data-dependent HBM read of expert ``block_expert[i+1]``) is
issued while step i multiplies — the Access loop running ahead of the
MXU Execute loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(be_ref, x_ref, w_ref, o_ref, acc, *, nd: int):
    di = pl.program_id(2)

    @pl.when(di == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(di == nd - 1)
    def _flush():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def gmm(x: jax.Array, w: jax.Array, block_expert: jax.Array, *, bt: int,
        bf: int, bd: int, interpret: bool = True) -> jax.Array:
    """x (T, D) sorted by expert, T % bt == 0; w (E, D, F);
    block_expert (T//bt,) int32.  Returns (T, F)."""
    t, d = x.shape
    e, _, f = w.shape
    ntb, nf, nd = t // bt, f // bf, d // bd
    grid = (ntb, nf, nd)

    kernel = functools.partial(_gmm_kernel, nd=nd)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bt, bd), lambda i, j, k, be: (i, k)),
                pl.BlockSpec((1, bd, bf), lambda i, j, k, be: (be[i], k, j)),
            ],
            out_specs=pl.BlockSpec((bt, bf), lambda i, j, k, be: (i, j)),
            scratch_shapes=[pltpu.VMEM((bt, bf), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((t, f), x.dtype),
        interpret=interpret,
    )(block_expert, x, w)
