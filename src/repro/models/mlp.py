"""Feed-forward layers: SwiGLU (llama-style) / plain ReLU/GeLU (seamless)."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, activation, dense_init


def mlp_init(cfg: ModelConfig, key, d_ff: int = 0) -> Dict[str, Any]:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    if cfg.mlp_kind == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d, d_ff, cfg.pdtype),
            "w_up": dense_init(ks[1], d, d_ff, cfg.pdtype),
            "w_down": dense_init(ks[2], d_ff, d, cfg.pdtype),
        }
    return {
        "w_up": dense_init(ks[0], d, d_ff, cfg.pdtype),
        "w_down": dense_init(ks[1], d_ff, d, cfg.pdtype),
    }


def mlp_apply(cfg: ModelConfig, p: Dict[str, Any], x: jnp.ndarray) -> jnp.ndarray:
    dt = cfg.adtype
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    else:
        h = activation(cfg.mlp_kind, x @ p["w_up"].astype(dt))
    return h @ p["w_down"].astype(dt)
