"""Assemble, persist and load ``BENCH_<axis>.json`` reports.

A report is self-describing: besides the cells it records the git SHA
it ran at, the JAX backend, the RNG seed and the Python version, so a
number in a months-old artifact can be traced to the exact tree and
environment that produced it.  Metadata lookups are tolerant — a
tarball checkout without git still benches, it just records
``git_sha: "unknown"``.
"""

from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Tuple

from repro.bench.registry import Cell, CellResult
from repro.bench.schema import SCHEMA_VERSION, validate_report

__all__ = ["bench_meta", "build_report", "bench_path", "write_report",
           "load_report", "cell_csv"]


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=Path(__file__).resolve().parent)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except OSError:
        return "unknown"


def _backend() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:  # report assembly must not require a live backend
        return "unavailable"


def bench_meta(*, seed: int) -> Dict[str, object]:
    return {
        "git_sha": _git_sha(),
        "backend": _backend(),
        "seed": int(seed),
        "python": sys.version.split()[0],
    }


def build_report(axis: str, results: Iterable[Tuple[Cell, CellResult]],
                 *, smoke: bool, seed: int) -> Dict:
    """One schema-valid report for a fully-run axis."""
    cells: List[Dict] = []
    for cell, result in results:
        row = {"name": cell.name, "group": cell.group,
               "coords": dict(cell.coords)}
        row.update(result.to_json())
        cells.append(row)
    report = {
        "schema": SCHEMA_VERSION,
        "axis": axis,
        "smoke": bool(smoke),
        "meta": bench_meta(seed=seed),
        "cells": cells,
    }
    return validate_report(report)


def bench_path(axis: str, directory: Path) -> Path:
    return Path(directory) / f"BENCH_{axis}.json"


def write_report(report: Dict, path: Path) -> Path:
    validate_report(report)
    path = Path(path)
    path.write_text(json.dumps(report, indent=1, sort_keys=True) + "\n")
    return path


def load_report(path: Path) -> Dict:
    """Parse + validate; the diff gate must not compare malformed files."""
    with open(path) as f:
        return validate_report(json.load(f))


def cell_csv(cell: Cell, result: CellResult) -> str:
    """Legacy ``name,us_per_call,derived`` CSV row for ``benchmarks.run``.

    ``us_per_call`` is the *warm* time (0 for cycle-only cells) — the
    cold/warm split lives in the JSON; the CSV stream keeps its
    historical three-column shape for eyeballing and grep.
    """
    us = result.us_warm or 0.0
    parts: List[str] = []
    if result.status != "ok":
        parts.append(f"status={result.status}")
    if result.cycles is not None:
        parts.append(f"cycles={result.cycles}")
    parts += [f"{k}={v}" for k, v in result.derived.items()]
    return f"{cell.name},{us:.0f},{';'.join(parts) or 'ok'}"
