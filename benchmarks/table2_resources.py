"""Paper Table 2 analogue: resource usage.

FPGA LUT/FF/BRAM have no TPU meaning; the comparable quantities for the
decoupled designs are (a) the number of channels (request/response pairs
~ dataflow units) and (b) total buffer bytes implied by channel
capacities (the BRAM analogue), plus memory-port counts.  We reconstruct
them by instrumenting the simulator channel registry at small scale.

As matrix cells (``sim`` axis, group ``table2``) all three quantities
are integer ``derived`` values, so the regression gate diffs them
exactly — a refactor that silently changes a workload's port count
fails the diff by name.
"""

from __future__ import annotations

from typing import List

from repro.bench import BenchContext, Cell, CellResult, coords, run_cells
from repro.core.simulator import DeadlockError
from repro.core.workloads import BENCHMARKS, run_workload


def _cell_run(bench: str, config: str):
    def run(ctx: BenchContext) -> CellResult:
        try:
            r = run_workload(bench, config, scale="small", latency=100,
                             rif=128)
        except DeadlockError:
            return CellResult(status="deadlock")
        n_ports = len(r.mem_reads)
        n_channels = max(1, n_ports - 1) * 2  # req/resp pair per port
        # buffer bytes: capacity entries x 4B words, summed over
        # channels (upper bound: every channel sized at RIF)
        buffer_bytes = n_channels * 128 * 4
        return CellResult(derived={"channels": n_channels,
                                   "ports": n_ports,
                                   "buffer_bytes": buffer_bytes})
    return run


def cells(ctx: BenchContext) -> List[Cell]:
    return [
        Cell(axis="sim", name=f"table2/{bench}/{config}", group="table2",
             coords=coords(bench, "sim"), run=_cell_run(bench, config))
        for bench in BENCHMARKS for config in ("vitis_dec", "rhls_dec")
    ]


def run(csv_print) -> None:
    ctx = BenchContext(smoke=False)
    run_cells(cells(ctx), ctx, csv_print)
