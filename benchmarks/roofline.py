"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs/dev            / PEAK_FLOPS_BF16
    memory term     = HLO_bytes/dev            / HBM_BW
    collective term = collective_link_bytes/dev / ICI_LINK_BW
FLOPs/bytes come from the scan-corrected cost probes (see
launch/dryrun.py: XLA counts while bodies once); collective bytes from
the HLO scan with ring-model link accounting (launch/hlo_stats.py).

MODEL_FLOPS = 6*N_active*tokens (train) / 2*N_active*tokens (prefill) /
2*N_active*batch (decode), with N_active = params - embedding table -
inactive expert weights.  The ratio MODEL_FLOPS / (HLO_FLOPs * chips)
measures how much compiled compute is "useful" (remat/attention/dispatch
overheads push it below 1).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--md out.md]
"""

from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

import numpy as np

from benchmarks.hw import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16

ROOT = Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun"


def kernel_bound_us(flops: float, hbm_bytes: float) -> float:
    """Roofline lower bound, in microseconds, for one kernel dispatch on
    the modelled TPU: the slower of the compute term and the HBM term.

    ``benchmarks/kernel_bench.py`` attaches this to the decoupled-kernel
    cells so interpret-mode wall-clock (where the rings lose to XLA on
    plumbing overhead) carries the expected-on-hardware bound alongside
    it — informational in ``benchmarks.diff``, never exact-gated.
    """
    return max(flops / PEAK_FLOPS_BF16, hbm_bytes / HBM_BW) * 1e6


def model_flops(arch: str, kind: str, seq_len: int, global_batch: int) -> dict:
    import sys
    sys.path.insert(0, str(ROOT / "src"))
    import jax
    from repro.configs import get_config
    from repro.launch.specs import param_specs

    cfg = get_config(arch)
    specs = param_specs(cfg)
    n_total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(specs))
    # embedding gather is not a matmul
    n_embed = cfg.vocab * cfg.d_model
    # inactive routed experts do no work for a given token
    n_inactive = 0
    if cfg.n_experts:
        n_moe_layers = cfg.n_layers - cfg.first_dense_layers
        per_expert = 3 * cfg.d_model * (cfg.moe_d_ff or cfg.d_ff)
        n_inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    n_active = n_total - n_embed - n_inactive
    tokens = seq_len * global_batch
    if kind == "train":
        mf = 6 * n_active * tokens
    elif kind == "prefill":
        mf = 2 * n_active * tokens
    else:  # decode: one new token per sequence
        mf = 2 * n_active * global_batch
    return {"n_total": n_total, "n_active": n_active, "model_flops": mf}


def analyze(rec: dict) -> dict:
    tot = rec["cost_corrected"]["total"]
    nd = rec["n_devices"]
    t_comp = tot["flops"] / PEAK_FLOPS_BF16
    t_mem = tot["bytes"] / HBM_BW
    t_coll = tot["link_bytes"] / ICI_LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(rec["arch"], rec["kind"], rec["seq_len"],
                     rec["global_batch"])
    useful = mf["model_flops"] / max(tot["flops"] * nd, 1.0)
    # roofline fraction: ideal model-compute time / achievable step time
    ideal = mf["model_flops"] / nd / PEAK_FLOPS_BF16
    frac = ideal / max(bound, 1e-12)
    return {
        **{f"t_{k}_s": v for k, v in terms.items()},
        "dominant": dominant,
        "useful_flops_ratio": useful,
        "roofline_fraction": frac,
        "hbm_per_dev_gb": (rec["memory"]["argument_bytes"]
                           + rec["memory"]["temp_bytes"]
                           + rec["memory"]["output_bytes"]) / nd / 2**30
        if rec["memory"]["argument_bytes"] > 0 else 0.0,
        **mf,
    }


_ADVICE = {
    "memory": "cut HBM traffic: fuse attention (chunked/flash), tighter "
              "remat policy, bf16 intermediates",
    "compute": "already MXU-bound: raise useful-ratio (less remat "
               "recompute), overlap the small collective tail",
    "collective": "re-shard to cut resharding collectives / overlap "
                  "all-gathers with compute / compress grads",
}


def build_table(records) -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | useful | roofline frac | bytes/dev GB | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        if rec["status"] == "skipped":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | — | — "
                f"| — | — | — | — | — | skipped: sub-quadratic attention "
                f"required |")
            continue
        a = rec["analysis"]
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {a['t_compute_s']:.3f} | {a['t_memory_s']:.3f} "
            f"| {a['t_collective_s']:.3f} | **{a['dominant']}** "
            f"| {a['useful_flops_ratio']:.2f} | {a['roofline_fraction']:.2f} "
            f"| {a['hbm_per_dev_gb']:.1f} | {_ADVICE[a['dominant']]} |")
    return "\n".join(lines)


def load_records(pattern: str = "*.json"):
    records = []
    for f in sorted(glob.glob(str(DRYRUN / pattern))):
        name = Path(f).stem
        if name.count("__") != 2:      # skip variant/baseline artifacts
            continue
        rec = json.loads(Path(f).read_text())
        if rec.get("variant"):
            continue
        if rec["status"] == "ok":
            rec["analysis"] = analyze(rec)
        records.append(rec)
    return records


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--md", default=str(ROOT / "experiments" / "roofline.md"))
    ap.add_argument("--json", default=str(ROOT / "experiments" /
                                          "roofline.json"))
    ns = ap.parse_args()
    records = load_records()
    table = build_table(records)
    Path(ns.md).write_text("# Roofline (single-pod 16x16 unless noted)\n\n"
                           + table + "\n")
    slim = [{k: v for k, v in r.items() if k != "traceback"}
            for r in records]
    Path(ns.json).write_text(json.dumps(slim, indent=1, default=float))
    print(table)


if __name__ == "__main__":
    main()
