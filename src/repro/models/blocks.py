"""Per-layer blocks for every architecture family, unified behind
``block_init(cfg, kind, key)`` / ``block_apply(cfg, kind, p, x, ...)`` so
stacks can lax.scan over homogeneous segments (common.LayerSpec)."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import ModelConfig, rmsnorm, rmsnorm_init
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.moe import moe_apply, moe_init
from repro.models.rwkv import (rwkv_channel_apply, rwkv_channel_init,
                               rwkv_time_apply, rwkv_time_init)
from repro.models.ssm import ssm_apply, ssm_init, ssm_init_state


def _attn_init(cfg: ModelConfig, key):
    if cfg.attn_kind == "mla":
        return attn.mla_init(cfg, key)
    return attn.gqa_init(cfg, key)


def _attn_apply(cfg, p, x, positions, *, causal=True, window=None, cache=None,
                valid=None, page_table=None):
    if cfg.attn_kind == "mla":
        return attn.mla_apply(cfg, p, x, positions, causal=causal, cache=cache,
                              valid=valid, page_table=page_table)
    return attn.gqa_apply(cfg, p, x, positions, causal=causal, window=window,
                          cache=cache, valid=valid, page_table=page_table)


def block_init(cfg: ModelConfig, kind: str, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind == "attn":
        return {"ln1": rmsnorm_init(d, cfg.pdtype),
                "attn": _attn_init(cfg, ks[0]),
                "ln2": rmsnorm_init(d, cfg.pdtype),
                "mlp": mlp_init(cfg, ks[1])}
    if kind == "moe":
        return {"ln1": rmsnorm_init(d, cfg.pdtype),
                "attn": _attn_init(cfg, ks[0]),
                "ln2": rmsnorm_init(d, cfg.pdtype),
                "moe": moe_init(cfg, ks[1])}
    if kind in ("hymba", "hymba_global"):
        return {"ln1": rmsnorm_init(d, cfg.pdtype),
                "attn": _attn_init(cfg, ks[0]),
                "ssm": ssm_init(cfg, ks[1]),
                "ln2": rmsnorm_init(d, cfg.pdtype),
                "mlp": mlp_init(cfg, ks[2])}
    if kind == "rwkv":
        return {"ln1": rmsnorm_init(d, cfg.pdtype),
                "time": rwkv_time_init(cfg, ks[0]),
                "ln2": rmsnorm_init(d, cfg.pdtype),
                "chan": rwkv_channel_init(cfg, ks[1])}
    if kind == "xattn":  # enc-dec decoder block
        return {"ln1": rmsnorm_init(d, cfg.pdtype),
                "attn": _attn_init(cfg, ks[0]),
                "lnx": rmsnorm_init(d, cfg.pdtype),
                "xattn": attn.gqa_init(cfg, ks[1]),
                "ln2": rmsnorm_init(d, cfg.pdtype),
                "mlp": mlp_init(cfg, ks[2])}
    if kind == "enc":    # bidirectional encoder block
        return {"ln1": rmsnorm_init(d, cfg.pdtype),
                "attn": _attn_init(cfg, ks[0]),
                "ln2": rmsnorm_init(d, cfg.pdtype),
                "mlp": mlp_init(cfg, ks[1])}
    raise ValueError(f"unknown block kind {kind!r}")


def block_apply(cfg: ModelConfig, kind: str, p, x, positions, *,
                cache: Optional[Dict[str, Any]] = None,
                enc_kv=None,
                valid: Optional[jnp.ndarray] = None,
                page_table: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]]]:
    """``valid`` (B, S) marks which of the S tokens are real per batch
    row (chunked cache fill / masked decode); ``None`` means all are —
    the pre-existing train and single-token decode paths.  A paged
    attention cache (from :func:`block_cache_init_paged`) additionally
    needs the slot->page ``page_table`` (B, NPB)."""
    eps = cfg.norm_eps
    new_cache: Optional[Dict[str, Any]] = None

    if kind in ("attn", "moe", "enc"):
        causal = kind != "enc"
        window = cfg.window if kind != "enc" else None
        h, ac = _attn_apply(cfg, p["attn"], rmsnorm(x, p["ln1"], eps),
                            positions, causal=causal, window=window,
                            cache=None if cache is None else cache["attn"],
                            valid=valid, page_table=page_table)
        x = x + h
        if kind == "moe":
            # decode: dropless dispatch (capacity drops would make decode
            # diverge from prefill); train: GShard-style capacity factor
            cf = float(cfg.n_experts) if cache is not None else 0.0
            x = x + moe_apply(cfg, p["moe"], rmsnorm(x, p["ln2"], eps),
                              capacity_factor=cf)
        else:
            x = x + mlp_apply(cfg, p["mlp"], rmsnorm(x, p["ln2"], eps))
        if cache is not None:
            new_cache = {"attn": ac}

    elif kind in ("hymba", "hymba_global"):
        window = None if kind == "hymba_global" else cfg.window
        xin = rmsnorm(x, p["ln1"], eps)
        h_attn, ac = _attn_apply(cfg, p["attn"], xin, positions,
                                 causal=True, window=window,
                                 cache=None if cache is None else cache["attn"],
                                 valid=valid)
        h_ssm, sc = ssm_apply(cfg, p["ssm"], xin,
                              None if cache is None else cache["ssm"],
                              valid=valid)
        x = x + 0.5 * (h_attn + h_ssm)       # parallel heads, mean-combined
        x = x + mlp_apply(cfg, p["mlp"], rmsnorm(x, p["ln2"], eps))
        if cache is not None:
            new_cache = {"attn": ac, "ssm": sc}

    elif kind == "rwkv":
        st = None if cache is None else {"shift": cache["time_shift"],
                                         "wkv": cache["wkv"]}
        h, ts = rwkv_time_apply(cfg, p["time"], rmsnorm(x, p["ln1"], eps), st,
                                valid=valid)
        x = x + h
        cs = None if cache is None else cache["chan_shift"]
        h, ns = rwkv_channel_apply(cfg, p["chan"], rmsnorm(x, p["ln2"], eps), cs,
                                   valid=valid)
        x = x + h
        if cache is not None:
            new_cache = {"time_shift": ts["shift"], "wkv": ts["wkv"],
                         "chan_shift": ns}

    elif kind == "xattn":
        h, ac = _attn_apply(cfg, p["attn"], rmsnorm(x, p["ln1"], eps),
                            positions, causal=True,
                            cache=None if cache is None else cache["attn"],
                            valid=valid)
        x = x + h
        x = x + attn.cross_attn_apply(cfg, p["xattn"],
                                      rmsnorm(x, p["lnx"], eps), enc_kv,
                                      positions,
                                      per_query=valid is not None)
        x = x + mlp_apply(cfg, p["mlp"], rmsnorm(x, p["ln2"], eps))
        if cache is not None:
            new_cache = {"attn": ac}

    else:
        raise ValueError(f"unknown block kind {kind!r}")

    return x, new_cache


def block_cache_init(cfg: ModelConfig, kind: str, batch: int, s_max: int
                     ) -> Dict[str, Any]:
    """Decode-cache pytree for one layer of ``kind``."""
    hd, kvh = cfg.hd, cfg.n_kv_heads
    if kind in ("attn", "moe", "enc", "xattn", "hymba", "hymba_global"):
        if cfg.attn_kind == "mla":
            ac = {"ckv": jnp.zeros((batch, s_max, cfg.kv_lora_rank), cfg.adtype),
                  "kr": jnp.zeros((batch, s_max, cfg.qk_rope_dim), cfg.adtype),
                  "len": jnp.zeros((batch,), jnp.int32)}
        else:
            # NOTE: sliding-window layers could use a ring buffer of size
            # `window`; we allocate the full horizon for simplicity and
            # account for it in the roofline (perf TODO in EXPERIMENTS.md).
            ac = {"k": jnp.zeros((batch, kvh, s_max, hd), cfg.adtype),
                  "v": jnp.zeros((batch, kvh, s_max, hd), cfg.adtype),
                  "len": jnp.zeros((batch,), jnp.int32)}
        if kind in ("hymba", "hymba_global"):
            return {"attn": ac, "ssm": ssm_init_state(cfg, batch)}
        return {"attn": ac}
    if kind == "rwkv":
        from repro.models.rwkv import rwkv_state_init
        return rwkv_state_init(cfg, batch)
    raise ValueError(kind)


def block_cache_init_paged(cfg: ModelConfig, kind: str, batch: int,
                           n_pages: int, page: int) -> Dict[str, Any]:
    """Paged decode-cache pytree for one layer of ``kind``.

    KV lives in a shared physical pool of ``n_pages`` fixed-size pages;
    each slot addresses its logical sequence through a page table
    (passed separately at apply time).  Page 0 is reserved as the trash
    page — unmapped table entries point there and its contents are never
    attended to because ``len`` masks them.  Only pure-attention kinds
    page; recurrent state (ssm/rwkv/hymba) has no growing KV to page.

    Under sharded serving the pool leaves (``kp``/``vp``/``ckvp``/
    ``krp``) shard their page dim over the data axis — see the
    ``_PAGED_POOL`` rule in ``parallel/sharding.py`` and the in-jit
    ``_pool_constraint`` in ``attention.py``; ``len`` stays replicated
    (it is the scheduler's per-slot control state).
    """
    hd, kvh = cfg.hd, cfg.n_kv_heads
    if kind not in ("attn", "moe"):
        raise ValueError(f"block kind {kind!r} has no paged cache")
    if cfg.attn_kind == "mla":
        ac = {"ckvp": jnp.zeros((n_pages, page, cfg.kv_lora_rank), cfg.adtype),
              "krp": jnp.zeros((n_pages, page, cfg.qk_rope_dim), cfg.adtype),
              "len": jnp.zeros((batch,), jnp.int32)}
    else:
        ac = {"kp": jnp.zeros((n_pages, kvh, page, hd), cfg.adtype),
              "vp": jnp.zeros((n_pages, kvh, page, hd), cfg.adtype),
              "len": jnp.zeros((batch,), jnp.int32)}
    return {"attn": ac}
