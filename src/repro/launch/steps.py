"""Sharded train / prefill / serve steps for every architecture.

These are the functions the dry-run lowers and the launchers run:
  * train_step(params, opt_state, batch) -> (params, opt_state, metrics)
  * prefill_step(params, batch) -> last-position logits
  * serve_step(params, cache, token, pos[, enc_out]) -> (logits, cache)
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import InputShape
from repro.launch import specs as _specs
from repro.models.common import ModelConfig
from repro.models.registry import ModelBundle, build_model
from repro.optim import AdamW, warmup_cosine
from repro.parallel.sharding import (ShardingRules, batch_sharding,
                                     cache_shardings, param_shardings)


def default_optimizer(total_steps: int = 10_000) -> AdamW:
    return AdamW(lr=warmup_cosine(3e-4, 200, total_steps), weight_decay=0.1)


def make_train_step(cfg: ModelConfig, optimizer: Optional[AdamW] = None
                    ) -> Callable:
    bundle = build_model(cfg)
    opt = optimizer or default_optimizer()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(bundle.loss)(params, batch)
        new_params, new_opt, gnorm = opt.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    bundle = build_model(cfg)

    def prefill_step(params, batch):
        if cfg.family == "encdec":
            enc_out = bundle.encode(params, batch["frames"])
            return enc_out
        logits = bundle.apply(params, batch["tokens"])
        return logits[:, -1, :].astype(jnp.float32)

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    bundle = build_model(cfg)

    if cfg.family == "encdec":
        def serve_step(params, cache, token, pos, enc_out):
            return bundle.decode_step(params, enc_out, cache, token, pos)
    else:
        def serve_step(params, cache, token, pos):
            return bundle.decode_step(params, cache, token, pos)

    return serve_step


# ---------------------------------------------------------------------------
# Sharded (jit) wrappers
# ---------------------------------------------------------------------------


def _bind_mesh_axes(cfg: ModelConfig, mesh: Mesh) -> ModelConfig:
    import dataclasses
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return dataclasses.replace(cfg, mesh_dp_axes=dp or ("data",))


def shard_train_step(cfg: ModelConfig, mesh: Mesh,
                     shape: InputShape, rules: Optional[ShardingRules] = None,
                     optimizer: Optional[AdamW] = None,
                     donate: bool = True):
    """Returns (jitted_step, arg_specs) ready to .lower(**arg_specs)."""
    rules = rules or ShardingRules()
    cfg = _bind_mesh_axes(cfg, mesh)
    step = make_train_step(cfg, optimizer)

    p_specs = _specs.param_specs(cfg)
    p_shard = param_shardings(p_specs, mesh, rules)
    opt = optimizer or default_optimizer()
    o_specs = jax.eval_shape(lambda: opt.init(p_specs))
    o_shard = jax.tree.map(
        lambda s: s if isinstance(s, NamedSharding) else s,
        param_shardings(o_specs, mesh, rules))
    b_specs = _specs.train_batch_specs(cfg, shape)
    b_shard = jax.tree.map(lambda s: batch_sharding(mesh, len(s.shape), rules),
                           b_specs)
    metrics_shard = {"loss": NamedSharding(mesh, P()),
                     "grad_norm": NamedSharding(mesh, P())}

    jitted = jax.jit(
        step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, metrics_shard),
        donate_argnums=(0, 1) if donate else (),
    )
    args = (p_specs, o_specs, b_specs)
    return jitted, args


def shard_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                       rules: Optional[ShardingRules] = None):
    rules = rules or ShardingRules()
    step = make_prefill_step(cfg)
    p_specs = _specs.param_specs(cfg)
    p_shard = param_shardings(p_specs, mesh, rules)
    b_specs = _specs.train_batch_specs(cfg, shape)
    b_specs.pop("labels")
    b_shard = jax.tree.map(lambda s: batch_sharding(mesh, len(s.shape), rules),
                           b_specs)
    out_shard = batch_sharding(mesh, 3 if cfg.family == "encdec" else 2, rules)
    jitted = jax.jit(step, in_shardings=(p_shard, b_shard),
                     out_shardings=out_shard)
    return jitted, (p_specs, b_specs)


def shard_serve_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                     rules: Optional[ShardingRules] = None,
                     donate: bool = True):
    rules = rules or ShardingRules()
    step = make_serve_step(cfg)
    p_specs = _specs.param_specs(cfg)
    p_shard = param_shardings(p_specs, mesh, rules)
    cache_specs, args = _specs.decode_arg_specs(cfg, shape)
    c_shard = cache_shardings(cache_specs, mesh, rules)
    b_div = shape.global_batch % _dp_size(mesh) == 0
    v_div = cfg.vocab % mesh.shape["model"] == 0
    tok_shard = (batch_sharding(mesh, 1, rules) if b_div
                 else NamedSharding(mesh, P(None)))
    logits_shard = NamedSharding(mesh, P(
        rules.dp_axes(mesh) if b_div else None,
        "model" if v_div else None))

    in_sh = [p_shard, c_shard, tok_shard, tok_shard]
    in_args = [p_specs, cache_specs, args["token"], args["pos"]]
    if cfg.family == "encdec":
        in_sh.append(batch_sharding(mesh, 3, rules))
        in_args.append(args["enc_out"])

    jitted = jax.jit(step, in_shardings=tuple(in_sh),
                     out_shardings=(logits_shard, c_shard),
                     donate_argnums=(1,) if donate else ())
    return jitted, tuple(in_args)


def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
