"""Jit'd wrapper for the grouped expert matmul."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import (cdiv, resolve_interpret, ring_rif,
                                  round_up, tuned_knobs)
from repro.kernels.grouped_matmul import kernel as _k
from repro.kernels.grouped_matmul.ref import grouped_matmul_ref


@functools.partial(jax.jit, static_argnames=("bt", "bf", "bd", "rif",
                                              "interpret", "method"))
def _gmm_impl(x, w, block_expert, *, bt, bf, bd, rif, interpret, method):
    if method == "ref":
        return grouped_matmul_ref(x, w, block_expert, bt)
    t, d = x.shape
    e, _, f = w.shape
    tp, dp, fp = round_up(t, bt), round_up(d, bd), round_up(f, bf)
    if tp != t:
        # pad-and-mask tail block: zero token rows multiply to zero
        # output rows, sliced back off below
        x = jnp.pad(x, ((0, tp - t), (0, 0)))
    if dp != d:
        x = jnp.pad(x, ((0, 0), (0, dp - d)))
        w = jnp.pad(w, ((0, 0), (0, dp - d), (0, 0)))
    if fp != f:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, fp - f)))
    out = _k.gmm(x, w, block_expert.astype(jnp.int32), bt=bt, bf=bf, bd=bd,
                 rif=rif, interpret=interpret)
    return out[:t, :f]


def grouped_matmul(x: jax.Array, w: jax.Array, block_expert: jax.Array, *,
                   bt: int = 128, bf: Optional[int] = None,
                   bd: Optional[int] = None, rif: Optional[int] = None,
                   method: str = "pallas",
                   interpret: Optional[bool] = None) -> jax.Array:
    """Expert-grouped GEMM: x (T, D) with tokens sorted by expert and
    grouped into ``bt``-token blocks; block_expert (ceil(T/bt),) is the
    expert of each token block; w (E, D, F).  Returns (T, F).

    A tail block (``T % bt != 0``) is padded with zero token rows and
    the padding is masked back off the result; ``T == 0`` (every expert
    group empty) short-circuits to an empty (0, F) result.  Experts that
    no block routes to are simply never fetched.

    ``bf``/``bd`` left ``None`` resolve via the tune cache (128/512);
    ``rif`` (the expert-weight ring depth) resolves explicit →
    tune-cache → ``plan_rif`` over one (bd, bf) tile's byte size.
    """
    t, d = x.shape
    f = w.shape[2]
    nblk = cdiv(t, bt)
    if block_expert.shape[0] != nblk:
        raise ValueError(
            f"block_expert has {block_expert.shape[0]} entries for "
            f"{nblk} token blocks (T={t}, bt={bt})")
    if t == 0:
        return jnp.zeros((0, f), x.dtype)
    interp = resolve_interpret(interpret)
    if bf is None or bd is None or rif is None:
        knobs = tuned_knobs("grouped_matmul", (t, d, f), x.dtype, interp,
                            bf=(bf, 128), bd=(bd, 512), rif=(rif, None))
        bf, bd, rif = knobs["bf"], knobs["bd"], knobs["rif"]
    bd = min(bd, round_up(d, 128))
    bf = min(bf, round_up(f, 128))
    rif = ring_rif(rif, bd * bf * x.dtype.itemsize)
    return _gmm_impl(x, w, block_expert, bt=bt, bf=bf, bd=bd, rif=rif,
                     interpret=interp, method=method)
