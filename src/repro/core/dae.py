"""Explicit-decoupling programming model (DAE4HLS §3).

This module embeds the paper's four primitives

    stream_enq(channel, value)        stream_deq(channel, capacity)
    decouple_request(channel, addr)   decouple_response(channel, capacity)

as an executable program representation.  A *DAE program* is a set of
communicating sequential processes (the paper's Access / Execute loops,
instantiated as parallel execution units by the HLS `dataflow` pragma).
Each process is a Python generator that yields effect objects; the
scheduler in :mod:`repro.core.simulator` executes them either

  * functionally (zero-latency memory) to check algorithmic correctness, or
  * under a cycle-level timing model (fixed-latency AXI or a MOMS-like
    coalescing memory) to reproduce the paper's cycle counts.

The same programs therefore serve as the paper-faithful reproduction and
as the oracle for the TPU adaptation in :mod:`repro.core.decouple`.

Correctness rules (paper §5.1) are enforced structurally:

  * every ``decouple_request`` must be matched by exactly one
    ``decouple_response`` on the same channel (checked at program end);
  * a request blocks while the channel already has ``capacity`` responses
    in flight or queued (deadlock-freedom by capacity bounding, §5.4);
  * streams block on enq when full and on deq when empty; leftover stream
    entries at termination are reported as a conservation violation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Generator, Iterable, List, Optional, Tuple

__all__ = [
    "Channel",
    "LoadChannel",
    "StreamChannel",
    "Req",
    "Resp",
    "Enq",
    "Deq",
    "Delay",
    "Store",
    "StoreWait",
    "Halt",
    "Process",
    "DaeProgram",
    "ConservationError",
]


class ConservationError(RuntimeError):
    """Raised when request/response or enq/deq counts do not match."""


# ---------------------------------------------------------------------------
# Channels
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Channel:
    """Base point-to-point channel identified by name.

    ``capacity`` bounds the number of in-flight entries; the paper passes
    capacity at the dequeue site (Listing 1), we attach it to the channel
    object (equivalent, single consumer).
    """

    name: str
    capacity: int = 16

    def __post_init__(self) -> None:
        if self.capacity < 1:
            raise ValueError(f"channel {self.name}: capacity must be >= 1")


@dataclasses.dataclass
class StreamChannel(Channel):
    """In-order value FIFO between two program points (paper §3.1)."""


@dataclasses.dataclass
class LoadChannel(Channel):
    """Decoupled-load channel (paper §3.2).

    A request enqueues an *address*; the memory subsystem supplies the
    response.  ``port`` names the memory port (AXI interface / HBM stream)
    this channel issues on; multiple channels may share a port, which is
    exactly the Mergesort deadlock scenario of §5.3 that capacity
    bounding protects against.
    """

    port: str = "mem"


# ---------------------------------------------------------------------------
# Effects yielded by processes
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Req:
    """decouple_request(channel, addr): issue a load for ``addr``."""

    channel: LoadChannel
    addr: int


@dataclasses.dataclass
class Resp:
    """decouple_response(channel): consume the oldest response (in order).

    The scheduler sends the loaded value back into the generator.
    """

    channel: LoadChannel


@dataclasses.dataclass
class Enq:
    """stream_enq(channel, value)."""

    channel: StreamChannel
    value: Any


@dataclasses.dataclass
class Deq:
    """stream_deq(channel) -> value (sent back into the generator)."""

    channel: StreamChannel


@dataclasses.dataclass
class Delay:
    """Occupy the process for ``cycles`` cycles of compute."""

    cycles: int = 1


@dataclasses.dataclass
class Store:
    """Issue a store of ``value`` to ``addr`` on ``port`` (fire and forget;

    ordering per static AXI ID is guaranteed by the memory model, paper
    §5.4)."""

    port: str
    addr: int
    value: Any


@dataclasses.dataclass
class StoreWait:
    """Wait until all previously issued stores on ``port`` are observable

    (the write-response channel of §5.4)."""

    port: str


@dataclasses.dataclass
class Halt:
    """Explicit end-of-process marker (optional; returning also halts)."""


Effect = Any
ProcessGen = Generator[Effect, Any, None]


@dataclasses.dataclass
class Process:
    """A named sequential process (one Access or Execute loop).

    ``gen`` accepts either a live generator (legacy, single-shot) or a
    zero-argument *factory* returning a fresh generator.  Factory-built
    processes are rebuildable: :meth:`fresh` re-instantiates them, which
    is what lets :meth:`DaeProgram.validate_channels` dry-run a program
    without consuming the generators the timed simulation will pump.
    Factories must create all of their mutable loop state inside the
    generator body (every builder in :mod:`repro.core.workloads` does).

    ``ii`` is the initiation interval floor imposed by the *schedule* of
    the surrounding implementation: statically scheduled HLS (the Vitis
    baseline) often cannot reach II=1 for these loops (paper §7), while
    dynamically scheduled R-HLS can.  Every yielded effect costs at least
    ``ii`` cycles of issue occupancy on the process.
    """

    name: str
    gen: Any  # ProcessGen, or Callable[[], ProcessGen] (a factory)
    ii: int = 1
    factory: Optional[Callable[[], ProcessGen]] = None

    def __post_init__(self) -> None:
        # live generators are not callable; factories (generator
        # functions, partials, closures) are
        if self.factory is None and callable(self.gen):
            self.factory = self.gen
        if self.factory is not None and (self.gen is self.factory
                                         or self.gen is None):
            self.gen = self.factory()

    @property
    def rebuildable(self) -> bool:
        return self.factory is not None

    def fresh(self) -> "Process":
        """A new :class:`Process` with a freshly instantiated generator
        (requires a factory)."""
        if self.factory is None:
            raise ValueError(
                f"process {self.name!r} was built from a live generator "
                f"and cannot be re-instantiated; pass the generator "
                f"function itself to Process to make it rebuildable")
        return Process(self.name, self.factory, ii=self.ii)


@dataclasses.dataclass
class DaeProgram:
    """A set of processes plus the memory ports they reference."""

    name: str
    processes: List[Process]
    # map port name -> one of the simulator's memory models; filled by the
    # scheduler, declared here so programs are self-describing.
    ports: Tuple[str, ...] = ("mem",)

    @property
    def rebuildable(self) -> bool:
        """True when every process carries a generator factory, so the
        program can be validated and re-instantiated at will."""
        return all(p.rebuildable for p in self.processes)

    def fresh(self) -> "DaeProgram":
        """A new program with freshly instantiated process generators
        (requires every process to be rebuildable)."""
        return dataclasses.replace(
            self, processes=[p.fresh() for p in self.processes])

    def validate_channels(
        self,
        memories: Optional[Dict[str, Any]] = None,
        max_steps: int = 1_000_000,
    ) -> Dict[str, Channel]:
        """Discover every channel via a functional (zero-latency,
        unbounded-capacity) dry run and reject conflicting declarations.

        Channels are created dynamically, so static inspection cannot see
        them; instead the program is executed functionally — loads answer
        immediately from ``memories`` (``{port: indexable}``; absent ports
        serve 0), capacities never block.  Two distinct channel objects
        sharing a name must agree on type and capacity, otherwise the
        timed simulation would silently bind both to one FIFO whose
        capacity depends on scheduling order — that is the §5.3/§5.4
        misconfiguration this check exists to catch.

        Returns ``{name: channel}``.  Raises :class:`ValueError` on a
        conflict and :class:`ConservationError` if the dry run stalls or
        ends with undrained channels (§5.1).

        When every process carries a generator *factory* (pass the
        generator function to :class:`Process` instead of calling it),
        the dry run pumps fresh instances and leaves the program's own
        generators untouched — validate-then-simulate needs no rebuild.
        Legacy programs built from live generators are still accepted,
        but for them (and only them) the dry run consumes the
        generators: validate a freshly built program, then rebuild it
        before simulating.  The staged compiler in :mod:`repro.compile`
        requires the factory form outright — its elaborate pass pumps
        this same loop twice and hands the untouched program back.
        """
        from repro.core.simulator import Fused, Par  # deferred: no cycle

        memories = memories or {}
        seen: Dict[str, Channel] = {}
        fifos: Dict[str, List[Any]] = {}

        def note(ch: Channel) -> None:
            prev = seen.get(ch.name)
            if prev is None:
                seen[ch.name] = ch
            elif prev is not ch and (type(prev) is not type(ch)
                                     or prev.capacity != ch.capacity):
                raise ValueError(
                    f"channel {ch.name!r} declared twice with conflicting "
                    f"{type(prev).__name__}(capacity={prev.capacity}) vs "
                    f"{type(ch).__name__}(capacity={ch.capacity})")

        def ready(eff: Any) -> bool:
            if isinstance(eff, (Resp, Deq)):
                note(eff.channel)
                return bool(fifos.get(eff.channel.name))
            if isinstance(eff, Par):
                return all(ready(s) for s in eff.effects)
            if isinstance(eff, Fused):
                return ready(eff.first)
            return True

        def run(eff: Any) -> Any:
            if isinstance(eff, Req):
                note(eff.channel)
                data = memories.get(eff.channel.port)
                value = data[eff.addr] if data is not None else 0
                fifos.setdefault(eff.channel.name, []).append(value)
                return None
            if isinstance(eff, (Resp, Deq)):
                note(eff.channel)
                return fifos[eff.channel.name].pop(0)
            if isinstance(eff, Enq):
                note(eff.channel)
                fifos.setdefault(eff.channel.name, []).append(eff.value)
                return None
            if isinstance(eff, Par):
                return tuple(run(s) for s in eff.effects)
            if isinstance(eff, Fused):
                value = run(eff.first)
                follow = eff.then(value)
                if follow is not None:
                    if not ready(follow):
                        # §simulator contract: the follow-up must be
                        # non-blocking by construction
                        raise ConservationError(
                            f"{self.name}: Fused follow-up {follow!r} "
                            f"would block (empty channel) — fused effects "
                            f"must be non-blocking by construction")
                    run(follow)
                return value
            return None  # Delay / Store / StoreWait / Halt

        gens = [(p.name, p.factory() if p.rebuildable else p.gen)
                for p in self.processes]
        steps = 0

        def advance(i: int, send: Any) -> Any:
            """Resume process i; its next effect, or None when finished."""
            nonlocal steps
            steps += 1
            if steps > max_steps:
                raise ConservationError(
                    f"{self.name}: dry run exceeded {max_steps} steps")
            try:
                return gens[i][1].send(send)
            except StopIteration:
                return None

        pending = {i: advance(i, None) for i in range(len(gens))}
        pending = {i: e for i, e in pending.items() if e is not None}
        while pending:
            progressed = False
            for i in list(pending):
                eff = pending[i]
                while eff is not None and ready(eff):
                    progressed = True
                    if isinstance(eff, Halt):
                        eff = None
                        break
                    eff = advance(i, run(eff))
                if eff is None:
                    pending.pop(i)
                else:
                    pending[i] = eff
            if pending and not progressed:
                stuck = [gens[i][0] for i in pending]
                raise ConservationError(
                    f"{self.name}: functional dry run stalled "
                    f"(processes {stuck} blocked on empty channels)")
        leftover = {n: len(f) for n, f in fifos.items() if f}
        if leftover:
            raise ConservationError(
                f"{self.name}: dry run ended with undrained channels "
                f"{leftover}")
        return seen


# ---------------------------------------------------------------------------
# Helpers used by workload authors
# ---------------------------------------------------------------------------


def request_all(channel: LoadChannel, addrs: Iterable[int]) -> ProcessGen:
    """An Access loop that issues one request per address (paper Listing 2/3)."""

    for a in addrs:
        yield Req(channel, a)


def drain(channel: StreamChannel, n: int) -> ProcessGen:
    for _ in range(n):
        yield Deq(channel)
