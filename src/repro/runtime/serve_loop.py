"""Decoupled Access/Execute serving pipeline (paper §3 applied to serving).

The legacy loop (kept below as :class:`LegacyServeLoop`) admitted each
request by feeding its prompt one token at a time through the
*full-batch* decode step: admitting a P-token prompt cost P full-batch
rounds during which every already-active slot was stalled — and, worse,
each warmup round also ran the decode step for the stalled slots,
scattering their current token into their KV caches once per prompt
token and never resetting a recycled slot's cache length.  That loop is
the textbook *coupled* access/execute program of DAE4HLS §3: one
lock-step stream in which a slow access (prefill) serializes everything
behind it.

The rewrite splits serving into two engines joined by explicit bounded
channels (the ``repro.core`` channel/occupancy vocabulary — the same
:class:`~repro.core.trace.Tracer` that profiles the DAE simulator
profiles serving):

    requests ──admit──▶ [ACCESS: admission + chunked batched prefill]
                 │                    │
                 │              prefill_done (first token rides along)
                 │                    ▼
                 └─◀─free_slots── [EXECUTE: dense batched decode] ──▶ results

Both engines drive ONE compiled primitive, ``bundle.prefill``:

  * the Access engine advances every admitting slot by up to ``chunk``
    prompt tokens per step (one call, all slots batched) — admitting a
    P-token prompt costs ceil(P / chunk) steps instead of P;
  * the Execute engine calls the same primitive at chunk width 1 with a
    0/1 per-slot valid mask — a *masked* decode step under which
    inactive and mid-prefill slots are provably untouched (validity
    gates every cache scatter and recurrent-state update).

The scheduler interleaves them one step per round, so the dense decode
stream never stalls for more than a single prefill chunk.  Greedy
outputs are bit-identical to the legacy loop on the cells where the
legacy loop was actually correct (one slot, one request at a time);
``tests/test_serve_loop.py`` pins both that and the teacher-forced
chunked-prefill/per-token equivalence per architecture family.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trace import Tracer

# slot phases
_FREE, _PREFILL, _HANDOFF, _DECODE = 0, 1, 2, 3


def _shared_jit(fn):
    """One jit wrapper (and hence one compile cache) per bundle
    function, shared across every loop instance built on that bundle —
    constructing a fresh ServeLoop costs no recompilation.  The wrapper
    is stashed on the function itself so it dies with the bundle."""
    jitted = getattr(fn, "_serve_jit", None)
    if jitted is None:
        jitted = jax.jit(fn)
        fn._serve_jit = jitted
    return jitted


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (P,) int — P may be 0 (treated as [bos])
    max_new: int = 16
    out: Optional[List[int]] = None
    frames: Optional[np.ndarray] = None   # encdec: (S_enc, D) frontend frames


class Channel:
    """Bounded FIFO between the serving engines.

    The serving analogue of the simulator's channel FIFOs: ``push``
    refuses beyond ``capacity`` (backpressure), and every push/pop
    reports the post-event depth to the tracer under the ``serve``
    instance — so serve traces read exactly like DAE program traces.
    """

    def __init__(self, name: str, capacity: Optional[int] = None,
                 tracer: Optional[Tracer] = None):
        self.name = name
        self.capacity = capacity
        self._q: deque = deque()
        self._tracer = tracer

    def push(self, item: Any) -> bool:
        if self.capacity is not None and len(self._q) >= self.capacity:
            return False
        self._q.append(item)
        if self._tracer is not None:
            self._tracer.on_occupancy("serve", self.name, len(self._q))
        return True

    def pop(self) -> Any:
        item = self._q.popleft()
        if self._tracer is not None:
            self._tracer.on_occupancy("serve", self.name, len(self._q))
        return item

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)


@dataclasses.dataclass
class ServeStats:
    """Counters the serve bench reports; ttft is wall-clock seconds from
    ``run()`` start to each request's first emitted token."""

    rounds: int = 0
    prefill_steps: int = 0
    decode_steps: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    admitted: int = 0
    ttft: Dict[int, float] = dataclasses.field(default_factory=dict)


class ServeLoop:
    """Continuous batching with decoupled chunked prefill (Access) and
    dense masked decode (Execute).

    ``chunk`` is the Access engine's tokens-per-step (the decoupling
    knob: larger chunks amortize dispatch, smaller chunks bound the
    decode stream's stall).  ``tracer`` (a ``repro.core.trace.Tracer``)
    records channel occupancy; ``stats`` counts steps/tokens and TTFT.
    Encoder-decoder bundles are served too: requests carry ``frames``,
    encoded once at admission into a per-slot encoder-output buffer.
    """

    def __init__(self, cfg, bundle, params, batch_slots: int, s_max: int,
                 eos_id: int = -1, chunk: int = 32, bos_id: int = 0,
                 tracer: Optional[Tracer] = None,
                 admit_capacity: Optional[int] = None):
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        self.cfg = cfg
        self.bundle = bundle
        self.params = params
        self.b = batch_slots
        self.s_max = s_max
        self.eos = eos_id
        self.chunk = chunk
        self.bos = bos_id
        self.tracer = tracer
        self.cache = bundle.cache_init(batch_slots, s_max)
        self.pos = np.zeros(batch_slots, np.int32)
        self.cur = np.zeros(batch_slots, np.int32)
        self.remaining = np.zeros(batch_slots, np.int64)
        self.phase = np.full(batch_slots, _FREE, np.int8)
        self.active: List[Optional[Request]] = [None] * batch_slots
        self._ptr = np.zeros(batch_slots, np.int64)     # prefill progress
        self._prompt: List[Optional[np.ndarray]] = [None] * batch_slots

        self._encdec = cfg.family == "encdec"
        if self._encdec:
            self._encode = _shared_jit(bundle.encode)
            self.enc_out = None                         # allocated lazily
        self._fwd = _shared_jit(bundle.prefill)
        self._reset = _shared_jit(bundle.cache_reset)

        # explicit bounded channels between the engines
        self.admit_q = Channel("admit", admit_capacity, tracer)
        self.handoff = Channel("prefill_done", batch_slots, tracer)
        self.free_slots = Channel("free_slots", batch_slots, tracer)
        for s in range(batch_slots):
            self.free_slots.push(s)
        self.stats = ServeStats()

    # -- shared step dispatch ------------------------------------------------

    def _step(self, tok: np.ndarray, n_valid: np.ndarray):
        args = (jnp.asarray(tok, jnp.int32), jnp.asarray(self.pos),
                jnp.asarray(n_valid, jnp.int32))
        if self._encdec:
            logits, self.cache = self._fwd(self.params, self.enc_out,
                                           self.cache, *args)
        else:
            logits, self.cache = self._fwd(self.params, self.cache, *args)
        return np.asarray(logits)

    # -- Access engine: admission + chunked prefill --------------------------

    def _admit(self) -> None:
        reset: List[int] = []
        while self.free_slots and self.admit_q:
            slot = self.free_slots.pop()
            req = self.admit_q.pop()
            prompt = np.asarray(req.prompt, np.int64).reshape(-1)
            if prompt.size == 0:
                # empty prompt: generate from an implicit BOS token
                prompt = np.array([self.bos], np.int64)
            req.out = []
            self.active[slot] = req
            self._prompt[slot] = prompt
            self._ptr[slot] = 0
            self.pos[slot] = 0
            self.phase[slot] = _PREFILL
            self.stats.admitted += 1
            reset.append(slot)
        if reset:
            keep = np.ones(self.b, bool)
            keep[reset] = False
            self.cache = self._reset(self.cache, jnp.asarray(keep))
            if self._encdec:
                self._encode_slots(reset)

    def _encode_slots(self, slots: List[int]) -> None:
        for slot in slots:
            req = self.active[slot]
            if req.frames is None:
                raise ValueError(f"request {req.rid}: encdec serving "
                                 "requires Request.frames")
            row = self._encode(self.params, jnp.asarray(req.frames)[None])
            if self.enc_out is None:
                # the per-slot encoder-output buffer (and hence the jit
                # signature of the decode/prefill step) is sized by the
                # first request; callers must pad frames to one fixed
                # encoder length per loop
                self.enc_out = jnp.zeros((self.b,) + row.shape[1:],
                                         row.dtype)
            elif row.shape[1:] != self.enc_out.shape[1:]:
                raise ValueError(
                    f"request {req.rid}: frames encode to {row.shape[1:]} "
                    f"but this loop's encoder buffer is "
                    f"{self.enc_out.shape[1:]}; pad all requests' frames "
                    "to one fixed encoder length per ServeLoop")
            self.enc_out = self.enc_out.at[slot].set(row[0])

    def _prefill_step(self, t0: float, results: Dict[int, List[int]]) -> None:
        slots = np.flatnonzero(self.phase == _PREFILL)
        if slots.size == 0:
            return
        tok = np.zeros((self.b, self.chunk), np.int64)
        n_valid = np.zeros(self.b, np.int64)
        for slot in slots:
            prompt = self._prompt[slot]
            n = min(self.chunk, prompt.size - self._ptr[slot])
            tok[slot, :n] = prompt[self._ptr[slot]:self._ptr[slot] + n]
            n_valid[slot] = n
        logits = self._step(tok, n_valid)
        self.stats.prefill_steps += 1
        self.stats.prefill_tokens += int(n_valid.sum())
        for slot in slots:
            self._ptr[slot] += n_valid[slot]
            self.pos[slot] += n_valid[slot]
            if self._ptr[slot] < self._prompt[slot].size:
                continue
            # prompt complete: the chunk's last-valid logits are the
            # prediction after the final prompt token — the first output
            # token rides the handoff channel into the Execute engine,
            # which activates the slot when it pops the entry
            req = self.active[slot]
            first = int(np.argmax(logits[slot]))
            req.out.append(first)
            self.stats.ttft[req.rid] = time.perf_counter() - t0
            self.remaining[slot] = req.max_new - 1
            if first == self.eos or self.remaining[slot] <= 0:
                self._finish(slot, results)
            else:
                self.phase[slot] = _HANDOFF
                self.handoff.push((slot, first))

    # -- Execute engine: dense masked decode ---------------------------------

    def _decode_step(self, results: Dict[int, List[int]]) -> None:
        # absorb freshly prefilled slots: the (slot, first token) entry
        # on the handoff channel is what activates decoding
        while self.handoff:
            slot, first = self.handoff.pop()
            self.cur[slot] = first
            self.phase[slot] = _DECODE
        active = self.phase == _DECODE
        if not active.any():
            return
        logits = self._step(self.cur[:, None], active.astype(np.int64))
        nxt = np.argmax(logits, axis=-1)
        self.stats.decode_steps += 1
        self.stats.decode_tokens += int(active.sum())
        for slot in np.flatnonzero(active):
            tok = int(nxt[slot])
            req = self.active[slot]
            req.out.append(tok)
            self.cur[slot] = tok
            self.pos[slot] += 1
            self.remaining[slot] -= 1
            if tok == self.eos or self.remaining[slot] <= 0:
                self._finish(slot, results)

    def _finish(self, slot: int, results: Dict[int, List[int]]) -> None:
        req = self.active[slot]
        results[req.rid] = req.out
        self.active[slot] = None
        self._prompt[slot] = None
        self.phase[slot] = _FREE
        self.free_slots.push(slot)

    # -- scheduler -----------------------------------------------------------

    def run(self, requests: List[Request], max_rounds: int = 100_000
            ) -> Dict[int, List[int]]:
        results: Dict[int, List[int]] = {}
        t0 = time.perf_counter()
        # validate everything up front: rejecting a request after some
        # of this batch was admitted would leave slots mid-flight
        for req in requests:
            psize = max(1, np.asarray(req.prompt).size)   # empty -> [bos]
            if psize + req.max_new > self.s_max:
                raise ValueError(
                    f"request {req.rid}: prompt ({psize}) + max_new "
                    f"({req.max_new}) exceeds s_max ({self.s_max})")
            if self._encdec and req.max_new > 0 and req.frames is None:
                raise ValueError(f"request {req.rid}: encdec serving "
                                 "requires Request.frames")
        overflow = deque()          # requests beyond admit_q capacity
        for req in requests:
            if req.max_new <= 0:
                results[req.rid] = []
                continue
            if not self.admit_q.push(req):
                overflow.append(req)
        rounds = 0
        while (self.admit_q or overflow
               or (self.phase != _FREE).any()):
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError("serve loop exceeded max_rounds")
            while overflow and self.admit_q.push(overflow[0]):
                overflow.popleft()
            self._admit()
            self._decode_step(results)
            self._prefill_step(t0, results)
        self.stats.rounds = rounds
        return results


class LegacyServeLoop:
    """The coupled pre-rewrite loop, kept as the serving baseline.

    Admission prefills one token at a time through the FULL-BATCH decode
    step, so every active slot stalls for the whole prompt length (and
    has its KV cache polluted once per prompt token — the loop is only
    actually correct for one slot serving one request from a fresh
    cache).  ``benchmarks/serve_bench.py`` measures the decoupled loop
    against this one, and the parity tests pin bit-identical outputs on
    the cells where this loop is correct.
    """

    def __init__(self, cfg, bundle, params, batch_slots: int, s_max: int,
                 eos_id: int = -1, bos_id: int = 0):
        self.cfg = cfg
        self.bundle = bundle
        self.params = params
        self.b = batch_slots
        self.s_max = s_max
        self.eos = eos_id
        self.bos = bos_id
        self.cache = bundle.cache_init(batch_slots, s_max)
        self.pos = jnp.zeros((batch_slots,), jnp.int32)
        self.cur = jnp.zeros((batch_slots,), jnp.int32)
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.remaining = np.zeros(batch_slots, np.int64)
        self._step = _shared_jit(bundle.decode_step)

    def _admit(self, queue: List[Request],
               results: Dict[int, List[int]]) -> None:
        for slot in range(self.b):
            if self.active[slot] is None and queue:
                req = queue.pop(0)
                req.out = []
                self.active[slot] = req
                prompt = np.asarray(req.prompt, np.int64).reshape(-1)
                if prompt.size == 0:
                    # empty prompt: generate from an implicit BOS token
                    # (without this, no prefill iteration ran and
                    # ``logits`` below was unbound)
                    prompt = np.array([self.bos], np.int64)
                # prefill: feed prompt tokens through the decode step
                pos = 0
                for tok in prompt:
                    logits, self.cache = self._step(
                        self.params, self.cache,
                        self.cur.at[slot].set(int(tok)),
                        self.pos.at[slot].set(pos))
                    pos += 1
                first = int(jnp.argmax(logits[slot]))
                req.out.append(first)          # prefill's own prediction
                self.pos = self.pos.at[slot].set(pos)
                self.cur = self.cur.at[slot].set(first)
                self.remaining[slot] = req.max_new - 1
                if first == self.eos or self.remaining[slot] <= 0:
                    results[req.rid] = req.out
                    self.active[slot] = None

    def run(self, requests: List[Request], max_rounds: int = 10_000
            ) -> Dict[int, List[int]]:
        queue = []
        results: Dict[int, List[int]] = {}
        for req in requests:
            if req.max_new <= 0:
                results[req.rid] = []
                continue
            queue.append(req)
        rounds = 0
        while (queue or any(a is not None for a in self.active)):
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError("serve loop exceeded max_rounds")
            self._admit(queue, results)
            if not any(a is not None for a in self.active):
                continue
            logits, self.cache = self._step(self.params, self.cache,
                                            self.cur, self.pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self.pos = self.pos + jnp.asarray(
                [a is not None for a in self.active], jnp.int32)
            self.cur = nxt
            for slot, req in enumerate(self.active):
                if req is None:
                    continue
                tok = int(nxt[slot])
                req.out.append(tok)
                self.remaining[slot] -= 1
                if tok == self.eos or self.remaining[slot] <= 0:
                    results[req.rid] = req.out
                    self.active[slot] = None
        return results
