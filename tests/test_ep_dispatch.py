"""All-to-all EP dispatch == single-device MoE oracle (subprocess with 8
forced devices)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

# subprocess-spawning multi-device run, same tier as test_distributed
pytestmark = pytest.mark.slow

ROOT = Path(__file__).resolve().parents[1]


def test_ep_dispatch_matches_oracle():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_debug_mesh
        from repro.parallel.ep_dispatch import ep_moe_reference, make_ep_moe

        mesh = make_debug_mesh((2, 4), ("data", "model"))
        T, D, F, E, K = 32, 16, 32, 8, 2
        r = np.random.default_rng(0)
        x = jnp.asarray(r.standard_normal((T, D)), jnp.float32)
        router = jnp.asarray(r.standard_normal((D, E)) * 0.3, jnp.float32)
        wg = jnp.asarray(r.standard_normal((E, D, F)) * 0.2, jnp.float32)
        wu = jnp.asarray(r.standard_normal((E, D, F)) * 0.2, jnp.float32)
        wd = jnp.asarray(r.standard_normal((E, F, D)) * 0.2, jnp.float32)

        ref = ep_moe_reference(x, router, wg, wu, wd, K)
        # ample capacity -> dropless -> exact match with the oracle
        fn = make_ep_moe(mesh, top_k=K, n_experts=E,
                         capacity_per_shard=T * K)
        with mesh:
            out = jax.jit(fn)(x, router, wg, wu, wd)
        err = float(jnp.abs(out - ref).max())
        assert err < 2e-4, err
        print("EP-A2A-OK", err)

        # capacity bounding drops deterministically, never corrupts
        fn_tight = make_ep_moe(mesh, top_k=K, n_experts=E,
                               capacity_per_shard=2)
        with mesh:
            out2 = jax.jit(fn_tight)(x, router, wg, wu, wd)
        assert bool(jnp.isfinite(out2).all())
        print("EP-A2A-CAP-OK")
    """)], capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "EP-A2A-OK" in out.stdout
