"""Grouped expert matmul — decoupled SPMV generalized to MoE (paper §4.1).

After top-k routing, the token→expert map is CSR-shaped (group offsets =
``rows``).  The false dependency the paper removes for SPMV — products
gated by row-pointer loads — appears here as expert GEMMs gated by the
routing result.  Decoupling: ops.py sorts tokens by expert and emits a
``block_expert`` stream (one expert id per token block), and the expert
*weight* tiles stream through the shared ring emitter
(:mod:`repro.kernels.ring`): a ``rif``-deep
:class:`~repro.kernels.ring.RingChannel` issues the HBM→VMEM copy for
tile ``b + rif`` — an irregular, data-dependent read of expert
``block_expert[...]`` at an address only the routing result determines —
while the MXU multiplies tile ``b`` (the Access loop of Listing 4
running ``rif`` tiles ahead of Execute).  The ring spans the whole flat
(token-block, f-tile, d-tile) stream via
:func:`~repro.kernels.ring.ring_step`, so the prefetch depth crosses
expert boundaries instead of being whatever the Pallas pipeliner decides
for a BlockSpec index map.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ring import (RingChannel, clamp_rif,
                                ring_scratch_shapes, ring_step)


def _gmm_kernel(be_ref, x_ref, w_hbm, o_ref, acc, wscr, wsem, *,
                nb: int, nf: int, nd: int, bd: int, bf: int, rif: int):
    b = pl.program_id(0)
    kd = jax.lax.rem(b, nd)

    def src(q):
        # Decode the flat tile index the Access loop is fetching for (q
        # runs up to ``rif`` ahead of ``b``), then read the expert id out
        # of the scalar-prefetched routing stream — the data-dependent
        # request address.
        ti = q // (nf * nd)
        jf = jax.lax.rem(q // nd, nf)
        kk = jax.lax.rem(q, nd)
        return w_hbm.at[pl.ds(be_ref[ti], 1), pl.ds(kk * bd, bd),
                        pl.ds(jf * bf, bf)]

    ring = RingChannel(wscr, wsem, rif, src=src)

    def execute(w_tile):
        @pl.when(kd == 0)
        def _init():
            acc[...] = jnp.zeros_like(acc)

        acc[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), w_tile[0].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

        @pl.when(kd == nd - 1)
        def _flush():
            o_ref[...] = acc[...].astype(o_ref.dtype)

    ring_step([ring], b, nb, execute)


def gmm(x: jax.Array, w: jax.Array, block_expert: jax.Array, *, bt: int,
        bf: int, bd: int, rif: int, interpret: bool = True) -> jax.Array:
    """x (T, D) sorted by expert, T % bt == 0; w (E, D, F);
    block_expert (T//bt,) int32.  Returns (T, F).  ``rif`` expert weight
    tiles stream ahead of the consuming grid step."""
    t, d = x.shape
    e, _, f = w.shape
    ntb, nf, nd = t // bt, f // bf, d // bd
    nb = ntb * nf * nd
    rif = clamp_rif(rif, nb)
    kernel = functools.partial(_gmm_kernel, nb=nb, nf=nf, nd=nd, bd=bd,
                               bf=bf, rif=rif)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb,),
            in_specs=[
                pl.BlockSpec((bt, bd),
                             lambda b, be: (b // (nf * nd), b % nd)),
                pl.BlockSpec(memory_space=pl.ANY),
            ],
            out_specs=pl.BlockSpec(
                (bt, bf), lambda b, be: (b // (nf * nd), (b // nd) % nf)),
            scratch_shapes=[
                pltpu.VMEM((bt, bf), jnp.float32),
                *ring_scratch_shapes(rif, (1, bd, bf), w.dtype),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((t, f), x.dtype),
        interpret=interpret,
    )(block_expert, x, w)
