"""The paper's primary contribution: explicit decoupling (DAE4HLS).

Layers:
  * :mod:`repro.core.dae` / :mod:`repro.core.simulator` /
    :mod:`repro.core.workloads` — the paper-faithful programming model,
    the multi-instance shared-memory engine (cycle-level simulation of
    N concurrent programs with round-robin port arbitration; an
    event-driven scheduler by default, with the legacy pass-based
    scheduler kept as a bit-exact ``engine="polling"`` oracle), and the
    seven benchmark programs (Tables 1/3, Fig 4) plus their
    multi-tenant variants.
  * :mod:`repro.core.trace` — streaming traces of per-channel
    occupancy, request latency, and port utilization.
  * :mod:`repro.core.decouple` / :mod:`repro.core.pipeline` — the
    TPU-native decoupled ops (Pallas kernels behind a JAX API) and RIF
    planning used by the LM framework.

See ``docs/architecture.md`` for the full paper→code map.
"""

from repro.core.decouple import *  # noqa: F401,F403
