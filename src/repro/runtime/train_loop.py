"""Fault-tolerant training loop.

Recovery model (designed for 1000+ nodes, exercised here on CPU):
  * checkpoint every ``ckpt_every`` steps (async, atomic, retained);
  * on (re)start, auto-resume from the latest complete checkpoint; the
    synthetic data pipeline is step-indexed, so data continues exactly
    where the restored step left off;
  * transient step failures (injected in tests via ``failure_hook``)
    trigger restore-from-checkpoint and replay instead of a crash —
    ``max_restarts`` bounds the retry budget;
  * a straggler monitor flags slow steps (on real pods this drives
    slice re-formation; the elastic reshard path is load_pytree's
    device_put against the new mesh).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.straggler import StragglerMonitor

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    log_every: int = 10
    max_restarts: int = 3
    async_ckpt: bool = True


class StepFailure(RuntimeError):
    """Raised by failure hooks to simulate a node fault."""


def fit(
    train_step: Callable,               # (params, opt, batch) -> (p, o, metrics)
    params: Any,
    opt_state: Any,
    batch_at: Callable[[int], Dict[str, np.ndarray]],
    cfg: TrainLoopConfig,
    shardings: Any = None,              # (param_shardings, opt_shardings)
    failure_hook: Optional[Callable[[int], None]] = None,
    monitor: Optional[StragglerMonitor] = None,
) -> Dict[str, Any]:
    """Run to cfg.total_steps with checkpoint/restart fault tolerance."""
    mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep,
                            async_write=cfg.async_ckpt)
    monitor = monitor or StragglerMonitor()

    state = {"params": params, "opt": opt_state}
    start_step = 0
    restored = mgr.restore_latest(jax.eval_shape(lambda: state), shardings)
    if restored is not None:
        start_step, state, meta = restored
        log.info("resumed from step %d", start_step)

    step = start_step
    restarts = 0
    losses = []
    while step < cfg.total_steps:
        try:
            batch = batch_at(step)
            if failure_hook is not None:
                failure_hook(step)
            monitor.start()
            state["params"], state["opt"], metrics = train_step(
                state["params"], state["opt"], batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            monitor.stop(step)
            losses.append(metrics["loss"])
            step += 1
            if step % cfg.log_every == 0:
                log.info("step %d loss %.4f", step, metrics["loss"])
            if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
                mgr.save(step, state, meta={"loss": metrics["loss"]},
                         block=not cfg.async_ckpt)
        except StepFailure as e:
            restarts += 1
            log.warning("step %d failed (%s); restart %d/%d", step, e,
                        restarts, cfg.max_restarts)
            if restarts > cfg.max_restarts:
                raise
            restored = mgr.restore_latest(jax.eval_shape(lambda: state),
                                          shardings)
            if restored is None:
                step = 0          # no checkpoint yet: replay from scratch
            else:
                step, state, _ = restored
    # final synchronous checkpoint so restarts after completion are clean
    mgr.save(step, state, block=True)
    return {"state": state, "steps": step, "losses": losses,
            "restarts": restarts, "straggler_events": monitor.events}
