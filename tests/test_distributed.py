"""Distribution tests that need multiple devices: run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps seeing ONE device."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow

ROOT = Path(__file__).resolve().parents[1]


def _run(snippet: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(snippet)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_sharded_train_step_runs():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.shapes import InputShape
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import shard_train_step, default_optimizer
        from repro.models.registry import build_model
        from repro.parallel.sharding import param_shardings

        mesh = make_debug_mesh((2, 4), ("data", "model"))
        cfg = get_config("qwen3-4b", smoke=True)
        shape = InputShape("t", 32, 8, "train")
        with mesh:
            jitted, specs = shard_train_step(cfg, mesh, shape)
            bundle = build_model(cfg)
            params = bundle.init(jax.random.PRNGKey(0))
            opt = default_optimizer()
            opt_state = opt.init(params)
            batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                     "labels": jnp.zeros((8, 32), jnp.int32)}
            p2, o2, m = jitted(params, opt_state, batch)
            # second step (donated buffers) with the *new* state
            p3, o3, m2 = jitted(p2, o2, batch)
            assert np.isfinite(float(m2["loss"]))
            assert float(m2["loss"]) <= float(m["loss"]) + 1.0
        print("SHARDED-TRAIN-OK", float(m["loss"]))
    """))


def test_sharded_serve_step_runs():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.shapes import InputShape
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import shard_serve_step
        from repro.models.registry import build_model

        mesh = make_debug_mesh((2, 4), ("data", "model"))
        cfg = get_config("hymba-1.5b", smoke=True)
        shape = InputShape("d", 64, 8, "decode")
        with mesh:
            jitted, specs = shard_serve_step(cfg, mesh, shape, donate=False)
            bundle = build_model(cfg)
            params = bundle.init(jax.random.PRNGKey(0))
            cache = bundle.cache_init(8, 64)
            tok = jnp.zeros((8,), jnp.int32)
            pos = jnp.zeros((8,), jnp.int32)
            logits, cache = jitted(params, cache, tok, pos)
            logits2, _ = jitted(params, cache, tok, pos + 1)
            assert np.isfinite(np.asarray(logits2)).all()
        print("SHARDED-SERVE-OK")
    """))


def test_compressed_psum_shardmap():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_debug_mesh
        from repro.parallel.compress import compressed_psum

        mesh = make_debug_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 128)) * 0.01
        res = jnp.zeros_like(g)

        def f(g, r):
            return compressed_psum(g, r, "data")

        out, new_res = jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data"))))(g, res)
        true_mean = g.mean(axis=0, keepdims=True)
        got = np.asarray(out)  # every shard row = mean over shards
        err = np.abs(got - np.asarray(true_mean)).max()
        scale = np.abs(np.asarray(g)).max() / 127
        assert err <= 8 * scale + 1e-6, (err, scale)
        print("COMPRESS-OK", float(err))
    """))


def test_pipeline_parallel_forward():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_debug_mesh
        from repro.parallel.pp import pipeline_forward

        S, M = 4, 6
        mesh = make_debug_mesh((S,), ("stage",))
        ws = jax.random.normal(jax.random.PRNGKey(0), (S, 16, 16)) * 0.3

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        x = jax.random.normal(jax.random.PRNGKey(1), (M, 2, 16))
        out = pipeline_forward(stage_fn, ws, x, mesh, axis="stage")
        ref = x
        for i in range(S):
            ref = jnp.tanh(ref @ ws[i])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print("PP-OK")
    """))


def test_elastic_checkpoint_reshard():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_pytree, load_pytree
        from repro.launch.mesh import make_debug_mesh

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        with tempfile.TemporaryDirectory() as d:
            save_pytree(d + "/c.npz", tree, {"step": 1})
            # restore onto a DIFFERENT mesh/sharding (elastic reshard)
            mesh = make_debug_mesh((4, 2), ("data", "model"))
            sh = {"w": NamedSharding(mesh, P("data", "model"))}
            out, meta = load_pytree(d + "/c.npz",
                                    jax.eval_shape(lambda: tree), sh)
            assert out["w"].sharding == sh["w"]
            np.testing.assert_array_equal(np.asarray(out["w"]),
                                          np.asarray(tree["w"]))
        print("ELASTIC-OK")
    """))


def test_dryrun_mini_mesh():
    """End-to-end dry-run machinery on a small forced mesh (the real
    512-device run is exercised by launch/dryrun.py itself)."""
    print(_run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.shapes import InputShape
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import shard_train_step
        from repro.launch.hlo_stats import collective_stats

        mesh = make_debug_mesh((2, 4), ("data", "model"))
        cfg = get_config("granite-moe-3b-a800m", smoke=True)
        shape = InputShape("t", 32, 8, "train")
        with mesh:
            jitted, specs = shard_train_step(cfg, mesh, shape)
            lowered = jitted.lower(*specs)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            cs = collective_stats(compiled.as_text())
        assert cost["flops"] > 0
        assert cs["_total"]["count"] > 0
        print("DRYRUN-MINI-OK", int(cs["_total"]["count"]))
    """))
