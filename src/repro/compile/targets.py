"""Named compile targets: workload programs wired up for the compiler.

Each target builds the *same* DAE program the simulator runs (from
:mod:`repro.core.workloads`), packages the plain port data for
:func:`repro.compile.compile_program`, and knows how to produce the
simulator oracle for differential parity.  This module is what the
parity tests, ``benchmarks/compile_bench`` and ``tune_compiled`` all
drive — one registry, no per-consumer re-wiring.

Targets:

  ``gather``          STATIC stream; comparable with the hand-written
                      ``dae_gather`` family.
  ``frontier_gather`` one INDIRECT hop (``dist[adj[...]]``); has NO
                      hand-written kernel — the compile-only proof.
  ``binsearch``       DEPENDENT stream + ChaseSpec (early-exit variant;
                      the spec carries Listing 5's lock-step form and
                      the check pass proves it reproduces the
                      round-robin simulator's stores).
  ``binsearch_for``   as above, fixed-iteration variant.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.compile.ir import ChaseSpec

__all__ = ["COMPILE_TARGETS", "BuiltTarget", "build_target",
           "compile_target", "assert_parity"]


@dataclasses.dataclass
class BuiltTarget:
    """One target instance: program + data + (maybe) chase semantics."""

    name: str
    prog: Any                          # DaeProgram (rebuildable)
    memories: Dict[str, List[Any]]     # plain copies, safe to stage
    chase: Optional[ChaseSpec]
    out_lens: Dict[str, int]
    _oracle: Callable[[], Dict[str, np.ndarray]]

    def simulate_oracle(self) -> Dict[str, np.ndarray]:
        """Run the event-driven simulator on a fresh build and return
        its stored output ports as dense arrays."""
        return self._oracle()


def _mem_factory(latency: int):
    from repro.core.simulator import FixedLatencyMemory

    def make(port: str, data: Any):
        return FixedLatencyMemory(data, latency=latency)
    return make


def _oracle_from_phases(build_phases: Callable[[], Any],
                        out_lens: Dict[str, int]
                        ) -> Callable[[], Dict[str, np.ndarray]]:
    def run() -> Dict[str, np.ndarray]:
        from repro.core.simulator import simulate
        progs, mems, _golden, check = build_phases()
        result = None
        for prog in progs:
            result = simulate(prog, mems)
        assert result is not None and check(result), \
            "simulator self-check failed (oracle invalid)"
        outs: Dict[str, np.ndarray] = {}
        for port, n in out_lens.items():
            got = result.stored_array(port, n)
            if got and isinstance(got[0], np.ndarray):
                outs[port] = np.stack(got)
            else:
                outs[port] = np.asarray([-1 if g is None else g
                                         for g in got])
        return outs
    return run


def _binsearch_chase(data: Dict[str, Any], early: bool) -> ChaseSpec:
    """The binsearch loop as a ChaseSpec: the jnp twin of the
    ``fixed_step`` closure in ``_binsearch_phases`` (Listing 5's
    lock-step form — check proves it equals the early-exit trace)."""
    import jax.numpy as jnp

    arr, keys, n = data["arr"], data["keys"], int(data["n"])
    iters = int(math.ceil(math.log2(n)))
    m = len(keys)
    state0 = np.zeros((m, 5), np.int32)          # (i, key, lo, hi, res)
    state0[:, 0] = np.arange(m)
    state0[:, 1] = keys
    state0[:, 3] = n
    state0[:, 4] = -1

    def _mid(lo, hi):
        return jnp.where(lo < hi, (lo + hi) // 2, jnp.minimum(lo, n - 1))

    def addr_fn(s):
        _i, _key, lo, hi, _res = s
        return _mid(lo, hi)

    def step_fn(s, row):
        i, key, lo, hi, res = s
        v = row[0]
        mid = _mid(lo, hi)
        if early:
            res = jnp.where((v == key) & (res < 0), mid, res)
        take = lo < hi
        lo2 = jnp.where(take & (v <= key), mid + 1, lo)
        hi2 = jnp.where(take & (v > key), mid, hi)
        return (i, key, lo2, hi2, res)

    def out_fn(s):
        i, _key, lo, _hi, res = s
        return (i, res if early else lo)

    return ChaseSpec("table", state0, iters, addr_fn, step_fn, out_fn)


def _build_gather(scale: str, latency: int, rif: int) -> BuiltTarget:
    from repro.core import workloads as wl

    data = wl.make_gather_data(scale)
    m = len(data["idx"])

    def phases():
        return wl.gather_phases(data, latency, rif, _mem_factory(latency))

    progs, mems, _g, _c = phases()
    return BuiltTarget(
        name="gather", prog=progs[0],
        memories={p: list(mem.data) for p, mem in mems.items()},
        chase=None, out_lens={"out": m},
        _oracle=_oracle_from_phases(phases, {"out": m}))


def _build_frontier(scale: str, latency: int, rif: int) -> BuiltTarget:
    from repro.core import workloads as wl

    data = wl.make_frontier_data(scale)
    m = len(data["frontier"]) * data["deg"]

    def phases():
        return wl.frontier_phases(data, latency, rif,
                                  _mem_factory(latency))

    progs, mems, _g, _c = phases()
    return BuiltTarget(
        name="frontier_gather", prog=progs[0],
        memories={p: list(mem.data) for p, mem in mems.items()},
        chase=None, out_lens={"out": m},
        _oracle=_oracle_from_phases(phases, {"out": m}))


def _build_spmv_gather(scale: str, latency: int, rif: int) -> BuiltTarget:
    from repro.core import workloads as wl

    data = wl.make_spmv_data(scale)
    m = data["nnz"]

    def phases():
        return wl.spmv_gather_phases(data, latency, rif,
                                     _mem_factory(latency))

    progs, mems, _g, _c = phases()
    return BuiltTarget(
        name="spmv_gather", prog=progs[0],
        memories={p: list(mem.data) for p, mem in mems.items()},
        chase=None, out_lens={"out": m},
        _oracle=_oracle_from_phases(phases, {"out": m}))


def _build_binsearch(scale: str, latency: int, rif: int, *,
                     early: bool) -> BuiltTarget:
    from repro.core import workloads as wl

    data = wl.make_binsearch_data(scale)
    m = len(data["keys"])
    name = "binsearch" if early else "binsearch_for"

    def phases():
        return wl._binsearch_phases(data, "rhls_dec", early, latency,
                                    rif, _mem_factory(latency))

    progs, mems, _g, _c = phases()
    return BuiltTarget(
        name=name, prog=progs[0],
        memories={p: list(mem.data) for p, mem in mems.items()},
        chase=_binsearch_chase(data, early), out_lens={"out": m},
        _oracle=_oracle_from_phases(phases, {"out": m}))


COMPILE_TARGETS: Dict[str, Callable[..., BuiltTarget]] = {
    "gather": _build_gather,
    "frontier_gather": _build_frontier,
    "spmv_gather": _build_spmv_gather,
    "binsearch": lambda scale, latency, rif:
        _build_binsearch(scale, latency, rif, early=True),
    "binsearch_for": lambda scale, latency, rif:
        _build_binsearch(scale, latency, rif, early=False),
}


def build_target(name: str, scale: str = "small", *, latency: int = 100,
                 rif: int = 8) -> BuiltTarget:
    if name not in COMPILE_TARGETS:
        raise KeyError(f"unknown compile target {name!r}; have "
                       f"{sorted(COMPILE_TARGETS)}")
    return COMPILE_TARGETS[name](scale, latency, rif)


def compile_target(name: str, scale: str = "small", **kwargs):
    """Build + compile a named target in one call; returns
    ``(CompiledKernel, BuiltTarget)``."""
    from repro.compile import compile_program

    t = build_target(name, scale)
    ck = compile_program(t.prog, t.memories, chase=t.chase, **kwargs)
    return ck, t


def assert_parity(compiled: Dict[str, np.ndarray],
                  oracle: Dict[str, np.ndarray]) -> None:
    """Bit-identity up to the documented staging cast: both sides are
    compared in float64, which is exact for every target's value range
    (ints < 2**31, float32 data float32 end-to-end)."""
    for port, want in oracle.items():
        got = compiled.get(port)
        assert got is not None, f"compiled output missing port {port!r}"
        assert got.shape == want.shape, (port, got.shape, want.shape)
        assert np.array_equal(got.astype(np.float64),
                              want.astype(np.float64)), \
            f"compiled-vs-simulator mismatch on port {port!r}"
