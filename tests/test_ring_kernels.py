"""Differential tests pinning every ring-emitter kernel against its
ref.py oracle in interpret mode.

Two tiers over the same check helpers:

* a deterministic edge-case grid that always runs (rif=1, rif > chunk /
  tile count, non-multiple tails, empty runs) — the regimes where the
  shared emitter's prologue/steady-state/drain structure degenerates;
* hypothesis sweeps over the case strategies in ``tests/strategies.py``
  (skipped when the optional ``hypothesis`` extra is missing, as in the
  fast local tier; CI installs it).

Plus dispatch-order tests for the chase and grouped-matmul ops:
explicit knob → tune-cache winner → ``plan_rif`` analytic seeding.
"""

import numpy as np
import pytest

import jax.numpy as jnp


def _np(x):
    return np.asarray(x, np.float32)


# ---------------------------------------------------------------------------
# Check helpers (shared by the deterministic grid and hypothesis sweeps)
# ---------------------------------------------------------------------------


def check_gather(case, seed=0):
    from repro.kernels.dae_gather import dae_gather, gather_ref
    r = np.random.default_rng(seed)
    dtype = jnp.dtype(case["dtype"])
    table = jnp.asarray(r.standard_normal((case["n"], case["d"])), dtype)
    idx = jnp.asarray(r.integers(0, case["n"], case["m"]), jnp.int32)
    out = dae_gather(table, idx, method="rif", chunk=case["chunk"],
                     rif=case["rif"], interpret=True)
    np.testing.assert_array_equal(_np(out), _np(gather_ref(table, idx)))


def check_merge(case, seed=0):
    from repro.kernels.dae_merge import merge_ref, merge_sorted
    r = np.random.default_rng(seed)
    n, m = case["n"], case["m"]
    dtype = jnp.dtype(case["dtype"])
    if dtype == jnp.int32:
        a = jnp.sort(jnp.asarray(r.integers(0, 40, max(n, 1))[:n], dtype))
        b = jnp.sort(jnp.asarray(r.integers(0, 40, max(m, 1))[:m], dtype))
    else:
        a = jnp.sort(jnp.asarray(r.standard_normal(max(n, 1))[:n], dtype))
        b = jnp.sort(jnp.asarray(r.standard_normal(max(m, 1))[:m], dtype))
    out = merge_sorted(a, b, tile=case["tile"], rif=case["rif"],
                       interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(merge_ref(a, b)))


def check_spmv(case, seed=0):
    from repro.kernels.dae_spmv import csr_to_bsr, dae_spmv, spmv_ref
    r = np.random.default_rng(seed)
    nrows, ncols, nnz = case["nrows"], case["ncols"], case["nnz"]
    counts = r.multinomial(nnz, np.ones(nrows) / nrows) if nnz else \
        np.zeros(nrows, int)
    rows = np.zeros(nrows + 1, np.int64)
    rows[1:] = np.cumsum(counts)
    cols = r.integers(0, ncols, nnz)
    val = r.standard_normal(nnz).astype(np.float32)
    vec = r.standard_normal(ncols).astype(np.float32)
    vb, ri, ci, _, nrb = csr_to_bsr(rows, cols, val, ncols, bm=8, bk=128)
    out = dae_spmv(jnp.asarray(vb), jnp.asarray(ri), jnp.asarray(ci),
                   jnp.asarray(vec), nrb, rif=case["rif"],
                   interpret=True)[:nrows]
    ref = spmv_ref(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(val),
                   jnp.asarray(vec)) if nnz else np.zeros(nrows, np.float32)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


def check_decode(case, seed=0):
    from repro.kernels.flash_attention import decode_ref, flash_decode
    from repro.kernels.flash_attention.ops import flash_decode_paged
    r = np.random.default_rng(seed)
    b, kvh, g, bk = case["b"], case["kvh"], case["g"], case["bk"]
    s = case["nblk"] * bk
    h = kvh * g
    q = jnp.asarray(r.standard_normal((b, h, 32)), jnp.float32)
    kc = jnp.asarray(r.standard_normal((b, kvh, s, 32)), jnp.float32)
    vc = jnp.asarray(r.standard_normal((b, kvh, s, 32)), jnp.float32)
    lens = jnp.asarray(r.integers(1, s + 1, b), jnp.int32)
    ref = decode_ref(q, kc, vc, lens)
    out = flash_decode(q, kc, vc, lens, bk=bk, rif=case["rif"],
                       interpret=True)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-5)
    npb = s // bk
    kp = kc.transpose(0, 2, 1, 3).reshape(b * npb, bk, kvh, 32) \
        .transpose(0, 2, 1, 3)
    vp = vc.transpose(0, 2, 1, 3).reshape(b * npb, bk, kvh, 32) \
        .transpose(0, 2, 1, 3)
    pt = jnp.arange(b * npb, dtype=jnp.int32).reshape(b, npb)
    out2 = flash_decode_paged(q, kp, vp, pt, lens, rif=case["rif"],
                              interpret=True)
    np.testing.assert_allclose(out2, ref, rtol=2e-4, atol=2e-5)


def check_searchsorted(case, seed=0):
    from repro.kernels.dae_chase import batched_searchsorted, searchsorted_ref
    r = np.random.default_rng(seed)
    n, m = case["n"], case["m"]
    dtype = jnp.dtype(case["dtype"])
    if dtype == jnp.int32:
        # heavy duplicates: insertion points often straddle block edges
        table = jnp.sort(jnp.asarray(r.integers(0, max(2, n // 4), n), dtype))
        keys = jnp.asarray(r.integers(-2, max(2, n // 4) + 2, m), dtype)
    else:
        table = jnp.sort(jnp.asarray(r.standard_normal(n), dtype))
        keys = jnp.asarray(3 * r.standard_normal(m), dtype)
    out = batched_searchsorted(table, keys, block=case["block"],
                               chunk=case["chunk"], rif=case["rif"],
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(searchsorted_ref(table, keys)))


def check_hash(case, seed=0):
    from repro.kernels.dae_chase import hash_lookup, hash_lookup_ref
    r = np.random.default_rng(seed)
    chains, L, m = case["chains"], case["chain_len"], case["m"]
    n = chains * L
    ek = jnp.asarray(np.arange(n), jnp.int32)
    ev = jnp.asarray(r.integers(0, 1000, n), jnp.int32)
    en = jnp.asarray([(i + 1) if (i + 1) % L else -1 for i in range(n)],
                     jnp.int32)
    heads = jnp.asarray(r.integers(0, chains, m) * L, jnp.int32)
    depth = r.integers(0, L, m).astype(np.int32)
    present = heads + jnp.asarray(depth)
    missing = jnp.full(m, n + 17, jnp.int32)
    take_miss = r.random(m) < case["miss_rate"]
    keys = jnp.where(jnp.asarray(take_miss), missing, present)
    steps = max(1, L + case["extra_steps"])
    out = hash_lookup(ek, ev, en, heads, keys, max_steps=steps,
                      chunk=case["chunk"], rif=case["rif"], interpret=True)
    ref = hash_lookup_ref(ek, ev, en, heads, keys, steps)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def check_gmm(case, seed=0):
    from repro.kernels.grouped_matmul import grouped_matmul, grouped_matmul_ref
    r = np.random.default_rng(seed)
    t, d, f, e, bt = case["t"], case["d"], case["f"], case["e"], case["bt"]
    nblk = -(-t // bt)
    # small-integer data: every partial product and partial sum is exactly
    # representable in float32, so pallas-vs-ref equality stays bitwise no
    # matter how bd splits the contraction into accumulated tiles
    x = jnp.asarray(r.integers(-4, 5, (t, d)), jnp.float32)
    w = jnp.asarray(r.integers(-4, 5, (e, d, f)), jnp.float32)
    hi = case.get("experts_used", e)
    blk = jnp.asarray(r.integers(0, hi, nblk), jnp.int32)
    out = grouped_matmul(x, w, blk, bt=bt, bf=case["bf"], bd=case["bd"],
                         rif=case["rif"], interpret=True)
    ref = grouped_matmul_ref(x, w, blk, bt)
    assert out.shape == (t, f)
    np.testing.assert_array_equal(_np(out), _np(ref))


# ---------------------------------------------------------------------------
# Deterministic edge-case grid (always runs)
# ---------------------------------------------------------------------------


GATHER_EDGES = [
    dict(n=40, d=128, m=17, chunk=8, rif=1, dtype="float32"),   # rif=1
    dict(n=40, d=128, m=17, chunk=8, rif=64, dtype="float32"),  # rif>chunk
    dict(n=7, d=130, m=5, chunk=64, rif=4, dtype="bfloat16"),   # tails
    dict(n=1, d=8, m=1, chunk=1, rif=1, dtype="float32"),       # singleton
]

MERGE_EDGES = [
    dict(n=100, m=300, tile=64, rif=1, dtype="float32"),
    dict(n=100, m=300, tile=64, rif=64, dtype="float32"),       # rif>tiles
    dict(n=17, m=5, tile=16, rif=2, dtype="int32"),             # tails
    dict(n=0, m=3, tile=16, rif=3, dtype="float32"),            # empty run
]

SPMV_EDGES = [
    dict(nrows=16, ncols=256, nnz=64, rif=1),
    dict(nrows=16, ncols=256, nnz=64, rif=64),                  # rif>nb
    dict(nrows=33, ncols=300, nnz=120, rif=3),                  # tails
    dict(nrows=8, ncols=128, nnz=0, rif=2),                     # empty
]

DECODE_EDGES = [
    dict(b=2, kvh=2, g=4, nblk=4, bk=16, rif=1),
    dict(b=2, kvh=2, g=4, nblk=2, bk=16, rif=64),               # rif>nk
    dict(b=1, kvh=1, g=1, nblk=1, bk=64, rif=2),                # one block
]

SEARCHSORTED_EDGES = [
    dict(n=600, m=33, block=64, chunk=8, rif=1, dtype="float32"),
    dict(n=600, m=33, block=64, chunk=8, rif=64, dtype="float32"),
    dict(n=130, m=7, block=128, chunk=64, rif=4, dtype="int32"),  # tails
    dict(n=1, m=1, block=64, chunk=1, rif=1, dtype="int32"),
]

HASH_EDGES = [
    dict(chains=16, chain_len=4, m=37, chunk=8, rif=1, extra_steps=0,
         miss_rate=0.3),
    dict(chains=16, chain_len=4, m=37, chunk=8, rif=64, extra_steps=0,
         miss_rate=0.3),                                        # rif>chunk
    dict(chains=5, chain_len=3, m=11, chunk=64, rif=4, extra_steps=-2,
         miss_rate=0.0),                                        # short walk
    dict(chains=1, chain_len=1, m=1, chunk=1, rif=1, extra_steps=2,
         miss_rate=1.0),
]

GMM_EDGES = [
    dict(t=256, d=128, f=128, e=4, bt=128, bf=128, bd=128, rif=1),  # rif=1
    dict(t=256, d=128, f=128, e=4, bt=128, bf=128, bd=128,
         rif=64),                                          # rif > num blocks
    dict(t=300, d=200, f=130, e=3, bt=128, bf=128, bd=128,
         rif=2),                                           # tails everywhere
    dict(t=64, d=64, f=64, e=1, bt=64, bf=128, bd=128, rif=2),  # one expert
    dict(t=384, d=256, f=256, e=5, bt=128, bf=128, bd=128, rif=3,
         experts_used=2),                # empty expert groups, nd = nf = 2
    dict(t=0, d=16, f=8, e=2, bt=128, bf=128, bd=128, rif=2),   # T == 0
]


@pytest.mark.parametrize("case", GATHER_EDGES)
def test_gather_edges(case):
    check_gather(case)


@pytest.mark.parametrize("case", MERGE_EDGES)
def test_merge_edges(case):
    check_merge(case)


@pytest.mark.parametrize("case", SPMV_EDGES)
def test_spmv_edges(case):
    check_spmv(case)


@pytest.mark.parametrize("case", DECODE_EDGES)
def test_decode_edges(case):
    check_decode(case)


@pytest.mark.parametrize("case", SEARCHSORTED_EDGES)
def test_searchsorted_edges(case):
    check_searchsorted(case)


@pytest.mark.parametrize("case", HASH_EDGES)
def test_hash_edges(case):
    check_hash(case)


@pytest.mark.parametrize("case", GMM_EDGES)
def test_gmm_edges(case):
    check_gmm(case)


# ---------------------------------------------------------------------------
# Ring construction contracts
# ---------------------------------------------------------------------------


def test_chase_empty_inputs():
    """Zero probes/lookups short-circuit before the kernel (a (0,)-shaped
    operand cannot legally enter a pallas_call block)."""
    from repro.kernels.dae_chase import batched_searchsorted, hash_lookup
    table = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    out = batched_searchsorted(table, jnp.zeros((0,), jnp.float32),
                               interpret=True)
    assert out.shape == (0,) and out.dtype == jnp.int32
    ek = jnp.arange(4, dtype=jnp.int32)
    out = hash_lookup(ek, ek, jnp.full(4, -1, jnp.int32),
                      jnp.zeros((0,), jnp.int32),
                      jnp.zeros((0,), jnp.int32), interpret=True)
    assert out.shape == (0,) and out.dtype == jnp.int32


def test_gmm_rejects_bad_routing_length():
    """The routing stream must carry exactly one expert id per token
    block (including the tail block) — a mismatch is a caller bug the op
    refuses rather than silently truncating."""
    from repro.kernels.grouped_matmul import grouped_matmul
    x = jnp.zeros((200, 32), jnp.float32)
    w = jnp.zeros((2, 32, 16), jnp.float32)
    with pytest.raises(ValueError, match="2 token blocks"):
        grouped_matmul(x, w, jnp.zeros(3, jnp.int32), bt=128)


def test_ring_scratch_shapes_rejects_bad_depth():
    from repro.kernels.ring import ring_scratch_shapes
    with pytest.raises(ValueError, match="rif=0"):
        ring_scratch_shapes(0, (1, 8), jnp.float32)


def test_ring_channel_rejects_mismatched_scratch():
    import dataclasses as _dc
    from repro.kernels.ring import RingChannel

    fake = _dc.make_dataclass("FakeRef", [("shape", tuple)])((4, 1, 8))
    with pytest.raises(ValueError, match="rif=8"):
        RingChannel(fake, None, 8, src=lambda k: None)


# ---------------------------------------------------------------------------
# Chase dispatch order: explicit → tune cache → plan_rif
# ---------------------------------------------------------------------------


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    from repro.tune import reset_default_cache
    path = tmp_path / "tune_cache.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    reset_default_cache()
    yield path
    reset_default_cache()


def _capture_searchsorted_calls(monkeypatch):
    import repro.kernels.dae_chase.ops as chase_ops
    calls = []
    real = chase_ops._k.searchsorted_blocks

    def spy(tiles, blk, keys, n, *, chunk, rif, interpret):
        calls.append({"chunk": chunk, "rif": rif})
        return real(tiles, blk, keys, n, chunk=chunk, rif=rif,
                    interpret=interpret)

    monkeypatch.setattr(chase_ops._k, "searchsorted_blocks", spy)
    return calls


def test_chase_dispatch_order(tmp_cache, monkeypatch):
    from repro.core.pipeline import plan_rif
    from repro.kernels.dae_chase import batched_searchsorted, searchsorted_ref
    from repro.tune import CacheEntry, backend_tag, default_cache, make_key

    r = np.random.default_rng(0)
    table = jnp.sort(jnp.asarray(r.standard_normal(500), jnp.float32))
    keys = jnp.asarray(r.standard_normal(20), jnp.float32)
    calls = _capture_searchsorted_calls(monkeypatch)

    def run(**kw):
        out = batched_searchsorted(table, keys, interpret=True, **kw)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(searchsorted_ref(table, keys)))
        return calls[-1]

    # 3. empty cache: rif falls back to the plan_rif analytic seed (the
    # kernel itself clips the ring depth to the chunk afterwards)
    seen = run()
    assert seen["rif"] == plan_rif(128 * 4).rif

    # 2. a tuned winner in the cache beats the analytic seed
    key = make_key("batched_searchsorted", (500, 20), "float32",
                   backend_tag(True), "wallclock")
    default_cache().put(key, CacheEntry(
        config={"block": 64, "chunk": 16, "rif": 3}, score=1.0))
    seen = run()
    assert seen == {"chunk": 16, "rif": 3}

    # 1. explicit caller knobs beat the cache
    seen = run(chunk=4, rif=2)
    assert seen == {"chunk": 4, "rif": 2}


def test_hash_dispatch_plan_fallback(tmp_cache, monkeypatch):
    from repro.core.pipeline import plan_rif
    import repro.kernels.dae_chase.ops as chase_ops
    from repro.kernels.dae_chase import hash_lookup
    from repro.kernels.dae_chase.kernel import ENTRY_LANES

    calls = []
    real = chase_ops._k.hash_probe

    def spy(packed, heads, keys, *, chunk, rif, max_steps, interpret):
        calls.append({"chunk": chunk, "rif": rif})
        return real(packed, heads, keys, chunk=chunk, rif=rif,
                    max_steps=max_steps, interpret=interpret)

    monkeypatch.setattr(chase_ops._k, "hash_probe", spy)
    ek = jnp.arange(8, dtype=jnp.int32)
    out = hash_lookup(ek, ek * 10, jnp.full(8, -1, jnp.int32),
                      jnp.arange(4, dtype=jnp.int32),
                      jnp.arange(4, dtype=jnp.int32), max_steps=2,
                      interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.arange(4) * 10)
    assert calls[-1]["rif"] == plan_rif(ENTRY_LANES * 4).rif


def test_gmm_dispatch_order(tmp_cache, monkeypatch):
    from repro.core.pipeline import plan_rif
    import repro.kernels.grouped_matmul.ops as gmm_ops
    from repro.kernels.grouped_matmul import grouped_matmul, grouped_matmul_ref
    from repro.tune import CacheEntry, backend_tag, default_cache, make_key

    calls = []
    real = gmm_ops._k.gmm

    def spy(x, w, blk, *, bt, bf, bd, rif, interpret):
        calls.append({"bf": bf, "bd": bd, "rif": rif})
        return real(x, w, blk, bt=bt, bf=bf, bd=bd, rif=rif,
                    interpret=interpret)

    monkeypatch.setattr(gmm_ops._k, "gmm", spy)

    r = np.random.default_rng(0)
    x = jnp.asarray(r.integers(-4, 5, (256, 192)), jnp.float32)
    w = jnp.asarray(r.integers(-4, 5, (3, 192, 128)), jnp.float32)
    blk = jnp.asarray([0, 2], jnp.int32)

    def run(**kw):
        gmm_ops._gmm_impl.clear_cache()    # retrace so the spy records
        out = grouped_matmul(x, w, blk, interpret=True, **kw)
        np.testing.assert_array_equal(
            _np(out), _np(grouped_matmul_ref(x, w, blk, 128)))
        return calls[-1]

    # 3. empty cache: bf/bd from the defaults (bd clipped to the padded
    # contraction), rif from the analytic plan over one weight tile
    seen = run()
    bd0 = 256                              # min(512, round_up(192, 128))
    assert seen == {"bf": 128, "bd": bd0,
                    "rif": plan_rif(bd0 * 128 * 4).rif}

    # 2. a tuned winner in the cache beats the analytic seed
    key = make_key("grouped_matmul", (256, 192, 128), "float32",
                   backend_tag(True), "wallclock")
    default_cache().put(key, CacheEntry(
        config={"bf": 64, "bd": 128, "rif": 3}, score=1.0))
    seen = run()
    assert seen == {"bf": 64, "bd": 128, "rif": 3}

    # 1. explicit caller knobs beat the cache
    seen = run(bf=128, bd=64, rif=2)
    assert seen == {"bf": 128, "bd": 64, "rif": 2}


# ---------------------------------------------------------------------------
# Hypothesis sweeps (CI tier; local runs skip without the extra)
# ---------------------------------------------------------------------------


# (only these sweeps skip without the extra — the deterministic grid
# above always runs, so the import cannot be a module-level importorskip)
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:
    pass
else:
    import strategies as repo_st  # tests/strategies.py

    SWEEP = settings(max_examples=12, deadline=None,
                     suppress_health_check=[HealthCheck.too_slow,
                                            HealthCheck.data_too_large])

    @SWEEP
    @given(case=repo_st.gather_cases(), seed=st.integers(0, 2**16))
    def test_gather_sweep_hypothesis(case, seed):
        check_gather(case, seed)

    @SWEEP
    @given(case=repo_st.merge_cases(), seed=st.integers(0, 2**16))
    def test_merge_sweep_hypothesis(case, seed):
        check_merge(case, seed)

    @SWEEP
    @given(case=repo_st.spmv_cases(), seed=st.integers(0, 2**16))
    def test_spmv_sweep_hypothesis(case, seed):
        check_spmv(case, seed)

    @SWEEP
    @given(case=repo_st.decode_cases(), seed=st.integers(0, 2**16))
    def test_decode_sweep_hypothesis(case, seed):
        check_decode(case, seed)

    @SWEEP
    @given(case=repo_st.searchsorted_cases(), seed=st.integers(0, 2**16))
    def test_searchsorted_sweep_hypothesis(case, seed):
        check_searchsorted(case, seed)

    @SWEEP
    @given(case=repo_st.hash_cases(), seed=st.integers(0, 2**16))
    def test_hash_sweep_hypothesis(case, seed):
        check_hash(case, seed)

    @SWEEP
    @given(case=repo_st.gmm_cases(), seed=st.integers(0, 2**16))
    def test_gmm_sweep_hypothesis(case, seed):
        check_gmm(case, seed)
