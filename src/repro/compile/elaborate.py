"""Pass 1 — elaborate: trace a DaeProgram into the dataflow IR.

The tracer is the same functional pump loop as
:meth:`repro.core.dae.DaeProgram.validate_channels` (loads answered
immediately, capacities never block, ``Par``/``Fused`` handled
recursively), extended to *record* every request address, response
value, and store event.  It requires a rebuildable program (generator
factories, the PR-5 contract) because it pumps fresh instances — the
caller's program is left untouched and can still be simulated.

Classification needs two runs: the second runs against *perturbed*
memories (every numeric element shifted by +1 — order-preserving, so
comparison-driven control flow keeps terminating) and streams are
compared across runs — identical address streams are STATIC, streams
tracking another channel's responses are INDIRECT, the rest DEPENDENT.
The perturbed run serves loads modulo the port length (a shifted
address may walk off the end; the *recorded* address stays raw so
INDIRECT matching sees the true dataflow) and is step-capped; if it
fails anyway, every stream conservatively degrades to DEPENDENT.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.dae import (ConservationError, DaeProgram, Deq, Enq, Halt,
                            LoadChannel, Req, Resp, Store)
from repro.compile.ir import (ChannelIR, DaeIR, PortArray, StoreIR,
                              StreamKind)

__all__ = ["elaborate", "ElaborationError"]


class ElaborationError(ConservationError):
    """The functional trace could not complete (stall, overrun, bad
    index) — the program cannot be staged."""


@dataclasses.dataclass
class _Trace:
    addrs: Dict[str, List[int]]
    values: Dict[str, List[Any]]
    stores: List[Tuple[str, int, Any]]
    channels: Dict[str, Any]              # name -> Channel object


def _perturb_value(v: Any) -> Any:
    if isinstance(v, bool) or v is None:
        return v
    if isinstance(v, (int, np.integer)):
        return v + 1
    if isinstance(v, (float, np.floating)):
        return v + 1.0
    if isinstance(v, np.ndarray) and np.issubdtype(v.dtype, np.number):
        return v + 1
    return v


def _perturb(memories: Dict[str, Any]) -> Dict[str, Any]:
    return {port: [_perturb_value(v) for v in data]
            for port, data in memories.items()}


def _run_trace(prog: DaeProgram, memories: Dict[str, Any], *,
               modulo: bool, max_steps: int) -> _Trace:
    """One recording dry run.  ``modulo`` wraps load addresses into the
    port's range (the perturbed run only — shifted pointers may walk
    out of bounds without that meaning anything about the original)."""
    from repro.core.simulator import Fused, Par  # deferred: no cycle

    tr = _Trace({}, {}, [], {})
    fifos: Dict[str, List[Any]] = {}

    def serve(port: str, addr: int) -> Any:
        data = memories.get(port)
        if data is None:
            return 0
        n = len(data)
        if modulo:
            if n == 0:
                return 0
            return data[int(addr) % n]
        try:
            return data[addr]
        except (IndexError, KeyError, TypeError) as e:
            raise ElaborationError(
                f"{prog.name}: load from port {port!r} address {addr!r} "
                f"failed during elaboration: {e}")

    def ready(eff: Any) -> bool:
        if isinstance(eff, (Resp, Deq)):
            return bool(fifos.get(eff.channel.name))
        if isinstance(eff, Par):
            return all(ready(s) for s in eff.effects)
        if isinstance(eff, Fused):
            return ready(eff.first)
        return True

    def run(eff: Any) -> Any:
        if isinstance(eff, Req):
            ch = eff.channel
            tr.channels.setdefault(ch.name, ch)
            addr = int(eff.addr)
            value = serve(ch.port, eff.addr)
            tr.addrs.setdefault(ch.name, []).append(addr)
            tr.values.setdefault(ch.name, []).append(value)
            fifos.setdefault(ch.name, []).append(value)
            return None
        if isinstance(eff, (Resp, Deq)):
            tr.channels.setdefault(eff.channel.name, eff.channel)
            return fifos[eff.channel.name].pop(0)
        if isinstance(eff, Enq):
            tr.channels.setdefault(eff.channel.name, eff.channel)
            fifos.setdefault(eff.channel.name, []).append(eff.value)
            return None
        if isinstance(eff, Store):
            tr.stores.append((eff.port, int(eff.addr), eff.value))
            return None
        if isinstance(eff, Par):
            return tuple(run(s) for s in eff.effects)
        if isinstance(eff, Fused):
            value = run(eff.first)
            follow = eff.then(value)
            if follow is not None:
                if not ready(follow):
                    raise ElaborationError(
                        f"{prog.name}: Fused follow-up {follow!r} would "
                        f"block during elaboration")
                run(follow)
            return value
        return None  # Delay / StoreWait / Halt

    gens = [(p.name, p.factory()) for p in prog.processes]
    steps = 0

    def advance(i: int, send: Any) -> Any:
        nonlocal steps
        steps += 1
        if steps > max_steps:
            raise ElaborationError(
                f"{prog.name}: elaboration exceeded {max_steps} steps")
        try:
            return gens[i][1].send(send)
        except StopIteration:
            return None

    pending = {i: advance(i, None) for i in range(len(gens))}
    pending = {i: e for i, e in pending.items() if e is not None}
    while pending:
        progressed = False
        for i in list(pending):
            eff = pending[i]
            while eff is not None and ready(eff):
                progressed = True
                if isinstance(eff, Halt):
                    eff = None
                    break
                eff = advance(i, run(eff))
            if eff is None:
                pending.pop(i)
            else:
                pending[i] = eff
        if pending and not progressed:
            stuck = [gens[i][0] for i in pending]
            raise ElaborationError(
                f"{prog.name}: elaboration stalled "
                f"(processes {stuck} blocked on empty channels)")
    return tr


# ---------------------------------------------------------------------------
# Stream classification + store matching (run A vs run B)
# ---------------------------------------------------------------------------


def _veq(a: Any, b: Any) -> bool:
    try:
        return bool(np.array_equal(a, b))
    except Exception:
        return a is b


def _as_int(v: Any) -> Optional[int]:
    if isinstance(v, bool):
        return None
    if isinstance(v, (int, np.integer)):
        return int(v)
    return None


def _classify(load_names: List[str], a: _Trace, b: _Trace
              ) -> Dict[str, Tuple[StreamKind, Optional[str], int]]:
    out: Dict[str, Tuple[StreamKind, Optional[str], int]] = {}
    for name in load_names:
        aa = a.addrs.get(name, [])
        ab = b.addrs.get(name, [])
        if len(aa) == len(ab) and aa == ab:
            out[name] = (StreamKind.STATIC, None, 0)
            continue
        # one-hop indirect: addr k tracks channel s's response k (+const)
        found = None
        for s in load_names:
            if s == name:
                continue
            va = [_as_int(v) for v in a.values.get(s, [])]
            vb = [_as_int(v) for v in b.values.get(s, [])]
            if (len(va) != len(aa) or len(vb) != len(ab)
                    or len(aa) != len(ab) or not aa
                    or any(v is None for v in va)
                    or any(v is None for v in vb)):
                continue
            off = aa[0] - va[0]
            if (all(aa[k] == va[k] + off for k in range(len(aa)))
                    and all(ab[k] == vb[k] + off for k in range(len(ab)))):
                found = (s, off)
                break
        if found is not None:
            out[name] = (StreamKind.INDIRECT, found[0], found[1])
        else:
            out[name] = (StreamKind.DEPENDENT, None, 0)
    return out


def _match_stores(load_names: List[str], a: _Trace, b: _Trace,
                  notes: List[str]) -> List[StoreIR]:
    stores = [StoreIR(port=p, addr=ad, value=v) for p, ad, v in a.stores]
    same_shape = (len(a.stores) == len(b.stores) and all(
        sa[0] == sb[0] and sa[1] == sb[1]
        for sa, sb in zip(a.stores, b.stores)))
    if not same_shape:
        notes.append("store sequence diverged under perturbation; "
                     "no copy/const matching (chase-spec only)")
        return stores
    used: Dict[str, set] = {n: set() for n in load_names}
    for t, st in enumerate(stores):
        va, vb = a.stores[t][2], b.stores[t][2]
        hit = None
        for c in load_names:
            ca, cb = a.values.get(c, []), b.values.get(c, [])
            if len(ca) != len(cb):
                continue
            idxs = [k for k in range(len(ca))
                    if _veq(ca[k], va) and _veq(cb[k], vb)]
            if not idxs:
                continue
            free = [k for k in idxs if k not in used[c]]
            hit = (c, (free or idxs)[0])
            break
        if hit is not None:
            used[hit[0]].add(hit[1])
            st.source = hit
        elif _veq(va, vb):
            st.const = True
    return stores


# ---------------------------------------------------------------------------
# Port staging
# ---------------------------------------------------------------------------


def _stage_port(name: str, data: Any, notes: List[str]
                ) -> Optional[PortArray]:
    rows = []
    width = None
    is_float = False
    for v in data:
        if v is None:
            rows.append(None)
            continue
        if isinstance(v, np.ndarray):
            row = np.atleast_1d(v)
        elif isinstance(v, (bool, str)):
            notes.append(f"port {name!r}: non-numeric element {v!r}; "
                         f"port not staged")
            return None
        elif isinstance(v, (int, np.integer)):
            row = np.array([int(v)])
        elif isinstance(v, (float, np.floating)):
            row = np.array([float(v)])
            is_float = True
        else:
            notes.append(f"port {name!r}: unstageable element type "
                         f"{type(v).__name__}")
            return None
        if np.issubdtype(row.dtype, np.floating):
            is_float = True
        elif not np.issubdtype(row.dtype, np.integer):
            notes.append(f"port {name!r}: non-numeric dtype {row.dtype}")
            return None
        if width is None:
            width = len(row)
        elif width != len(row):
            notes.append(f"port {name!r}: ragged rows ({width} vs "
                         f"{len(row)}); port not staged")
            return None
        rows.append(row)
    width = width or 1
    dtype = np.float32 if is_float else np.int32
    arr = np.zeros((len(rows), width), dtype)
    for i, row in enumerate(rows):
        if row is not None:
            arr[i] = row.astype(dtype)
    return PortArray(name, arr)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def elaborate(prog: DaeProgram, memories: Dict[str, Any], *,
              max_steps: int = 1_000_000) -> DaeIR:
    """Trace ``prog`` (twice) into a :class:`DaeIR`.

    ``memories`` maps port name -> indexable data, exactly as
    :meth:`DaeProgram.validate_channels` takes it.  Raises
    :class:`ElaborationError` if the true-memory trace cannot complete;
    a failing *perturbed* trace only degrades classification.
    """
    if not prog.rebuildable:
        bad = [p.name for p in prog.processes if not p.rebuildable]
        raise ElaborationError(
            f"{prog.name}: processes {bad} were built from live "
            f"generators; elaboration stages fresh instances — pass the "
            f"generator function itself to Process")

    notes: List[str] = []
    tr_a = _run_trace(prog, memories, modulo=False, max_steps=max_steps)

    perturbed_ok = True
    try:
        tr_b = _run_trace(prog, _perturb(memories), modulo=True,
                          max_steps=max_steps)
    except ElaborationError as e:
        perturbed_ok = False
        tr_b = tr_a
        notes.append(f"perturbed run failed ({e}); every stream "
                     f"conservatively DEPENDENT")

    load_names = [n for n, ch in tr_a.channels.items()
                  if isinstance(ch, LoadChannel)]

    if perturbed_ok:
        kinds = _classify(load_names, tr_a, tr_b)
        stores = _match_stores(load_names, tr_a, tr_b, notes)
    else:
        kinds = {n: (StreamKind.DEPENDENT, None, 0) for n in load_names}
        stores = [StoreIR(port=p, addr=ad, value=v)
                  for p, ad, v in tr_a.stores]

    channels = {}
    for name in load_names:
        ch = tr_a.channels[name]
        kind, source, offset = kinds[name]
        channels[name] = ChannelIR(
            name=name, port=ch.port, capacity=ch.capacity,
            addrs=tr_a.addrs.get(name, []),
            values=tr_a.values.get(name, []),
            kind=kind, source=source, offset=offset)

    stream_only = [n for n, ch in tr_a.channels.items()
                   if not isinstance(ch, LoadChannel)]
    if stream_only:
        notes.append(f"stream channels {stream_only} elaborated away "
                     f"(internal plumbing; values flow through the trace)")

    ports = {}
    for pname, data in memories.items():
        staged = _stage_port(pname, data, notes)
        if staged is not None:
            ports[pname] = staged

    return DaeIR(name=prog.name, channels=channels, stores=stores,
                 ports=ports, raw_memories=dict(memories),
                 perturbed_ok=perturbed_ok, notes=notes)
