"""Differential parity: the event-driven scheduler vs the polling oracle.

The event engine (``engine="event"``, the default) must be *bit-exact*
with the legacy pass-based scheduler (``engine="polling"``, kept
verbatim as the differential oracle): same cycle counts, same stored
arrays, same per-instance accounting, same trace summaries, and the
same deadlock messages.  Three layers of evidence:

  * **workload grid** — every paper benchmark × memory model × a grid
    of (rif, cap_slack, instances) cells runs on both engines and every
    observable field is compared (the exhaustive config × benchmark
    matrix is in the ``slow`` tier);
  * **deadlock parity** — §5.3 capacity violations and the R-HLS-Stream
    mergesort deadlock must produce identical error messages;
  * **randomized programs** — seeds drive ``tests/strategies.py`` specs
    through both engines, single- and multi-instance, comparing results
    or exceptions; with hypothesis installed the same generator runs
    under ``@given`` with shrinking.
"""

import json
import random

import pytest

from repro.core.dae import ConservationError
from repro.core.simulator import (DeadlockError, SharedMemoryEngine,
                                  simulate)
from repro.core.trace import Tracer
from repro.core.workloads import (BENCHMARKS, CONFIGS, MULTI_BENCHMARKS,
                                  run_workload, run_workload_multi)

import strategies

SMALL = dict(scale="small", latency=100)


# ---------------------------------------------------------------------------
# Workload grid
# ---------------------------------------------------------------------------

# (rif, cap_slack) cells: legacy sizing, tuner-tight sizing, tuner-roomy
PARAM_CELLS = ((8, None), (4, 1))

SINGLE_GRID = [
    (bench, "rhls_dec", mem, rif, cap)
    for bench in BENCHMARKS
    for mem in ("fixed", "moms")
    for rif, cap in PARAM_CELLS
] + [
    ("hashtable", "vitis", "fixed", 8, None),
    ("spmv", "rhls", "fixed", 8, None),
    ("mergesort_opt", "vitis_dec", "fixed", 8, None),
    ("binsearch", "rhls_stream", "fixed", 8, None),
    ("multispmv", "vitis_dec", "moms", 4, 1),
]


def _single_pair(bench, config, mem, rif, cap_slack):
    reps = {}
    for engine in ("polling", "event"):
        reps[engine] = run_workload(bench, config, mem=mem, rif=rif,
                                    cap_slack=cap_slack, trace=True,
                                    engine=engine, **SMALL)
    return reps["polling"], reps["event"]


@pytest.mark.parametrize("bench,config,mem,rif,cap", SINGLE_GRID)
def test_single_instance_parity(bench, config, mem, rif, cap):
    if config == "rhls_stream" and bench.startswith("mergesort"):
        pytest.skip("structural deadlock cell, covered by deadlock parity")
    poll, event = _single_pair(bench, config, mem, rif, cap)
    assert event.cycles == poll.cycles
    assert event.mem_reads == poll.mem_reads
    assert event.correct == poll.correct
    assert event.golden == poll.golden
    assert event.trace.to_json() == poll.trace.to_json()


MULTI_GRID = [
    (bench, "rhls_dec", mem, n)
    for bench in MULTI_BENCHMARKS
    for mem, n in (("fixed", 2), ("moms", 3))
]


@pytest.mark.parametrize("bench,config,mem,n", MULTI_GRID)
def test_multi_instance_parity(bench, config, mem, n):
    reps = {}
    for engine in ("polling", "event"):
        reps[engine] = run_workload_multi(
            bench, config, n, mem=mem, rif=8, max_outstanding=64,
            trace=True, engine=engine, **SMALL)
    poll, event = reps["polling"], reps["event"]
    assert event.cycles == poll.cycles
    assert event.per_instance_cycles == poll.per_instance_cycles
    assert event.mem_reads == poll.mem_reads
    assert event.correct == poll.correct
    # byte-identical trace summaries through the JSON round trip
    assert json.dumps(event.trace.to_json(), sort_keys=True) == \
        json.dumps(poll.trace.to_json(), sort_keys=True)


@pytest.mark.slow
@pytest.mark.parametrize("bench", BENCHMARKS)
@pytest.mark.parametrize("config", CONFIGS)
def test_single_instance_parity_full_matrix(bench, config):
    """Exhaustive benchmark × config sweep (slow tier)."""
    if config == "rhls_stream" and bench.startswith("mergesort"):
        with pytest.raises(DeadlockError):
            run_workload(bench, config, engine="polling", **SMALL)
        with pytest.raises(DeadlockError):
            run_workload(bench, config, engine="event", **SMALL)
        return
    poll, event = _single_pair(bench, config, "fixed", 8, None)
    assert event.cycles == poll.cycles
    assert event.mem_reads == poll.mem_reads
    assert event.correct == poll.correct
    assert event.trace.to_json() == poll.trace.to_json()


@pytest.mark.scale
@pytest.mark.parametrize("n", [16, 64])
def test_multi_instance_parity_large_n(n):
    """The N-tenant sweep cells the event engine exists for."""
    reps = {}
    for engine in ("polling", "event"):
        reps[engine] = run_workload_multi(
            "hashtable", "rhls_dec", n, rif=32, max_outstanding=64,
            engine=engine, **SMALL)
    assert reps["event"].cycles == reps["polling"].cycles
    assert reps["event"].per_instance_cycles == \
        reps["polling"].per_instance_cycles


# ---------------------------------------------------------------------------
# Deadlock parity
# ---------------------------------------------------------------------------


def _error_of(fn):
    try:
        fn()
    except (DeadlockError, ConservationError) as e:
        return type(e).__name__, str(e)
    return None


@pytest.mark.parametrize("n", [1, 2, 4])
def test_capacity_violation_deadlock_message_parity(n):
    """§5.3: capacity < RIF deadlocks identically, message included."""
    errs = {}
    for engine in ("polling", "event"):
        errs[engine] = _error_of(lambda: run_workload_multi(
            "hashtable", "rhls_dec", n, rif=8, cap_slack=-4,
            engine=engine, **SMALL))
    assert errs["event"] is not None
    assert errs["event"][0] == "DeadlockError"
    assert errs["event"] == errs["polling"]


def test_single_program_deadlock_message_parity():
    errs = {}
    for engine in ("polling", "event"):
        errs[engine] = _error_of(lambda: run_workload(
            "binsearch", "rhls_dec", rif=8, cap_slack=-6,
            engine=engine, **SMALL))
    assert errs["event"] is not None
    assert errs["event"] == errs["polling"]


def test_par_with_ready_storewait_sub_parity():
    """Regression: a Par whose StoreWait sub is *ready* at park time is
    a non-monotone park — another process's Store later write-gates it,
    handing the Par a new finite retry the clock jump must see.  The
    event engine once missed this (it eagerly watched only ready Req
    subs), desynchronizing jump targets and deadlock messages."""
    from repro.core.dae import (DaeProgram, Delay, Enq, Process, Store,
                                StoreWait, StreamChannel)
    from repro.core.simulator import FixedLatencyMemory, Par

    def build():
        c = StreamChannel("c", capacity=1)

        def p1():
            yield Enq(c, 1)                           # fills the stream
            yield Par([Enq(c, 2), StoreWait("out")])  # Enq full; SW ready

        def p2():
            yield Delay(2)
            yield Store("out", 0, 7)

        prog = DaeProgram("t", [Process("p1", p1()), Process("p2", p2())])
        mems = {"mem": FixedLatencyMemory(list(range(4)), 10),
                "out": FixedLatencyMemory([None] * 4, 10)}
        return prog, mems

    errs = {}
    for engine in ("polling", "event"):
        prog, mems = build()
        errs[engine] = _error_of(lambda: simulate(prog, mems,
                                                  engine=engine))
    assert errs["event"] is not None
    assert errs["event"][0] == "DeadlockError"
    assert errs["event"] == errs["polling"]


# ---------------------------------------------------------------------------
# Randomized program parity (no hypothesis needed)
# ---------------------------------------------------------------------------


def _outcome_single(spec, engine):
    prog, mems = strategies.build_program(spec)
    tracer = Tracer(bin_cycles=32)
    try:
        r = simulate(prog, mems, tracer=tracer, engine=engine)
    except (DeadlockError, ConservationError) as e:
        return type(e).__name__, str(e)
    return (r.cycles, r.stores, r.counts, r.mem_reads,
            json.dumps(tracer.summary().to_json(), sort_keys=True))


def _outcome_multi(spec, n, engine):
    instances, shared = strategies.build_engine_inputs(spec, n)
    tracer = Tracer(bin_cycles=32)
    try:
        res = SharedMemoryEngine(instances, shared, tracer=tracer,
                                 engine=engine).run()
    except (DeadlockError, ConservationError) as e:
        return type(e).__name__, str(e)
    return (res.cycles, res.events, res.passes,
            [(r.cycles, r.stores, r.counts, r.mem_reads)
             for r in res.instances],
            json.dumps(tracer.summary().to_json(), sort_keys=True))


@pytest.mark.parametrize("seed", range(50))
def test_random_program_parity(seed):
    spec = strategies.random_spec(random.Random(seed))
    assert _outcome_single(spec, "event") == _outcome_single(spec, "polling")


@pytest.mark.parametrize("seed", range(50, 70))
@pytest.mark.parametrize("n", [2, 3])
def test_random_program_parity_multi(seed, n):
    spec = strategies.random_spec(random.Random(seed))
    assert _outcome_multi(spec, n, "event") == \
        _outcome_multi(spec, n, "polling")


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(70, 400))
def test_random_program_parity_deep(seed):
    spec = strategies.random_spec(random.Random(seed))
    assert _outcome_single(spec, "event") == _outcome_single(spec, "polling")


# ---------------------------------------------------------------------------
# Hypothesis-driven parity (shrinks failing specs to minimal programs);
# guarded import so the seed-grid parity above still runs without the
# optional 'test' extra
# ---------------------------------------------------------------------------

try:
    from hypothesis import given
except ImportError:
    given = None

if given is not None:
    @given(spec=strategies.program_specs())
    def test_random_program_parity_hypothesis(spec):
        assert _outcome_single(spec, "event") == \
            _outcome_single(spec, "polling")

    @given(spec=strategies.program_specs())
    def test_random_program_parity_multi_hypothesis(spec):
        assert _outcome_multi(spec, 2, "event") == \
            _outcome_multi(spec, 2, "polling")
