"""Int8-compressed gradient all-reduce with error feedback.

Distributed-optimization trick for bandwidth-bound meshes: gradients are
quantized per-tensor to int8 against a max-abs scale, summed across the
data axis, and dequantized; the quantization residual is fed back into
the next step's gradient (error feedback), which keeps SGD/Adam unbiased
over time.  Wire format is int8 + one f32 scale per tensor => 4x less
ICI traffic than f32 all-reduce (the sum itself is carried in int32 to
avoid overflow across <= 2^23 participants' worth of int8 addends).

Used via shard_map over the data axis; see tests/test_compress.py.
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jnp.ndarray, residual: jnp.ndarray, axis: str
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inside shard_map: all-reduce ``g`` over ``axis`` in int8 wire format
    with error feedback.  Returns (mean gradient, new residual)."""
    g_fb = g + residual
    q, scale = quantize(g_fb)
    new_residual = g_fb - dequantize(q, scale)
    # scales differ per shard -> dequantize locally, sum the int32 payload
    # against the max scale (shared scale keeps the sum exact in int space)
    scale_max = jax.lax.pmax(scale, axis)
    q_rescaled = jnp.round(dequantize(q, scale) / scale_max).astype(jnp.int32)
    total = jax.lax.psum(q_rescaled, axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return total.astype(jnp.float32) * scale_max / n, new_residual


def compressed_grad_mean(grads: Any, residuals: Any, axis: str
                         ) -> Tuple[Any, Any]:
    """Tree version of compressed_psum."""
    pairs = jax.tree.map(lambda g, r: compressed_psum(g, r, axis),
                         grads, residuals)
    mean = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return mean, res
