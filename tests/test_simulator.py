"""DAE programming-model + simulator semantics (paper §3/§5.1)."""

import pytest

from repro.core.dae import (ConservationError, DaeProgram, Delay, Deq, Enq,
                            LoadChannel, Process, Req, Resp, Store, StoreWait,
                            StreamChannel)
from repro.core.simulator import (DeadlockError, FixedLatencyMemory, Fused,
                                  MomsMemory, Par, simulate)


def run(procs, data=None, latency=100, ports=("mem",)):
    mems = {p: FixedLatencyMemory(list(data or range(100)), latency)
            for p in ports}
    mems["out"] = FixedLatencyMemory([None] * 64, latency)
    return simulate(DaeProgram("t", procs), mems)


def test_blocking_load_costs_latency():
    ch = LoadChannel("c", capacity=4)

    def gen():
        yield Req(ch, 3)
        v = yield Resp(ch)
        yield Store("out", 0, v)

    r = run([Process("p", gen())])
    assert r.stores["out"][0] == 3
    # issue(1) + latency(100) + store; end includes write response
    assert 100 <= r.cycles <= 210


def test_pipelined_requests_hide_latency():
    ch = LoadChannel("c", capacity=128)
    n = 64

    def req():
        for i in range(n):
            yield Req(ch, i)

    def resp():
        for i in range(n):
            yield Fused(Resp(ch), lambda v, i=i: Store("out", i, v))

    r = run([Process("a", req()), Process("e", resp())], latency=100)
    # decoupled: ~latency + n, NOT n * latency
    assert r.cycles < 100 + n + 120
    assert r.stores["out"][n - 1] == n - 1


def test_request_response_conservation_enforced():
    ch = LoadChannel("c", capacity=8)

    def bad():
        yield Req(ch, 0)
        yield Req(ch, 1)
        _ = yield Resp(ch)  # second response never consumed

    with pytest.raises(ConservationError):
        run([Process("p", bad())])


def test_stream_enq_deq_order():
    st = StreamChannel("s", capacity=4)

    def prod():
        for i in (5, 3, 9):
            yield Enq(st, i)

    got = []

    def cons():
        for _ in range(3):
            v = yield Deq(st)
            got.append(v)

    run([Process("p", prod()), Process("c", cons())])
    assert got == [5, 3, 9]


def test_capacity_blocks_producer():
    st = StreamChannel("s", capacity=2)

    def prod():
        for i in range(4):
            yield Enq(st, i)

    def cons():
        yield Delay(1000)
        for _ in range(4):
            yield Deq(st)

    r = run([Process("p", prod()), Process("c", cons())])
    assert r.cycles >= 1000  # producer had to wait for consumer


def test_deadlock_detection():
    a = StreamChannel("a", capacity=1)
    b = StreamChannel("b", capacity=1)

    def p1():
        _ = yield Deq(a)
        yield Enq(b, 1)

    def p2():
        _ = yield Deq(b)
        yield Enq(a, 1)

    with pytest.raises(DeadlockError):
        run([Process("p1", p1()), Process("p2", p2())])


def test_par_same_cycle():
    c1 = LoadChannel("c1", capacity=4, port="mem")
    c2 = LoadChannel("c2", capacity=4, port="mem2")

    def gen():
        yield Par([Req(c1, 1), Req(c2, 2)])
        vals = yield Par([Resp(c1), Resp(c2)])
        yield Store("out", 0, tuple(vals))

    r = run([Process("p", gen())], ports=("mem", "mem2"))
    assert r.stores["out"][0] == (1, 2)


def test_store_wait_blocks_until_write_response():
    def gen():
        yield Store("out", 0, 42)
        yield StoreWait("out")
        yield Delay(1)

    r = run([Process("p", gen())], latency=77)
    assert r.cycles >= 77


def test_moms_coalescing_and_cache():
    mem = MomsMemory(list(range(1024)), line_words=16)
    t1, v = mem.access(0, 0.0)
    assert v == 0
    t2, _ = mem.access(1, 0.0)        # same line, in flight -> coalesced
    assert t2 <= t1 + 1
    t3, _ = mem.access(2, t1 + 10)    # landed -> cache hit
    assert t3 - (t1 + 10) == mem.hit_latency
    assert mem.stats["coalesced"] == 1
    assert mem.stats["hits"] == 1


def test_outstanding_cap_throttles():
    ch = LoadChannel("c", capacity=1000)
    n = 200

    def req():
        for i in range(n):
            yield Req(ch, i % 64)

    def resp():
        for _ in range(n):
            yield Resp(ch)

    mems = {"mem": FixedLatencyMemory(list(range(64)), 100, max_outstanding=4),
            "out": FixedLatencyMemory([None], 100)}
    r = simulate(DaeProgram("t", [Process("a", req()),
                                  Process("e", resp())]), mems)
    # 4 outstanding with latency 100 -> throughput 4/100
    assert r.cycles > n / (4 / 100) * 0.8
