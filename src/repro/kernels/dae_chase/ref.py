"""Pure-jnp oracles for the pointer-chasing ops."""

from __future__ import annotations

import jax.numpy as jnp


def searchsorted_ref(table: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    """Index of the first element > key (i.e. 'right' insertion point)."""
    return jnp.searchsorted(table, keys, side="right").astype(jnp.int32)


def hash_lookup_ref(entry_keys, entry_vals, entry_next, heads, keys,
                    max_steps: int) -> jnp.ndarray:
    """Walk separate-chaining buckets; -1 when not found in max_steps."""
    import jax

    def step(state, _):
        idx, found, val = state
        safe = jnp.clip(idx, 0, entry_keys.shape[0] - 1)
        k = entry_keys[safe]
        v = entry_vals[safe]
        nxt = entry_next[safe]
        alive = (idx >= 0) & ~found
        hit = alive & (k == keys)
        val = jnp.where(hit, v, val)
        found = found | hit
        idx = jnp.where(alive & ~hit, nxt, idx)
        return (idx, found, val), None

    n = heads.shape[0]
    init = (heads.astype(jnp.int32), jnp.zeros(n, bool),
            jnp.full(n, -1, entry_vals.dtype))
    (idx, found, val), _ = jax.lax.scan(step, init, None, length=max_steps)
    return jnp.where(found, val, -1)
