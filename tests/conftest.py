import os
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

# Smoke tests and benches must see ONE device (the dry-run alone forces
# 512 via its own first lines); make sure nothing leaks in.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings, HealthCheck  # noqa: E402

settings.register_profile(
    "repro",
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")
