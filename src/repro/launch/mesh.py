"""Production mesh definitions.

A FUNCTION (not module-level constant) so importing this module never
touches jax device state.  The dry-run forces 512 host devices via
XLA_FLAGS *before* any jax import (see dryrun.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax")
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev_array, axes)


def make_debug_mesh(shape=(2, 4), axes=("data", "model")) -> Mesh:
    """Small mesh for unit tests (e.g. 8 forced host devices)."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "before importing jax")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


@dataclasses.dataclass(frozen=True)
class ServeMeshes:
    """Device placement of the sharded serving pipeline.

    ``prefill``/``decode`` are the Access and Execute engines' compute
    meshes; ``union`` covers both and carries the cross-engine
    :class:`~repro.channels.mesh.MeshChannel` ring.  When
    ``disaggregated`` the two engine meshes are *disjoint* submeshes
    (the union gains a leading ``role`` axis of size 2: row 0 prefill,
    row 1 decode) and the engines are joined only by mesh-transport
    channels; otherwise all three are the same mesh and the channels
    ride its ``data`` axis.
    """

    union: Mesh
    prefill: Mesh
    decode: Mesh
    disaggregated: bool
    axis: str = "data"
    role_axis: str = "role"


def make_serve_meshes(n: Optional[int] = None, *,
                      disaggregate: Optional[bool] = None) -> ServeMeshes:
    """Carve the first ``n`` devices into serving meshes.

    ``disaggregate`` defaults to splitting whenever an even n >= 2 is
    available; ``n`` defaults to every visible device.  n=1 always
    degenerates to one single-device mesh shared by both engines (the
    bit-parity configuration the serve matrix pins).
    """
    devices = jax.devices()
    if n is None:
        n = len(devices)
    if n < 1:
        raise ValueError(f"need n >= 1 serving devices, got {n}")
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for serving meshes, have {len(devices)}; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "before importing jax")
    if disaggregate is None:
        disaggregate = n >= 2 and n % 2 == 0
    if disaggregate and (n < 2 or n % 2):
        raise ValueError(
            f"disaggregated serving splits devices in half, got n={n}")
    devs = np.asarray(devices[:n])
    if not disaggregate:
        mesh = Mesh(devs, ("data",))
        return ServeMeshes(mesh, mesh, mesh, False)
    half = n // 2
    union = Mesh(devs.reshape(2, half), ("role", "data"))
    prefill = Mesh(devs[:half], ("data",))
    decode = Mesh(devs[half:], ("data",))
    return ServeMeshes(union, prefill, decode, True)
