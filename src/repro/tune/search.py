"""Deterministic searchers over decoupling-parameter spaces.

Two strategies, selected automatically by space size:

* exhaustive grid for small spaces;
* greedy hill-climb from the analytic seed (`plan_rif`) for larger ones —
  evaluate the ±1-step neighbourhood on every axis, move to the best
  neighbour, stop when no neighbour improves or the eval budget runs out.

Both are deterministic: configs are visited in a fixed order, ties break
toward the earlier-visited (and therefore seed-closer) config, and the
only randomness allowed anywhere is the ``seed`` the measurement
function may use for its own input data.

A measurement returning ``inf`` (or raising one of the exception types in
``PENALIZED``) marks the config invalid — notably a simulated deadlock
from an undersized channel capacity (§5.3); the searcher treats it as an
infinitely bad score rather than an error, so the boundary of the
deadlock-free region is mapped, not tripped over.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.dae import ConservationError
from repro.core.simulator import DeadlockError
from repro.tune.space import Config, SearchSpace

__all__ = ["TuneResult", "search", "grid_search", "hill_climb", "PENALIZED"]

PENALIZED: Tuple[type, ...] = (DeadlockError, ConservationError)

Measure = Callable[[Config], float]


@dataclasses.dataclass
class TuneResult:
    space: str
    best: Config
    best_score: float
    seed: Config
    seed_score: float
    evals: int
    trace: List[Tuple[Config, float]]   # evaluation order, for debugging

    @property
    def improvement(self) -> float:
        """seed_score / best_score (>= 1.0 when the tuner helped)."""
        if not math.isfinite(self.seed_score) or self.best_score <= 0:
            return float("inf") if math.isfinite(self.best_score) else 1.0
        return self.seed_score / self.best_score


def _key(cfg: Config) -> Tuple:
    return tuple(sorted(cfg.items()))


class _Memo:
    """Evaluate-once wrapper that maps penalized failures to +inf."""

    def __init__(self, measure: Measure):
        self.measure = measure
        self.scores: Dict[Tuple, float] = {}
        self.trace: List[Tuple[Config, float]] = []

    def __call__(self, cfg: Config) -> float:
        k = _key(cfg)
        if k in self.scores:
            return self.scores[k]
        try:
            s = float(self.measure(cfg))
        except PENALIZED:
            s = float("inf")
        if math.isnan(s):
            s = float("inf")
        self.scores[k] = s
        self.trace.append((dict(cfg), s))
        return s

    @property
    def evals(self) -> int:
        return len(self.scores)


def grid_search(space: SearchSpace, measure: Measure,
                max_evals: Optional[int] = None) -> TuneResult:
    """Exhaustively evaluate the grid (optionally capped at max_evals,
    seed first so the cap never loses the analytic baseline)."""
    memo = _Memo(measure)
    seed = space.snap(space.seed)
    seed_score = memo(seed)
    best, best_score = dict(seed), seed_score
    for cfg in space.grid():
        if max_evals is not None and memo.evals >= max_evals:
            break
        s = memo(cfg)
        if s < best_score:
            best, best_score = dict(cfg), s
    return TuneResult(space.name, best, best_score, seed, seed_score,
                      memo.evals, memo.trace)


def hill_climb(space: SearchSpace, measure: Measure,
               max_evals: int = 64) -> TuneResult:
    """Greedy best-neighbour descent from the analytic seed."""
    memo = _Memo(measure)
    cur = space.snap(space.seed)
    cur_score = memo(cur)
    seed, seed_score = dict(cur), cur_score
    while memo.evals < max_evals:
        best_n, best_n_score = None, cur_score
        for n in space.neighbours(cur):
            if memo.evals >= max_evals:
                break
            s = memo(n)
            if s < best_n_score:
                best_n, best_n_score = n, s
        if best_n is None:
            break
        cur, cur_score = best_n, best_n_score
    # the climb can start from an infeasible (deadlocking) seed: if it never
    # escaped, fall back to a coarse probe of the grid corners
    if not math.isfinite(cur_score):
        for cfg in space.grid():
            if memo.evals >= max_evals:
                break
            s = memo(cfg)
            if s < cur_score:
                cur, cur_score = dict(cfg), s
    return TuneResult(space.name, cur, cur_score, seed, seed_score,
                      memo.evals, memo.trace)


def search(space: SearchSpace, measure: Measure, *, max_evals: int = 64,
           strategy: str = "auto") -> TuneResult:
    """Tune ``space`` with ``measure`` (lower is better).

    ``strategy``: 'grid', 'hill', or 'auto' (grid when the whole space
    fits in the eval budget, hill-climb otherwise).
    """
    if strategy == "auto":
        strategy = "grid" if space.size <= max_evals else "hill"
    if strategy == "grid":
        return grid_search(space, measure, max_evals=max_evals)
    if strategy == "hill":
        return hill_climb(space, measure, max_evals=max_evals)
    raise ValueError(f"unknown strategy {strategy!r}")
