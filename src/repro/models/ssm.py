"""Selective state-space (Mamba-style) mixer — used by the Hymba hybrid.

Training path uses an associative scan over time (sub-quadratic,
O(S log S) depth); decode carries (conv window, ssm state) recurrently.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init


def ssm_init(cfg: ModelConfig, key) -> Dict[str, Any]:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    dtr = cfg.dt_rank
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], d, 2 * di, cfg.pdtype),       # x and gate z
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
                   * 0.1).astype(cfg.pdtype),
        "conv_b": jnp.zeros((di,), cfg.pdtype),
        "w_bcdt": dense_init(ks[2], di, 2 * n + dtr, cfg.pdtype),
        "w_dt": dense_init(ks[3], dtr, di, cfg.pdtype),
        "dt_bias": jnp.full((di,), -4.6, cfg.pdtype),           # softplus ~ 0.01
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))).astype(cfg.pdtype),  # (di, n)
        "d_skip": jnp.ones((di,), cfg.pdtype),
        "w_out": dense_init(ks[4], di, d, cfg.pdtype),
    }


def _conv1d_causal(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                   init_window: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """x (B, S, DI); w (K, DI) depthwise causal conv."""
    k = w.shape[0]
    if init_window is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = init_window.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                       # (B, S+K-1, DI)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out + b[None, None, :]


def ssm_apply(cfg: ModelConfig, p: Dict[str, Any], x: jnp.ndarray,
              state: Optional[Dict[str, Any]] = None,
              valid: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]]]:
    """x (B, S, D) -> (B, S, D).  ``state`` (decode): {"conv": (B,K-1,DI),
    "ssm": (B, DI, N)}.

    With a state and S > 1 (or an explicit ``valid`` (B, S) mask) this is
    the chunked cache-fill path: the decode recurrence runs over the
    chunk token-by-token (same math as S=1 decode steps; XLA's shape-
    dependent fusion of the discretization chain can still move the
    result by ~1 ulp — see tests/test_serve_loop.py), and rows with no
    valid tokens carry their state through unchanged.
    """
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    dtr = cfg.dt_rank
    dt = cfg.adtype

    xz = x @ p["w_in"].astype(dt)                                # (B,S,2DI)
    xs, z = xz[..., :di], xz[..., di:]

    conv_in = None if state is None else state["conv"]
    xs_conv = jax.nn.silu(_conv1d_causal(xs, p["conv_w"].astype(dt),
                                         p["conv_b"].astype(dt), conv_in))

    bcdt = xs_conv @ p["w_bcdt"].astype(dt)                      # (B,S,2N+dtr)
    bmat = bcdt[..., :n].astype(jnp.float32)                     # (B,S,N)
    cmat = bcdt[..., n:2 * n].astype(jnp.float32)
    dt_in = bcdt[..., 2 * n:]
    delta = jax.nn.softplus(dt_in @ p["w_dt"].astype(dt)
                            + p["dt_bias"].astype(dt)).astype(jnp.float32)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))                 # (DI, N)
    # discretize: da (B,S,DI,N) decay, db*u input
    da = jnp.exp(delta[..., None] * a[None, None])               # (B,S,DI,N)
    dbu = (delta * xs_conv.astype(jnp.float32))[..., None] * bmat[:, :, None, :]

    if state is None:
        # associative scan over time: h_t = da_t * h_{t-1} + dbu_t
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, br + ar * bl
        da_s, h = jax.lax.associative_scan(combine, (da, dbu), axis=1)
        new_state = None
    elif s == 1 and valid is None:
        h_prev = state["ssm"].astype(jnp.float32)                # (B,DI,N)
        h = da[:, 0] * h_prev + dbu[:, 0]
        h = h[:, None]                                           # (B,1,DI,N)
        conv_win = jnp.concatenate([state["conv"], xs], axis=1)[:, 1:]
        new_state = {"conv": conv_win, "ssm": h[:, 0].astype(state["ssm"].dtype)}
    else:
        if valid is None:
            valid = jnp.ones((b, s), bool)

        def step(h_c, inp):
            da_t, dbu_t, v_t = inp                               # (B,DI,N) x2
            h_new = jnp.where(v_t[:, None, None],
                              da_t * h_c + dbu_t, h_c)
            return h_new, h_new

        h_fin, hs = jax.lax.scan(
            step, state["ssm"].astype(jnp.float32),
            (da.transpose(1, 0, 2, 3), dbu.transpose(1, 0, 2, 3),
             valid.T))
        h = hs.transpose(1, 0, 2, 3)                             # (B,S,DI,N)
        # conv window: the K-1 inputs ending at each row's last valid token
        hist = jnp.concatenate([state["conv"].astype(xs.dtype), xs], axis=1)
        n_valid = valid.sum(-1).astype(jnp.int32)                # (B,)
        idx = n_valid[:, None] + jnp.arange(cfg.ssm_conv - 1)[None, :]
        conv_win = jnp.take_along_axis(hist, idx[..., None], axis=1)
        new_state = {"conv": conv_win.astype(state["conv"].dtype),
                     "ssm": h_fin.astype(state["ssm"].dtype)}

    y = jnp.einsum("bsdn,bsn->bsd", h, cmat)                     # (B,S,DI)
    y = y + xs_conv.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y.astype(dt)) * jax.nn.silu(z)
    return y @ p["w_out"].astype(dt), new_state


def ssm_init_state(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    di = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), cfg.adtype),
        "ssm": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    }
