"""Model substrate: layers and full-model builders for the 10 assigned
architectures (dense/GQA, MLA, MoE, SSM, RWKV6, hybrid, enc-dec, VLM)."""

from repro.models.common import ModelConfig, LayerSpec
from repro.models.registry import build_model

__all__ = ["ModelConfig", "LayerSpec", "build_model"]
