"""Paper Table 3: the read-only-compatible subset under a MOMS +
row-buffer DRAM model instead of fixed latency."""

from __future__ import annotations

from repro.core.workloads import run_workload

PAPER_TABLE3 = {
    ("binsearch", "vitis"): 2_239_063, ("binsearch", "vitis_dec"): 65_011,
    ("binsearch", "rhls"): 677_274, ("binsearch", "rhls_dec"): 23_302,
    ("binsearch_for", "vitis"): 2_294_243,
    ("binsearch_for", "vitis_dec"): 83_937,
    ("binsearch_for", "rhls"): 701_472,
    ("binsearch_for", "rhls_dec"): 25_928,
    ("hashtable", "vitis"): 1_904_751, ("hashtable", "vitis_dec"): 53_887,
    ("hashtable", "rhls"): 1_008_246, ("hashtable", "rhls_dec"): 18_716,
    ("spmv", "vitis"): 283_829, ("spmv", "vitis_dec"): 55_037,
    ("spmv", "rhls"): 29_918, ("spmv", "rhls_dec"): 29_732,
}

SUBSET = ("binsearch", "binsearch_for", "hashtable", "spmv")  # read-only


def run(csv_print) -> None:
    for bench in SUBSET:
        fixed_cycles = {}
        for config in ("vitis", "vitis_dec", "rhls", "rhls_dec"):
            fixed = run_workload(bench, config, scale="paper", mem="fixed")
            moms = run_workload(bench, config, scale="paper", mem="moms",
                                max_outstanding=64)
            fixed_cycles[config] = fixed.cycles
            paper = PAPER_TABLE3.get((bench, config), 0)
            csv_print(
                f"table3/{bench}/{config},{moms.cycles},"
                f"fixed={fixed.cycles};moms_vs_fixed="
                f"{moms.cycles / fixed.cycles:.2f};paper_moms={paper};"
                f"correct={moms.correct}")
