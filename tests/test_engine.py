"""Multi-instance SharedMemoryEngine + trace subsystem.

Three contracts:

  * N=1 through the multi-tenant wiring is bit-exact with the legacy
    single-program ``run_workload`` cycle counts (the engine IS the old
    scheduler when there is nobody to share with);
  * N>1 shared-port runs stay deadlock-free and correct under the §5.4
    capacity bounds, and violating the bounds raises ``DeadlockError``;
  * trace records round-trip through the structured JSON format and
    their invariants (occupancy <= capacity, one histogram entry per
    request) hold.
"""

import json

import pytest

from repro.core.dae import DaeProgram, LoadChannel, Process, Req, Resp, Store
from repro.core.simulator import (DeadlockError, EngineInstance,
                                  FixedLatencyMemory, Fused,
                                  SharedMemoryEngine, simulate)
from repro.core.trace import TraceSummary, Tracer, pow2_bucket
from repro.core.workloads import (MULTI_BENCHMARKS, run_workload,
                                  run_workload_multi)

SMALL = dict(scale="small", latency=100, rif=8)

# pinned pre-engine cycle counts (captured before the SharedMemoryEngine
# refactor) — the engine must not drift the single-program timing model
LEGACY_CYCLES = {
    ("binsearch", "rhls_dec"): 3104,
    ("binsearch_for", "rhls_dec"): 3116,
    ("hashtable", "rhls_dec"): 915,
    ("hashtable", "vitis"): 7235,
    ("spmv", "rhls_dec"): 1000,
    ("spmv", "rhls"): 1103,
    ("mergesort", "rhls_dec"): 6198,
    ("mergesort_opt", "rhls_dec"): 2598,
    ("multispmv", "rhls_dec"): 2139,
}


@pytest.mark.parametrize("bench,config", sorted(LEGACY_CYCLES))
def test_single_program_cycles_pinned(bench, config):
    r = run_workload(bench, config, **SMALL)
    assert r.correct
    assert r.cycles == LEGACY_CYCLES[(bench, config)]


@pytest.mark.parametrize("bench", MULTI_BENCHMARKS)
@pytest.mark.parametrize("config", ["rhls_dec", "vitis_dec", "rhls"])
def test_n1_multi_matches_single(bench, config):
    single = run_workload(bench, config, **SMALL)
    multi = run_workload_multi(bench, config, 1, **SMALL)
    assert single.correct and multi.correct
    assert multi.cycles == single.cycles
    assert multi.per_instance_cycles == [single.cycles]


@pytest.mark.parametrize("bench", MULTI_BENCHMARKS)
def test_shared_port_contention_correct_and_slower(bench):
    one = run_workload_multi(bench, "rhls_dec", 1, max_outstanding=64,
                             **SMALL)
    four = run_workload_multi(bench, "rhls_dec", 4, max_outstanding=64,
                              **SMALL)
    assert four.correct
    assert four.n_instances == 4 and len(four.per_instance_cycles) == 4
    # sharing the port cannot make the makespan shorter, and must cost
    # per-tenant throughput
    assert four.cycles >= one.cycles
    assert four.throughput_per_instance < one.throughput_per_instance


def test_round_robin_arbitration_is_fair():
    """Two identical tenants on one port finish within one capacity
    batch of each other — neither persistently wins the tie."""
    n = 64

    def build(i):
        ch = LoadChannel("c", capacity=16, port="table")

        def req():
            for k in range(n):
                yield Req(ch, k)

        def resp():
            for k in range(n):
                yield Fused(Resp(ch), lambda v, k=k: Store("out", k, v))

        return EngineInstance(
            f"t{i}",
            DaeProgram(f"copy{i}", [Process("req", req()),
                                    Process("resp", resp())]),
            {"out": FixedLatencyMemory([None] * n, 100)})

    shared = {"table": FixedLatencyMemory(list(range(n)), 100)}
    res = SharedMemoryEngine([build(0), build(1)], shared).run()
    c0, c1 = (r.cycles for r in res.instances)
    # tenants drain in capacity-sized batches, so the fair bound is one
    # batch of issue slots, not one cycle
    assert abs(c0 - c1) <= 16
    assert res.cycles == max(c0, c1)
    # both tenants' results landed in their private out ports, and each
    # is credited only its OWN reads on the shared port (the model's
    # global counter holds both tenants' traffic)
    for r in res.instances:
        assert r.stores["out"][n - 1] == n - 1
        assert r.mem_reads["table"] == n
    assert shared["table"].reads == 2 * n


def test_capacity_violation_raises_deadlock_multi():
    """capacity < RIF on the round-robin chase is the §5.3 scenario; the
    engine must detect it, not hang."""
    with pytest.raises(DeadlockError):
        run_workload_multi("hashtable", "rhls_dec", 2, scale="small",
                           latency=100, rif=8, cap_slack=-4)


def test_deadlock_free_under_capacity_bounds_multi():
    """With capacity >= RIF (cap_slack >= 0 per §5.4) every N completes."""
    for n in (1, 2, 4):
        rep = run_workload_multi("hashtable", "rhls_dec", n, scale="small",
                                 latency=100, rif=8, cap_slack=1)
        assert rep.correct


def test_trace_roundtrip_and_invariants():
    rep = run_workload_multi("binsearch", "rhls_dec", 2, trace=True, **SMALL)
    ts = rep.trace
    assert ts is not None

    # structured round trip through JSON text
    ts2 = TraceSummary.from_json(json.loads(json.dumps(ts.to_json())))
    assert ts2 == ts

    # occupancy can never exceed the channel capacity (rif + 1 here)
    for name, cs in ts.channels.items():
        assert cs.occ_max <= SMALL["rif"] + 1, name
        assert 0 <= cs.occ_mean <= cs.occ_max

    # exactly one latency-histogram entry per memory read on the shared port
    total_reqs = sum(cs.requests for cs in ts.channels.values())
    assert total_reqs == rep.mem_reads["table"]

    # the shared port's utilization timeline is bounded by 1 issue/cycle,
    # and its issue total matches the read count (table takes no writes)
    for _, frac in ts.utilization("table"):
        assert 0.0 < frac <= 1.0
    assert ts.port_issues("table") == rep.mem_reads["table"]
    assert ts.port_issues("table") <= rep.cycles


def test_trace_disabled_by_default():
    rep = run_workload_multi("binsearch", "rhls_dec", 2, **SMALL)
    assert rep.trace is None


def test_simulate_accepts_tracer():
    ch = LoadChannel("c", capacity=4)

    def gen():
        yield Req(ch, 3)
        v = yield Resp(ch)
        yield Store("out", 0, v)

    tr = Tracer(bin_cycles=32)
    mems = {"mem": FixedLatencyMemory(list(range(8)), 100),
            "out": FixedLatencyMemory([None] * 4, 100)}
    r = simulate(DaeProgram("t", [Process("p", gen())]), mems, tracer=tr)
    assert r.stores["out"][0] == 3
    ts = tr.summary()
    # single-instance traces keep bare channel/port names
    assert "c" in ts.channels
    assert ts.channels["c"].requests == 1
    assert "mem" in ts.ports and "out" in ts.ports


def test_pow2_bucket():
    assert pow2_bucket(0) == 1
    assert pow2_bucket(1) == 1
    assert pow2_bucket(2) == 2
    assert pow2_bucket(3) == 4
    assert pow2_bucket(100) == 128
    assert pow2_bucket(128) == 128
    assert pow2_bucket(128.5) == 256


def test_engine_rejects_duplicate_instance_names():
    prog = DaeProgram("p", [])
    with pytest.raises(ValueError):
        SharedMemoryEngine([EngineInstance("a", prog),
                            EngineInstance("a", prog)])


def test_multi_rejects_unknown_benchmark():
    with pytest.raises(ValueError):
        run_workload_multi("multispmv", "rhls_dec", 2)


def test_mergesort_stream_still_deadlocks_multi():
    with pytest.raises(DeadlockError):
        run_workload_multi("mergesort", "rhls_stream", 2, **SMALL)
