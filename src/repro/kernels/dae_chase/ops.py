"""Parallel pointer chasing (paper §4.2, Listings 4/5) on TPU.

Hardware adaptation (docs/architecture.md §"TPU adaptation"): an FPGA
follows one pointer per chain per memory response; a TPU fetches
512-byte DMA granules.  Two consequences drive the design:

* **binsearch** becomes a *block* search: every probe fetches a whole
  block of the sorted table, which resolves log2(block) levels of the
  search in one response.  The VMEM-resident summary search (the top of
  the B-tree) runs in XLA here; the decoupled block probes run in the
  ``searchsorted_blocks`` Pallas kernel with ``rif`` fetches in flight.

* **hashtable** keeps the chain-walk structure: the ``hash_probe``
  kernel walks ``chunk`` chains per grid step in lock-step, ``rif``
  independent dependent-load chains in flight per level (a resolved
  chain keeps re-requesting its tail, exactly like the paper's
  fixed-length variant keeps issuing redundant loads rather than adding
  conditional-issue circuitry).

Both kernels are emitted through the shared :mod:`repro.kernels.ring`
layer; knobs left at ``None`` resolve in the dispatch order explicit →
tune-cache winner → ``plan_rif`` analytic seeding.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import (cdiv, resolve_interpret, ring_rif,
                                  round_up, tuned_knobs)
from repro.kernels.dae_chase import kernel as _k
from repro.kernels.dae_chase.kernel import ENTRY_LANES


def _chase_knobs(op: str, dims, dtype, interp, *, block_bytes, chunk, rif,
                 **extra):
    """Shared explicit → tune-cache → ``plan_rif`` resolution for the
    chase ops' ``chunk``/``rif`` (and any op-specific ``extra``) knobs."""
    knobs = tuned_knobs(op, dims, dtype, interp, chunk=(chunk, 64),
                        rif=(rif, None), **extra)
    knobs["rif"] = ring_rif(knobs["rif"], block_bytes)
    return knobs


@functools.partial(jax.jit, static_argnames=("block", "chunk", "rif",
                                             "interpret", "method"))
def _searchsorted_impl(table, keys, *, block, chunk, rif, interpret, method):
    n = table.shape[0]
    m = keys.shape[0]
    if method == "ref":
        return jnp.searchsorted(table, keys, side="right").astype(jnp.int32)
    if m == 0:  # no probes: a zero-sized operand cannot enter the kernel
        return jnp.zeros((0,), jnp.int32)

    big = (jnp.inf if jnp.issubdtype(table.dtype, jnp.floating)
           else jnp.iinfo(table.dtype).max)
    np_ = round_up(max(n, 1), block)
    tp = jnp.concatenate([table, jnp.full((np_ - n,), big, table.dtype)])
    tiles = tp.reshape(-1, block)          # (NB, block)
    n_blocks = tiles.shape[0]

    # level-0 summary: first element of each block (table is sorted)
    summary = tiles[:, 0]                   # (NB,)
    # block id per key: last block whose min <= key  (searchsorted on the
    # small summary is VMEM-resident compute — the top of the B-tree)
    blk = jnp.clip(jnp.searchsorted(summary, keys, side="right") - 1,
                   0, n_blocks - 1).astype(jnp.int32)

    # decoupled probe: the kernel fetches each key's block through the
    # ring emitter and resolves the within-block position in one pass
    c = min(chunk, max(m, 1))
    mp = round_up(m, c)
    if mp != m:
        keys = jnp.concatenate([keys, jnp.zeros((mp - m,), keys.dtype)])
        blk = jnp.concatenate([blk, jnp.zeros((mp - m,), blk.dtype)])
    out = _k.searchsorted_blocks(tiles, blk, keys, n, chunk=c, rif=rif,
                                 interpret=interpret)
    return out[:m]


def batched_searchsorted(table: jax.Array, keys: jax.Array, *,
                         block: Optional[int] = None,
                         chunk: Optional[int] = None,
                         rif: Optional[int] = None, method: str = "pallas",
                         interpret: Optional[bool] = None) -> jax.Array:
    """'right' insertion points of ``keys`` in sorted ``table`` via
    decoupled block probes.  ``block``/``chunk``/``rif`` left ``None``
    resolve explicit → tune cache → analytic (128-lane DMA granule;
    ``plan_rif`` over one block's byte size)."""
    interp = resolve_interpret(interpret)
    if block is None or chunk is None or rif is None:
        knobs = _chase_knobs("batched_searchsorted",
                             (table.shape[0], keys.shape[0]), table.dtype,
                             interp, block_bytes=(block or 128)
                             * table.dtype.itemsize, chunk=chunk, rif=rif,
                             block=(block, 128))
        block, chunk, rif = knobs["block"], knobs["chunk"], knobs["rif"]
    return _searchsorted_impl(table, keys, block=block, chunk=chunk, rif=rif,
                              interpret=interp, method=method)


@functools.partial(jax.jit, static_argnames=("max_steps", "chunk", "rif",
                                             "interpret", "method"))
def _hash_lookup_impl(entry_keys, entry_vals, entry_next, heads, keys, *,
                      max_steps, chunk, rif, interpret, method):
    from repro.kernels.dae_chase.ref import hash_lookup_ref
    if method == "ref":
        return hash_lookup_ref(entry_keys, entry_vals, entry_next, heads,
                               keys, max_steps)

    n = entry_keys.shape[0]
    m = heads.shape[0]
    if m == 0:  # no lookups: a zero-sized operand cannot enter the kernel
        return jnp.zeros((0,), jnp.int32)
    # pack (key, val, next) into DMA-aligned rows so one decoupled fetch
    # returns a full entry
    packed = jnp.zeros((max(n, 1), ENTRY_LANES), jnp.int32)
    packed = packed.at[:n, 0].set(entry_keys.astype(jnp.int32))
    packed = packed.at[:n, 1].set(entry_vals.astype(jnp.int32))
    packed = packed.at[:n, 2].set(entry_next.astype(jnp.int32))

    c = min(chunk, max(m, 1))
    mp = round_up(m, c)
    heads = heads.astype(jnp.int32)
    keys = keys.astype(jnp.int32)
    if mp != m:
        # padding chains start dead (head -1) and resolve to -1
        heads = jnp.concatenate([heads, jnp.full((mp - m,), -1, jnp.int32)])
        keys = jnp.concatenate([keys, jnp.zeros((mp - m,), jnp.int32)])
    out = _k.hash_probe(packed, heads, keys, chunk=c, rif=rif,
                        max_steps=max_steps, interpret=interpret)
    return out[:m]


def hash_lookup(entry_keys: jax.Array, entry_vals: jax.Array,
                entry_next: jax.Array, heads: jax.Array, keys: jax.Array, *,
                max_steps: int = 16, chunk: Optional[int] = None,
                rif: Optional[int] = None, method: str = "pallas",
                interpret: Optional[bool] = None) -> jax.Array:
    """Lock-step parallel chain walk over a separate-chaining hash table.

    ``chunk``/``rif`` left ``None`` resolve explicit → tune cache →
    analytic (``plan_rif`` over one packed entry's byte size)."""
    interp = resolve_interpret(interpret)
    if chunk is None or rif is None:
        knobs = _chase_knobs("hash_lookup",
                             (entry_keys.shape[0], heads.shape[0]),
                             jnp.int32.dtype, interp,
                             block_bytes=ENTRY_LANES * 4, chunk=chunk,
                             rif=rif)
        chunk, rif = knobs["chunk"], knobs["rif"]
    return _hash_lookup_impl(entry_keys, entry_vals, entry_next, heads, keys,
                             max_steps=max_steps, chunk=chunk, rif=rif,
                             interpret=interp, method=method)
