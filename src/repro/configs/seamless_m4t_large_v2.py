"""seamless-m4t-large-v2 [audio]: enc-dec multimodal backbone.

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206
[arXiv:2308.11596; hf].  The audio frontend is a STUB: input_specs
provides precomputed frame embeddings.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256_206,
    mlp_kind="relu",
    norm_eps=1e-5,
)
